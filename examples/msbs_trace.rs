//! Fig. 1 / Fig. 2 reproduction: trace the MSBS candidate-tree sampling
//! cycles on a single molecule and compare its model-call count with
//! classic beam search.
//!
//! The paper's Fig. 1 shows two MSBS cycles (draft call + verify call,
//! nucleus acceptance, top-K harvest); Fig. 2 contrasts 6 MSBS calls
//! with 52 beam-search calls for the same two output sequences. This
//! example prints the same story for a held-out molecule.
//!
//! `cargo run --release --example msbs_trace [-- --smiles S] [--k 2] [--mock]`

use anyhow::Result;
use retroserve::benchkit::Flags;
use retroserve::decoding::beam::BeamSearch;
use retroserve::decoding::msbs::Msbs;
use retroserve::decoding::{DecodeStats, Decoder};
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::model::StepModel;
use retroserve::runtime::PjrtModel;
use retroserve::tokenizer::Vocab;

fn main() -> Result<()> {
    let flags = Flags::parse();
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let k = flags.usize_or("k", 2);

    let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
    let model: Box<dyn StepModel> = if flags.has("mock") {
        Box::new(MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() }))
    } else {
        Box::new(PjrtModel::load(&art)?)
    };
    let smiles = if flags.has("smiles") {
        flags.str_or("smiles", "")
    } else {
        retroserve::benchkit::load_test_pairs(&art, 20)?
            .into_iter()
            .map(|p| p.product)
            .max_by_key(|s| s.len())
            .expect("test set not empty")
    };
    println!("source molecule: {smiles}\n");
    let src = vec![vocab.encode(&smiles, true)];

    // --- MSBS with a cycle trace ---
    let msbs = Msbs::default();
    let mut stats = DecodeStats::default();
    let mut trace = Some(Vec::new());
    let outputs = msbs.generate_traced(model.as_ref(), &src, k, &mut stats, &mut trace)?;
    for t in trace.unwrap() {
        println!("cycle {} (2 model calls):", t.cycle);
        for (i, d) in t.drafts.iter().enumerate() {
            println!(
                "  beam {i}: draft \"{}\" -> {} of {} tokens accepted",
                vocab.decode(d),
                t.accepted.get(i).copied().unwrap_or(0),
                d.len()
            );
        }
        for (tokens, logp) in t.beams.iter().take(k) {
            println!(
                "  -> beam (logp {:7.3}): {}",
                logp,
                vocab.decode(&tokens[1..])
            );
        }
        println!();
    }
    println!("MSBS result ({} model calls):", stats.model_calls);
    for h in &outputs[0].hyps {
        println!("  logp {:7.3}  {}", h.logp, vocab.decode(h.body()));
    }

    // --- classic beam search on the same molecule ---
    let mut bs_stats = DecodeStats::default();
    let bs_out = BeamSearch::vanilla().generate(model.as_ref(), &src, k, &mut bs_stats)?;
    println!("\nBeam search result ({} model calls):", bs_stats.model_calls);
    for h in &bs_out[0].hyps {
        println!("  logp {:7.3}  {}", h.logp, vocab.decode(h.body()));
    }
    println!(
        "\nFig. 2 takeaway: {} MSBS calls vs {} beam-search calls ({}x), top-1 identical: {}",
        stats.model_calls,
        bs_stats.model_calls,
        bs_stats.model_calls as f64 / stats.model_calls.max(1) as f64,
        outputs[0].hyps[0].tokens == bs_out[0].hyps[0].tokens
    );
    Ok(())
}
