//! Fig. 1 / Fig. 2 reproduction: trace the MSBS candidate-tree sampling
//! cycles on a single molecule and compare its model-call count with
//! classic beam search.
//!
//! The paper's Fig. 1 shows two MSBS cycles (draft call + verify call,
//! nucleus acceptance, top-K harvest); Fig. 2 contrasts 6 MSBS calls
//! with 52 beam-search calls for the same two output sequences. This
//! example prints the same story for a held-out molecule.
//!
//! `cargo run --release --example msbs_trace [-- --smiles S] [--k 2] [--mock]`
//!
//! `--mock` needs no artifacts: the copy-task mock model and a built-in
//! molecule stand in for the trained transformer — CI's smoke path,
//! which also asserts the Fig. 2 call-count relation.

use anyhow::Result;
use retroserve::benchkit::Flags;
use retroserve::decoding::beam::BeamSearch;
use retroserve::decoding::msbs::Msbs;
use retroserve::decoding::{DecodeStats, Decoder};
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::model::StepModel;
use retroserve::runtime::PjrtModel;
use retroserve::tokenizer::Vocab;

fn main() -> Result<()> {
    let flags = Flags::parse();
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let k = flags.usize_or("k", 2);

    let mock = flags.has("mock");
    let smiles = if flags.has("smiles") {
        flags.str_or("smiles", "")
    } else if mock {
        // Artifact-free default: long enough that per-token beam search
        // pays visibly more model calls than MSBS's draft+verify cycles.
        "CC(=O)NCC(=O)OCC.CC(=O)O.CN".to_string()
    } else {
        retroserve::benchkit::load_test_pairs(&art, 20)?
            .into_iter()
            .map(|p| p.product)
            .max_by_key(|s| s.len())
            .expect("test set not empty")
    };
    let (vocab, model): (Vocab, Box<dyn StepModel>) = if mock {
        let vocab = Vocab::build([smiles.as_str()]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        (vocab, Box::new(model))
    } else {
        let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
        (vocab, Box::new(PjrtModel::load(&art)?))
    };
    println!("source molecule: {smiles}\n");
    let src = vec![vocab.encode(&smiles, true)];

    // --- MSBS with a cycle trace ---
    let msbs = Msbs::default();
    let mut stats = DecodeStats::default();
    let mut trace = Some(Vec::new());
    let outputs = msbs.generate_traced(model.as_ref(), &src, k, &mut stats, &mut trace)?;
    for t in trace.unwrap() {
        println!("cycle {} (2 model calls):", t.cycle);
        for (i, d) in t.drafts.iter().enumerate() {
            println!(
                "  beam {i}: draft \"{}\" -> {} of {} tokens accepted",
                vocab.decode(d),
                t.accepted.get(i).copied().unwrap_or(0),
                d.len()
            );
        }
        for (tokens, logp) in t.beams.iter().take(k) {
            println!(
                "  -> beam (logp {:7.3}): {}",
                logp,
                vocab.decode(&tokens[1..])
            );
        }
        println!();
    }
    println!("MSBS result ({} model calls):", stats.model_calls);
    for h in &outputs[0].hyps {
        println!("  logp {:7.3}  {}", h.logp, vocab.decode(h.body()));
    }

    // --- classic beam search on the same molecule ---
    let mut bs_stats = DecodeStats::default();
    let bs_out = BeamSearch::vanilla().generate(model.as_ref(), &src, k, &mut bs_stats)?;
    println!("\nBeam search result ({} model calls):", bs_stats.model_calls);
    for h in &bs_out[0].hyps {
        println!("  logp {:7.3}  {}", h.logp, vocab.decode(h.body()));
    }
    println!(
        "\nFig. 2 takeaway: {} MSBS calls vs {} beam-search calls ({}x), top-1 identical: {}",
        stats.model_calls,
        bs_stats.model_calls,
        bs_stats.model_calls as f64 / stats.model_calls.max(1) as f64,
        outputs[0].hyps[0].tokens == bs_out[0].hyps[0].tokens
    );
    if mock {
        anyhow::ensure!(
            stats.model_calls <= bs_stats.model_calls,
            "MSBS must not pay more model calls than beam search"
        );
        println!(
            "EXAMPLE OK: msbs_trace ({} msbs vs {} bs calls)",
            stats.model_calls, bs_stats.model_calls
        );
    }
    Ok(())
}
