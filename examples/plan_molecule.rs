//! Compare planners and decoders on one molecule: DFS vs Retro\*,
//! BS vs MSBS — the single-molecule version of Table 3.
//!
//! `cargo run --release --example plan_molecule [-- --smiles S]
//! [--deadline-ms 15000] [--oracle]`

use anyhow::Result;
use retroserve::benchkit::Flags;
use retroserve::decoding::make_decoder;
use retroserve::runtime::PjrtModel;
use retroserve::search::policy::{ModelPolicy, OraclePolicy};
use retroserve::search::{
    dfs::Dfs, retrostar::RetroStar, ExpansionPolicy, Planner, SearchLimits, Stock,
};
use retroserve::tokenizer::Vocab;

fn main() -> Result<()> {
    let flags = Flags::parse();
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
    let stock = Stock::load(art.join("stock.txt"))?;
    let smiles = if flags.has("smiles") {
        flags.str_or("smiles", "")
    } else {
        retroserve::benchkit::load_queries(&art, 100)?
            .into_iter()
            .find(|q| q.solvable_hint && q.depth >= 2)
            .map(|q| q.smiles)
            .expect("a solvable query")
    };
    let limits = SearchLimits {
        deadline: std::time::Duration::from_millis(flags.usize_or("deadline-ms", 15000) as u64),
        ..Default::default()
    };
    println!("target: {smiles}\n");
    println!(
        "{:<12} {:<8} {:>8} {:>8} {:>12} {:>10}",
        "planner", "decoder", "solved", "iters", "model calls", "wall s"
    );

    for planner_name in ["dfs", "retrostar"] {
        for decoder_name in ["bs", "msbs"] {
            let policy: Box<dyn ExpansionPolicy> = if flags.has("oracle") {
                Box::new(OraclePolicy::new())
            } else {
                let model = PjrtModel::load(&art)?;
                Box::new(ModelPolicy::new(model, make_decoder(decoder_name, 1)?, vocab.clone()))
            };
            let planner: Box<dyn Planner> = match planner_name {
                "dfs" => Box::new(Dfs),
                _ => Box::new(RetroStar::new(1)),
            };
            let r = planner.solve(&smiles, policy.as_ref(), &stock, &limits)?;
            println!(
                "{:<12} {:<8} {:>8} {:>8} {:>12} {:>10.2}",
                planner_name,
                decoder_name,
                r.solved,
                r.iterations,
                r.decode_stats.model_calls,
                r.wall_secs
            );
            if flags.has("show-route") {
                if let Some(route) = &r.route {
                    println!("{}", route.render());
                }
            }
        }
    }
    Ok(())
}
