//! Compare planners and decoders on one molecule: DFS vs Retro\*,
//! BS vs MSBS — the single-molecule version of Table 3.
//!
//! `cargo run --release --example plan_molecule [-- --smiles S]
//! [--deadline-ms 15000] [--oracle] [--mock]`
//!
//! `--mock` needs no artifacts: the SynthChem world provides the stock
//! and target, and a scripted model replays the oracle retro templates
//! through the real decoders — CI's smoke path.

use anyhow::{ensure, Result};
use retroserve::benchkit::Flags;
use retroserve::decoding::make_decoder;
use retroserve::model::scripted::{oracle_script, smiles_vocab, ScriptedModel};
use retroserve::runtime::PjrtModel;
use retroserve::search::policy::{ModelPolicy, OraclePolicy};
use retroserve::search::{
    dfs::Dfs, retrostar::RetroStar, ExpansionPolicy, Planner, SearchLimits, Stock,
};
use retroserve::synthchem::blocks::generate_blocks;
use retroserve::synthchem::gen::{gen_tree, BlockIndex};
use retroserve::tokenizer::Vocab;
use retroserve::util::Rng;

fn main() -> Result<()> {
    let flags = Flags::parse();
    let mock = flags.has("mock");
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let (stock, smiles, vocab) = if mock {
        // Artifact-free: generated stock + target, vocab wide enough
        // for anything the oracle script emits.
        let blocks = generate_blocks(7, 300);
        let stock = Stock::from_iter(blocks.iter().map(|b| b.smiles()).chain([
            retroserve::chem::canonicalize(retroserve::synthchem::templates::BOC_REAGENT)
                .unwrap(),
        ]));
        let idx = BlockIndex::new(blocks);
        let mut rng = Rng::new(21);
        let t = (0..40)
            .find_map(|_| gen_tree(&idx, &mut rng, 2, 26))
            .expect("synthetic target");
        let smiles = match flags.has("smiles") {
            true => flags.str_or("smiles", ""),
            false => t.product_smiles().to_string(),
        };
        let vocab = smiles_vocab([smiles.as_str()]);
        (stock, smiles, vocab)
    } else {
        let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
        let stock = Stock::load(art.join("stock.txt"))?;
        let smiles = if flags.has("smiles") {
            flags.str_or("smiles", "")
        } else {
            retroserve::benchkit::load_queries(&art, 100)?
                .into_iter()
                .find(|q| q.solvable_hint && q.depth >= 2)
                .map(|q| q.smiles)
                .expect("a solvable query")
        };
        (stock, smiles, vocab)
    };
    let limits = SearchLimits {
        deadline: std::time::Duration::from_millis(flags.usize_or("deadline-ms", 15000) as u64),
        ..Default::default()
    };
    println!("target: {smiles}\n");
    println!(
        "{:<12} {:<8} {:>8} {:>8} {:>12} {:>10}",
        "planner", "decoder", "solved", "iters", "model calls", "wall s"
    );

    let mut any_solved = false;
    for planner_name in ["dfs", "retrostar"] {
        for decoder_name in ["bs", "msbs"] {
            let policy: Box<dyn ExpansionPolicy> = if flags.has("oracle") {
                Box::new(OraclePolicy::new())
            } else if mock {
                let model = ScriptedModel::new(vocab.clone(), oracle_script());
                Box::new(ModelPolicy::new(model, make_decoder(decoder_name, 1)?, vocab.clone()))
            } else {
                let model = PjrtModel::load(&art)?;
                Box::new(ModelPolicy::new(model, make_decoder(decoder_name, 1)?, vocab.clone()))
            };
            let planner: Box<dyn Planner> = match planner_name {
                "dfs" => Box::new(Dfs),
                _ => Box::new(RetroStar::new(1)),
            };
            let r = planner.solve(&smiles, policy.as_ref(), &stock, &limits)?;
            any_solved |= r.solved;
            println!(
                "{:<12} {:<8} {:>8} {:>8} {:>12} {:>10.2}",
                planner_name,
                decoder_name,
                r.solved,
                r.iterations,
                r.decode_stats.model_calls,
                r.wall_secs
            );
            if flags.has("show-route") {
                if let Some(route) = &r.route {
                    println!("{}", route.render());
                }
            }
        }
    }
    if mock {
        ensure!(any_solved, "scripted oracle world must solve the generated target");
        println!("EXAMPLE OK: plan_molecule (solved via scripted oracle)");
    }
    Ok(())
}
