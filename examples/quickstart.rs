//! Quickstart: load the served model, run one accelerated single-step
//! expansion and one multi-step plan.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Optional flags: `--artifacts DIR`, `--smiles S`, `--mock`.
//!
//! `--mock` needs no artifacts: it runs the identical flow over the
//! in-memory scripted SynthChem world (the oracle retro templates
//! spoken through a real neural decode path) — CI's smoke path.

use anyhow::{ensure, Result};
use retroserve::benchkit::Flags;
use retroserve::decoding::msbs::Msbs;
use retroserve::model::scripted::{oracle_script, smiles_vocab, ScriptedModel};
use retroserve::model::StepModel;
use retroserve::runtime::PjrtModel;
use retroserve::search::policy::ModelPolicy;
use retroserve::search::{retrostar::RetroStar, ExpansionPolicy, Planner, SearchLimits, Stock};
use retroserve::synthchem::blocks::generate_blocks;
use retroserve::synthchem::gen::{gen_tree, BlockIndex};
use retroserve::tokenizer::Vocab;
use retroserve::util::Rng;

fn main() -> Result<()> {
    let flags = Flags::parse();
    if flags.has("mock") {
        return mock_world(&flags);
    }
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));

    // 1. Load the AOT artifacts through the PJRT runtime (pure Rust —
    //    Python was only involved at build time).
    let model = PjrtModel::load(&art)?;
    let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
    let stock = Stock::load(art.join("stock.txt"))?;
    println!(
        "loaded model: vocab={} medusa_heads={} | stock: {} building blocks",
        model.config().vocab,
        model.config().n_medusa,
        stock.len()
    );

    // 2. Pick a target: a held-out planning query unless one is given.
    let smiles = match flags.has("smiles") {
        true => flags.str_or("smiles", ""),
        false => {
            let queries = retroserve::benchkit::load_queries(&art, 50)?;
            queries
                .iter()
                .find(|q| q.solvable_hint && q.depth >= 2)
                .map(|q| q.smiles.clone())
                .unwrap_or_else(|| queries[0].smiles.clone())
        }
    };
    run(model, vocab, stock, smiles, &flags)
}

/// The artifact-free world: a scripted model replaying the SynthChem
/// oracle templates over a generated target, same flow as the real one.
fn mock_world(flags: &Flags) -> Result<()> {
    let blocks = generate_blocks(7, 300);
    let stock = Stock::from_iter(blocks.iter().map(|b| b.smiles()).chain([
        retroserve::chem::canonicalize(retroserve::synthchem::templates::BOC_REAGENT).unwrap(),
    ]));
    let idx = BlockIndex::new(blocks);
    let mut rng = Rng::new(9);
    let t = (0..40)
        .find_map(|_| gen_tree(&idx, &mut rng, 2, 26))
        .expect("synthetic target");
    let smiles = match flags.has("smiles") {
        true => flags.str_or("smiles", ""),
        false => t.product_smiles().to_string(),
    };
    let vocab = smiles_vocab([smiles.as_str()]);
    let model = ScriptedModel::new(vocab.clone(), oracle_script());
    println!(
        "loaded mock world: vocab={} medusa_heads={} | stock: {} building blocks",
        vocab.len(),
        model.medusa_heads(),
        stock.len()
    );
    run(model, vocab, stock, smiles, flags)
}

fn run<M: StepModel>(
    model: M,
    vocab: Vocab,
    stock: Stock,
    smiles: String,
    flags: &Flags,
) -> Result<()> {
    println!("\ntarget molecule: {smiles}");

    // 3. Single-step expansion with MSBS (the paper's accelerated
    //    decoder): 10 candidate precursor sets in a couple of model
    //    calls per cycle instead of one per token.
    let policy = ModelPolicy::new(model, Box::new(Msbs::default()), vocab);
    let t0 = std::time::Instant::now();
    let proposals = &policy.expand_batch(&[&smiles], 10)?[0];
    println!(
        "\nsingle-step: {} precursor proposals in {:.0} ms (acceptance {:.0}%):",
        proposals.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        policy.decode_stats().acceptance_rate() * 100.0
    );
    for p in proposals.iter().take(3) {
        println!("  logp {:7.3}  {}", p.logp, p.reactants.join(" . "));
    }

    // 4. Multi-step planning with Retro* under a deadline.
    let limits = SearchLimits {
        deadline: std::time::Duration::from_secs(flags.usize_or("deadline-s", 15) as u64),
        ..Default::default()
    };
    let result = RetroStar::new(1).solve(&smiles, &policy, &stock, &limits)?;
    println!(
        "\nmulti-step: solved={} stop={} in {:.2}s ({} iterations, {} model calls)",
        result.solved,
        result.stop_reason,
        result.wall_secs,
        result.iterations,
        result.decode_stats.model_calls
    );
    if let Some(route) = result.route {
        println!("route:\n{}", route.render());
    }
    if flags.has("mock") {
        ensure!(!proposals.is_empty(), "scripted world must propose precursors");
        println!(
            "EXAMPLE OK: quickstart (proposals={}, stop={})",
            proposals.len(),
            result.stop_reason
        );
    }
    Ok(())
}
