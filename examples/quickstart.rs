//! Quickstart: load the served model, run one accelerated single-step
//! expansion and one multi-step plan.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Optional flags: `--artifacts DIR`, `--smiles S`.

use anyhow::Result;
use retroserve::benchkit::Flags;
use retroserve::decoding::msbs::Msbs;
use retroserve::runtime::PjrtModel;
use retroserve::search::policy::ModelPolicy;
use retroserve::search::{retrostar::RetroStar, Planner, SearchLimits, Stock};
use retroserve::tokenizer::Vocab;

fn main() -> Result<()> {
    let flags = Flags::parse();
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));

    // 1. Load the AOT artifacts through the PJRT runtime (pure Rust —
    //    Python was only involved at build time).
    let model = PjrtModel::load(&art)?;
    let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
    let stock = Stock::load(art.join("stock.txt"))?;
    println!(
        "loaded model: vocab={} medusa_heads={} | stock: {} building blocks",
        model.config().vocab,
        model.config().n_medusa,
        stock.len()
    );

    // 2. Pick a target: a held-out planning query unless one is given.
    let smiles = match flags.has("smiles") {
        true => flags.str_or("smiles", ""),
        false => {
            let queries = retroserve::benchkit::load_queries(&art, 50)?;
            queries
                .iter()
                .find(|q| q.solvable_hint && q.depth >= 2)
                .map(|q| q.smiles.clone())
                .unwrap_or_else(|| queries[0].smiles.clone())
        }
    };
    println!("\ntarget molecule: {smiles}");

    // 3. Single-step expansion with MSBS (the paper's accelerated
    //    decoder): 10 candidate precursor sets in a couple of model
    //    calls per cycle instead of one per token.
    use retroserve::search::ExpansionPolicy as _;
    let policy = ModelPolicy::new(model, Box::new(Msbs::default()), vocab);
    let t0 = std::time::Instant::now();
    let proposals = &policy.expand_batch(&[&smiles], 10)?[0];
    println!(
        "\nsingle-step: {} precursor proposals in {:.0} ms (acceptance {:.0}%):",
        proposals.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        policy.decode_stats().acceptance_rate() * 100.0
    );
    for p in proposals.iter().take(3) {
        println!("  logp {:7.3}  {}", p.logp, p.reactants.join(" . "));
    }

    // 4. Multi-step planning with Retro* under a deadline.
    let limits = SearchLimits {
        deadline: std::time::Duration::from_secs(flags.usize_or("deadline-s", 15) as u64),
        ..Default::default()
    };
    let result = RetroStar::new(1).solve(&smiles, &policy, &stock, &limits)?;
    println!(
        "\nmulti-step: solved={} in {:.2}s ({} iterations, {} model calls)",
        result.solved, result.wall_secs, result.iterations, result.decode_stats.model_calls
    );
    if let Some(route) = result.route {
        println!("route:\n{}", route.render());
    }
    Ok(())
}
