//! End-to-end serving driver (the repository's system validation).
//!
//! Proves all layers compose: the AOT artifacts (L1 Pallas kernels
//! lowered inside the L2 JAX model) load into the PJRT runtime, the L3
//! coordinator serves concurrent planning sessions over TCP with
//! cross-tree dynamic batching, and the paper's MSBS decoder drives the
//! single-step expansions. Reports solved counts, latency percentiles,
//! throughput and batcher merge statistics.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_e2e -- \
//!     --n 24 --clients 4 --deadline-ms 3000
//! ```
//!
//! `--mock` needs no artifacts: the SynthChem world plus a scripted
//! oracle model stand in for the trained transformer, behind the same
//! supervised executor / hub / TCP stack — CI's smoke path. In both
//! modes the driver finishes with the anytime demonstration: a plan
//! whose `deadline_ms` is already spent still answers, with
//! `stop_reason = "deadline"`.

use anyhow::Result;
use retroserve::benchkit::Flags;
use retroserve::config::ServeConfig;
use retroserve::coordinator::batcher::{BatcherConfig, ExpansionHub};
use retroserve::coordinator::server::{Client, Server, ServerCtx};
use retroserve::decoding::make_decoder;
use retroserve::jsonx::Json;
use retroserve::metrics::Metrics;
use retroserve::model::scripted::{oracle_script, smiles_vocab, ScriptedModel};
use retroserve::runtime::server::{SharedModel, SupervisorConfig};
use retroserve::runtime::PjrtModel;
use retroserve::search::Stock;
use retroserve::synthchem::blocks::generate_blocks;
use retroserve::synthchem::gen::{gen_tree, BlockIndex};
use retroserve::tokenizer::Vocab;
use retroserve::util::stats::{mean, percentile};
use retroserve::util::Rng;
use std::sync::Arc;

fn main() -> Result<()> {
    let flags = Flags::parse();
    let art = flags.str_or("artifacts", "artifacts");
    let n = flags.usize_or("n", 24);
    let clients = flags.usize_or("clients", 4);
    let deadline_ms = flags.usize_or("deadline-ms", 3000);
    let decoder = flags.str_or("decoder", "msbs");
    let mock = flags.has("mock");

    // --- boot the full stack (supervised executor in both modes: a
    // model panic fails only its in-flight calls, then the factory
    // rebuilds) ---
    let t_boot = std::time::Instant::now();
    let (vocab, stock, queries, model) = if mock {
        let blocks = generate_blocks(7, 400);
        let stock = Arc::new(Stock::from_iter(blocks.iter().map(|b| b.smiles()).chain([
            retroserve::chem::canonicalize(retroserve::synthchem::templates::BOC_REAGENT)
                .unwrap(),
        ])));
        let idx = BlockIndex::new(blocks);
        let mut rng = Rng::new(33);
        let mut queries = Vec::new();
        let mut guard = 0;
        while queries.len() < n && guard < n * 40 {
            guard += 1;
            let depth = 1 + rng.gen_range(2);
            if let Some(t) = gen_tree(&idx, &mut rng, depth, 24) {
                queries.push(t.product_smiles().to_string());
            }
        }
        let vocab = smiles_vocab(queries.iter().map(String::as_str));
        let v2 = vocab.clone();
        let model = SharedModel::spawn_supervised(
            move || Ok(ScriptedModel::new(v2.clone(), oracle_script())),
            SupervisorConfig::default(),
        )?;
        (vocab, stock, queries, model)
    } else {
        let vocab = Vocab::load(&std::path::Path::new(&art).join("vocab.json"))
            .map_err(|e| anyhow::anyhow!(e))?;
        let stock = Arc::new(Stock::load(std::path::Path::new(&art).join("stock.txt"))?);
        let queries: Vec<String> =
            retroserve::benchkit::load_queries(std::path::Path::new(&art), n)?
                .into_iter()
                .map(|q| q.smiles)
                .collect();
        let art2 = art.clone();
        let model = SharedModel::spawn_supervised(
            move || PjrtModel::load(&art2),
            SupervisorConfig::default(),
        )?;
        (vocab, stock, queries, model)
    };
    anyhow::ensure!(!queries.is_empty(), "no queries; run `make artifacts` (or pass --mock)");
    let metrics = Arc::new(Metrics::new());
    let hub = ExpansionHub::start(
        model,
        make_decoder(&decoder, 4)?,
        vocab.clone(),
        BatcherConfig {
            max_batch: 16,
            max_wait: std::time::Duration::from_micros(3000),
            ..Default::default()
        },
        metrics.clone(),
    );
    let sc = ServeConfig::from_config(&retroserve::config::Config::new());
    let mut limits = sc.limits();
    limits.deadline = std::time::Duration::from_millis(deadline_ms as u64);
    let server = Server::start(
        "127.0.0.1:0",
        ServerCtx {
            hub: hub.clone(),
            stock: stock.clone(),
            metrics: metrics.clone(),
            default_limits: limits,
            default_algo: "retrostar".into(),
            default_beam_width: 1,
            default_spec_depth: 1,
            default_spec_adaptive: false,
            default_spec_max: 8,
            screen: Default::default(),
            overload: Default::default(),
            store: None,
        },
    )?;
    let addr = server.addr();
    println!(
        "booted full stack in {:.2}s (decoder={decoder}, stock={}) on {addr}",
        t_boot.elapsed().as_secs_f64(),
        stock.len()
    );

    // --- drive it with concurrent clients over real TCP ---
    let t0 = std::time::Instant::now();
    let chunk = queries.len().div_ceil(clients);
    let mut joins = Vec::new();
    for (c, batch) in queries.chunks(chunk).enumerate() {
        let batch: Vec<String> = batch.to_vec();
        joins.push(std::thread::spawn(move || -> Result<Vec<(bool, f64)>> {
            let mut client = Client::connect(addr)?;
            let mut out = Vec::new();
            for q in &batch {
                let t = std::time::Instant::now();
                let resp = client.call(Json::obj(vec![
                    ("op", Json::str("plan")),
                    ("smiles", Json::str(q.clone())),
                ]))?;
                let solved = resp.get("solved").and_then(|x| x.as_bool()).unwrap_or(false);
                out.push((solved, t.elapsed().as_secs_f64()));
            }
            eprintln!("client {c}: {} queries done", batch.len());
            Ok(out)
        }));
    }
    let mut lat = Vec::new();
    let mut solved = 0usize;
    for j in joins {
        for (s, l) in j.join().expect("client thread")? {
            solved += s as usize;
            lat.push(l);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (batches, merged) = hub.merge_ratio();

    println!("\n=== end-to-end serving report ===");
    println!("queries:        {} over {clients} concurrent clients", lat.len());
    println!("solved:         {} ({:.0}%)", solved, 100.0 * solved as f64 / lat.len() as f64);
    println!("throughput:     {:.2} molecules/s", lat.len() as f64 / wall);
    println!(
        "latency:        mean {:.2}s  p50 {:.2}s  p90 {:.2}s  max {:.2}s",
        mean(&lat),
        percentile(&lat, 50.0),
        percentile(&lat, 90.0),
        percentile(&lat, 100.0)
    );
    println!(
        "batcher:        {merged} expansion requests merged into {batches} decode tasks \
         ({:.2}x)",
        merged as f64 / batches.max(1) as f64
    );
    let (fused_calls, fused_rows) = hub.fused_ratio();
    println!(
        "fused decoding: {fused_calls} device calls, avg effective batch {:.1} rows/call",
        fused_rows as f64 / fused_calls.max(1) as f64
    );
    let stats = hub.stats();
    println!(
        "decode:         {} model calls, acceptance {:.0}%, avg effective batch {:.1}",
        stats.model_calls,
        stats.acceptance_rate() * 100.0,
        stats.avg_effective_batch()
    );

    // --- anytime demonstration: a plan whose budget is already spent
    // still answers within one scheduler tick — ok = true, stop_reason
    // "deadline", partial statistics instead of a hang ---
    let mut c = Client::connect(addr)?;
    let resp = c.call(Json::obj(vec![
        ("op", Json::str("plan")),
        ("smiles", Json::str(queries[0].clone())),
        ("deadline_ms", Json::num(0.0)),
    ]))?;
    let stop = resp
        .get("stop_reason")
        .and_then(|x| x.as_str())
        .unwrap_or("<missing>")
        .to_string();
    anyhow::ensure!(
        resp.get("ok").and_then(|x| x.as_bool()) == Some(true) && stop == "deadline",
        "deadline_ms=0 must answer ok with stop_reason=deadline (got {stop})"
    );
    println!("anytime: deadline_ms=0 answered ok with stop_reason={stop}");
    if mock {
        println!(
            "EXAMPLE OK: serve_e2e ({} queries, {solved} solved, anytime deadline verified)",
            lat.len()
        );
    }
    server.shutdown();
    Ok(())
}
