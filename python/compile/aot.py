"""AOT export: lower the trained model to HLO text artifacts for the
Rust PJRT runtime.

Interchange format is HLO *text* (not serialized HloModuleProto): jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Exported executables (all take the full parameter list first, in
``model_config.json`` order, so the Rust side can keep one set of device
buffers):

* ``encode_b{B}.hlo.txt``    — ``(params..., src i32[B, Ls]) -> f32[B, Ls, D]``
* ``decode_r{R}_l{L}_w{W}.hlo.txt`` —
  ``(params..., mem f32[R, Ls, D], src_mask f32[R, Ls], tgt i32[R, L],
  pos i32[R]) -> f32[R, W, H, V]`` — logits for a W-wide window of
  positions starting at ``min(pos[r], L - W)`` per row (dynamic_slice
  clamp semantics; the Rust runtime mirrors the clamp).

Batch/row/length/window bucket grids are in :data:`ENC_BUCKETS` and
:data:`DEC_BUCKETS`; the runtime pads every call up to the nearest
bucket. A ``selftest.npz`` with a known input/output pair is written for
the Rust integration test to verify numerics across the language
boundary.

Usage: ``python -m compile.aot [--artifacts DIR] [--no-pallas]``
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .model import ModelConfig

ENC_BUCKETS = [1, 2, 4, 8, 16, 32]
DEC_ROW_BUCKETS = [1, 2, 4, 8, 16, 32, 64, 128]
DEC_LEN_BUCKETS = [24, 48, 72]
DEC_WIN_BUCKETS = [1, 8, 24]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def load_params(art: Path, cfg: ModelConfig) -> list[np.ndarray]:
    npz = np.load(art / "params.npz")
    return [np.asarray(npz[name]) for name in model_mod.param_names(cfg)]


def make_encode(cfg: ModelConfig, names, use_pallas: bool):
    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        (src,) = args[len(names) :]
        return (model_mod.encode(params, cfg, src, use_pallas=use_pallas),)

    return fn


def make_decode(cfg: ModelConfig, names, w: int, use_pallas: bool):
    heads = cfg.n_medusa + 1

    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        mem, src_mask, tgt, pos = args[len(names) :]
        logits = model_mod.decode(params, cfg, mem, src_mask, tgt, use_pallas=use_pallas)

        def slice_row(lg, p):
            return jax.lax.dynamic_slice(lg, (p, 0, 0), (w, heads, cfg.vocab))

        return (jax.vmap(slice_row)(logits, pos),)

    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument(
        "--pallas",
        action="store_true",
        help="lower the decode through the interpret-mode Pallas kernels. "
        "Numerics are identical to the default jnp path (pytest asserts "
        "kernel==ref), but interpret-mode Pallas compiles to a sequential "
        "grid loop that is ~20x slower under the CPU PJRT plugin "
        "(EXPERIMENTS.md §Perf), so serving artifacts default to the "
        "jnp lowering; on a real TPU the Mosaic lowering replaces both.",
    )
    args = ap.parse_args()
    art = Path(args.artifacts)

    with open(art / "model_config.json") as f:
        config = json.load(f)
    cfg = ModelConfig(**config["model"])
    names = model_mod.param_names(cfg)
    params = load_params(art, cfg)
    param_specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    use_pallas = args.pallas

    d, ls = cfg.d_model, cfg.max_src
    heads = cfg.n_medusa + 1
    files = {}
    t0 = time.time()

    # --- encode buckets ---
    for b in ENC_BUCKETS:
        fn = make_encode(cfg, names, use_pallas=False)  # encoder has no medusa; jnp path
        spec = jax.ShapeDtypeStruct((b, ls), jnp.int32)
        lowered = jax.jit(fn, keep_unused=True).lower(*param_specs, spec)
        text = to_hlo_text(lowered)
        name = f"encode_b{b}.hlo.txt"
        (art / name).write_text(text)
        files[name] = {"kind": "encode", "rows": b}
    print(f"encode buckets done ({time.time() - t0:.1f}s)", flush=True)

    # --- decode buckets ---
    for r in DEC_ROW_BUCKETS:
        for l in DEC_LEN_BUCKETS:
            for w in DEC_WIN_BUCKETS:
                if w > l:
                    continue
                fn = make_decode(cfg, names, w, use_pallas=use_pallas)
                mem = jax.ShapeDtypeStruct((r, ls, d), jnp.float32)
                mask = jax.ShapeDtypeStruct((r, ls), jnp.float32)
                tgt = jax.ShapeDtypeStruct((r, l), jnp.int32)
                pos = jax.ShapeDtypeStruct((r,), jnp.int32)
                lowered = jax.jit(fn, keep_unused=True).lower(*param_specs, mem, mask, tgt, pos)
                text = to_hlo_text(lowered)
                name = f"decode_r{r}_l{l}_w{w}.hlo.txt"
                (art / name).write_text(text)
                files[name] = {"kind": "decode", "rows": r, "len": l, "win": w}
        print(f"decode r={r} done ({time.time() - t0:.1f}s)", flush=True)

    # --- selftest fixture: known numerics across the language boundary ---
    rng = np.random.default_rng(0)
    b = 2
    src = np.zeros((b, ls), np.int32)
    src[0, :7] = [1, 5, 6, 7, 8, 9, 2]
    src[1, :5] = [1, 10, 11, 12, 2]
    pdict = dict(zip(names, params))
    mem = np.asarray(model_mod.encode(pdict, cfg, jnp.asarray(src)))
    mask = (src != 0).astype(np.float32)
    lt, w = 24, 8
    tgt = np.zeros((b, lt), np.int32)
    tgt[0, :4] = [1, 5, 6, 7]
    tgt[1, :3] = [1, 10, 11]
    pos = np.array([3, 2], np.int32)
    dec_fn = make_decode(cfg, names, w, use_pallas=use_pallas)
    logits = np.asarray(dec_fn(*params, jnp.asarray(mem), jnp.asarray(mask),
                               jnp.asarray(tgt), jnp.asarray(pos))[0])
    np.savez(art / "selftest.npz", src=src, mem=mem, mask=mask, tgt=tgt, pos=pos,
             logits=logits)

    manifest = {
        "files": files,
        "enc_buckets": ENC_BUCKETS,
        "dec_row_buckets": DEC_ROW_BUCKETS,
        "dec_len_buckets": DEC_LEN_BUCKETS,
        "dec_win_buckets": DEC_WIN_BUCKETS,
        "heads": heads,
        "pallas": use_pallas,
        "selftest": {"lt": lt, "w": w},
    }
    with open(art / "aot_manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(files)} HLO artifacts to {art} in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
