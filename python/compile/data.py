"""Dataset loading and batching for training (build-time only)."""

from __future__ import annotations

from pathlib import Path

import numpy as np

from .tokenizer import Vocab, BOS, EOS, PAD


def load_pairs(path: str | Path) -> list[tuple[str, str]]:
    """Read a `src \t tgt [\t ...]` TSV."""
    out = []
    with open(path) as f:
        for line in f:
            parts = line.rstrip("\n").split("\t")
            if len(parts) >= 2:
                out.append((parts[0], parts[1]))
    return out


def encode_pairs(
    pairs: list[tuple[str, str]], vocab: Vocab, max_src: int, max_tgt: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Tokenize and pad to fixed shapes.

    Returns (src, tgt_in, tgt_out):
      src     (N, max_src)  BOS ... EOS PAD*
      tgt_in  (N, max_tgt)  BOS tokens...            (decoder input)
      tgt_out (N, max_tgt)  tokens... EOS PAD*       (next-token targets)
    Pairs that do not fit are dropped.
    """
    srcs, tins, touts = [], [], []
    for s, t in pairs:
        se = vocab.encode(s, wrap=True)
        te = vocab.encode(t, wrap=True)  # BOS ... EOS
        if len(se) > max_src or len(te) > max_tgt:
            continue
        src = se + [PAD] * (max_src - len(se))
        tin = te[:-1] + [PAD] * (max_tgt - (len(te) - 1))
        tout = te[1:] + [PAD] * (max_tgt - (len(te) - 1))
        srcs.append(src)
        tins.append(tin)
        touts.append(tout)
    return (
        np.asarray(srcs, np.int32),
        np.asarray(tins, np.int32),
        np.asarray(touts, np.int32),
    )


class Batches:
    """Shuffled epoch iterator over pre-encoded arrays."""

    def __init__(self, src, tgt_in, tgt_out, batch: int, seed: int = 0):
        self.src, self.tgt_in, self.tgt_out = src, tgt_in, tgt_out
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        self.n = src.shape[0]

    def __iter__(self):
        order = self.rng.permutation(self.n)
        for i in range(0, self.n - self.batch + 1, self.batch):
            idx = order[i : i + self.batch]
            yield self.src[idx], self.tgt_in[idx], self.tgt_out[idx]
