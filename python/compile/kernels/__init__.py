"""L1 Pallas kernels + pure-jnp oracles.

``attention`` and ``medusa_heads`` are the interpret-mode Pallas kernels
used by the AOT export; ``ref`` holds the semantics oracles used for
training and for pytest/hypothesis equivalence checks.
"""

from . import ref  # noqa: F401
from .attention import attention  # noqa: F401
from .medusa import medusa_heads  # noqa: F401
