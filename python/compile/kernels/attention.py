"""L1 Pallas kernel: fused scaled-dot-product attention.

TPU adaptation: the whole (Lq, Lk) score tile for one (batch, head) pair
is computed in VMEM — QK^T on the MXU, on-chip softmax, then the PV
product — so scores never round-trip to HBM (the flash-attention
property). Sequence lengths in this system are short (<= 72), so a
single-tile-per-(b, h) schedule fits VMEM comfortably:

    q/k/v tiles   3 x L x Dh      (72 x 16 f32 each ~ 4.5 KiB)
    scores        L x L           (72 x 72 f32     ~ 20 KiB)

For longer sequences the grid would add a KV-block dimension with an
online-softmax accumulator; the BlockSpec layout below already isolates
(b, h) so that change is local to this file.

interpret=True is mandatory on this image (CPU PJRT; Mosaic custom-calls
cannot execute) — see DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, scale):
    q = q_ref[0]  # (Lq, Dh)
    k = k_ref[0]  # (Lk, Dh)
    v = v_ref[0]  # (Lk, Dh)
    mask = m_ref[0]  # (Lq, Lk)
    scores = (q @ k.T) * scale + mask
    # numerically-stable softmax in VMEM
    mx = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - mx)
    w = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = w @ v


@functools.partial(jax.jit, static_argnames=("interpret",))
def attention(q, k, v, mask, *, interpret: bool = True):
    """Fused SDPA. q: (B, H, Lq, Dh); k/v: (B, H, Lk, Dh);
    mask: (B, Lq, Lk) additive. Returns (B, H, Lq, Dh)."""
    b, h, lq, dh = q.shape
    lk = k.shape[2]
    scale = 1.0 / (dh ** 0.5)
    qf = q.reshape(b * h, lq, dh)
    kf = k.reshape(b * h, lk, dh)
    vf = v.reshape(b * h, lk, dh)
    # broadcast the mask across heads
    mf = jnp.broadcast_to(mask[:, None, :, :], (b, h, lq, lk)).reshape(b * h, lq, lk)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, lq, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lk, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lk, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lq, lk), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lq, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lq, dh), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, mf.astype(q.dtype))
    return out.reshape(b, h, lq, dh)
