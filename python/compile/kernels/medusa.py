"""L1 Pallas kernel: fused Medusa-head fan-out.

TPU adaptation of the paper's GPU-framed Medusa heads (see DESIGN.md
§Hardware-Adaptation): instead of M separate GEMM launches that each
stream the hidden states from HBM, one kernel keeps a ``(TILE_L, D)``
block of hidden states resident in VMEM and iterates the M heads over
the MXU, so ``h`` is read from HBM exactly once per tile.

VMEM budget per grid step (f32):
    h tile        TILE_L x D
    per-head W1/W2  D x HH + HH x D   (streamed per head)
    unembed       D x V
    out tile      TILE_L x M x V
With the default config (D=64, HH=64, V<=64, TILE_L=32, M=6) this is
well under 1 MiB — far below the ~16 MiB VMEM ceiling, leaving room to
scale D/V by an order of magnitude.

Runs with ``interpret=True`` everywhere in this repo: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the correctness
path and real-TPU performance is *estimated* (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_L = 32


def _medusa_kernel(h_ref, w1_ref, b1_ref, w2_ref, b2_ref, g_ref, b_ref, u_ref, o_ref, *, eps):
    """One grid step: rows tile x all heads.

    h_ref:  (TILE, D)
    w1_ref: (M, D, HH);  b1_ref: (M, HH)
    w2_ref: (M, HH, D);  b2_ref: (M, D)
    g_ref/b_ref: (M, D); u_ref: (D, V)
    o_ref:  (TILE, M, V)
    """
    h = h_ref[...]
    m = w1_ref.shape[0]
    u = u_ref[...]
    for head in range(m):  # static unroll: heads iterate in-kernel so h is loaded once
        t = jnp.maximum(h @ w1_ref[head] + b1_ref[head][None, :], 0.0)
        r = t @ w2_ref[head] + b2_ref[head][None, :] + h
        mu = jnp.mean(r, axis=-1, keepdims=True)
        var = jnp.mean((r - mu) * (r - mu), axis=-1, keepdims=True)
        r = (r - mu) / jnp.sqrt(var + eps) * g_ref[head][None, :] + b_ref[head][None, :]
        o_ref[:, head, :] = r @ u


@functools.partial(jax.jit, static_argnames=("tile_l", "interpret"))
def medusa_heads(h, w1, b1, w2, b2, ln_g, ln_b, unembed, *, tile_l: int = DEFAULT_TILE_L,
                 interpret: bool = True, eps: float = 1e-5):
    """Fused Medusa-head projection.

    h: (B, L, D) -> logits (B, L, M, V). See ``ref.medusa_heads_ref`` for
    the semantics oracle.
    """
    b, l, d = h.shape
    m, _, hh = w1.shape
    v = unembed.shape[1]
    rows = b * l
    hf = h.reshape(rows, d)
    # pad rows to a multiple of the tile
    tile = min(tile_l, max(rows, 1))
    pad = (-rows) % tile
    if pad:
        hf = jnp.concatenate([hf, jnp.zeros((pad, d), h.dtype)], axis=0)
    grid = (hf.shape[0] // tile,)
    out = pl.pallas_call(
        functools.partial(_medusa_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d, hh), lambda i: (0, 0, 0)),
            pl.BlockSpec((m, hh), lambda i: (0, 0)),
            pl.BlockSpec((m, hh, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((d, v), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, m, v), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hf.shape[0], m, v), h.dtype),
        interpret=interpret,
    )(hf, w1, b1, w2, b2, ln_g, ln_b, unembed)
    return out[:rows].reshape(b, l, m, v)
