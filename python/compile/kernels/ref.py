"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: pytest checks the Pallas
kernels (interpret mode) against these implementations, and the training
loop uses them directly (identical math, faster than interpret-mode
Pallas on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, mask):
    """Scaled dot-product attention.

    q: (B, H, Lq, Dh); k, v: (B, H, Lk, Dh); mask: (B, Lq, Lk) additive.
    Returns (B, H, Lq, Dh).
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    scores = scores + mask[:, None, :, :].astype(q.dtype)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def medusa_heads_ref(h, w1, b1, w2, b2, ln_g, ln_b, unembed, eps: float = 1e-5):
    """Medusa head fan-out.

    h: (B, L, D) final decoder hidden states;
    w1: (M, D, Hh); b1: (M, Hh); w2: (M, Hh, D); b2: (M, D);
    ln_g/ln_b: (M, D); unembed: (D, V).
    Head m: ``LN_m(h + relu(h @ w1_m + b1_m) @ w2_m + b2_m) @ unembed``.
    Returns (B, L, M, V).
    """
    t = jnp.einsum("bld,mdh->blmh", h, w1) + b1[None, None]
    t = jax.nn.relu(t)
    r = jnp.einsum("blmh,mhd->blmd", t, w2) + b2[None, None]
    r = r + h[:, :, None, :]
    mu = jnp.mean(r, axis=-1, keepdims=True)
    var = jnp.var(r, axis=-1, keepdims=True)
    r = (r - mu) / jnp.sqrt(var + eps) * ln_g[None, None] + ln_b[None, None]
    return jnp.einsum("blmd,dv->blmv", r, unembed)
