"""L2: the SMILES-to-SMILES encoder-decoder transformer with Medusa heads.

Pure-JAX (no flax/optax in the image); parameters live in a flat dict of
arrays with deterministic ordering (see :func:`param_names`) so the Rust
runtime can feed them positionally as PJRT buffers.

Architecture (scaled-down Molecular Transformer + Medusa):

* pre-LN encoder/decoder stacks, sinusoidal positions, tied unembedding;
* ``n_medusa`` extra heads: per-head one-hidden-layer MLP with residual
  and layer norm (the Medusa-1 recipe), sharing the tied unembedding;
* decoder output is ``(B, L, 1 + n_medusa, V)``: index 0 is the main
  next-token head, index k predicts the token ``k`` positions further.

The compute hot-spots can route through the Pallas kernels in
``kernels/`` (``use_pallas=True``; interpret mode) — the AOT export uses
them so the L1 kernels genuinely lower into the served HLO.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 26
    d_model: int = 64
    n_heads: int = 4
    d_ff: int = 256
    n_enc: int = 2
    n_dec: int = 2
    n_medusa: int = 6
    medusa_hidden: int = 64
    max_src: int = 64
    max_tgt: int = 72
    pad_id: int = 0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def to_json_dict(self) -> dict:
        return asdict(self)


# ---------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    """Ordered (insertion order = positional order) name -> shape map."""
    d, f, hh, v, m = cfg.d_model, cfg.d_ff, cfg.medusa_hidden, cfg.vocab, cfg.n_medusa
    shapes: dict[str, tuple[int, ...]] = {}
    shapes["embed"] = (v, d)
    for i in range(cfg.n_enc):
        p = f"enc{i}"
        shapes[f"{p}.ln1.g"] = (d,)
        shapes[f"{p}.ln1.b"] = (d,)
        shapes[f"{p}.attn.wq"] = (d, d)
        shapes[f"{p}.attn.wk"] = (d, d)
        shapes[f"{p}.attn.wv"] = (d, d)
        shapes[f"{p}.attn.wo"] = (d, d)
        shapes[f"{p}.ln2.g"] = (d,)
        shapes[f"{p}.ln2.b"] = (d,)
        shapes[f"{p}.ff.w1"] = (d, f)
        shapes[f"{p}.ff.b1"] = (f,)
        shapes[f"{p}.ff.w2"] = (f, d)
        shapes[f"{p}.ff.b2"] = (d,)
    shapes["enc.lnf.g"] = (d,)
    shapes["enc.lnf.b"] = (d,)
    for i in range(cfg.n_dec):
        p = f"dec{i}"
        shapes[f"{p}.ln1.g"] = (d,)
        shapes[f"{p}.ln1.b"] = (d,)
        shapes[f"{p}.attn.wq"] = (d, d)
        shapes[f"{p}.attn.wk"] = (d, d)
        shapes[f"{p}.attn.wv"] = (d, d)
        shapes[f"{p}.attn.wo"] = (d, d)
        shapes[f"{p}.ln2.g"] = (d,)
        shapes[f"{p}.ln2.b"] = (d,)
        shapes[f"{p}.xattn.wq"] = (d, d)
        shapes[f"{p}.xattn.wk"] = (d, d)
        shapes[f"{p}.xattn.wv"] = (d, d)
        shapes[f"{p}.xattn.wo"] = (d, d)
        shapes[f"{p}.ln3.g"] = (d,)
        shapes[f"{p}.ln3.b"] = (d,)
        shapes[f"{p}.ff.w1"] = (d, f)
        shapes[f"{p}.ff.b1"] = (f,)
        shapes[f"{p}.ff.w2"] = (f, d)
        shapes[f"{p}.ff.b2"] = (d,)
    shapes["dec.lnf.g"] = (d,)
    shapes["dec.lnf.b"] = (d,)
    # Medusa heads, stacked along a leading head axis.
    shapes["medusa.w1"] = (m, d, hh)
    shapes["medusa.b1"] = (m, hh)
    shapes["medusa.w2"] = (m, hh, d)
    shapes["medusa.b2"] = (m, d)
    shapes["medusa.ln.g"] = (m, d)
    shapes["medusa.ln.b"] = (m, d)
    return shapes


def param_names(cfg: ModelConfig) -> list[str]:
    return list(param_shapes(cfg).keys())


def init_params(key, cfg: ModelConfig) -> dict[str, jnp.ndarray]:
    params = {}
    for name, shape in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name.endswith((".b", ".b1", ".b2")) or ".ln" in name or name.startswith("enc.lnf") or name.startswith("dec.lnf"):
            if name.endswith(".g"):
                params[name] = jnp.ones(shape, jnp.float32)
            else:
                params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "embed":
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.02
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) / np.sqrt(fan_in)
    return params


# ---------------------------------------------------------------------
# Model pieces
# ---------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def sinusoidal_positions(length: int, d: int) -> jnp.ndarray:
    pos = np.arange(length)[:, None]
    i = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / d)
    enc = np.zeros((length, d), np.float32)
    enc[:, 0::2] = np.sin(angle)
    enc[:, 1::2] = np.cos(angle)
    return jnp.asarray(enc)


def multi_head_attention(q_in, kv_in, mask, wq, wk, wv, wo, n_heads, use_pallas=False):
    """mask: (B, Lq, Lk) additive (0 or -inf-ish)."""
    b, lq, d = q_in.shape
    lk = kv_in.shape[1]
    dh = d // n_heads
    q = (q_in @ wq).reshape(b, lq, n_heads, dh).transpose(0, 2, 1, 3)
    k = (kv_in @ wk).reshape(b, lk, n_heads, dh).transpose(0, 2, 1, 3)
    v = (kv_in @ wv).reshape(b, lk, n_heads, dh).transpose(0, 2, 1, 3)
    if use_pallas:
        out = kernels.attention(q, k, v, mask)  # (B, H, Lq, Dh)
    else:
        out = kernels.ref.attention_ref(q, k, v, mask)
    out = out.transpose(0, 2, 1, 3).reshape(b, lq, d)
    return out @ wo


def feed_forward(x, w1, b1, w2, b2):
    return jax.nn.relu(x @ w1 + b1) @ w2 + b2


def encode(params, cfg: ModelConfig, src, use_pallas: bool = False):
    """src: (B, Ls) int32 -> memory (B, Ls, D)."""
    b, ls = src.shape
    x = params["embed"][src] * np.sqrt(cfg.d_model)
    x = x + sinusoidal_positions(ls, cfg.d_model)[None]
    pad_mask = (src != cfg.pad_id).astype(jnp.float32)  # (B, Ls)
    attn_mask = (pad_mask[:, None, :] - 1.0) * 1e9  # (B, 1->Lq, Lk)
    attn_mask = jnp.broadcast_to(attn_mask, (b, ls, ls))
    for i in range(cfg.n_enc):
        p = f"enc{i}"
        h = layer_norm(x, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"])
        x = x + multi_head_attention(
            h, h, attn_mask,
            params[f"{p}.attn.wq"], params[f"{p}.attn.wk"],
            params[f"{p}.attn.wv"], params[f"{p}.attn.wo"],
            cfg.n_heads, use_pallas,
        )
        h = layer_norm(x, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"])
        x = x + feed_forward(
            h, params[f"{p}.ff.w1"], params[f"{p}.ff.b1"],
            params[f"{p}.ff.w2"], params[f"{p}.ff.b2"],
        )
    x = layer_norm(x, params["enc.lnf.g"], params["enc.lnf.b"])
    # zero out pad positions so downstream cross-attention sees clean memory
    return x * pad_mask[:, :, None]


def decode(params, cfg: ModelConfig, mem, src_mask, tgt, use_pallas: bool = False,
           pallas_attention: bool | None = None):
    """Full-prefix decode.

    mem: (B, Ls, D) encoder memory; src_mask: (B, Ls) 1.0/0.0;
    tgt: (B, Lt) int32 (BOS-led, PAD-padded).
    Returns logits (B, Lt, 1 + n_medusa, V).

    ``use_pallas`` routes the Medusa fan-out through the Pallas kernel;
    ``pallas_attention`` (default: same as ``use_pallas``) additionally
    routes attention through the fused Pallas SDPA kernel. The AOT export
    keeps attention on the jnp path by default because interpret-mode
    Pallas attention compiles to a per-(b,h) loop that is slow under the
    CPU PJRT backend (see DESIGN.md §Hardware-Adaptation).
    """
    if pallas_attention is None:
        pallas_attention = use_pallas
    b, lt = tgt.shape
    ls = mem.shape[1]
    x = params["embed"][tgt] * np.sqrt(cfg.d_model)
    x = x + sinusoidal_positions(lt, cfg.d_model)[None]
    causal = jnp.tril(jnp.ones((lt, lt), jnp.float32))
    self_mask = (causal[None] - 1.0) * 1e9
    self_mask = jnp.broadcast_to(self_mask, (b, lt, lt))
    cross_mask = (src_mask[:, None, :] - 1.0) * 1e9
    cross_mask = jnp.broadcast_to(cross_mask, (b, lt, ls))
    for i in range(cfg.n_dec):
        p = f"dec{i}"
        h = layer_norm(x, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"])
        x = x + multi_head_attention(
            h, h, self_mask,
            params[f"{p}.attn.wq"], params[f"{p}.attn.wk"],
            params[f"{p}.attn.wv"], params[f"{p}.attn.wo"],
            cfg.n_heads, pallas_attention,
        )
        h = layer_norm(x, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"])
        x = x + multi_head_attention(
            h, mem, cross_mask,
            params[f"{p}.xattn.wq"], params[f"{p}.xattn.wk"],
            params[f"{p}.xattn.wv"], params[f"{p}.xattn.wo"],
            cfg.n_heads, pallas_attention,
        )
        h = layer_norm(x, params[f"{p}.ln3.g"], params[f"{p}.ln3.b"])
        x = x + feed_forward(
            h, params[f"{p}.ff.w1"], params[f"{p}.ff.b1"],
            params[f"{p}.ff.w2"], params[f"{p}.ff.b2"],
        )
    h = layer_norm(x, params["dec.lnf.g"], params["dec.lnf.b"])
    unembed = params["embed"].T  # tied
    main = h @ unembed  # (B, Lt, V)
    if cfg.n_medusa == 0:
        return main[:, :, None, :]
    if use_pallas:
        med = kernels.medusa_heads(
            h,
            params["medusa.w1"], params["medusa.b1"],
            params["medusa.w2"], params["medusa.b2"],
            params["medusa.ln.g"], params["medusa.ln.b"],
            unembed,
        )  # (B, Lt, M, V)
    else:
        med = kernels.ref.medusa_heads_ref(
            h,
            params["medusa.w1"], params["medusa.b1"],
            params["medusa.w2"], params["medusa.b2"],
            params["medusa.ln.g"], params["medusa.ln.b"],
            unembed,
        )
    return jnp.concatenate([main[:, :, None, :], med], axis=2)


def forward(params, cfg: ModelConfig, src, tgt, use_pallas: bool = False):
    """Encode + decode in one pass (training convenience)."""
    mem = encode(params, cfg, src, use_pallas)
    src_mask = (src != cfg.pad_id).astype(jnp.float32)
    return decode(params, cfg, mem, src_mask, tgt, use_pallas)


# ---------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------


def training_loss(params, cfg: ModelConfig, src, tgt_in, tgt_out):
    """Joint Medusa loss ("joint training, combined loss").

    tgt_in:  (B, Lt) decoder input (BOS-led);
    tgt_out: (B, Lt) next-token targets (tgt_in shifted left, EOS-capped).
    Head k (0 = main) is trained to predict ``tgt_out`` shifted k more
    positions; its loss contribution is weighted ``1/(k+1)`` to give the
    main head priority (the paper's recipe).
    """
    logits = forward(params, cfg, src, tgt_in)  # (B, Lt, M+1, V)
    b, lt, heads, v = logits.shape
    log_p = jax.nn.log_softmax(logits, axis=-1)
    total = 0.0
    denom = 0.0
    for k in range(heads):
        # target for head k at position i is tgt_out[i + k]
        tk = tgt_out[:, k:]
        lp = log_p[:, : lt - k, k, :]
        mask = (tk != cfg.pad_id).astype(jnp.float32)
        nll = -jnp.take_along_axis(lp, tk[:, :, None], axis=-1)[:, :, 0]
        w = 1.0 / (k + 1.0)
        total = total + w * jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        denom += w
    return total / denom


def main_head_token_accuracy(params, cfg: ModelConfig, src, tgt_in, tgt_out):
    logits = forward(params, cfg, src, tgt_in)
    pred = jnp.argmax(logits[:, :, 0, :], axis=-1)
    mask = tgt_out != cfg.pad_id
    return jnp.sum((pred == tgt_out) & mask) / jnp.maximum(jnp.sum(mask), 1)
