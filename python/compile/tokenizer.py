"""Atomwise SMILES tokenizer — exact mirror of ``rust/src/tokenizer``.

The vocabulary is built by the Rust ``datagen`` binary and stored in
``artifacts/vocab.json``; both sides must tokenize identically, so keep
this function in lockstep with ``tokenize`` in ``rust/src/tokenizer/mod.rs``.
"""

from __future__ import annotations

import json
from pathlib import Path

PAD, BOS, EOS, UNK = 0, 1, 2, 3
SPECIALS = ["<pad>", "<bos>", "<eos>", "<unk>"]


def tokenize(s: str) -> list[str]:
    """Split a SMILES string into atomwise tokens.

    Bracket expressions ``[...]``, two-character halogens ``Cl``/``Br`` and
    ``%nn`` ring indices are single tokens; everything else is one char.
    """
    out: list[str] = []
    i = 0
    n = len(s)
    while i < n:
        c = s[i]
        if c == "[":
            j = i
            while j < n and s[j] != "]":
                j += 1
            j = min(j + 1, n)
            out.append(s[i:j])
            i = j
        elif c == "C" and i + 1 < n and s[i + 1] == "l":
            out.append("Cl")
            i += 2
        elif c == "B" and i + 1 < n and s[i + 1] == "r":
            out.append("Br")
            i += 2
        elif c == "%":
            out.append(s[i : i + 3])
            i += 3
        else:
            out.append(c)
            i += 1
    return out


class Vocab:
    """Fixed vocabulary loaded from ``vocab.json``."""

    def __init__(self, tokens: list[str]):
        assert tokens[: len(SPECIALS)] == SPECIALS, "special tokens must lead the vocab"
        self.tokens = list(tokens)
        self.id_of = {t: i for i, t in enumerate(self.tokens)}

    @classmethod
    def load(cls, path: str | Path) -> "Vocab":
        with open(path) as f:
            data = json.load(f)
        return cls(data["tokens"])

    def __len__(self) -> int:
        return len(self.tokens)

    def id(self, token: str) -> int:
        return self.id_of.get(token, UNK)

    def encode(self, s: str, wrap: bool = True) -> list[int]:
        ids = [self.id(t) for t in tokenize(s)]
        return [BOS] + ids + [EOS] if wrap else ids

    def decode(self, ids) -> str:
        out = []
        for i in ids:
            i = int(i)
            if i == EOS:
                break
            if i in (PAD, BOS):
                continue
            out.append(self.tokens[i] if 0 <= i < len(self.tokens) else "<unk>")
        return "".join(out)
