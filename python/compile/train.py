"""Build-time training of the Medusa SMILES-to-SMILES transformer.

Hand-rolled Adam (no optax in the image) with the classic transformer
inverse-sqrt warmup schedule. Trains on ``artifacts/dataset_train.tsv``
(produced by the Rust ``datagen`` binary) and writes:

* ``artifacts/params.npz``        — flat-named parameter arrays
* ``artifacts/train_log.txt``     — step/loss/accuracy log
* ``artifacts/model_config.json`` — architecture + vocab + buckets

Usage: ``python -m compile.train [--steps N] [--batch N] [--artifacts DIR]``
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .model import ModelConfig
from .tokenizer import Vocab


def adam_init(params):
    return (
        {k: jnp.zeros_like(v) for k, v in params.items()},
        {k: jnp.zeros_like(v) for k, v in params.items()},
    )


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.98, eps=1e-9):
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        mk = b1 * m[k] + (1 - b1) * g
        vk = b2 * v[k] + (1 - b2) * g * g
        mhat = mk / (1 - b1**step)
        vhat = vk / (1 - b2**step)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k] = mk
        new_v[k] = vk
    return new_p, new_m, new_v


def lr_schedule(step, d_model, warmup=400, scale=2.0):
    step = jnp.maximum(step, 1.0)
    return scale * d_model**-0.5 * jnp.minimum(step**-0.5, step * warmup**-1.5)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--steps", type=int, default=6000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=500)
    args = ap.parse_args()

    art = Path(args.artifacts)
    vocab = Vocab.load(art / "vocab.json")
    cfg = ModelConfig(vocab=len(vocab))
    print(f"model config: {cfg}")

    pairs = data_mod.load_pairs(art / "dataset_train.tsv")
    src, tin, tout = data_mod.encode_pairs(pairs, vocab, cfg.max_src, cfg.max_tgt)
    print(f"train samples: {src.shape[0]} (of {len(pairs)} pairs)")
    test_pairs = data_mod.load_pairs(art / "dataset_test.tsv")
    tsrc, ttin, ttout = data_mod.encode_pairs(test_pairs[:512], vocab, cfg.max_src, cfg.max_tgt)

    key = jax.random.PRNGKey(args.seed)
    params = model_mod.init_params(key, cfg)
    m, v = adam_init(params)

    loss_fn = lambda p, s, ti, to: model_mod.training_loss(p, cfg, s, ti, to)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    acc_fn = jax.jit(lambda p, s, ti, to: model_mod.main_head_token_accuracy(p, cfg, s, ti, to))

    @jax.jit
    def train_step(params, m, v, step, s, ti, to):
        loss, grads = jax.value_and_grad(loss_fn)(params, s, ti, to)
        lr = lr_schedule(step.astype(jnp.float32), cfg.d_model)
        params, m, v = adam_update(params, grads, m, v, step, lr)
        return params, m, v, loss

    batches = data_mod.Batches(src, tin, tout, args.batch, seed=args.seed)
    log_path = art / "train_log.txt"
    log = open(log_path, "w")
    step = 0
    t0 = time.time()
    running = []
    while step < args.steps:
        for bs, bti, bto in batches:
            step += 1
            params, m, v, loss = train_step(
                params, m, v, jnp.asarray(step, jnp.float32), bs, bti, bto
            )
            running.append(float(loss))
            if step % 100 == 0:
                msg = (
                    f"step {step} loss {np.mean(running[-100:]):.4f} "
                    f"({(time.time() - t0) / step * 1000:.0f} ms/step)"
                )
                print(msg, flush=True)
                log.write(msg + "\n")
                log.flush()
            if step % args.eval_every == 0 or step == args.steps:
                acc = float(acc_fn(params, tsrc, ttin, ttout))
                msg = f"step {step} test token accuracy (main head) {acc:.4f}"
                print(msg, flush=True)
                log.write(msg + "\n")
                log.flush()
            if step >= args.steps:
                break

    # Per-head accuracy on the eval slice (acceptance-rate proxy).
    logits = model_mod.forward(params, cfg, tsrc, ttin)
    head_accs = []
    for k in range(cfg.n_medusa + 1):
        lt = ttout.shape[1]
        tk = ttout[:, k:]
        pred = np.argmax(np.asarray(logits[:, : lt - k, k, :]), axis=-1)
        mask = tk != cfg.pad_id
        head_accs.append(float(((pred == tk) & mask).sum() / max(mask.sum(), 1)))
    msg = "per-head token accuracy: " + " ".join(f"{a:.3f}" for a in head_accs)
    print(msg)
    log.write(msg + "\n")
    log.close()

    # Save parameters with flat names (ordering via model_mod.param_names).
    np.savez(art / "params.npz", **{k: np.asarray(p) for k, p in params.items()})
    config = {
        "model": cfg.to_json_dict(),
        "param_names": model_mod.param_names(cfg),
        "param_shapes": {k: list(s) for k, s in model_mod.param_shapes(cfg).items()},
        "head_token_accuracy": head_accs,
        "train_steps": step,
    }
    with open(art / "model_config.json", "w") as f:
        json.dump(config, f, indent=1)
    print(f"saved params.npz + model_config.json to {art}")


if __name__ == "__main__":
    main()
