"""L1 Pallas kernels vs the pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes (and the f32/bf16 dtypes the kernels support);
every case asserts allclose against ``kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------
# medusa_heads
# ---------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    l=st.integers(1, 9),
    d=st.sampled_from([8, 16, 64]),
    hh=st.sampled_from([8, 32]),
    m=st.integers(1, 7),
    v=st.sampled_from([11, 26, 32]),
    tile=st.sampled_from([4, 32]),
)
def test_medusa_kernel_matches_ref(b, l, d, hh, m, v, tile):
    keys = jax.random.split(jax.random.PRNGKey(b * 1000 + l * 100 + m), 8)
    h = rand(keys[0], (b, l, d))
    w1 = rand(keys[1], (m, d, hh), scale=d**-0.5)
    b1 = rand(keys[2], (m, hh), scale=0.1)
    w2 = rand(keys[3], (m, hh, d), scale=hh**-0.5)
    b2 = rand(keys[4], (m, d), scale=0.1)
    g = 1.0 + rand(keys[5], (m, d), scale=0.1)
    bb = rand(keys[6], (m, d), scale=0.1)
    u = rand(keys[7], (d, v), scale=d**-0.5)
    got = kernels.medusa_heads(h, w1, b1, w2, b2, g, bb, u, tile_l=tile)
    want = ref.medusa_heads_ref(h, w1, b1, w2, b2, g, bb, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_medusa_kernel_bf16():
    keys = jax.random.split(jax.random.PRNGKey(7), 8)
    b, l, d, hh, m, v = 2, 6, 16, 16, 3, 13
    h = rand(keys[0], (b, l, d), jnp.bfloat16)
    args = [
        rand(keys[1], (m, d, hh), jnp.bfloat16, 0.3),
        rand(keys[2], (m, hh), jnp.bfloat16, 0.1),
        rand(keys[3], (m, hh, d), jnp.bfloat16, 0.3),
        rand(keys[4], (m, d), jnp.bfloat16, 0.1),
        (1.0 + rand(keys[5], (m, d), jnp.float32, 0.1)).astype(jnp.bfloat16),
        rand(keys[6], (m, d), jnp.bfloat16, 0.1),
        rand(keys[7], (d, v), jnp.bfloat16, 0.3),
    ]
    got = kernels.medusa_heads(h, *args)
    want = ref.medusa_heads_ref(h, *args)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=0.1, atol=0.1
    )


def test_medusa_kernel_row_padding_exact():
    """rows not a multiple of the tile exercise the padding path."""
    keys = jax.random.split(jax.random.PRNGKey(3), 8)
    b, l, d, hh, m, v = 1, 5, 8, 8, 2, 9  # rows=5 with tile 4
    h = rand(keys[0], (b, l, d))
    w1 = rand(keys[1], (m, d, hh))
    b1 = rand(keys[2], (m, hh))
    w2 = rand(keys[3], (m, hh, d))
    b2 = rand(keys[4], (m, d))
    g = jnp.ones((m, d))
    bb = jnp.zeros((m, d))
    u = rand(keys[7], (d, v))
    got = kernels.medusa_heads(h, w1, b1, w2, b2, g, bb, u, tile_l=4)
    want = ref.medusa_heads_ref(h, w1, b1, w2, b2, g, bb, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    lq=st.integers(1, 12),
    lk=st.integers(1, 12),
    dh=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
)
def test_attention_kernel_matches_ref(b, h, lq, lk, dh, causal):
    keys = jax.random.split(jax.random.PRNGKey(b + h * 10 + lq * 100), 3)
    q = rand(keys[0], (b, h, lq, dh))
    k = rand(keys[1], (b, h, lk, dh))
    v = rand(keys[2], (b, h, lk, dh))
    if causal and lq == lk:
        mask = (jnp.tril(jnp.ones((lq, lk))) - 1.0) * 1e9
        mask = jnp.broadcast_to(mask[None], (b, lq, lk))
    else:
        mask = jnp.zeros((b, lq, lk))
    got = kernels.attention(q, k, v, mask)
    want = ref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_attention_respects_padding_mask():
    """Fully masked-out keys must receive zero attention weight."""
    b, h, l, dh = 1, 2, 6, 8
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(keys[0], (b, h, l, dh))
    k = rand(keys[1], (b, h, l, dh))
    v = rand(keys[2], (b, h, l, dh))
    mask = jnp.zeros((b, l, l)).at[:, :, 3:].set(-1e9)  # keys 3.. masked
    got = kernels.attention(q, k, v, mask)
    # recompute with the masked keys replaced by garbage: result must not change
    v_garbage = v.at[:, :, 3:, :].set(1e3)
    got2 = kernels.attention(q, k, v_garbage, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got2), rtol=1e-5, atol=1e-5)


def test_attention_softmax_rows_sum_to_one_property():
    """Uniform values -> output equals value vector (softmax normalizes)."""
    b, h, l, dh = 1, 1, 5, 4
    q = jnp.zeros((b, h, l, dh))
    k = jnp.zeros((b, h, l, dh))
    v = jnp.broadcast_to(jnp.arange(dh, dtype=jnp.float32), (b, h, l, dh))
    mask = jnp.zeros((b, l, l))
    got = kernels.attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(v), rtol=1e-6, atol=1e-6)
