"""L2 model semantics: shapes, masking, causality, Medusa heads, loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import ModelConfig

CFG = ModelConfig(vocab=20, d_model=32, n_heads=2, d_ff=64, n_enc=1, n_dec=1,
                  n_medusa=3, medusa_hidden=16, max_src=16, max_tgt=12)


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), CFG)


def toks(rows, cols, fill, lens):
    x = np.zeros((rows, cols), np.int32)
    for r, l in enumerate(lens):
        x[r, :l] = fill[r][:l]
    return jnp.asarray(x)


def test_shapes(params):
    src = toks(2, 16, [[1, 5, 6, 2], [1, 7, 2, 0]], [4, 3])
    tgt = toks(2, 12, [[1, 5, 6], [1, 7, 8]], [3, 3])
    mem = model.encode(params, CFG, src)
    assert mem.shape == (2, 16, CFG.d_model)
    logits = model.forward(params, CFG, src, tgt)
    assert logits.shape == (2, 12, CFG.n_medusa + 1, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_encoder_pad_positions_zeroed(params):
    src = toks(1, 16, [[1, 5, 6, 2]], [4])
    mem = model.encode(params, CFG, src)
    assert float(jnp.abs(mem[0, 4:]).max()) == 0.0


def test_encoder_invariant_to_pad_content(params):
    """Changing tokens in the padded tail must not change real positions."""
    a = np.zeros((1, 16), np.int32)
    a[0, :4] = [1, 5, 6, 2]
    b = a.copy()
    b[0, 10] = 0  # stays pad
    a2 = a.copy()
    # Put a *different padding amount* via mask: emulate by altering a pad slot
    # directly is impossible (mask keys off pad_id), so instead check two
    # encodes of identical content agree and a longer real prefix differs.
    ma = model.encode(params, CFG, jnp.asarray(a))
    mb = model.encode(params, CFG, jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(ma), np.asarray(mb), atol=0)


def test_decoder_causality(params):
    """Logits at position i must not depend on tgt tokens at j > i."""
    src = toks(1, 16, [[1, 5, 6, 2]], [4])
    mem = model.encode(params, CFG, src)
    mask = (src != 0).astype(jnp.float32)
    t1 = toks(1, 12, [[1, 5, 6, 7, 8]], [5])
    t2 = np.asarray(t1).copy()
    t2[0, 4] = 9  # change token at position 4
    l1 = model.decode(params, CFG, mem, mask, t1)
    l2 = model.decode(params, CFG, mem, mask, jnp.asarray(t2))
    np.testing.assert_allclose(
        np.asarray(l1[0, :4]), np.asarray(l2[0, :4]), rtol=1e-6, atol=1e-6
    )
    assert float(jnp.abs(l1[0, 4] - l2[0, 4]).max()) > 1e-6


def test_medusa_heads_differ_from_main(params):
    src = toks(1, 16, [[1, 5, 6, 2]], [4])
    tgt = toks(1, 12, [[1, 5, 6]], [3])
    logits = model.forward(params, CFG, src, tgt)
    # heads produce different distributions (they are differently
    # initialized MLPs)
    assert float(jnp.abs(logits[0, 0, 0] - logits[0, 0, 1]).max()) > 1e-6


def test_pallas_and_ref_paths_agree(params):
    src = toks(2, 16, [[1, 5, 6, 2], [1, 9, 4, 2]], [4, 4])
    tgt = toks(2, 12, [[1, 5, 6], [1, 9, 4]], [3, 3])
    a = model.forward(params, CFG, src, tgt, use_pallas=False)
    b = model.forward(params, CFG, src, tgt, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-5, atol=5e-5)


def test_training_loss_finite_and_improves(params):
    key = jax.random.PRNGKey(1)
    src = jax.random.randint(key, (8, 16), 4, CFG.vocab).astype(jnp.int32)
    tgt_in = jax.random.randint(key, (8, 12), 4, CFG.vocab).astype(jnp.int32)
    tgt_out = jnp.concatenate([tgt_in[:, 1:], jnp.full((8, 1), 2, jnp.int32)], axis=1)
    loss_fn = lambda p: model.training_loss(p, CFG, src, tgt_in, tgt_out)
    l0 = float(loss_fn(params))
    assert np.isfinite(l0)
    # a few SGD steps reduce the loss on this fixed batch
    p = params
    g_fn = jax.jit(jax.grad(loss_fn))
    for _ in range(10):
        g = g_fn(p)
        p = {k: v - 0.1 * g[k] for k, v in p.items()}
    l1 = float(loss_fn(p))
    assert l1 < l0, (l0, l1)


def test_loss_ignores_pad(params):
    """Extending targets with PAD must not change the loss."""
    src = toks(1, 16, [[1, 5, 6, 2]], [4])
    tgt_in = toks(1, 12, [[1, 5, 6]], [3])
    tgt_out = toks(1, 12, [[5, 6, 2]], [3])
    l1 = float(model.training_loss(params, CFG, src, tgt_in, tgt_out))
    # same content, one extra pad column already present -> identical
    l2 = float(model.training_loss(params, CFG, src, tgt_in, tgt_out))
    assert l1 == l2


def test_param_names_order_is_stable():
    names1 = model.param_names(CFG)
    names2 = model.param_names(CFG)
    assert names1 == names2
    assert names1[0] == "embed"
    assert names1[-1] == "medusa.ln.b"
    shapes = model.param_shapes(CFG)
    p = model.init_params(jax.random.PRNGKey(0), CFG)
    for n in names1:
        assert tuple(p[n].shape) == shapes[n]
