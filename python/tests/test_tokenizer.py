"""Tokenizer parity and vocabulary behaviour."""

import json

import pytest

from compile.tokenizer import BOS, EOS, PAD, UNK, Vocab, tokenize


def test_tokenize_atomwise():
    assert tokenize("CCO") == ["C", "C", "O"]
    assert tokenize("CCl") == ["C", "Cl"]
    assert tokenize("BrCC") == ["Br", "C", "C"]
    assert tokenize("c1cc[nH]c1") == ["c", "1", "c", "c", "[nH]", "c", "1"]
    assert tokenize("C%12C") == ["C", "%12", "C"]
    assert tokenize("CC(=O)O.CN") == ["C", "C", "(", "=", "O", ")", "O", ".", "C", "N"]


def test_tokenize_brackets_with_charge():
    assert tokenize("C[N+](C)C") == ["C", "[N+]", "(", "C", ")", "C"]
    assert tokenize("[O-]C") == ["[O-]", "C"]


def make_vocab(corpus):
    toks = sorted({t for s in corpus for t in tokenize(s)})
    return Vocab(["<pad>", "<bos>", "<eos>", "<unk>"] + toks)


def test_encode_decode_roundtrip():
    v = make_vocab(["CC(=O)O", "c1cc[nH]c1", "ClCCBr"])
    for s in ["CC(=O)O", "c1cc[nH]c1", "ClCCBr"]:
        ids = v.encode(s)
        assert ids[0] == BOS and ids[-1] == EOS
        assert v.decode(ids) == s


def test_unknown_token():
    v = make_vocab(["CC"])
    ids = v.encode("CN", wrap=False)
    assert ids == [v.id("C"), UNK]


def test_decode_stops_at_eos():
    v = make_vocab(["CO"])
    c, o = v.id("C"), v.id("O")
    assert v.decode([BOS, c, EOS, o]) == "C"
    assert v.decode([c, PAD, o]) == "CO"


def test_specials_assertion():
    with pytest.raises(AssertionError):
        Vocab(["<pad>", "x"])


def test_vocab_load_matches_rust(tmp_path):
    """vocab.json written by the Rust side loads and orders identically."""
    doc = {"tokens": ["<pad>", "<bos>", "<eos>", "<unk>", "C", "Cl", "c"]}
    p = tmp_path / "vocab.json"
    p.write_text(json.dumps(doc))
    v = Vocab.load(p)
    assert len(v) == 7
    assert v.id("Cl") == 5
    assert v.encode("CClc", wrap=False) == [4, 5, 6]
