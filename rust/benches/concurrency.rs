//! Concurrency bench: request-granularity serving vs cycle-level fused
//! scheduling, at 1 / 4 / 16 / 64 / 256 concurrent mock planning
//! sessions (the 64/256 rows are the single-hub reference points for
//! `BENCH_sharded.json`'s scaling comparison).
//!
//! Closed-loop simulation: each session issues a chain of expansion
//! requests (one molecule each, varied length), issuing the next the
//! moment the previous completes. Two serving disciplines over the SAME
//! workload and model:
//!
//! * **request-granular** — the pre-scheduler hub: all currently
//!   pending requests merge into one group and a whole multi-cycle
//!   `generate` runs to completion before anyone is answered. Every
//!   session stalls behind the slowest molecule in the group, and the
//!   device batch decays as beams finish (Table 1C).
//! * **cycle-fused** — a [`DecodeScheduler`]: every request is a
//!   resumable task; each tick fuses ALL in-flight tasks' rows into one
//!   device call, and a finishing task's session re-enters the pipeline
//!   on the very next tick.
//!
//! The mock model sleeps a fixed `DEVICE_CALL_US` per decode call so
//! device time dominates, making latency percentiles meaningful. The
//! counting global allocator reports steady-state allocations per fused
//! tick (ticks with no submit/retire, past warm-up) — the
//! zero-allocation discipline check for the scheduler hot path.
//!
//! A third scenario exercises the request-budget path end to end: every
//! request carries a `DEADLINE_MS` wall-clock budget through
//! `ExpansionHub::submit_deadline`, and the bench reports the expiry
//! rate, time-to-result percentiles, and how far past its deadline an
//! expired request came back (the anytime-overrun, which the hub bounds
//! at roughly one scheduler tick).
//!
//! Emits `BENCH_concurrency.json` and `BENCH_deadline.json`.

use retroserve::benchkit::{
    allocs_now, write_bench_json, BenchRecord, CountingAlloc, InstrumentedModel,
};
use retroserve::coordinator::batcher::{BatcherConfig, ExpansionHub};
use retroserve::decoding::msbs::Msbs;
use retroserve::decoding::scheduler::{DecodeScheduler, Finished, SchedulerConfig, TaskId};
use retroserve::decoding::{DecodeStats, Decoder};
use retroserve::metrics::Metrics;
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::model::{encode_shared, StepModel};
use retroserve::tokenizer::{Vocab, BOS, EOS};
use retroserve::util::stats::percentile;
use retroserve::util::Rng;
use std::sync::Arc;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Synthetic device latency per decode call.
const DEVICE_CALL_US: u64 = 200;
/// Requests each session issues, back to back.
const REQUESTS_PER_SESSION: usize = 6;
const K: usize = 10;

fn make_model() -> InstrumentedModel<MockModel> {
    InstrumentedModel::new(MockModel::new(MockConfig::default()))
        .with_decode_delay(std::time::Duration::from_micros(DEVICE_CALL_US))
}

/// The (session, step) request workload: same for both disciplines.
fn workload(sessions: usize) -> Vec<Vec<Vec<i32>>> {
    let mut rng = Rng::new(0x5E55);
    (0..sessions)
        .map(|_| {
            (0..REQUESTS_PER_SESSION)
                .map(|_| {
                    let len = 6 + rng.gen_range(25);
                    let mut s = vec![BOS];
                    for _ in 0..len {
                        s.push(4 + rng.gen_range(20) as i32);
                    }
                    s.push(EOS);
                    s
                })
                .collect()
        })
        .collect()
}

struct RunReport {
    model_calls: u64,
    encode_calls: u64,
    avg_effective_batch: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    wall_ms: f64,
    allocs_per_tick_steady: f64,
}

/// Request-granularity discipline: drain everything pending into one
/// group, run `generate` to completion, answer, repeat.
fn run_request_granular(sessions: usize) -> RunReport {
    let work = workload(sessions);
    let model = make_model();
    let dec = Msbs::default();
    let mut stats = DecodeStats::default();
    // (session, step index, issue time)
    let mut pending: Vec<(usize, usize)> = (0..sessions).map(|s| (s, 0)).collect();
    let mut issue: Vec<std::time::Instant> = vec![std::time::Instant::now(); sessions];
    let mut latencies: Vec<f64> = Vec::new();
    let t0 = std::time::Instant::now();
    while !pending.is_empty() {
        let batch: Vec<(usize, usize)> = pending.drain(..).collect();
        let srcs: Vec<Vec<i32>> = batch.iter().map(|&(s, i)| work[s][i].clone()).collect();
        dec.generate(&model, &srcs, K, &mut stats).expect("generate");
        let now = std::time::Instant::now();
        for &(s, i) in &batch {
            latencies.push(now.duration_since(issue[s]).as_secs_f64() * 1e3);
            if i + 1 < REQUESTS_PER_SESSION {
                issue[s] = now;
                pending.push((s, i + 1));
            }
        }
    }
    RunReport {
        model_calls: stats.model_calls,
        encode_calls: model.inner().encode_calls.load(std::sync::atomic::Ordering::Relaxed),
        avg_effective_batch: stats.avg_effective_batch(),
        p50_ms: percentile(&latencies, 50.0),
        p95_ms: percentile(&latencies, 95.0),
        p99_ms: percentile(&latencies, 99.0),
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        allocs_per_tick_steady: f64::NAN,
    }
}

/// Cycle-fused discipline: one task per request, every tick fuses all
/// in-flight tasks' rows into one device call. Admission is
/// encode-fused like the hub's: all requests becoming ready in the
/// same round (initial co-arrivals, and the sessions whose previous
/// request retired in the same tick) share ONE `encode_shared` call.
fn run_cycle_fused(sessions: usize) -> RunReport {
    let work = workload(sessions);
    let model = make_model();
    let dec = Msbs::default();
    // Generous row cap: the request-granular discipline has none (one
    // whole-batch `generate`), so the comparison stays about scheduling
    // granularity, not device capacity, up to 256 sessions.
    let mut sched = DecodeScheduler::new(SchedulerConfig { max_rows: 16384 });
    let mut issue: Vec<std::time::Instant> = vec![std::time::Instant::now(); sessions];
    let mut latencies: Vec<f64> = Vec::new();
    let mut task_of = std::collections::HashMap::new();
    let mut finished: Vec<Finished> = Vec::new();
    let t0 = std::time::Instant::now();
    // One fused encode admits a whole round of co-arriving requests.
    fn submit_round(
        model: &dyn StepModel,
        dec: &Msbs,
        work: &[Vec<Vec<i32>>],
        sched: &mut DecodeScheduler,
        task_of: &mut std::collections::HashMap<TaskId, (usize, usize)>,
        round: &[(usize, usize)],
    ) {
        let srcs: Vec<Vec<i32>> = round.iter().map(|&(s, i)| work[s][i].clone()).collect();
        let views = encode_shared(model, &srcs).expect("encode");
        for ((&(s, i), view), src) in round.iter().zip(views).zip(srcs.iter()) {
            let one = std::slice::from_ref(src);
            let task = dec.start_task_on(model, vec![view], one, K).expect("task");
            task_of.insert(sched.submit(task), (s, i));
        }
    }
    let first_round: Vec<(usize, usize)> = (0..sessions).map(|s| (s, 0)).collect();
    submit_round(&model, &dec, &work, &mut sched, &mut task_of, &first_round);
    let mut ticks = 0u64;
    let mut steady_ticks = 0u64;
    let mut steady_allocs = 0u64;
    let mut next_round: Vec<(usize, usize)> = Vec::new();
    while !sched.is_idle() {
        finished.clear();
        let a0 = allocs_now();
        sched.tick(&model, &mut finished).expect("tick");
        let spent = allocs_now() - a0;
        ticks += 1;
        // Steady state = past buffer warm-up, no task retiring in this
        // tick (retiring finalizes hypotheses, which rightly allocates).
        if ticks > 12 && finished.is_empty() {
            steady_ticks += 1;
            steady_allocs += spent;
        }
        let now = std::time::Instant::now();
        next_round.clear();
        for f in finished.drain(..) {
            let (s, i) = task_of.remove(&f.id).expect("task bookkeeping");
            latencies.push(now.duration_since(issue[s]).as_secs_f64() * 1e3);
            if i + 1 < REQUESTS_PER_SESSION {
                issue[s] = now;
                next_round.push((s, i + 1));
            }
        }
        if !next_round.is_empty() {
            submit_round(&model, &dec, &work, &mut sched, &mut task_of, &next_round);
        }
    }
    RunReport {
        model_calls: sched.stats.fused_calls,
        encode_calls: model.inner().encode_calls.load(std::sync::atomic::Ordering::Relaxed),
        avg_effective_batch: sched.stats.avg_effective_batch(),
        p50_ms: percentile(&latencies, 50.0),
        p95_ms: percentile(&latencies, 95.0),
        p99_ms: percentile(&latencies, 99.0),
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        allocs_per_tick_steady: if steady_ticks == 0 {
            f64::NAN
        } else {
            steady_allocs as f64 / steady_ticks as f64
        },
    }
}

/// Wall-clock budget each deadline-scenario request carries.
const DEADLINE_MS: u64 = 4;

struct DeadlineReport {
    expired_rate: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    p95_overrun_ms: f64,
    wall_ms: f64,
}

/// Deadline discipline through the real hub: closed-loop sessions whose
/// every request carries a `DEADLINE_MS` budget. Time-to-result is the
/// wait until *either* the proposals or the scoped deadline error
/// arrives — the anytime contract says the latter lands within about
/// one scheduler tick of expiry, so the overrun percentile is the
/// bound under test. Distinct random molecules defeat the expansion
/// cache (every request pays real decode work).
fn run_deadline(sessions: usize) -> DeadlineReport {
    let mut rng = Rng::new(0xDEAD ^ sessions as u64);
    let work: Vec<Vec<String>> = (0..sessions)
        .map(|_| {
            (0..REQUESTS_PER_SESSION)
                .map(|_| {
                    let len = 4 + rng.gen_range(10);
                    (0..len).map(|_| ['C', 'C', 'C', 'O', 'N'][rng.gen_range(5)]).collect()
                })
                .collect()
        })
        .collect();
    let vocab = Vocab::build(work.iter().flatten().map(String::as_str));
    let hub = ExpansionHub::start(
        make_model(),
        Box::new(Msbs::default()),
        vocab,
        BatcherConfig {
            max_wait: std::time::Duration::from_micros(100),
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for mols in work {
        let hub = hub.clone();
        joins.push(std::thread::spawn(move || {
            let mut out: Vec<(f64, bool)> = Vec::new();
            for m in &mols {
                let issue = std::time::Instant::now();
                let d = issue + std::time::Duration::from_millis(DEADLINE_MS);
                let expired = match hub.submit_deadline(m, K, Some(d)) {
                    Ok(fut) => match fut.wait_deadline(d) {
                        Ok(_) => false,
                        Err(e) => format!("{e:#}").contains("deadline"),
                    },
                    Err(_) => false,
                };
                out.push((issue.elapsed().as_secs_f64() * 1e3, expired));
            }
            out
        }));
    }
    let mut lat: Vec<f64> = Vec::new();
    let mut overruns: Vec<f64> = Vec::new();
    for j in joins {
        for (ms, expired) in j.join().expect("session thread") {
            if expired {
                overruns.push((ms - DEADLINE_MS as f64).max(0.0));
            }
            lat.push(ms);
        }
    }
    DeadlineReport {
        expired_rate: overruns.len() as f64 / lat.len().max(1) as f64,
        p50_ms: percentile(&lat, 50.0),
        p95_ms: percentile(&lat, 95.0),
        p99_ms: percentile(&lat, 99.0),
        p95_overrun_ms: if overruns.is_empty() { 0.0 } else { percentile(&overruns, 95.0) },
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    }
}

fn main() {
    println!(
        "== concurrency bench (msbs, K={K}, {REQUESTS_PER_SESSION} requests/session, \
         device call {DEVICE_CALL_US}us) =="
    );
    let mut records = Vec::new();
    for sessions in [1usize, 4, 16, 64, 256] {
        let rg = run_request_granular(sessions);
        let cf = run_cycle_fused(sessions);
        let requests = (sessions * REQUESTS_PER_SESSION) as u64;
        for (name, r) in [("request-granular", &rg), ("cycle-fused", &cf)] {
            println!(
                "{name:<18} s={sessions:<3} calls {:>5}  encodes {:>4}  eff.batch {:>6.1}  \
                 p50 {:>7.2}ms  p95 {:>7.2}ms  p99 {:>7.2}ms  wall {:>8.1}ms",
                r.model_calls, r.encode_calls, r.avg_effective_batch, r.p50_ms, r.p95_ms,
                r.p99_ms, r.wall_ms
            );
            let mut rec = BenchRecord::new(format!("{name}-s{sessions}"))
                .metric("sessions", sessions as f64)
                .metric("model_calls", r.model_calls as f64)
                .metric("encode_calls", r.encode_calls as f64)
                .metric("encode_calls_per_request", r.encode_calls as f64 / requests as f64)
                .metric("avg_effective_batch", r.avg_effective_batch)
                .metric("p50_ms", r.p50_ms)
                .metric("p95_ms", r.p95_ms)
                .metric("p99_ms", r.p99_ms)
                .metric("wall_ms", r.wall_ms);
            if r.allocs_per_tick_steady.is_finite() {
                rec = rec.metric("allocs_per_tick_steady", r.allocs_per_tick_steady);
            }
            records.push(rec);
        }
        if sessions == 16 {
            let fewer = cf.model_calls < rg.model_calls;
            let batch_x = cf.avg_effective_batch / rg.avg_effective_batch.max(1e-9);
            println!(
                "  -> at 16 sessions: fused calls {} vs {} ({}), effective batch {:.2}x; \
                 {} encodes for {requests} requests (admission fused; see \
                 BENCH_encode_fusion.json for the fan-in sweep)",
                cf.model_calls,
                rg.model_calls,
                if fewer { "fewer" } else { "NOT fewer" },
                batch_x,
                cf.encode_calls
            );
        }
        if sessions == 64 {
            println!(
                "  -> 64/256-session rows: single-scheduler reference for the \
                 shard/replica sweep in BENCH_sharded.json"
            );
        }
    }
    let path = std::path::Path::new("BENCH_concurrency.json");
    match write_bench_json(path, "concurrency", &records) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }

    println!("== deadline scenario ({DEADLINE_MS}ms budget per request) ==");
    let mut dl_records = Vec::new();
    for sessions in [1usize, 4, 16, 64, 256] {
        let r = run_deadline(sessions);
        println!(
            "deadline           s={sessions:<3} expired {:>5.1}%  p50 {:>7.2}ms  \
             p95 {:>7.2}ms  p99 {:>7.2}ms  p95 overrun {:>6.2}ms  wall {:>8.1}ms",
            r.expired_rate * 100.0,
            r.p50_ms,
            r.p95_ms,
            r.p99_ms,
            r.p95_overrun_ms,
            r.wall_ms
        );
        dl_records.push(
            BenchRecord::new(format!("deadline-s{sessions}"))
                .metric("sessions", sessions as f64)
                .metric("deadline_ms", DEADLINE_MS as f64)
                .metric("expired_rate", r.expired_rate)
                .metric("p50_ms", r.p50_ms)
                .metric("p95_ms", r.p95_ms)
                .metric("p99_ms", r.p99_ms)
                .metric("p95_overrun_ms", r.p95_overrun_ms)
                .metric("wall_ms", r.wall_ms),
        );
    }
    let dpath = std::path::Path::new("BENCH_deadline.json");
    match write_bench_json(dpath, "deadline", &dl_records) {
        Ok(()) => println!("wrote {}", dpath.display()),
        Err(e) => eprintln!("failed to write {}: {e}", dpath.display()),
    }
}
