//! Decoding-engine scaling benches over the mock model: how host-side
//! cost (beam bookkeeping, draft construction, verification, candidate
//! pools) grows with beam width K and group size B, with model latency
//! held at ~0. Complements `benches/micro.rs`, which measures one fixed
//! workload and emits `BENCH_decoding.json`; this bench sweeps the
//! axes. Steady-state heap allocations per group are reported via a
//! counting global allocator — the zero-allocation decoding core should
//! keep them flat as K grows (the seed scaled with K * sequence length).

use retroserve::benchkit::{allocs_now, CountingAlloc};
use retroserve::decoding::{beam::BeamSearch, hsbs::Hsbs, msbs::Msbs, DecodeStats, Decoder};
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::tokenizer::{BOS, EOS};
use retroserve::util::stats::mean;
use retroserve::util::Rng;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn srcs(n: usize, len: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut s = vec![BOS];
            for _ in 0..len {
                s.push(4 + rng.gen_range(20) as i32);
            }
            s.push(EOS);
            s
        })
        .collect()
}

fn engines() -> Vec<(&'static str, Box<dyn Decoder>)> {
    vec![
        ("beam-search", Box::new(BeamSearch::vanilla()) as Box<dyn Decoder>),
        ("beam-search-optimized", Box::new(BeamSearch::optimized())),
        ("hsbs (3x10 drafts)", Box::new(Hsbs::new(3, 10))),
        ("msbs", Box::new(Msbs::default())),
    ]
}

fn sweep(label: &str, group: &[Vec<i32>], k: usize, reps: u64) {
    println!("-- {label} --");
    for (name, decoder) in engines() {
        let model = MockModel::new(MockConfig::default());
        // warmup: exclude one-time buffer growth from the steady state
        decoder.generate(&model, group, k, &mut DecodeStats::default()).unwrap();
        // pre-size harness buffers so they don't pollute the counter
        let mut times = Vec::with_capacity(reps as usize);
        let mut stats = DecodeStats::default();
        let a0 = allocs_now();
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            decoder.generate(&model, group, k, &mut stats).unwrap();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let allocs_per_group = (allocs_now() - a0) / reps;
        println!(
            "{name:<28} {:>9.2} ms/group  ({} calls, eff batch {:.0}, {} allocs/group)",
            mean(&times),
            stats.model_calls / reps,
            stats.avg_effective_batch(),
            allocs_per_group
        );
    }
}

fn main() {
    println!("== decoding engine scaling benches (mock model) ==");
    // K sweep at fixed B: host-side cost and allocations vs beam width.
    for k in [1usize, 5, 10, 20] {
        let group = srcs(4, 25, 3);
        sweep(&format!("B=4, len=25, K={k}"), &group, k, 8);
    }
    // B sweep at fixed K: group batching behaviour.
    for b in [1usize, 8, 16] {
        let group = srcs(b, 25, 7);
        sweep(&format!("B={b}, len=25, K=10"), &group, 10, 8);
    }
}
