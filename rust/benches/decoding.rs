//! Decoding-engine benches over the mock model: pure L3 algorithm cost
//! (beam bookkeeping, draft construction, verification, candidate
//! pools) with model latency held at ~0.

use retroserve::decoding::{beam::BeamSearch, hsbs::Hsbs, msbs::Msbs, DecodeStats, Decoder};
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::tokenizer::{BOS, EOS};
use retroserve::util::stats::mean;
use retroserve::util::Rng;

fn srcs(n: usize, len: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut s = vec![BOS];
            for _ in 0..len {
                s.push(4 + rng.gen_range(20) as i32);
            }
            s.push(EOS);
            s
        })
        .collect()
}

fn main() {
    println!("== decoding engine benches (mock model, K=10) ==");
    let model = MockModel::new(MockConfig::default());
    let group = srcs(8, 30, 3);
    for (name, decoder) in [
        ("beam-search", Box::new(BeamSearch::vanilla()) as Box<dyn Decoder>),
        ("beam-search-optimized", Box::new(BeamSearch::optimized())),
        ("hsbs (3x10 drafts)", Box::new(Hsbs::new(3, 10))),
        ("msbs", Box::new(Msbs::default())),
    ] {
        let mut times = Vec::new();
        let mut stats = DecodeStats::default();
        for _ in 0..12 {
            let t0 = std::time::Instant::now();
            decoder.generate(&model, &group, 10, &mut stats).unwrap();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        println!(
            "{name:<28} {:>9.2} ms/group  ({} calls, eff batch {:.0})",
            mean(&times),
            stats.model_calls / 12,
            stats.avg_effective_batch()
        );
    }
}
