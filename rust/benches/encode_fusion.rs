//! Encode-fusion bench: physical encoder calls per admission round
//! through the [`ExpansionHub`], at 1 / 4 / 16 / 64 / 256 co-submitting
//! sessions, with per-request time-to-result percentiles (p50/p95/p99)
//! alongside the counters.
//!
//! Workload: `WAVES` waves; in each wave every session submits ONE
//! distinct (cache-missing) molecule and all futures are awaited before
//! the next wave — the co-arrival shape multi-session serving produces
//! and the shape the fused-encode admission groups exist for. Before
//! this stage, every miss paid its own `StepModel::encode` call
//! (encoder calls = requests); with shared-encode admission, every
//! gather round pays exactly ONE (encoder calls = rounds), so at
//! fan-in N one call does the work of N.
//!
//! The mock model sleeps a fixed latency per encode *and* per decode
//! call so the amortization shows up in wall time, not just in the
//! counters. Reported per session count:
//!
//! * `encode_calls` (physical, from the hub counter) vs `requests`
//!   (what per-molecule encoding would have paid) — `fusion_x` is the
//!   ratio; the acceptance bar is >= 4x at 16 sessions;
//! * the one-call-per-round invariant (`encode_calls == encode_rounds`),
//!   printed as a PASS/VIOLATION check (CI runs this bench advisory).
//!
//! Emits `BENCH_encode_fusion.json`.

use retroserve::benchkit::{write_bench_json, BenchRecord, InstrumentedModel};
use retroserve::coordinator::batcher::{BatcherConfig, ExpansionHub};
use retroserve::decoding::msbs::Msbs;
use retroserve::metrics::Metrics;
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::tokenizer::Vocab;
use retroserve::util::stats::percentile;
use retroserve::util::Rng;
use std::sync::Arc;

/// Synthetic device latency per encoder call.
const ENCODE_CALL_US: u64 = 300;
/// Synthetic device latency per decode call.
const DEVICE_CALL_US: u64 = 200;
const WAVES: usize = 6;
const K: usize = 8;

/// Distinct pseudo-SMILES per (wave, session) so every request misses
/// the cache, plus a vocabulary covering them all.
fn workload(sessions: usize) -> (Vec<Vec<String>>, Vocab) {
    let mut rng = Rng::new(0xFACADE ^ sessions as u64);
    let mut seen = std::collections::HashSet::new();
    let alphabet = ['C', 'N', 'O'];
    let mut waves = Vec::with_capacity(WAVES);
    for _ in 0..WAVES {
        let mut wave = Vec::with_capacity(sessions);
        while wave.len() < sessions {
            let len = 6 + rng.gen_range(24);
            let s: String = (0..len).map(|_| alphabet[rng.gen_range(3)]).collect();
            if seen.insert(s.clone()) {
                wave.push(s);
            }
        }
        waves.push(wave);
    }
    let vocab = Vocab::build(waves.iter().flatten().map(String::as_str));
    (waves, vocab)
}

struct RunReport {
    requests: u64,
    encode_calls: u64,
    encode_rounds: u64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    wall_ms: f64,
}

fn run(sessions: usize) -> RunReport {
    let (waves, vocab) = workload(sessions);
    let hub = ExpansionHub::start(
        InstrumentedModel::new(MockModel::new(MockConfig {
            vocab: vocab.len(),
            ..Default::default()
        }))
        .with_encode_delay(std::time::Duration::from_micros(ENCODE_CALL_US))
        .with_decode_delay(std::time::Duration::from_micros(DEVICE_CALL_US)),
        Box::new(Msbs::default()),
        vocab,
        BatcherConfig {
            max_batch: 2 * sessions.max(8),
            max_wait: std::time::Duration::from_millis(3),
            max_rows: 4096,
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    let t0 = std::time::Instant::now();
    // One thread per co-submitting session within each wave, so every
    // request's time-to-result is measured at ITS completion rather
    // than behind a sequential wait loop.
    let mut lat: Vec<f64> = Vec::new();
    for wave in &waves {
        let joins: Vec<_> = wave
            .iter()
            .map(|m| {
                let hub = hub.clone();
                let m = m.clone();
                std::thread::spawn(move || {
                    let t = std::time::Instant::now();
                    hub.expand(&m, K).expect("expansion");
                    t.elapsed().as_secs_f64() * 1e3
                })
            })
            .collect();
        for j in joins {
            lat.push(j.join().expect("request thread"));
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let (encode_calls, encode_rounds) = hub.encode_ratio();
    RunReport {
        requests: (sessions * WAVES) as u64,
        encode_calls,
        encode_rounds,
        p50_ms: percentile(&lat, 50.0),
        p95_ms: percentile(&lat, 95.0),
        p99_ms: percentile(&lat, 99.0),
        wall_ms,
    }
}

fn main() {
    println!(
        "== encode fusion bench (msbs, K={K}, {WAVES} waves, encode {ENCODE_CALL_US}us, \
         decode {DEVICE_CALL_US}us) =="
    );
    let mut records = Vec::new();
    let mut all_ok = true;
    for sessions in [1usize, 4, 16, 64, 256] {
        let r = run(sessions);
        let fusion = r.requests as f64 / r.encode_calls.max(1) as f64;
        let per_round_ok = r.encode_calls <= r.encode_rounds;
        all_ok &= per_round_ok;
        println!(
            "sessions {sessions:<3} requests {:>4}  encode calls {:>3}  rounds {:>3}  \
             fusion {fusion:>5.1}x  p50 {:>7.2}ms  p95 {:>7.2}ms  p99 {:>7.2}ms  \
             wall {:>8.1}ms  one-call-per-round {}",
            r.requests,
            r.encode_calls,
            r.encode_rounds,
            r.p50_ms,
            r.p95_ms,
            r.p99_ms,
            r.wall_ms,
            if per_round_ok { "PASS" } else { "VIOLATION" }
        );
        records.push(
            BenchRecord::new(format!("encode-fusion-s{sessions}"))
                .metric("sessions", sessions as f64)
                .metric("requests", r.requests as f64)
                .metric("encode_calls", r.encode_calls as f64)
                .metric("encode_rounds", r.encode_rounds as f64)
                .metric("encode_calls_per_request", r.encode_calls as f64 / r.requests as f64)
                .metric("fusion_x", fusion)
                .metric("p50_ms", r.p50_ms)
                .metric("p95_ms", r.p95_ms)
                .metric("p99_ms", r.p99_ms)
                .metric("wall_ms", r.wall_ms),
        );
        if sessions == 16 {
            println!(
                "  -> at 16-session fan-in: {} encode calls for {} misses \
                 ({fusion:.1}x fewer; target >= 4x)",
                r.encode_calls, r.requests
            );
        }
    }
    println!(
        "encoder-calls-per-round invariant: {}",
        if all_ok { "PASS" } else { "VIOLATION" }
    );
    let path = std::path::Path::new("BENCH_encode_fusion.json");
    match write_bench_json(path, "encode-fusion", &records) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
}
