//! Micro benches for the L3 hot-path primitives (criterion is not
//! available offline; this is a minimal warmup+repeat harness with
//! mean/stddev reporting, run via `cargo bench`).

use retroserve::chem;
use retroserve::tokenizer::{tokenize, Vocab};
use retroserve::util::stats::{mean, stddev};
use retroserve::util::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..iters.min(10) {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    println!("{name:<44} {:>10.2} µs ± {:>8.2}", mean(&times), stddev(&times));
}

fn main() {
    println!("== micro benches (hot-path primitives) ==");
    let smiles = "CC(C)(C)OC(=O)NCCc1ccc(S(=O)(=O)NCC(=O)OCC)cc1";
    let mol = chem::parse_smiles(smiles).unwrap();
    let vocab = Vocab::build([smiles]);
    let ids = vocab.encode(smiles, true);

    bench("smiles parse (47 chars)", 2000, || {
        std::hint::black_box(chem::parse_smiles(smiles).unwrap());
    });
    bench("valence validate", 2000, || {
        std::hint::black_box(chem::valence::validate(&mol).unwrap());
    });
    bench("canonical ranks", 2000, || {
        std::hint::black_box(chem::canon::canonical_ranks(&mol));
    });
    bench("canonical smiles (full)", 2000, || {
        std::hint::black_box(chem::canonical_smiles(&mol));
    });
    bench("canonicalize end-to-end", 1000, || {
        std::hint::black_box(chem::canonicalize(smiles).unwrap());
    });
    bench("tokenize", 5000, || {
        std::hint::black_box(tokenize(smiles));
    });
    bench("vocab encode+decode", 5000, || {
        let e = vocab.encode(smiles, true);
        std::hint::black_box(vocab.decode(&e));
    });
    std::hint::black_box(&ids);

    // template application
    bench("find_disconnections", 2000, || {
        std::hint::black_box(retroserve::synthchem::find_disconnections(&mol));
    });
    let ds = retroserve::synthchem::find_disconnections(&mol);
    bench("apply_retro (first site)", 2000, || {
        std::hint::black_box(retroserve::synthchem::apply_retro(&mol, &ds[0]));
    });

    // nucleus verification math
    let mut rng = Rng::new(1);
    let logits: Vec<f32> = (0..26).map(|_| rng.gen_f64() as f32 * 8.0).collect();
    bench("softmax+log_softmax (V=26)", 5000, || {
        std::hint::black_box(retroserve::model::softmax(&logits));
        std::hint::black_box(retroserve::model::log_softmax(&logits));
    });
}
