//! Micro benches for the L3 hot-path primitives (criterion is not
//! available offline; this is a minimal warmup+repeat harness with
//! mean/stddev reporting, run via `cargo bench`).
//!
//! The decode-cycle section measures the host-side cost of every
//! decoding engine over the mock model (model latency ~0, so this
//! isolates beam bookkeeping, scoring, candidate pools) and emits
//! `BENCH_decoding.json` with tokens/sec, model calls and a heap
//! allocations-per-cycle proxy from the counting global allocator.

use retroserve::benchkit::{
    allocs_now, write_bench_json, BenchRecord, CountingAlloc, InstrumentedModel,
};
use retroserve::chem;
use retroserve::decoding::{beam::BeamSearch, hsbs::Hsbs, msbs::Msbs, DecodeStats, Decoder};
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::tokenizer::{tokenize, Vocab, BOS, EOS};
use retroserve::util::stats::{mean, stddev};
use retroserve::util::Rng;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..iters.min(10) {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    println!("{name:<44} {:>10.2} µs ± {:>8.2}", mean(&times), stddev(&times));
}

fn rand_srcs(n: usize, len: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut s = vec![BOS];
            for _ in 0..len {
                s.push(4 + rng.gen_range(20) as i32);
            }
            s.push(EOS);
            s
        })
        .collect()
}

/// Decode-cycle benchmark over the mock model: wall time, model calls,
/// generated tokens/sec, steady-state allocations per decode cycle
/// (model-call cost held constant by the mock), and the incremental
/// decode protocol's headline number — decoder positions processed per
/// generated token, against the full-prefix path's O(prefix) charge.
fn bench_decode_cycles() -> Vec<BenchRecord> {
    println!("== decode-cycle benches (mock model, B=8, K=10) ==");
    let group = rand_srcs(8, 30, 3);
    let k = 10;
    let reps = 12usize;
    let mut records = Vec::new();
    for (name, decoder) in [
        ("beam-search", Box::new(BeamSearch::vanilla()) as Box<dyn Decoder>),
        ("beam-search-optimized", Box::new(BeamSearch::optimized())),
        ("hsbs-3x10", Box::new(Hsbs::new(3, 10))),
        ("msbs", Box::new(Msbs::default())),
    ] {
        // One fresh model per engine so mock handle ids (and therefore
        // Medusa corruption patterns) are identical across engines.
        let model = MockModel::new(MockConfig::default());
        // warmup
        let mut warm = DecodeStats::default();
        decoder.generate(&model, &group, k, &mut warm).unwrap();

        let mut times = Vec::with_capacity(reps);
        let mut stats = DecodeStats::default();
        let mut gen_tokens = 0u64;
        let a0 = allocs_now();
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let out = decoder.generate(&model, &group, k, &mut stats).unwrap();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
            gen_tokens += out
                .iter()
                .flat_map(|g| g.hyps.iter())
                .map(|h| h.tokens.len() as u64)
                .sum::<u64>();
        }
        let allocs = allocs_now() - a0;
        let ms = mean(&times);
        let calls = stats.model_calls / reps as u64;
        // Cycles: one decode call per cycle for BS/HSBS, two for MSBS.
        let cycles = if name == "msbs" { calls / 2 } else { calls };
        let allocs_per_cycle = allocs as f64 / (cycles.max(1) * reps as u64) as f64;
        let toks_per_sec = gen_tokens as f64 / (ms * 1e-3 * reps as f64);
        // Full-prefix reference for the same workload: capability
        // forced off, so every row resends its whole prefix. Mirror the
        // measured run's shape exactly (one warmup + `reps` repeats) so
        // the mock's handle-id-keyed Medusa corruption — and therefore
        // draft acceptance and prefix lengths — match row for row.
        let full_model =
            InstrumentedModel::new(MockModel::new(MockConfig::default())).with_incremental(false);
        let mut full_warm = DecodeStats::default();
        decoder.generate(&full_model, &group, k, &mut full_warm).unwrap();
        let mut full_stats = DecodeStats::default();
        for _ in 0..reps {
            decoder.generate(&full_model, &group, k, &mut full_stats).unwrap();
        }
        let decode_tokens = stats.decode_tokens / reps as u64;
        let per_gen = stats.decode_tokens as f64 / gen_tokens.max(1) as f64;
        let full_per_gen = full_stats.decode_tokens as f64 / gen_tokens.max(1) as f64;
        println!(
            "{name:<24} {ms:>9.3} ms/group  {calls:>4} calls  \
             {allocs_per_cycle:>8.1} allocs/cycle  {toks_per_sec:>12.0} tok/s  \
             {per_gen:>6.2} dec-tok/gen (full-prefix {full_per_gen:>7.2})"
        );
        records.push(
            BenchRecord::new(name)
                .metric("ms_per_group", ms)
                .metric("model_calls", calls as f64)
                .metric("tokens_per_sec", toks_per_sec)
                .metric("allocs_per_cycle", allocs_per_cycle)
                .metric("avg_effective_batch", stats.avg_effective_batch())
                .metric("decode_tokens", decode_tokens as f64)
                .metric("decode_tokens_per_gen", per_gen)
                .metric(
                    "fullprefix_decode_tokens",
                    (full_stats.decode_tokens / reps as u64) as f64,
                ),
        );
    }
    records
}

fn main() {
    println!("== micro benches (hot-path primitives) ==");
    let smiles = "CC(C)(C)OC(=O)NCCc1ccc(S(=O)(=O)NCC(=O)OCC)cc1";
    let mol = chem::parse_smiles(smiles).unwrap();
    let vocab = Vocab::build([smiles]);
    let ids = vocab.encode(smiles, true);

    bench("smiles parse (47 chars)", 2000, || {
        std::hint::black_box(chem::parse_smiles(smiles).unwrap());
    });
    bench("valence validate", 2000, || {
        std::hint::black_box(chem::valence::validate(&mol).unwrap());
    });
    bench("canonical ranks", 2000, || {
        std::hint::black_box(chem::canon::canonical_ranks(&mol));
    });
    bench("canonical smiles (full)", 2000, || {
        std::hint::black_box(chem::canonical_smiles(&mol));
    });
    bench("canonicalize end-to-end", 1000, || {
        std::hint::black_box(chem::canonicalize(smiles).unwrap());
    });
    bench("tokenize", 5000, || {
        std::hint::black_box(tokenize(smiles));
    });
    bench("vocab encode+decode", 5000, || {
        let e = vocab.encode(smiles, true);
        std::hint::black_box(vocab.decode(&e));
    });
    std::hint::black_box(&ids);

    // template application
    bench("find_disconnections", 2000, || {
        std::hint::black_box(retroserve::synthchem::find_disconnections(&mol));
    });
    let ds = retroserve::synthchem::find_disconnections(&mol);
    bench("apply_retro (first site)", 2000, || {
        std::hint::black_box(retroserve::synthchem::apply_retro(&mol, &ds[0]));
    });

    // nucleus verification math
    let mut rng = Rng::new(1);
    let logits: Vec<f32> = (0..26).map(|_| rng.gen_f64() as f32 * 8.0).collect();
    bench("softmax+log_softmax (V=26)", 5000, || {
        std::hint::black_box(retroserve::model::softmax(&logits));
        std::hint::black_box(retroserve::model::log_softmax(&logits));
    });
    let mut scratch = retroserve::model::scratch::ScoringScratch::new();
    bench("scratch top_k_log_softmax (V=26,k=10)", 5000, || {
        scratch.top_k_log_softmax(&logits, 10);
        std::hint::black_box(scratch.topk.len());
    });
    bench("fused nucleus_mass_before (V=26)", 5000, || {
        std::hint::black_box(retroserve::model::scratch::nucleus_mass_before(&logits, 3));
    });

    // decoding engines end-to-end (host-side cost only)
    let records = bench_decode_cycles();
    let path = std::path::Path::new("BENCH_decoding.json");
    match write_bench_json(path, "decoding-micro", &records) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
}
