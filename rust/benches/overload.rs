//! Overload bench: the admission-control / degradation ladder under
//! 1x / 2x / 4x saturation.
//!
//! A real TCP server over the mock model (fixed decode delay, so
//! capacity is known: `SHARDS x MAX_BATCH` concurrent sessions is the
//! spill-path saturation point, load score 1.0). Closed-loop sessions
//! each issue a chain of interactive plans, issuing the next the moment
//! the previous answers. At 1x the ladder should stay quiet; at 2x and
//! 4x the queue watermark sheds and the degradation ladder clamps —
//! what this bench measures is that the *answered* interactive p95
//! stays bounded while the shed rate absorbs the excess.
//!
//! Hard invariants (exit 1 on breach, so CI can gate on the binary):
//! every request gets exactly one structured terminal answer — an
//! admitted plan or an `overloaded` shed with its retry hint — and no
//! transport error or hang appears at any load.
//!
//! Emits `BENCH_overload.json`.

use retroserve::benchkit::{write_bench_json, BenchRecord, CountingAlloc, InstrumentedModel};
use retroserve::coordinator::batcher::{BatcherConfig, ExpansionHub};
use retroserve::coordinator::overload::{OverloadConfig, OverloadController};
use retroserve::coordinator::server::{Client, Server, ServerCtx};
use retroserve::decoding::msbs::Msbs;
use retroserve::jsonx::Json;
use retroserve::metrics::Metrics;
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::search::{SearchLimits, Stock};
use retroserve::tokenizer::Vocab;
use retroserve::util::stats::percentile;
use retroserve::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Molecules the mock's copy task can expand.
const POOL: [&str; 3] = ["CC(=O)NC", "CC(=O)O.CN", "CCO"];
/// Hub geometry: capacity = SHARDS x MAX_BATCH sessions.
const SHARDS: usize = 2;
const MAX_BATCH: usize = 8;
const CAPACITY: usize = SHARDS * MAX_BATCH;
/// Synthetic device latency per decode call.
const DEVICE_CALL_US: u64 = 400;
/// Plans each session issues, back to back.
const REQUESTS_PER_SESSION: usize = 5;
/// Per-plan wall budget (anytime answers keep the loop tight).
const DEADLINE_MS: u64 = 50;

struct LoadReport {
    sessions: usize,
    requests: usize,
    answered: usize,
    shed: usize,
    degraded: usize,
    transport_errors: usize,
    p50_ms: f64,
    p95_ms: f64,
    wall_ms: f64,
}

fn start_server() -> (Server, Arc<ExpansionHub>) {
    let vocab = Vocab::build(POOL);
    let model = InstrumentedModel::new(MockModel::new(MockConfig {
        vocab: vocab.len(),
        ..Default::default()
    }))
    .with_decode_delay(Duration::from_micros(DEVICE_CALL_US));
    let hub = ExpansionHub::start(
        model,
        Box::new(Msbs::default()),
        vocab,
        BatcherConfig {
            max_batch: MAX_BATCH,
            max_wait: Duration::from_micros(200),
            shards: SHARDS,
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    let server = Server::start(
        "127.0.0.1:0",
        ServerCtx {
            hub: hub.clone(),
            stock: Arc::new(Stock::new()),
            metrics: Arc::new(Metrics::new()),
            default_limits: SearchLimits {
                deadline: Duration::from_millis(DEADLINE_MS),
                max_iterations: 12,
                max_depth: 3,
                expansions_per_step: 4,
                ..Default::default()
            },
            default_algo: "retrostar".into(),
            default_beam_width: 2,
            default_spec_depth: 1,
            default_spec_adaptive: false,
            default_spec_max: 8,
            screen: Default::default(),
            overload: Arc::new(OverloadController::new(OverloadConfig {
                // Shed once the backlog is twice the spill-path
                // capacity; degrade earlier via the default watermarks.
                max_queue: 2 * CAPACITY,
                retry_after_ms: 5,
                degraded_beam: 1,
                degraded_deadline_ms: DEADLINE_MS / 2,
                ..Default::default()
            })),
            store: None,
        },
    )
    .expect("server start");
    (server, hub)
}

fn run_load(sessions: usize) -> LoadReport {
    let (server, _hub) = start_server();
    let addr = server.addr();
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for t in 0..sessions as u64 {
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t ^ 0x0E71);
            // (latency_ms, answered, shed, degraded, transport_error)
            let mut out: Vec<(f64, bool, bool, bool, bool)> = Vec::new();
            let mut client = Client::connect_retry(addr, 10).ok();
            for _ in 0..REQUESTS_PER_SESSION {
                let Some(c) = client.as_mut() else {
                    out.push((0.0, false, false, false, true));
                    continue;
                };
                let issue = Instant::now();
                match c.call(Json::obj(vec![
                    ("op", Json::str("plan")),
                    ("smiles", Json::str(POOL[rng.gen_range(POOL.len())])),
                ])) {
                    Ok(r) => {
                        let ms = issue.elapsed().as_secs_f64() * 1e3;
                        let ok = r.get("ok").and_then(|x| x.as_bool()) == Some(true);
                        let shed =
                            r.get("code").and_then(|x| x.as_str()) == Some("overloaded");
                        let degraded =
                            r.get("degraded").and_then(|x| x.as_bool()) == Some(true);
                        // A shed without its retry hint is a protocol
                        // bug; count it as unanswered so CI fails. Any
                        // other structured reply (an admitted plan or a
                        // scoped error) counts as answered.
                        let hinted = !shed
                            || r.get("retry_after_ms").and_then(|x| x.as_usize()).is_some();
                        let answered = !shed
                            && (ok || r.get("error").and_then(|x| x.as_str()).is_some());
                        out.push((ms, answered, shed && hinted, degraded, shed && !hinted));
                    }
                    Err(_) => {
                        out.push((0.0, false, false, false, true));
                        client = None;
                    }
                }
            }
            out
        }));
    }
    let (mut answered, mut shed, mut degraded, mut transport_errors) = (0, 0, 0, 0);
    let mut requests = 0usize;
    let mut lat: Vec<f64> = Vec::new();
    for j in joins {
        for (ms, ok, sh, dg, err) in j.join().expect("session thread") {
            requests += 1;
            if ok {
                answered += 1;
                lat.push(ms);
            }
            if sh {
                shed += 1;
            }
            if dg {
                degraded += 1;
            }
            if err {
                transport_errors += 1;
            }
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    server.shutdown();
    LoadReport {
        sessions,
        requests,
        answered,
        shed,
        degraded,
        transport_errors,
        p50_ms: percentile(&lat, 50.0),
        p95_ms: percentile(&lat, 95.0),
        wall_ms,
    }
}

fn main() {
    println!(
        "== overload bench (capacity {CAPACITY} sessions, {REQUESTS_PER_SESSION} \
         plans/session, {DEADLINE_MS}ms deadline, device call {DEVICE_CALL_US}us) =="
    );
    let mut records = Vec::new();
    let mut breached = false;
    for mult in [1usize, 2, 4] {
        let sessions = mult * CAPACITY;
        let r = run_load(sessions);
        let shed_rate = r.shed as f64 / r.requests.max(1) as f64;
        let degraded_rate = r.degraded as f64 / r.requests.max(1) as f64;
        println!(
            "load {mult}x s={sessions:<3} answered {:>3}/{:<3} shed {:>5.1}%  \
             degraded {:>5.1}%  p50 {:>7.2}ms  p95 {:>7.2}ms  wall {:>8.1}ms",
            r.answered,
            r.requests,
            shed_rate * 100.0,
            degraded_rate * 100.0,
            r.p50_ms,
            r.p95_ms,
            r.wall_ms
        );
        // Zero-hang / all-answered invariants: every request must come
        // back as an admitted answer or a hinted shed, promptly.
        if r.transport_errors > 0 || r.answered + r.shed != r.requests {
            eprintln!(
                "INVARIANT BREACH at {mult}x: {} transport errors, \
                 {} answered + {} shed != {} requests",
                r.transport_errors, r.answered, r.shed, r.requests
            );
            breached = true;
        }
        let wall_cap_ms = (REQUESTS_PER_SESSION as f64) * (DEADLINE_MS as f64) * 40.0;
        if r.wall_ms > wall_cap_ms {
            eprintln!("INVARIANT BREACH at {mult}x: wall {}ms > {}ms", r.wall_ms, wall_cap_ms);
            breached = true;
        }
        records.push(
            BenchRecord::new(format!("overload-{mult}x"))
                .metric("sessions", r.sessions as f64)
                .metric("requests", r.requests as f64)
                .metric("shed_rate", shed_rate)
                .metric("degraded_rate", degraded_rate)
                .metric("p50_ms", r.p50_ms)
                .metric("p95_ms", r.p95_ms)
                .metric("wall_ms", r.wall_ms),
        );
    }
    let path = std::path::Path::new("BENCH_overload.json");
    match write_bench_json(path, "overload", &records) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
    if breached {
        std::process::exit(1);
    }
}
