//! High-throughput screening bench: one 256-target [`ScreeningJob`]
//! with cross-target intermediate overlap vs 256 solo plans, plus the
//! interactive-latency protection check.
//!
//! The synthetic library is a forest of depth-2 routes: every target
//! expands into two intermediates and every intermediate expands into
//! stock leaves. With probability `--overlap` (default 0.75) an
//! intermediate is drawn from a small shared pool — the screening
//! job's sharing opportunity: a shared intermediate decoded for one
//! target serves every later target from the hub's expansion cache or
//! by joining the in-flight decode. The scripted model sleeps a fixed
//! latency per encode and per fused decode call, so device work
//! dominates and decode-task counts are the cost measure.
//!
//! Four scenarios:
//!
//! 1. **solo** — every target planned on its OWN fresh hub (nothing
//!    shared), the per-target baseline the paper's screening numbers
//!    multiply out; total per-query decode tasks are summed.
//! 2. **job** — the same targets as ONE `ScreeningJob` over a shared
//!    2-shard / 2-replica hub at `--concurrency` (default 16).
//! 3. **interactive baseline** — sequential interactive plans on an
//!    otherwise idle hub; per-plan p95.
//! 4. **mixed** — the SAME interactive plans while the screening job
//!    runs on the same hub; batch-class admission must keep them fast.
//!
//! Printed invariants (the acceptance bar; nonzero exit on violation):
//! the job issues strictly FEWER total decode tasks than the solo
//! sweep (needs `--overlap` > 0 — at 0 every intermediate is private
//! and the two are equal by construction), and mixed interactive p95
//! stays within 15% of the no-job baseline.
//!
//! Emits `BENCH_screening.json`.

use retroserve::benchkit::{write_bench_json, BenchRecord, Flags, InstrumentedModel};
use retroserve::coordinator::batcher::{BatchedPolicy, BatcherConfig, ExpansionHub};
use retroserve::decoding::msbs::Msbs;
use retroserve::metrics::Metrics;
use retroserve::model::scripted::{smiles_vocab, Script, ScriptedModel};
use retroserve::model::{PooledModel, ReplicaPool};
use retroserve::search::retrostar::RetroStar;
use retroserve::search::{ScreenConfig, ScreenSummary, ScreeningJob, SearchLimits, Stock};
use retroserve::tokenizer::Vocab;
use retroserve::util::stats::percentile;
use retroserve::util::Rng;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Synthetic device latency per encoder call.
const ENCODE_CALL_US: u64 = 200;
/// Synthetic device latency per fused decode call.
const DEVICE_CALL_US: u64 = 150;
/// Shared-pool size the overlap knob draws intermediates from.
const SHARED_POOL: usize = 32;
/// Interactive plans per latency scenario.
const INTERACTIVE_PLANS: usize = 32;

struct World {
    targets: Vec<String>,
    interactive: Vec<String>,
    /// Canonical molecule -> its one scripted retro proposal.
    script: Arc<HashMap<String, String>>,
    vocab: Vocab,
    stock: Arc<Stock>,
}

/// A fresh canonical chain molecule never handed out before.
fn fresh(rng: &mut Rng, seen: &mut HashSet<String>, base: usize, spread: usize) -> String {
    let alphabet = ['C', 'N', 'O'];
    loop {
        let len = base + rng.gen_range(spread);
        let s: String = (0..len).map(|_| alphabet[rng.gen_range(3)]).collect();
        match retroserve::chem::canonicalize(&s) {
            Ok(c) if seen.insert(c.clone()) => return c,
            _ => {}
        }
    }
}

fn gen_world(n_targets: usize, overlap: f64) -> World {
    let mut rng = Rng::new(0x5C12_EE00 ^ n_targets as u64);
    let mut seen: HashSet<String> = HashSet::new();
    let cc = retroserve::chem::canonicalize("CC").unwrap();
    let co = retroserve::chem::canonicalize("CO").unwrap();
    let leaves = format!("{cc}.{co}");
    seen.insert(cc.clone());
    seen.insert(co.clone());

    let shared: Vec<String> =
        (0..SHARED_POOL).map(|_| fresh(&mut rng, &mut seen, 8, 6)).collect();
    let mut script: HashMap<String, String> = HashMap::new();
    for m in &shared {
        script.insert(m.clone(), leaves.clone());
    }

    let roll = (overlap.clamp(0.0, 1.0) * 1000.0) as usize;
    let mut targets = Vec::with_capacity(n_targets);
    for _ in 0..n_targets {
        let t = fresh(&mut rng, &mut seen, 14, 8);
        let mut pair = Vec::with_capacity(2);
        for _ in 0..2 {
            let m = if rng.gen_range(1000) < roll {
                shared[rng.gen_range(SHARED_POOL)].clone()
            } else {
                let p = fresh(&mut rng, &mut seen, 8, 6);
                script.insert(p.clone(), leaves.clone());
                p
            };
            pair.push(m);
        }
        script.insert(t.clone(), format!("{}.{}", pair[0], pair[1]));
        targets.push(t);
    }

    // Interactive queries use PRIVATE intermediates: no sharing with the
    // job, so the mixed scenario measures pure scheduling interference.
    let mut interactive = Vec::with_capacity(INTERACTIVE_PLANS);
    for _ in 0..INTERACTIVE_PLANS {
        let t = fresh(&mut rng, &mut seen, 14, 8);
        let a = fresh(&mut rng, &mut seen, 8, 6);
        let b = fresh(&mut rng, &mut seen, 8, 6);
        script.insert(a.clone(), leaves.clone());
        script.insert(b.clone(), leaves.clone());
        script.insert(t.clone(), format!("{a}.{b}"));
        interactive.push(t);
    }

    let mut corpus: Vec<&str> = Vec::with_capacity(script.len() * 2);
    for (k, v) in &script {
        corpus.push(k);
        corpus.push(v);
    }
    let vocab = smiles_vocab(corpus);
    World {
        targets,
        interactive,
        script: Arc::new(script),
        vocab,
        stock: Arc::new(Stock::from_iter([cc, co])),
    }
}

fn hub(world: &World, shards: usize, replicas: usize) -> Arc<ExpansionHub> {
    let models: Vec<PooledModel> = (0..replicas)
        .map(|_| {
            let map = world.script.clone();
            let script: Script =
                Box::new(move |p| map.get(p).map(|r| vec![(r.clone(), -0.5)]).unwrap_or_default());
            Arc::new(
                InstrumentedModel::new(ScriptedModel::new(world.vocab.clone(), script))
                    .with_encode_delay(Duration::from_micros(ENCODE_CALL_US))
                    .with_decode_delay(Duration::from_micros(DEVICE_CALL_US)),
            ) as PooledModel
        })
        .collect();
    ExpansionHub::start_pool(
        ReplicaPool::from_models(models),
        Box::new(Msbs::default()),
        world.vocab.clone(),
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            shards,
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    )
}

fn limits() -> SearchLimits {
    SearchLimits {
        deadline: Duration::from_secs(30),
        max_depth: 6,
        expansions_per_step: 4,
        ..Default::default()
    }
}

struct SoloReport {
    solved: usize,
    decode_tasks: u64,
    requests: u64,
    decode_tokens: u64,
    wall_ms: f64,
}

/// Every target on its own fresh single-shard hub: no cache, no dedup,
/// no co-batching across targets — the per-target cost multiplied out.
fn run_solo(world: &World) -> SoloReport {
    let planner = RetroStar::new(1).with_spec_depth(1);
    let lim = limits();
    let t0 = Instant::now();
    let (mut tasks, mut requests, mut tokens) = (0u64, 0u64, 0u64);
    let mut solved = 0usize;
    for t in &world.targets {
        let h = hub(world, 1, 1);
        let policy = BatchedPolicy::new(h.clone());
        let r = planner.solve_pipelined(t, &policy, &world.stock, &lim).expect("solo plan");
        assert!(r.solved, "every solo target is solvable by construction ({t})");
        solved += 1;
        let (dt, req) = h.merge_ratio();
        tasks += dt;
        requests += req;
        tokens += h.stats().decode_tokens;
    }
    SoloReport {
        solved,
        decode_tasks: tasks,
        requests,
        decode_tokens: tokens,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    }
}

fn screen_cfg(concurrency: usize) -> ScreenConfig {
    ScreenConfig {
        concurrency,
        job_deadline: None,
        job_decode_tokens: 0,
        beam_width: 1,
        spec_depth: 1,
        spec_adaptive: false,
        limits: limits(),
    }
}

fn run_job(world: &World, concurrency: usize) -> ScreenSummary {
    let h = hub(world, 2, 2);
    let job = ScreeningJob::new(screen_cfg(concurrency));
    let metrics = Metrics::new();
    let mut streamed = 0usize;
    let summary = job
        .run(&h, &world.stock, &world.targets, &metrics, &mut |_r| streamed += 1)
        .expect("screening job");
    assert_eq!(streamed, world.targets.len(), "every target streams exactly one result");
    summary
}

/// Sequential interactive plans; returns per-plan latencies (ms).
fn drive_interactive(h: &Arc<ExpansionHub>, world: &World) -> Vec<f64> {
    let planner = RetroStar::new(1).with_spec_depth(1);
    let lim = limits();
    world
        .interactive
        .iter()
        .map(|t| {
            let policy = BatchedPolicy::new(h.clone());
            let t0 = Instant::now();
            let r = planner
                .solve_pipelined(t, &policy, &world.stock, &lim)
                .expect("interactive plan");
            assert!(r.solved, "every interactive target is solvable by construction");
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect()
}

/// Interactive plans with the screening job live on the SAME hub.
/// Returns (latencies, job summary, job still running when the last
/// interactive plan finished).
fn run_mixed(world: &Arc<World>, concurrency: usize) -> (Vec<f64>, ScreenSummary, bool) {
    let h = hub(world, 2, 2);
    let done = Arc::new(AtomicBool::new(false));
    let (jw, jh, jdone) = (world.clone(), h.clone(), done.clone());
    let job_thread = std::thread::spawn(move || {
        let job = ScreeningJob::new(screen_cfg(concurrency));
        let metrics = Metrics::new();
        let s = job
            .run(&jh, &jw.stock, &jw.targets, &metrics, &mut |_| {})
            .expect("background screening job");
        jdone.store(true, Ordering::SeqCst);
        s
    });
    // Let the job occupy the hub before the first interactive arrival.
    std::thread::sleep(Duration::from_millis(10));
    let lat = drive_interactive(&h, world);
    let overlapped = !done.load(Ordering::SeqCst);
    let summary = job_thread.join().expect("job thread");
    (lat, summary, overlapped)
}

fn main() {
    let flags = Flags::parse();
    let n_targets = flags.usize_or("targets", 256);
    let overlap = flags.f64_or("overlap", 0.75);
    let concurrency = flags.usize_or("concurrency", 16);
    println!(
        "== screening bench ({n_targets} targets, overlap {overlap:.2}, \
         job concurrency {concurrency}, encode {ENCODE_CALL_US}us, \
         decode {DEVICE_CALL_US}us per fused call) =="
    );
    let world = Arc::new(gen_world(n_targets, overlap));
    let mut records = Vec::new();

    let solo = run_solo(&world);
    println!(
        "solo         {} plans  decode tasks {:>5}  requests {:>5}  tokens {:>7}  \
         wall {:>8.1}ms",
        solo.solved, solo.decode_tasks, solo.requests, solo.decode_tokens, solo.wall_ms
    );
    records.push(
        BenchRecord::new("solo")
            .metric("targets", n_targets as f64)
            .metric("solved", solo.solved as f64)
            .metric("decode_tasks", solo.decode_tasks as f64)
            .metric("requests", solo.requests as f64)
            .metric("decode_tokens", solo.decode_tokens as f64)
            .metric("wall_ms", solo.wall_ms),
    );

    let job = run_job(&world, concurrency);
    let solved_per_sec = job.solved as f64 / job.wall_secs.max(1e-9);
    println!(
        "job          {}/{} solved  decode tasks {:>5}  requests {:>5}  dedup joins {:>4}  \
         cache-hit {:>5.1}%  tokens/solved {:>7.1}  {solved_per_sec:>6.1} solved/s  \
         wall {:>8.1}ms",
        job.solved,
        job.targets,
        job.decode_tasks,
        job.requests,
        job.dedup_joins,
        job.cache_hit_rate * 100.0,
        job.tokens_per_solved,
        job.wall_secs * 1e3
    );
    records.push(
        BenchRecord::new("job")
            .metric("targets", job.targets as f64)
            .metric("solved", job.solved as f64)
            .metric("overlap", overlap)
            .metric("concurrency", concurrency as f64)
            .metric("decode_tasks", job.decode_tasks as f64)
            .metric("requests", job.requests as f64)
            .metric("dedup_joins", job.dedup_joins as f64)
            .metric("cache_hit_rate", job.cache_hit_rate)
            .metric("dedup_join_rate", job.dedup_join_rate)
            .metric("decode_tokens", job.decode_tokens as f64)
            .metric("tokens_per_solved", job.tokens_per_solved)
            .metric("solved_per_sec", solved_per_sec)
            .metric("wall_ms", job.wall_secs * 1e3),
    );

    let base_h = hub(&world, 2, 2);
    let base = drive_interactive(&base_h, &world);
    let (p50_base, p95_base) = (percentile(&base, 50.0), percentile(&base, 95.0));
    drop(base_h);
    println!(
        "interactive  {} plans (idle hub)        p50 {p50_base:>7.2}ms  p95 {p95_base:>7.2}ms",
        base.len()
    );
    records.push(
        BenchRecord::new("interactive-base")
            .metric("plans", base.len() as f64)
            .metric("p50_ms", p50_base)
            .metric("p95_ms", p95_base),
    );

    let (mixed, mixed_job, overlapped) = run_mixed(&world, concurrency);
    let (p50_mixed, p95_mixed) = (percentile(&mixed, 50.0), percentile(&mixed, 95.0));
    println!(
        "interactive  {} plans (concurrent job)  p50 {p50_mixed:>7.2}ms  \
         p95 {p95_mixed:>7.2}ms  (job solved {}/{}, {})",
        mixed.len(),
        mixed_job.solved,
        mixed_job.targets,
        if overlapped { "ran past the interactive phase" } else { "finished during it" }
    );
    records.push(
        BenchRecord::new("interactive-mixed")
            .metric("plans", mixed.len() as f64)
            .metric("p50_ms", p50_mixed)
            .metric("p95_ms", p95_mixed)
            .metric("job_solved", mixed_job.solved as f64)
            .metric("job_wall_ms", mixed_job.wall_secs * 1e3)
            .metric("job_overlapped_phase", overlapped as i32 as f64),
    );

    let sharing_ok = job.decode_tasks < solo.decode_tasks;
    let p95_ok = p95_mixed <= 1.15 * p95_base;
    println!(
        "  -> job vs solo decode tasks: {} vs {} ({})",
        job.decode_tasks,
        solo.decode_tasks,
        if sharing_ok { "strictly fewer: PASS" } else { "VIOLATION" }
    );
    println!(
        "  -> interactive p95 with job {p95_mixed:.2}ms vs baseline {p95_base:.2}ms \
         (limit {:.2}ms): {}",
        1.15 * p95_base,
        if p95_ok { "within 15%: PASS" } else { "VIOLATION" }
    );

    let path = std::path::Path::new("BENCH_screening.json");
    match write_bench_json(path, "screening", &records) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
    if !(sharing_ok && p95_ok) {
        eprintln!("screening invariant VIOLATION (see above)");
        std::process::exit(1);
    }
}
