//! Planner benches with the oracle policy: Retro* graph maintenance and
//! DFS traversal cost isolated from model latency.

use retroserve::chem;
use retroserve::search::policy::OraclePolicy;
use retroserve::search::{dfs::Dfs, retrostar::RetroStar, Planner, SearchLimits, Stock};
use retroserve::synthchem::blocks::generate_blocks;
use retroserve::synthchem::gen::{gen_tree, BlockIndex};
use retroserve::util::stats::mean;
use retroserve::util::Rng;

fn main() {
    println!("== planner benches (oracle policy) ==");
    let blocks = generate_blocks(71, 600);
    let stock = Stock::from_iter(blocks.iter().map(|b| b.smiles()).chain([
        chem::canonicalize(retroserve::synthchem::templates::BOC_REAGENT).unwrap(),
    ]));
    let idx = BlockIndex::new(blocks);
    let mut rng = Rng::new(5);
    let mut targets = Vec::new();
    while targets.len() < 20 {
        let depth = 2 + rng.gen_range(3);
        if let Some(t) = gen_tree(&idx, &mut rng, depth, 26) {
            targets.push(t.product_smiles().to_string());
        }
    }
    let limits = SearchLimits {
        deadline: std::time::Duration::from_secs(10),
        max_iterations: 200,
        max_depth: 5,
        expansions_per_step: 10,
        ..Default::default()
    };
    let mut records = Vec::new();
    for (name, planner) in [
        ("retro* bw=1", Box::new(RetroStar::new(1)) as Box<dyn Planner>),
        ("retro* bw=8", Box::new(RetroStar::new(8))),
        ("dfs", Box::new(Dfs)),
    ] {
        let policy = OraclePolicy::new();
        let mut times = Vec::new();
        let mut solved = 0;
        for t in &targets {
            let t0 = std::time::Instant::now();
            let r = planner.solve(t, &policy, &stock, &limits).unwrap();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
            solved += r.solved as usize;
        }
        println!(
            "{name:<14} {:>9.2} ms/target (solved {}/{})",
            mean(&times),
            solved,
            targets.len()
        );
        records.push(
            retroserve::benchkit::BenchRecord::new(name)
                .metric("ms_per_target", mean(&times))
                .metric("solved", solved as f64)
                .metric("targets", targets.len() as f64),
        );
    }
    let path = std::path::Path::new("BENCH_search.json");
    match retroserve::benchkit::write_bench_json(path, "search-oracle", &records) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
}
