//! Search-layer comparison: blocking Retro\* vs speculative pipelined
//! Retro\* over the SAME hub/scheduler serving stack, at **one**
//! planning session.
//!
//! This is the gap PR 2 left open: the scheduler fuses decode cycles
//! across sessions, but a solo blocking session keeps exactly one
//! per-query task in flight, so every scheduler tick carries one task's
//! rows (effective batch ≈ 1) and the tick count per solved molecule is
//! the full serial sum of decode cycles. Speculative mode
//! (`spec_depth = 4`) keeps the top-1 frontier expansion plus three
//! next-best speculative expansions in flight as per-query futures, so
//! one fused tick advances up to four expansions — the headline metric
//! is **scheduler ticks per solved molecule**, which speculation should
//! cut by ≥ 2x on this workload.
//!
//! The model is a [`ScriptedModel`] replaying the SynthChem oracle
//! through real multi-cycle MSBS decoding, with a fixed synthetic
//! device latency per fused call so tick counts dominate wall time the
//! way device calls would. Emits `BENCH_search_pipelined.json`.

use retroserve::benchkit::{write_bench_json, BenchRecord, InstrumentedModel};
use retroserve::coordinator::batcher::{BatcherConfig, ExpansionHub};
use retroserve::coordinator::BatchedPolicy;
use retroserve::decoding::msbs::Msbs;
use retroserve::metrics::Metrics;
use retroserve::model::scripted::{oracle_script, smiles_vocab, ScriptedModel};
use retroserve::search::{retrostar::RetroStar, Planner, SearchLimits, SpecStats, Stock};
use retroserve::synthchem::blocks::generate_blocks;
use retroserve::synthchem::gen::{gen_tree, BlockIndex};
use retroserve::tokenizer::Vocab;
use retroserve::util::Rng;
use std::sync::Arc;

/// Synthetic device latency per fused decode call.
const DEVICE_CALL_US: u64 = 150;
const SPEC_DEPTH: usize = 4;
const TARGETS: usize = 14;
const K: usize = 8;

fn workload() -> (Vec<String>, Stock, Vocab) {
    let blocks = generate_blocks(71, 400);
    let stock = Stock::from_iter(blocks.iter().map(|b| b.smiles()).chain([
        retroserve::chem::canonicalize(retroserve::synthchem::templates::BOC_REAGENT).unwrap(),
    ]));
    let idx = BlockIndex::new(blocks);
    let mut rng = Rng::new(0xBEEF);
    let mut targets = Vec::new();
    while targets.len() < TARGETS {
        let depth = 2 + rng.gen_range(3);
        if let Some(t) = gen_tree(&idx, &mut rng, depth, 26) {
            targets.push(t.product_smiles().to_string());
        }
    }
    let vocab = smiles_vocab(targets.iter().map(String::as_str));
    (targets, stock, vocab)
}

struct RunReport {
    solved: usize,
    ticks: u64,
    fused_rows: u64,
    model_calls: u64,
    encode_calls: u64,
    wall_ms: f64,
    spec: SpecStats,
}

fn run(targets: &[String], stock: &Stock, vocab: &Vocab, spec_depth: usize) -> RunReport {
    // Fresh hub per discipline: identical cold caches, fair tick counts.
    let hub = ExpansionHub::start(
        InstrumentedModel::new(ScriptedModel::new(vocab.clone(), oracle_script()))
            .with_decode_delay(std::time::Duration::from_micros(DEVICE_CALL_US)),
        Box::new(Msbs::default()),
        vocab.clone(),
        BatcherConfig {
            max_wait: std::time::Duration::from_micros(100),
            max_rows: 1024,
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    let policy = BatchedPolicy::new(hub.clone());
    let limits = SearchLimits {
        deadline: std::time::Duration::from_secs(20),
        max_iterations: 100,
        max_depth: 5,
        expansions_per_step: K,
        ..Default::default()
    };
    let planner = RetroStar::new(1).with_spec_depth(spec_depth);
    let mut solved = 0usize;
    let mut spec = SpecStats::default();
    let t0 = std::time::Instant::now();
    for t in targets {
        // spec_depth = 1 rides the classic blocking path; deeper rides
        // per-query futures.
        let r = if spec_depth == 1 {
            planner.solve(t, &policy, stock, &limits).expect("solve")
        } else {
            planner
                .solve_pipelined(t, &policy, stock, &limits)
                .expect("solve_pipelined")
        };
        solved += r.solved as usize;
        spec.groups_submitted += r.spec.groups_submitted;
        spec.groups_applied += r.spec.groups_applied;
        spec.groups_cancelled += r.spec.groups_cancelled;
        spec.spec_hits += r.spec.spec_hits;
        spec.max_in_flight = spec.max_in_flight.max(r.spec.max_in_flight);
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let (ticks, fused_rows) = hub.fused_ratio();
    let (encode_calls, _rounds) = hub.encode_ratio();
    RunReport {
        solved,
        ticks,
        fused_rows,
        model_calls: hub.stats().model_calls,
        encode_calls,
        wall_ms,
        spec,
    }
}

fn main() {
    println!(
        "== search pipelined bench (msbs, K={K}, 1 session, device call {DEVICE_CALL_US}us) =="
    );
    let (targets, stock, vocab) = workload();
    let mut records = Vec::new();
    let mut reports = Vec::new();
    for (name, sd) in [("search-blocking", 1usize), ("search-pipelined", SPEC_DEPTH)] {
        let r = run(&targets, &stock, &vocab, sd);
        let tps = r.ticks as f64 / (r.solved.max(1)) as f64;
        let eff = r.fused_rows as f64 / (r.ticks.max(1)) as f64;
        let eps = r.encode_calls as f64 / (r.solved.max(1)) as f64;
        println!(
            "{name:<17} spec_depth={sd}  solved {:>2}/{}  ticks {:>5}  ticks/solved {:>7.1}  \
             eff.rows/tick {:>5.2}  encodes/solved {:>5.1}  wall {:>8.1}ms",
            r.solved,
            targets.len(),
            r.ticks,
            tps,
            eff,
            eps,
            r.wall_ms
        );
        if sd > 1 {
            println!(
                "  speculation: submitted {} applied {} cancelled {} hits {} max_in_flight {}",
                r.spec.groups_submitted,
                r.spec.groups_applied,
                r.spec.groups_cancelled,
                r.spec.spec_hits,
                r.spec.max_in_flight
            );
        }
        records.push(
            BenchRecord::new(name)
                .metric("spec_depth", sd as f64)
                .metric("solved", r.solved as f64)
                .metric("targets", targets.len() as f64)
                .metric("scheduler_ticks", r.ticks as f64)
                .metric("ticks_per_solved", tps)
                .metric("rows_per_tick", eff)
                .metric("model_calls", r.model_calls as f64)
                .metric("encode_calls", r.encode_calls as f64)
                .metric("encode_calls_per_solved", eps)
                .metric("wall_ms", r.wall_ms)
                .metric("spec_submitted", r.spec.groups_submitted as f64)
                .metric("spec_cancelled", r.spec.groups_cancelled as f64)
                .metric("spec_hits", r.spec.spec_hits as f64),
        );
        reports.push(r);
    }
    let (blocking, pipelined) = (&reports[0], &reports[1]);
    let b_tps = blocking.ticks as f64 / blocking.solved.max(1) as f64;
    let p_tps = pipelined.ticks as f64 / pipelined.solved.max(1) as f64;
    let ratio = b_tps / p_tps.max(1e-9);
    println!(
        "  -> ticks/solved: blocking {b_tps:.1} vs pipelined {p_tps:.1} ({ratio:.2}x fewer; \
         target >= 2x at 1 session)"
    );
    let path = std::path::Path::new("BENCH_search_pipelined.json");
    match write_bench_json(path, "search-pipelined", &records) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
}
