//! Replica-sharded serving bench: the horizontal scaling tier at 64 /
//! 256 concurrent sessions — the single-hub configuration vs
//! session-sharded, replicated ones, over the SAME closed-loop
//! workload.
//!
//! Every session issues `REQUESTS_PER_SESSION` distinct (cache-missing)
//! expansion requests back to back through [`ExpansionHub`], arrivals
//! lightly staggered the way real clients are. The mock model sleeps a
//! fixed latency per encode and per decode call, and a fused call
//! carries at most `max_rows` rows (the synthetic device's batch
//! capacity) — so once 64 sessions are in flight, one hub thread must
//! *serialize* several device calls per decode cycle, while S shards
//! tick concurrently and N replicas give their fused calls independent
//! executors. Device sleeps dominate, so the wall clock divided by the
//! device latency counts the fused scheduler ticks serialized on the
//! critical path:
//!
//! ```text
//! ticks_per_request = (wall / DEVICE_CALL_US) / requests
//! ```
//!
//! The printed invariant (the acceptance bar): at 64 sessions the
//! sharded configuration reports strictly LOWER ticks-per-request and
//! strictly lower p95 latency than the single-shard one. The bench
//! exits nonzero on violation; CI runs it inside the bench-regression
//! step, and the numeric gate arms once `bench/baseline/` is populated.
//!
//! A second, hot-set scenario draws molecules from a small shared pool
//! so sessions collide on the same molecule: concurrent collisions must
//! join ONE in-flight decode (cross-shard dedup), and the report
//! carries the join rate alongside the steal counters and per-replica
//! utilization.
//!
//! Emits `BENCH_sharded.json`.

use retroserve::benchkit::{write_bench_json, BenchRecord, InstrumentedModel};
use retroserve::coordinator::batcher::{BatcherConfig, ExpansionHub};
use retroserve::decoding::msbs::Msbs;
use retroserve::metrics::Metrics;
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::model::{PooledModel, ReplicaPool};
use retroserve::tokenizer::Vocab;
use retroserve::util::stats::percentile;
use retroserve::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Synthetic device latency per encoder call.
const ENCODE_CALL_US: u64 = 200;
/// Synthetic device latency per fused decode call.
const DEVICE_CALL_US: u64 = 150;
/// Requests each session issues, back to back.
const REQUESTS_PER_SESSION: usize = 3;
const K: usize = 8;
/// Arrival stagger between session starts. Clients never co-arrive
/// perfectly, and a perfectly cold simultaneous burst would also give
/// the replica pool's load signal (charged as rounds admit) nothing to
/// steer by.
const STAGGER_US: u64 = 200;

/// (label, shards, replicas) — the single config is the reference the
/// invariant compares against.
const CONFIGS: [(&str, usize, usize); 3] =
    [("single", 1, 1), ("sharded-2x2", 2, 2), ("sharded-4x4", 4, 4)];

fn mock(vocab: usize) -> PooledModel {
    Arc::new(
        InstrumentedModel::new(MockModel::new(MockConfig { vocab, ..Default::default() }))
            .with_encode_delay(Duration::from_micros(ENCODE_CALL_US))
            .with_decode_delay(Duration::from_micros(DEVICE_CALL_US)),
    )
}

fn hub(vocab: Vocab, shards: usize, replicas: usize) -> Arc<ExpansionHub> {
    let models: Vec<PooledModel> = (0..replicas).map(|_| mock(vocab.len())).collect();
    ExpansionHub::start_pool(
        ReplicaPool::from_models(models),
        Box::new(Msbs::default()),
        vocab,
        BatcherConfig {
            max_wait: Duration::from_micros(500),
            shards,
            // max_batch / max_rows stay at their serving defaults: the
            // row cap IS the per-call device capacity under test.
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    )
}

/// Distinct pseudo-SMILES chains per session (every request misses the
/// cache and joins nothing), plus a vocabulary covering them all.
fn distinct_workload(sessions: usize) -> (Vec<Vec<String>>, Vocab) {
    let mut rng = Rng::new(0x5AA5 ^ sessions as u64);
    let mut seen = std::collections::HashSet::new();
    let alphabet = ['C', 'N', 'O'];
    let chains: Vec<Vec<String>> = (0..sessions)
        .map(|_| {
            let mut chain = Vec::with_capacity(REQUESTS_PER_SESSION);
            while chain.len() < REQUESTS_PER_SESSION {
                let len = 6 + rng.gen_range(20);
                let s: String = (0..len).map(|_| alphabet[rng.gen_range(3)]).collect();
                if seen.insert(s.clone()) {
                    chain.push(s);
                }
            }
            chain
        })
        .collect();
    let vocab = Vocab::build(chains.iter().flatten().map(String::as_str));
    (chains, vocab)
}

/// Closed-loop sessions against one hub config: spawn a thread per
/// session, time every request, and return per-request latencies.
fn drive(h: &Arc<ExpansionHub>, chains: Vec<Vec<String>>) -> Vec<f64> {
    let mut joins = Vec::new();
    for (i, chain) in chains.into_iter().enumerate() {
        let h = h.clone();
        joins.push(std::thread::spawn(move || {
            std::thread::sleep(Duration::from_micros(STAGGER_US * (i % 32) as u64));
            chain
                .iter()
                .map(|m| {
                    let t = Instant::now();
                    h.expand(m, K).expect("expansion");
                    t.elapsed().as_secs_f64() * 1e3
                })
                .collect::<Vec<f64>>()
        }));
    }
    let mut lat = Vec::new();
    for j in joins {
        lat.extend(j.join().expect("session thread"));
    }
    lat
}

struct ScaleReport {
    requests: u64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    wall_ms: f64,
    ticks_per_request: f64,
    fused_calls: u64,
    encode_calls: u64,
    dedup_joins: u64,
    spills: u64,
    steals: u64,
    util_min: f64,
    util_max: f64,
}

fn run_scale(sessions: usize, shards: usize, replicas: usize) -> ScaleReport {
    let (chains, vocab) = distinct_workload(sessions);
    let h = hub(vocab, shards, replicas);
    let t0 = Instant::now();
    let lat = drive(&h, chains);
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let requests = lat.len() as u64;
    let rs = h.replica_stats();
    assert!(rs.iter().all(|r| r.alive), "no replica may die in the bench");
    assert!(rs.iter().all(|r| r.outstanding_rows == 0), "idle pool carries no charge");
    // Per-replica busy share: fused decode time dispatched to the
    // replica over the run's wall clock (encode time not attributed).
    let wall_us = wall_ms * 1e3;
    let utils: Vec<f64> =
        rs.iter().map(|r| r.fused_calls as f64 * DEVICE_CALL_US as f64 / wall_us).collect();
    let ticks_critical = wall_us / DEVICE_CALL_US as f64;
    let (fused_calls, _) = h.fused_ratio();
    let (encode_calls, _) = h.encode_ratio();
    let (spills, steals) = h.steal_stats();
    ScaleReport {
        requests,
        p50_ms: percentile(&lat, 50.0),
        p95_ms: percentile(&lat, 95.0),
        p99_ms: percentile(&lat, 99.0),
        wall_ms,
        ticks_per_request: ticks_critical / requests.max(1) as f64,
        fused_calls,
        encode_calls,
        dedup_joins: h.dedup_joins(),
        spills,
        steals,
        util_min: utils.iter().cloned().fold(f64::INFINITY, f64::min),
        util_max: utils.iter().cloned().fold(0.0, f64::max),
    }
}

struct HotsetReport {
    requests: u64,
    dedup_joins: u64,
    dedup_rate: f64,
    encode_calls: u64,
    p50_ms: f64,
    p95_ms: f64,
    wall_ms: f64,
}

/// Hot-set scenario: many sessions, few molecules. Concurrent
/// collisions join one in-flight decode (the cross-shard dedup path);
/// later repeats come from the shared cache.
fn run_hotset(sessions: usize, shards: usize, replicas: usize) -> HotsetReport {
    const HOT: usize = 16;
    const REQS: usize = 2;
    let mut rng = Rng::new(0x5EED_CAFE);
    let alphabet = ['C', 'N', 'O'];
    let mut hot: Vec<String> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    while hot.len() < HOT {
        let len = 8 + rng.gen_range(12);
        let s: String = (0..len).map(|_| alphabet[rng.gen_range(3)]).collect();
        if seen.insert(s.clone()) {
            hot.push(s);
        }
    }
    let vocab = Vocab::build(hot.iter().map(String::as_str));
    let h = hub(vocab, shards, replicas);
    let chains: Vec<Vec<String>> = (0..sessions)
        .map(|_| (0..REQS).map(|_| hot[rng.gen_range(HOT)].clone()).collect())
        .collect();
    let t0 = Instant::now();
    let lat = drive(&h, chains);
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let requests = lat.len() as u64;
    let dedup_joins = h.dedup_joins();
    let (encode_calls, _) = h.encode_ratio();
    HotsetReport {
        requests,
        dedup_joins,
        dedup_rate: dedup_joins as f64 / requests.max(1) as f64,
        encode_calls,
        p50_ms: percentile(&lat, 50.0),
        p95_ms: percentile(&lat, 95.0),
        wall_ms,
    }
}

fn main() {
    println!(
        "== sharded serving bench (msbs, K={K}, {REQUESTS_PER_SESSION} requests/session, \
         encode {ENCODE_CALL_US}us, decode {DEVICE_CALL_US}us per fused call) =="
    );
    let mut records = Vec::new();
    let mut single64: Option<ScaleReport> = None;
    let mut sharded64: Option<ScaleReport> = None;
    for sessions in [64usize, 256] {
        for (name, shards, replicas) in CONFIGS {
            let r = run_scale(sessions, shards, replicas);
            println!(
                "{name:<12} s={sessions:<4} p50 {:>7.2}ms  p95 {:>7.2}ms  p99 {:>7.2}ms  \
                 ticks/req {:>6.2}  util {:>3.0}-{:>3.0}%  spill/steal {:>3}/{:<3} \
                 wall {:>8.1}ms",
                r.p50_ms,
                r.p95_ms,
                r.p99_ms,
                r.ticks_per_request,
                r.util_min * 100.0,
                r.util_max * 100.0,
                r.spills,
                r.steals,
                r.wall_ms
            );
            records.push(
                BenchRecord::new(format!("{name}-s{sessions}"))
                    .metric("sessions", sessions as f64)
                    .metric("shards", shards as f64)
                    .metric("replicas", replicas as f64)
                    .metric("requests", r.requests as f64)
                    .metric("p50_ms", r.p50_ms)
                    .metric("p95_ms", r.p95_ms)
                    .metric("p99_ms", r.p99_ms)
                    .metric("ticks_per_request", r.ticks_per_request)
                    .metric("fused_calls", r.fused_calls as f64)
                    .metric("encode_calls", r.encode_calls as f64)
                    .metric("steal_spills", r.spills as f64)
                    .metric("steals", r.steals as f64)
                    .metric("dedup_joins", r.dedup_joins as f64)
                    .metric("replica_util_min", r.util_min)
                    .metric("replica_util_max", r.util_max)
                    .metric("wall_ms", r.wall_ms),
            );
            if sessions == 64 {
                match name {
                    "single" => single64 = Some(r),
                    "sharded-4x4" => sharded64 = Some(r),
                    _ => {}
                }
            }
        }
    }

    let single = single64.expect("single config ran");
    let sharded = sharded64.expect("sharded config ran");
    let ticks_ok = sharded.ticks_per_request < single.ticks_per_request;
    let p95_ok = sharded.p95_ms < single.p95_ms;
    println!(
        "  -> 64 sessions, sharded-4x4 vs single: ticks/req {:.2} vs {:.2} ({}), \
         p95 {:.2}ms vs {:.2}ms ({})",
        sharded.ticks_per_request,
        single.ticks_per_request,
        if ticks_ok { "strictly lower: PASS" } else { "VIOLATION" },
        sharded.p95_ms,
        single.p95_ms,
        if p95_ok { "strictly lower: PASS" } else { "VIOLATION" }
    );

    let hs = run_hotset(64, 2, 2);
    println!(
        "hot-set      s=64   p50 {:>7.2}ms  p95 {:>7.2}ms  dedup joins {:>3} \
         ({:>4.1}% of {} requests)  encodes {:>3}  wall {:>8.1}ms",
        hs.p50_ms,
        hs.p95_ms,
        hs.dedup_joins,
        hs.dedup_rate * 100.0,
        hs.requests,
        hs.encode_calls,
        hs.wall_ms
    );
    records.push(
        BenchRecord::new("hotset-s64")
            .metric("sessions", 64.0)
            .metric("requests", hs.requests as f64)
            .metric("dedup_joins", hs.dedup_joins as f64)
            .metric("dedup_rate", hs.dedup_rate)
            .metric("encode_calls", hs.encode_calls as f64)
            .metric("p50_ms", hs.p50_ms)
            .metric("p95_ms", hs.p95_ms)
            .metric("wall_ms", hs.wall_ms),
    );

    let path = std::path::Path::new("BENCH_sharded.json");
    match write_bench_json(path, "sharded", &records) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
    if !(ticks_ok && p95_ok) {
        eprintln!("sharded scaling invariant VIOLATION at 64 sessions (see above)");
        std::process::exit(1);
    }
}
