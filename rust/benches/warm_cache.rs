//! Warm-cache restart bench: cold vs restart-warm screening over the
//! persistent expansion/route store.
//!
//! Scenario: a 128-target screening job (depth-2 synthetic routes with
//! shared intermediates, same world shape as the screening bench) runs
//! twice against the SAME store log, with a simulated process restart
//! in between — the hub (and its L1 cache) is torn down and rebuilt,
//! only the log file survives. The scripted model sleeps a fixed
//! latency per encode and per fused decode call, so decode-task counts
//! are the cost measure.
//!
//! 1. **cold** — fresh hub, empty store: the full decode workload, and
//!    it populates the log.
//! 2. **warm** — fresh hub (empty L1) reopening the log: every
//!    expansion the cold run decoded promotes from the L2 tier on its
//!    first L1 miss, so the model only sees molecules the cold run
//!    never decoded (none, here).
//! 3. **hot-path probe** — the no-blocking-disk-I/O evidence: 100k
//!    `get_expansion` probes against the warm store, timed per call
//!    under the counting allocator, interleaved with write-behind
//!    appends. The L2 read path is a mutex-guarded map probe; the
//!    flusher thread owns all disk writes.
//!
//! Printed invariants (the acceptance bar; nonzero exit on violation):
//! the warm run issues strictly FEWER decode tasks than the cold run
//! with `cache.l2_hits` > 0 doing the saving, and the slowest hot-path
//! probe stays far below disk-write latency.
//!
//! Emits `BENCH_warm_cache.json`.

use retroserve::benchkit::{
    allocs_now, write_bench_json, BenchRecord, CountingAlloc, Flags, InstrumentedModel,
};
use retroserve::coordinator::batcher::{BatcherConfig, ExpansionHub};
use retroserve::decoding::msbs::Msbs;
use retroserve::metrics::Metrics;
use retroserve::model::scripted::{smiles_vocab, Script, ScriptedModel};
use retroserve::model::{PooledModel, ReplicaPool};
use retroserve::search::{ScreenConfig, ScreenSummary, ScreeningJob, SearchLimits, Stock};
use retroserve::store::{ExpansionStore, StoreConfig};
use retroserve::tokenizer::Vocab;
use retroserve::util::stats::percentile;
use retroserve::util::Rng;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Synthetic device latency per encoder call.
const ENCODE_CALL_US: u64 = 200;
/// Synthetic device latency per fused decode call.
const DEVICE_CALL_US: u64 = 150;
/// Shared-pool size intermediates are drawn from.
const SHARED_POOL: usize = 24;
/// Hot-path probes in the no-disk-I/O evidence pass.
const PROBES: usize = 100_000;
/// The slowest probe must stay below this to count as "no blocking
/// disk I/O on the hot path" — generous against scheduler noise, far
/// below a synchronous write+fsync.
const PROBE_MAX_MS: f64 = 2.0;

struct World {
    targets: Vec<String>,
    script: Arc<HashMap<String, String>>,
    vocab: Vocab,
    stock: Arc<Stock>,
}

fn fresh(rng: &mut Rng, seen: &mut HashSet<String>, base: usize, spread: usize) -> String {
    let alphabet = ['C', 'N', 'O'];
    loop {
        let len = base + rng.gen_range(spread);
        let s: String = (0..len).map(|_| alphabet[rng.gen_range(3)]).collect();
        match retroserve::chem::canonicalize(&s) {
            Ok(c) if seen.insert(c.clone()) => return c,
            _ => {}
        }
    }
}

fn gen_world(n_targets: usize, overlap: f64) -> World {
    let mut rng = Rng::new(0x3A9B_CAFE ^ n_targets as u64);
    let mut seen: HashSet<String> = HashSet::new();
    let cc = retroserve::chem::canonicalize("CC").unwrap();
    let co = retroserve::chem::canonicalize("CO").unwrap();
    let leaves = format!("{cc}.{co}");
    seen.insert(cc.clone());
    seen.insert(co.clone());

    let shared: Vec<String> =
        (0..SHARED_POOL).map(|_| fresh(&mut rng, &mut seen, 8, 6)).collect();
    let mut script: HashMap<String, String> = HashMap::new();
    for m in &shared {
        script.insert(m.clone(), leaves.clone());
    }
    let roll = (overlap.clamp(0.0, 1.0) * 1000.0) as usize;
    let mut targets = Vec::with_capacity(n_targets);
    for _ in 0..n_targets {
        let t = fresh(&mut rng, &mut seen, 14, 8);
        let mut pair = Vec::with_capacity(2);
        for _ in 0..2 {
            let m = if rng.gen_range(1000) < roll {
                shared[rng.gen_range(SHARED_POOL)].clone()
            } else {
                let p = fresh(&mut rng, &mut seen, 8, 6);
                script.insert(p.clone(), leaves.clone());
                p
            };
            pair.push(m);
        }
        script.insert(t.clone(), format!("{}.{}", pair[0], pair[1]));
        targets.push(t);
    }
    let mut corpus: Vec<&str> = Vec::with_capacity(script.len() * 2);
    for (k, v) in &script {
        corpus.push(k);
        corpus.push(v);
    }
    World {
        targets,
        script: Arc::new(script),
        vocab: smiles_vocab(corpus),
        stock: Arc::new(Stock::from_iter([cc, co])),
    }
}

fn hub(
    world: &World,
    metrics: Arc<Metrics>,
    store: Option<Arc<ExpansionStore>>,
) -> Arc<ExpansionHub> {
    let models: Vec<PooledModel> = (0..2)
        .map(|_| {
            let map = world.script.clone();
            let script: Script =
                Box::new(move |p| map.get(p).map(|r| vec![(r.clone(), -0.5)]).unwrap_or_default());
            Arc::new(
                InstrumentedModel::new(ScriptedModel::new(world.vocab.clone(), script))
                    .with_encode_delay(Duration::from_micros(ENCODE_CALL_US))
                    .with_decode_delay(Duration::from_micros(DEVICE_CALL_US)),
            ) as PooledModel
        })
        .collect();
    ExpansionHub::start_pool_with_store(
        ReplicaPool::from_models(models),
        Box::new(Msbs::default()),
        world.vocab.clone(),
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            shards: 2,
            ..Default::default()
        },
        metrics,
        store,
    )
}

fn screen_cfg(concurrency: usize) -> ScreenConfig {
    ScreenConfig {
        concurrency,
        job_deadline: None,
        job_decode_tokens: 0,
        beam_width: 1,
        spec_depth: 1,
        spec_adaptive: false,
        limits: SearchLimits {
            deadline: Duration::from_secs(30),
            max_depth: 6,
            expansions_per_step: 4,
            ..Default::default()
        },
    }
}

/// One "server process": build a hub over `store`, run the screening
/// job, and return (summary, metrics). The hub (and its L1) dies with
/// the call — only the store log carries state to the next process.
fn run_process(
    world: &World,
    store: Arc<ExpansionStore>,
    concurrency: usize,
) -> (ScreenSummary, Arc<Metrics>) {
    let metrics = Arc::new(Metrics::new());
    let h = hub(world, metrics.clone(), Some(store.clone()));
    let job = ScreeningJob::new(screen_cfg(concurrency)).with_store(store.clone());
    let summary = job
        .run(&h, &world.stock, &world.targets, &metrics, &mut |_| {})
        .expect("screening job");
    // Durability barrier before "shutdown": shard threads drain
    // asynchronously, so the flush IS the clean-shutdown point.
    store.flush();
    (summary, metrics)
}

/// The no-blocking-disk-I/O evidence: time individual `get_expansion`
/// probes against a live store while write-behind appends stream past
/// them. Returns (max_ms, p99_ms, allocs_per_probe).
fn probe_hot_path(store: &ExpansionStore, world: &World) -> (f64, f64, f64) {
    let mols: Vec<&String> = world.script.keys().collect();
    let mut rng = Rng::new(0xD15C);
    let mut lat_ms = Vec::with_capacity(PROBES);
    let a0 = allocs_now();
    for i in 0..PROBES {
        // Keep the flusher busy so a probe that DID touch the file
        // would serialize behind real writes and show up in the tail.
        if i % 64 == 0 {
            let m = mols[rng.gen_range(mols.len())];
            store.put_expansion(
                m,
                4,
                &[retroserve::search::policy::Proposal {
                    reactants: vec![m.to_string()],
                    logp: -0.5,
                }],
            );
        }
        let m = mols[rng.gen_range(mols.len())];
        let t0 = Instant::now();
        let _ = std::hint::black_box(store.get_expansion(m, 4));
        lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let allocs_per_probe = (allocs_now() - a0) as f64 / PROBES as f64;
    let max = lat_ms.iter().cloned().fold(0.0f64, f64::max);
    (max, percentile(&lat_ms, 99.0), allocs_per_probe)
}

fn main() {
    let flags = Flags::parse();
    let n_targets = flags.usize_or("targets", 128);
    let overlap = flags.f64_or("overlap", 0.5);
    let concurrency = flags.usize_or("concurrency", 16);
    let path = std::env::temp_dir().join(format!(
        "retroserve-bench-warm-{}.log",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    println!(
        "== warm-cache bench ({n_targets} targets, overlap {overlap:.2}, \
         concurrency {concurrency}, store {}) ==",
        path.display()
    );
    let world = gen_world(n_targets, overlap);
    let fp = "bench-scripted|msbs|k4";
    let mut records = Vec::new();

    // Process 1: cold — empty log, full decode workload.
    let cold_store = Arc::new(
        ExpansionStore::open(StoreConfig::new(&path, fp), Arc::new(Metrics::new())).unwrap(),
    );
    let (cold, cold_metrics) = run_process(&world, cold_store, concurrency);
    assert_eq!(cold.solved, n_targets, "cold run must solve everything");
    let cold_l2 = cold_metrics.counter("cache.l2_hits");
    println!(
        "cold         {}/{} solved  decode tasks {:>5}  l2 hits {:>5}  wall {:>8.1}ms",
        cold.solved,
        cold.targets,
        cold.decode_tasks,
        cold_l2,
        cold.wall_secs * 1e3
    );
    records.push(
        BenchRecord::new("cold")
            .metric("targets", n_targets as f64)
            .metric("solved", cold.solved as f64)
            .metric("decode_tasks", cold.decode_tasks as f64)
            .metric("l2_hits", cold_l2 as f64)
            .metric("wall_ms", cold.wall_secs * 1e3),
    );

    // Process 2: restart-warm — fresh hub and L1, same log.
    let warm_store_metrics = Arc::new(Metrics::new());
    let store = Arc::new(
        ExpansionStore::open(StoreConfig::new(&path, fp), warm_store_metrics.clone()).unwrap(),
    );
    assert_eq!(store.recovered_records(), 0, "flushed log must reopen clean");
    let warm_entries = store.expansions_len();
    let (warm, warm_metrics) = run_process(&world, store.clone(), concurrency);
    assert_eq!(warm.solved, n_targets, "warm run must solve everything");
    let l2_hits = warm_metrics.counter("cache.l2_hits");
    let l2_promotions = warm_metrics.counter("cache.l2_promotions");
    println!(
        "warm         {}/{} solved  decode tasks {:>5}  l2 hits {:>5}  \
         promotions {:>5}  ({} entries replayed)  wall {:>8.1}ms",
        warm.solved,
        warm.targets,
        warm.decode_tasks,
        l2_hits,
        l2_promotions,
        warm_entries,
        warm.wall_secs * 1e3
    );
    records.push(
        BenchRecord::new("warm")
            .metric("targets", n_targets as f64)
            .metric("solved", warm.solved as f64)
            .metric("decode_tasks", warm.decode_tasks as f64)
            .metric("l2_hits", l2_hits as f64)
            .metric("l2_promotions", l2_promotions as f64)
            .metric("replayed_entries", warm_entries as f64)
            .metric("wall_ms", warm.wall_secs * 1e3),
    );

    // Hot-path probe against the live warm store.
    let (probe_max_ms, probe_p99_ms, allocs_per_probe) = probe_hot_path(&store, &world);
    println!(
        "hot path     {PROBES} get probes  max {probe_max_ms:>7.4}ms  \
         p99 {probe_p99_ms:>7.4}ms  allocs/probe {allocs_per_probe:>5.1}"
    );
    records.push(
        BenchRecord::new("hot-path-probe")
            .metric("probes", PROBES as f64)
            .metric("max_ms", probe_max_ms)
            .metric("p99_ms", probe_p99_ms)
            .metric("allocs_per_probe", allocs_per_probe),
    );

    let fewer_ok = warm.decode_tasks < cold.decode_tasks;
    let l2_ok = l2_hits > 0;
    let probe_ok = probe_max_ms < PROBE_MAX_MS;
    println!(
        "  -> warm vs cold decode tasks: {} vs {} ({})",
        warm.decode_tasks,
        cold.decode_tasks,
        if fewer_ok { "strictly fewer: PASS" } else { "VIOLATION" }
    );
    println!(
        "  -> cache.l2_hits on warm run: {l2_hits} ({})",
        if l2_ok { "nonzero: PASS" } else { "VIOLATION" }
    );
    println!(
        "  -> slowest hot-path probe {probe_max_ms:.4}ms (limit {PROBE_MAX_MS:.1}ms): {}",
        if probe_ok { "no blocking disk I/O: PASS" } else { "VIOLATION" }
    );

    drop(store);
    let _ = std::fs::remove_file(&path);
    let out = std::path::Path::new("BENCH_warm_cache.json");
    match write_bench_json(out, "warm_cache", &records) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out.display()),
    }
    if !(fewer_ok && l2_ok && probe_ok) {
        eprintln!("warm-cache invariant VIOLATION (see above)");
        std::process::exit(1);
    }
}
