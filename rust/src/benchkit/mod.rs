//! Shared plumbing for the table-reproduction bench harnesses
//! (`bench_table1..4`) and the criterion-style micro benches.

use crate::jsonx::Json;
use crate::model::{DecodeOut, DecodeRow, MemHandle, StateId, StepModel};
use anyhow::{Context, Result};
use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicIsize, AtomicU64, Ordering};
use std::sync::Arc;

/// Instrumented [`StepModel`] wrapper shared by the benches and the
/// integration tests, so harnesses stop hand-writing ~40-line
/// delegation impls per knob:
///
/// * optional fixed per-call **device latencies** (`with_encode_delay`
///   / `with_decode_delay`) — synthetic device time so batching wins
///   show up in wall clock, not just in call counters;
/// * an optional **decode gate** (`with_gate`): while the shared flag
///   is set, decode calls block — tests use it to pin "a task is
///   mid-flight when X happens" without timing games;
/// * a shared **live-handle counter** (`with_live_counter`): `encode`
///   minus `release`, observable from outside even after the model
///   moves onto a [`crate::runtime::server::SharedModel`] executor
///   thread — the ref-count tests' probe;
/// * **encode-failure injection** (`with_encode_failure`): `encode`
///   errors for any batch the predicate matches — blast-radius and
///   fallback tests;
/// * an **incremental override** (`with_incremental(false)`): force the
///   full-prefix path on a state-caching model — the A/B lever the
///   incremental parity tests and the `decode_tokens` benches use;
/// * a shared **live state-claim counter** (`with_state_counter`):
///   commits + retains − releases, observable across the executor
///   thread — the state-leak tests' probe (zero when every task chain
///   was released).
///
/// Everything defaults to a transparent pass-through.
pub struct InstrumentedModel<M> {
    inner: M,
    encode_delay: std::time::Duration,
    decode_delay: std::time::Duration,
    hold: Arc<AtomicBool>,
    live: Arc<AtomicIsize>,
    encode_fail: Option<Box<dyn Fn(&[Vec<i32>]) -> bool + Send + Sync>>,
    incremental: Option<bool>,
    state_claims: Arc<AtomicIsize>,
}

impl<M> InstrumentedModel<M> {
    pub fn new(inner: M) -> Self {
        Self {
            inner,
            encode_delay: std::time::Duration::ZERO,
            decode_delay: std::time::Duration::ZERO,
            hold: Arc::new(AtomicBool::new(false)),
            live: Arc::new(AtomicIsize::new(0)),
            encode_fail: None,
            incremental: None,
            state_claims: Arc::new(AtomicIsize::new(0)),
        }
    }

    /// Sleep this long inside every `encode` call.
    pub fn with_encode_delay(mut self, d: std::time::Duration) -> Self {
        self.encode_delay = d;
        self
    }

    /// Sleep this long inside every `decode`/`decode_into` call.
    pub fn with_decode_delay(mut self, d: std::time::Duration) -> Self {
        self.decode_delay = d;
        self
    }

    /// Decode calls block while `hold` is set (checked every 200µs —
    /// this is a test gate, not a serving wait path).
    pub fn with_gate(mut self, hold: Arc<AtomicBool>) -> Self {
        self.hold = hold;
        self
    }

    /// Mirror the live encoded-batch count (`encode` − `release`) into
    /// `live`.
    pub fn with_live_counter(mut self, live: Arc<AtomicIsize>) -> Self {
        self.live = live;
        self
    }

    /// `encode` errors for any batch the predicate matches (failure
    /// injection for blast-radius / fallback tests).
    pub fn with_encode_failure<F>(mut self, f: F) -> Self
    where
        F: Fn(&[Vec<i32>]) -> bool + Send + Sync + 'static,
    {
        self.encode_fail = Some(Box::new(f));
        self
    }

    /// Override the wrapped model's incremental capability (pass
    /// `false` to force the full-prefix path on a state-caching model).
    pub fn with_incremental(mut self, on: bool) -> Self {
        self.incremental = Some(on);
        self
    }

    /// Mirror the live state-claim count (commits + retains − releases)
    /// into `claims`.
    pub fn with_state_counter(mut self, claims: Arc<AtomicIsize>) -> Self {
        self.state_claims = claims;
        self
    }

    /// The wrapped model (e.g. to read `MockModel::encode_calls`).
    pub fn inner(&self) -> &M {
        &self.inner
    }

    fn wait_gate(&self) {
        while self.hold.load(Ordering::Relaxed) {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
}

impl<M: StepModel> StepModel for InstrumentedModel<M> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn medusa_heads(&self) -> usize {
        self.inner.medusa_heads()
    }

    fn max_src(&self) -> usize {
        self.inner.max_src()
    }

    fn max_tgt(&self) -> usize {
        self.inner.max_tgt()
    }

    fn encode(&self, src: &[Vec<i32>]) -> Result<MemHandle> {
        if let Some(fail) = &self.encode_fail {
            if fail(src) {
                anyhow::bail!("injected encode failure");
            }
        }
        if !self.encode_delay.is_zero() {
            std::thread::sleep(self.encode_delay);
        }
        let h = self.inner.encode(src)?;
        self.live.fetch_add(1, Ordering::SeqCst);
        Ok(h)
    }

    fn decode(&self, rows: &[DecodeRow], win: usize) -> Result<DecodeOut> {
        self.wait_gate();
        if !self.decode_delay.is_zero() {
            std::thread::sleep(self.decode_delay);
        }
        self.inner.decode(rows, win)
    }

    fn decode_into(&self, rows: &[DecodeRow], win: usize, out: &mut DecodeOut) -> Result<()> {
        self.wait_gate();
        if !self.decode_delay.is_zero() {
            std::thread::sleep(self.decode_delay);
        }
        self.inner.decode_into(rows, win, out)
    }

    fn pad_rows(&self, n: usize) -> usize {
        self.inner.pad_rows(n)
    }

    fn release(&self, mem: MemHandle) {
        self.live.fetch_sub(1, Ordering::SeqCst);
        self.inner.release(mem)
    }

    fn supports_incremental(&self) -> bool {
        self.incremental.unwrap_or_else(|| self.inner.supports_incremental())
    }

    fn state_commit(
        &self,
        mem: MemHandle,
        mem_row: usize,
        parent: StateId,
        delta: &[i32],
    ) -> Result<StateId> {
        let s = self.inner.state_commit(mem, mem_row, parent, delta)?;
        self.state_claims.fetch_add(1, Ordering::SeqCst);
        Ok(s)
    }

    fn state_retain(&self, state: StateId) {
        self.state_claims.fetch_add(1, Ordering::SeqCst);
        self.inner.state_retain(state)
    }

    fn state_release(&self, state: StateId) {
        self.state_claims.fetch_sub(1, Ordering::SeqCst);
        self.inner.state_release(state)
    }
}

/// Fault menu for [`ChaosModel`]: scripted (1-based global call
/// indices) and seeded-random (per-call probabilities) injection of
/// encode/decode errors, latency spikes, stalls and panics.
///
/// Injection happens strictly on the *call* paths (`encode`, `decode`,
/// `decode_into`). Release paths (`release`, `state_release`,
/// `state_retain`) are never faulted: recovery code runs them while
/// cleaning up after an injected panic, and a fault there would turn
/// containment itself into the crash under test.
#[derive(Clone, Debug, Default)]
pub struct ChaosConfig {
    /// Seed for the random schedule; equal seeds give equal fault
    /// sequences (the soak test's reproducibility contract).
    pub seed: u64,
    /// Per-call probability of an injected `Err` from `encode`.
    pub encode_error_rate: f64,
    /// Per-call probability of an injected `Err` from `decode`.
    pub decode_error_rate: f64,
    /// Per-call probability of an injected panic in `encode`.
    pub encode_panic_rate: f64,
    /// Per-call probability of an injected panic in `decode`.
    pub decode_panic_rate: f64,
    /// Per-call probability of sleeping `delay` (latency spike).
    pub delay_rate: f64,
    pub delay: std::time::Duration,
    /// Per-call probability of sleeping `stall` (long wedge; pair with
    /// request deadlines to exercise the anytime path).
    pub stall_rate: f64,
    pub stall: std::time::Duration,
    /// Scripted faults: 1-based global call indices per phase.
    pub err_on_encode: Vec<usize>,
    pub err_on_decode: Vec<usize>,
    pub panic_on_encode: Vec<usize>,
    pub panic_on_decode: Vec<usize>,
    /// Overload storm: a *correlated* latency window, unlike the
    /// independent per-call `delay_rate` draws. Every call whose
    /// 1-based per-phase index lands in `[storm_after, storm_after +
    /// storm_calls)` pays `storm_delay`, so the model slows down for a
    /// sustained stretch and real queueing builds behind it — the
    /// overload-protection tests use this to push the hub's load score
    /// through the shed/degrade watermarks. `storm_calls == 0` (the
    /// default) disables the window.
    pub storm_after: u64,
    pub storm_calls: u64,
    pub storm_delay: std::time::Duration,
}

/// Shared tally of injected faults, readable after the model moves onto
/// an executor/hub thread (grab a clone via [`ChaosModel::counters`]).
#[derive(Debug, Default)]
pub struct ChaosCounters {
    pub encode_errors: AtomicU64,
    pub decode_errors: AtomicU64,
    pub panics: AtomicU64,
    pub delays: AtomicU64,
    pub stalls: AtomicU64,
    /// Calls slowed by the correlated storm window.
    pub storms: AtomicU64,
}

enum Fault {
    None,
    Err,
    Panic,
}

/// Chaos-injection [`StepModel`] wrapper — layer it over
/// [`InstrumentedModel`] to combine fault schedules with the live
/// handle/state probes:
/// `ChaosModel::new(InstrumentedModel::new(mock).with_live_counter(..), cfg)`.
pub struct ChaosModel<M> {
    inner: M,
    cfg: ChaosConfig,
    rng: std::sync::Mutex<crate::util::Rng>,
    encode_calls: AtomicU64,
    decode_calls: AtomicU64,
    injected: Arc<ChaosCounters>,
}

impl<M> ChaosModel<M> {
    pub fn new(inner: M, cfg: ChaosConfig) -> Self {
        let rng = std::sync::Mutex::new(crate::util::Rng::new(cfg.seed));
        Self {
            inner,
            cfg,
            rng,
            encode_calls: AtomicU64::new(0),
            decode_calls: AtomicU64::new(0),
            injected: Arc::new(ChaosCounters::default()),
        }
    }

    /// Clone of the shared fault tally (take it before handing the
    /// model to a hub/executor).
    pub fn counters(&self) -> Arc<ChaosCounters> {
        self.injected.clone()
    }

    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// Decide this call's fate. All random draws happen in one short
    /// lock scope and in a fixed order, so the schedule is a pure
    /// function of (seed, call sequence) — and the injected panic fires
    /// *after* the rng lock is released.
    fn plan(
        &self,
        n: u64,
        err_on: &[usize],
        panic_on: &[usize],
        err_rate: f64,
        panic_rate: f64,
    ) -> (Fault, std::time::Duration) {
        let mut rng = self.rng.lock().unwrap_or_else(|p| p.into_inner());
        let spike = rng.gen_bool(self.cfg.delay_rate);
        let stall = rng.gen_bool(self.cfg.stall_rate);
        let err = rng.gen_bool(err_rate);
        let panic = rng.gen_bool(panic_rate);
        drop(rng);
        let mut sleep = std::time::Duration::ZERO;
        if spike && !self.cfg.delay.is_zero() {
            self.injected.delays.fetch_add(1, Ordering::Relaxed);
            sleep += self.cfg.delay;
        }
        if stall && !self.cfg.stall.is_zero() {
            self.injected.stalls.fetch_add(1, Ordering::Relaxed);
            sleep += self.cfg.stall;
        }
        if self.cfg.storm_calls > 0
            && !self.cfg.storm_delay.is_zero()
            && n >= self.cfg.storm_after
            && n < self.cfg.storm_after + self.cfg.storm_calls
        {
            self.injected.storms.fetch_add(1, Ordering::Relaxed);
            sleep += self.cfg.storm_delay;
        }
        let fault = if panic || panic_on.contains(&(n as usize)) {
            Fault::Panic
        } else if err || err_on.contains(&(n as usize)) {
            Fault::Err
        } else {
            Fault::None
        };
        (fault, sleep)
    }
}

impl<M: StepModel> StepModel for ChaosModel<M> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn medusa_heads(&self) -> usize {
        self.inner.medusa_heads()
    }

    fn max_src(&self) -> usize {
        self.inner.max_src()
    }

    fn max_tgt(&self) -> usize {
        self.inner.max_tgt()
    }

    fn encode(&self, src: &[Vec<i32>]) -> Result<MemHandle> {
        let n = self.encode_calls.fetch_add(1, Ordering::SeqCst) + 1;
        let (fault, sleep) = self.plan(
            n,
            &self.cfg.err_on_encode,
            &self.cfg.panic_on_encode,
            self.cfg.encode_error_rate,
            self.cfg.encode_panic_rate,
        );
        if !sleep.is_zero() {
            std::thread::sleep(sleep);
        }
        match fault {
            Fault::Panic => {
                self.injected.panics.fetch_add(1, Ordering::Relaxed);
                panic!("chaos: injected encode panic (call #{n})");
            }
            Fault::Err => {
                self.injected.encode_errors.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("chaos: injected encode error (call #{n})");
            }
            Fault::None => self.inner.encode(src),
        }
    }

    fn decode(&self, rows: &[DecodeRow], win: usize) -> Result<DecodeOut> {
        let n = self.decode_calls.fetch_add(1, Ordering::SeqCst) + 1;
        let (fault, sleep) = self.plan(
            n,
            &self.cfg.err_on_decode,
            &self.cfg.panic_on_decode,
            self.cfg.decode_error_rate,
            self.cfg.decode_panic_rate,
        );
        if !sleep.is_zero() {
            std::thread::sleep(sleep);
        }
        match fault {
            Fault::Panic => {
                self.injected.panics.fetch_add(1, Ordering::Relaxed);
                panic!("chaos: injected decode panic (call #{n})");
            }
            Fault::Err => {
                self.injected.decode_errors.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("chaos: injected decode error (call #{n})");
            }
            Fault::None => self.inner.decode(rows, win),
        }
    }

    fn decode_into(&self, rows: &[DecodeRow], win: usize, out: &mut DecodeOut) -> Result<()> {
        let n = self.decode_calls.fetch_add(1, Ordering::SeqCst) + 1;
        let (fault, sleep) = self.plan(
            n,
            &self.cfg.err_on_decode,
            &self.cfg.panic_on_decode,
            self.cfg.decode_error_rate,
            self.cfg.decode_panic_rate,
        );
        if !sleep.is_zero() {
            std::thread::sleep(sleep);
        }
        match fault {
            Fault::Panic => {
                self.injected.panics.fetch_add(1, Ordering::Relaxed);
                panic!("chaos: injected decode panic (call #{n})");
            }
            Fault::Err => {
                self.injected.decode_errors.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("chaos: injected decode error (call #{n})");
            }
            Fault::None => self.inner.decode_into(rows, win, out),
        }
    }

    fn pad_rows(&self, n: usize) -> usize {
        self.inner.pad_rows(n)
    }

    fn release(&self, mem: MemHandle) {
        self.inner.release(mem)
    }

    fn supports_incremental(&self) -> bool {
        self.inner.supports_incremental()
    }

    fn state_commit(
        &self,
        mem: MemHandle,
        mem_row: usize,
        parent: StateId,
        delta: &[i32],
    ) -> Result<StateId> {
        self.inner.state_commit(mem, mem_row, parent, delta)
    }

    fn state_retain(&self, state: StateId) {
        self.inner.state_retain(state)
    }

    fn state_release(&self, state: StateId) {
        self.inner.state_release(state)
    }
}

/// One held-out single-step sample.
#[derive(Clone, Debug)]
pub struct TestPair {
    pub src: String,
    pub tgt: String,
    pub product: String,
    /// Ground-truth canonical reactants joined with '.'.
    pub reactants: String,
    pub template: String,
}

/// Load `dataset_test.tsv`.
pub fn load_test_pairs(art: &Path, limit: usize) -> Result<Vec<TestPair>> {
    let text = std::fs::read_to_string(art.join("dataset_test.tsv"))
        .context("dataset_test.tsv (run `make artifacts`)")?;
    let mut out = Vec::new();
    for line in text.lines().take(limit) {
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() >= 5 {
            out.push(TestPair {
                src: f[0].into(),
                tgt: f[1].into(),
                product: f[2].into(),
                reactants: f[3].into(),
                template: f[4].into(),
            });
        }
    }
    Ok(out)
}

/// One multi-step planning query.
#[derive(Clone, Debug)]
pub struct QueryRow {
    pub smiles: String,
    pub depth: usize,
    pub solvable_hint: bool,
}

/// Load `queries10k.tsv`.
pub fn load_queries(art: &Path, limit: usize) -> Result<Vec<QueryRow>> {
    let text = std::fs::read_to_string(art.join("queries10k.tsv"))
        .context("queries10k.tsv (run `make artifacts`)")?;
    let mut out = Vec::new();
    for line in text.lines().take(limit) {
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() >= 3 {
            out.push(QueryRow {
                smiles: f[0].into(),
                depth: f[1].parse().unwrap_or(0),
                solvable_hint: f[2] == "1",
            });
        }
    }
    Ok(out)
}

/// Tiny flag parser for the bench binaries (`--name value`).
pub struct Flags(std::collections::HashMap<String, String>);

impl Flags {
    pub fn parse() -> Flags {
        let mut m = std::collections::HashMap::new();
        let mut it = std::env::args().skip(1);
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                m.insert(name.to_string(), it.next().unwrap_or_else(|| "true".into()));
            }
        }
        Flags(m)
    }

    pub fn str_or(&self, k: &str, d: &str) -> String {
        self.0.get(k).cloned().unwrap_or_else(|| d.to_string())
    }

    pub fn usize_or(&self, k: &str, d: usize) -> usize {
        self.0.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    }

    pub fn f64_or(&self, k: &str, d: f64) -> f64 {
        self.0.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    }

    pub fn has(&self, k: &str) -> bool {
        self.0.contains_key(k)
    }
}

/// Allocation-counting `GlobalAlloc` wrapper shared by the bench
/// binaries (each still declares its own `#[global_allocator]`
/// registration — that attribute must live in the final binary).
/// `alloc`/`realloc` bump a global counter; read it with
/// [`allocs_now`] and difference across a measured window.
pub struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

/// Total allocations (+reallocations) since process start.
pub fn allocs_now() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

// SAFETY: delegates directly to `System`; the counter has no effect on
// allocation behaviour.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// One benchmark result for machine-readable emission: a name plus
/// flat metric key/value pairs.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub name: String,
    pub metrics: Vec<(String, f64)>,
}

impl BenchRecord {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), metrics: Vec::new() }
    }

    pub fn metric(mut self, key: impl Into<String>, value: f64) -> Self {
        self.metrics.push((key.into(), value));
        self
    }
}

/// Serialize bench records to a `BENCH_*.json` file so the perf
/// trajectory is machine-readable across PRs. Shape:
/// `{"suite": ..., "results": [{"name": ..., <metric>: <value>, ...}]}`.
pub fn write_bench_json(path: &Path, suite: &str, records: &[BenchRecord]) -> Result<()> {
    let results: Vec<Json> = records
        .iter()
        .map(|r| {
            let mut pairs: Vec<(&str, Json)> = vec![("name", Json::str(r.name.clone()))];
            for (k, v) in &r.metrics {
                pairs.push((k.as_str(), Json::num(*v)));
            }
            Json::obj(pairs)
        })
        .collect();
    let doc = Json::obj(vec![
        ("suite", Json::str(suite)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(path, doc.to_string()).with_context(|| format!("writing {path:?}"))?;
    Ok(())
}

/// Pretty-print one table row: name + columns.
pub fn row(name: &str, cols: &[String]) -> String {
    let mut s = format!("{name:<24}");
    for c in cols {
        s.push_str(&format!(" | {c:>14}"));
    }
    s
}

/// Group query molecules into batches of `b` BOS/EOS-encoded sources.
pub fn encode_groups(
    vocab: &crate::tokenizer::Vocab,
    srcs: &[String],
    b: usize,
    max_src: usize,
) -> Vec<Vec<Vec<i32>>> {
    let mut groups = Vec::new();
    let mut cur: Vec<Vec<i32>> = Vec::with_capacity(b);
    for s in srcs {
        let ids = vocab.encode(s, true);
        if ids.len() > max_src {
            continue;
        }
        cur.push(ids);
        if cur.len() == b {
            groups.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    groups
}

/// Warm up the runtime's lazily-compiled executables so compile time
/// stays out of the measured window.
pub fn warmup_model(model: &dyn StepModel, vocab: &crate::tokenizer::Vocab, sample: &str) {
    let ids = vocab.encode(sample, true);
    if let Ok(mem) = model.encode(&[ids]) {
        let _ = model.decode(
            &[crate::model::DecodeRow::full(mem, 0, vec![crate::tokenizer::BOS], 0)],
            1,
        );
        model.release(mem);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_groups_batches_correctly() {
        let vocab = crate::tokenizer::Vocab::build(["CC", "CCC", "CCCC"]);
        let srcs = vec!["CC".to_string(), "CCC".to_string(), "CCCC".to_string()];
        let g = encode_groups(&vocab, &srcs, 2, 16);
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].len(), 2);
        assert_eq!(g[1].len(), 1);
    }

    #[test]
    fn bench_json_roundtrips() {
        let recs = vec![
            BenchRecord::new("msbs").metric("ms_per_group", 1.5).metric("model_calls", 20.0),
            BenchRecord::new("beam-search").metric("ms_per_group", 4.0),
        ];
        let path = std::env::temp_dir().join("retroserve_bench_json_test.json");
        write_bench_json(&path, "decoding-micro", &recs).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("suite").and_then(|s| s.as_str()), Some("decoding-micro"));
        let results = doc.get("results").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").and_then(|s| s.as_str()), Some("msbs"));
        assert_eq!(results[0].get("ms_per_group").and_then(|x| x.as_f64()), Some(1.5));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn instrumented_model_tracks_live_handles_and_delegates() {
        use crate::model::mock::{MockConfig, MockModel};
        use crate::tokenizer::{BOS, EOS};
        let live = Arc::new(AtomicIsize::new(0));
        let m = InstrumentedModel::new(MockModel::new(MockConfig::default()))
            .with_live_counter(live.clone());
        let h = m.encode(&[vec![BOS, 5, 6, EOS]]).unwrap();
        assert_eq!(live.load(Ordering::SeqCst), 1);
        let out = m
            .decode(&[DecodeRow::full(h, 0, vec![BOS], 0)], 1)
            .unwrap();
        assert_eq!(out.rows, 1);
        m.release(h);
        assert_eq!(live.load(Ordering::SeqCst), 0);
        assert_eq!(m.inner().encode_calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chaos_model_scripted_faults_hit_exact_calls() {
        use crate::model::mock::{MockConfig, MockModel};
        use crate::tokenizer::{BOS, EOS};
        let m = ChaosModel::new(
            MockModel::new(MockConfig::default()),
            ChaosConfig { err_on_encode: vec![2], ..Default::default() },
        );
        let c = m.counters();
        let h = m.encode(&[vec![BOS, 5, 6, EOS]]).unwrap();
        m.release(h);
        let err = m.encode(&[vec![BOS, 5, 6, EOS]]).unwrap_err();
        assert!(err.to_string().contains("chaos"), "{err:#}");
        let h = m.encode(&[vec![BOS, 5, 6, EOS]]).unwrap();
        m.release(h);
        assert_eq!(c.encode_errors.load(Ordering::Relaxed), 1);
        assert_eq!(c.panics.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn chaos_schedule_is_deterministic_per_seed() {
        use crate::model::mock::{MockConfig, MockModel};
        use crate::tokenizer::{BOS, EOS};
        let run = |seed: u64| -> Vec<bool> {
            let m = ChaosModel::new(
                MockModel::new(MockConfig::default()),
                ChaosConfig { seed, encode_error_rate: 0.5, ..Default::default() },
            );
            (0..32)
                .map(|_| {
                    let r = m.encode(&[vec![BOS, 5, 6, EOS]]);
                    if let Ok(h) = &r {
                        m.release(*h);
                    }
                    r.is_ok()
                })
                .collect()
        };
        assert_eq!(run(7), run(7), "equal seeds must give equal fault schedules");
        assert_ne!(run(7), run(8), "different seeds should differ at rate 0.5");
    }

    #[test]
    fn chaos_panic_is_injected_on_schedule() {
        use crate::model::mock::{MockConfig, MockModel};
        use crate::tokenizer::{BOS, EOS};
        let m = ChaosModel::new(
            MockModel::new(MockConfig::default()),
            ChaosConfig { panic_on_encode: vec![1], ..Default::default() },
        );
        let c = m.counters();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.encode(&[vec![BOS, 5, 6, EOS]])
        }));
        assert!(r.is_err(), "scripted panic must fire");
        assert_eq!(c.panics.load(Ordering::Relaxed), 1);
        // The next call is healthy again.
        let h = m.encode(&[vec![BOS, 5, 6, EOS]]).unwrap();
        m.release(h);
    }

    #[test]
    fn chaos_storm_window_slows_exactly_its_calls() {
        use crate::model::mock::{MockConfig, MockModel};
        use crate::tokenizer::{BOS, EOS};
        let m = ChaosModel::new(
            MockModel::new(MockConfig::default()),
            ChaosConfig {
                storm_after: 2,
                storm_calls: 3,
                storm_delay: std::time::Duration::from_millis(1),
                ..Default::default()
            },
        );
        let c = m.counters();
        for _ in 0..6 {
            let h = m.encode(&[vec![BOS, 5, 6, EOS]]).unwrap();
            m.release(h);
        }
        // Calls 2, 3, 4 of the six land in [storm_after, storm_after +
        // storm_calls); calls 1, 5, 6 stay fast.
        assert_eq!(c.storms.load(Ordering::Relaxed), 3);
        assert_eq!(c.delays.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn row_formats() {
        let s = row("beam search", &["1.0".into(), "2.0".into()]);
        assert!(s.contains("beam search"));
        assert!(s.contains('|'));
    }
}
