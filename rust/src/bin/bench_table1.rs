//! Table 1 harness: single-step decoding comparison on the held-out
//! test set.
//!
//! Reproduces all four sections of the paper's Table 1 — (A) wall time,
//! (B) model calls, (C) average effective batch size, (D) acceptance
//! rate — for BS / BS-optimized / HSBS / MSBS at batch sizes
//! B ∈ {1, 4, 8, 16, 32}, K = 10.
//!
//! `bench_table1 [--artifacts DIR] [--n 200] [--k 10] [--runs 1]
//! [--mock] [--batches 1,4,8,16,32]`
//!
//! `--mock` swaps the PJRT model for the deterministic in-process mock
//! (useful to exercise the harness without artifacts). The molecule
//! count is scaled down from the paper's 5007 to fit the single-core
//! testbed; EXPERIMENTS.md records the scaling.

use anyhow::Result;
use retroserve::benchkit::{encode_groups, load_test_pairs, row, warmup_model, Flags};
use retroserve::decoding::{beam::BeamSearch, hsbs::Hsbs, msbs::Msbs, DecodeStats, Decoder};
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::model::StepModel;
use retroserve::runtime::PjrtModel;
use retroserve::tokenizer::Vocab;
use retroserve::util::stats::{mean, stddev};

fn run_algo(
    model: &dyn StepModel,
    decoder: &dyn Decoder,
    groups: &[Vec<Vec<i32>>],
    k: usize,
) -> (f64, DecodeStats) {
    let mut stats = DecodeStats::default();
    let t0 = std::time::Instant::now();
    for g in groups {
        decoder
            .generate(model, g, k, &mut stats)
            .expect("decode failed");
    }
    (t0.elapsed().as_secs_f64(), stats)
}

fn main() -> Result<()> {
    let flags = Flags::parse();
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let n = flags.usize_or("n", 200);
    let k = flags.usize_or("k", 10);
    let runs = flags.usize_or("runs", 1);
    let batches: Vec<usize> = flags
        .str_or("batches", "1,4,8,16,32")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();

    let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
    let model: Box<dyn StepModel> = if flags.has("mock") {
        Box::new(MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() }))
    } else {
        Box::new(PjrtModel::load(&art)?)
    };
    let pairs = load_test_pairs(&art, n)?;
    let srcs: Vec<String> = pairs.iter().map(|p| p.product.clone()).collect();
    eprintln!(
        "table1: {} molecules, K={}, batches {:?}, runs {} (paper: 5007 molecules)",
        srcs.len(),
        k,
        batches,
        runs
    );
    warmup_model(model.as_ref(), &vocab, &srcs[0]);

    // algo name -> per-B (wall mean, wall std, calls, eff batch, acceptance)
    let algos: Vec<(&str, Box<dyn Fn(usize) -> Box<dyn Decoder>>)> = vec![
        ("Beam search", Box::new(|_b| Box::new(BeamSearch::vanilla()))),
        ("Beam search optimized", Box::new(|_b| Box::new(BeamSearch::optimized()))),
        ("HSBS", Box::new(|b| Box::new(Hsbs::for_batch_size(b)))),
        ("MSBS", Box::new(|_b| Box::new(Msbs::default()))),
    ];

    let mut wall: Vec<Vec<String>> = vec![Vec::new(); algos.len()];
    let mut calls: Vec<Vec<String>> = vec![Vec::new(); algos.len()];
    let mut eff: Vec<Vec<String>> = vec![Vec::new(); algos.len()];
    let mut acc: Vec<Vec<String>> = vec![Vec::new(); algos.len()];

    for &b in &batches {
        let groups = encode_groups(&vocab, &srcs, b, model.max_src());
        for (ai, (name, make)) in algos.iter().enumerate() {
            let decoder = make(b);
            // warm the buckets this (algo, B) combination needs
            let _ = run_algo(model.as_ref(), decoder.as_ref(), &groups[..1.min(groups.len())], k);
            let mut times = Vec::new();
            let mut last_stats = DecodeStats::default();
            for _ in 0..runs {
                let (t, s) = run_algo(model.as_ref(), decoder.as_ref(), &groups, k);
                times.push(t);
                last_stats = s;
            }
            eprintln!(
                "  B={b:<3} {name:<24} {:.2}s calls={} eff={:.0} acc={:.0}%",
                mean(&times),
                last_stats.model_calls,
                last_stats.avg_effective_batch(),
                last_stats.acceptance_rate() * 100.0
            );
            wall[ai].push(format!("{:.2} ± {:.2}", mean(&times), stddev(&times)));
            calls[ai].push(format!("{}", last_stats.model_calls));
            eff[ai].push(format!("{:.0}", last_stats.avg_effective_batch()));
            acc[ai].push(if name.contains("SBS") {
                format!("{:.0}", last_stats.acceptance_rate() * 100.0)
            } else {
                "-".to_string()
            });
        }
    }

    let header: Vec<String> = batches.iter().map(|b| format!("B={b}")).collect();
    println!("\n(A) Decoding wall time (K={k}), seconds");
    println!("{}", row("", &header));
    for (ai, (name, _)) in algos.iter().enumerate() {
        println!("{}", row(name, &wall[ai]));
    }
    println!("\n(B) Model calls (K={k})");
    println!("{}", row("", &header));
    for (ai, (name, _)) in algos.iter().enumerate() {
        println!("{}", row(name, &calls[ai]));
    }
    println!("\n(C) Average effective batch size (K={k})");
    println!("{}", row("", &header));
    for (ai, (name, _)) in algos.iter().enumerate() {
        println!("{}", row(name, &eff[ai]));
    }
    println!("\n(D) Acceptance rate (K={k}), %");
    println!("{}", row("", &header));
    for (ai, (name, _)) in algos.iter().enumerate() {
        if acc[ai].iter().any(|s| s != "-") {
            println!("{}", row(name, &acc[ai]));
        }
    }
    Ok(())
}
