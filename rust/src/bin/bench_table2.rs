//! Table 2 harness: single-step top-N accuracy and invalid-SMILES rate
//! per decoding strategy (BS / HSBS / MSBS; BS-optimized is
//! accuracy-identical to BS by construction and can be added with
//! `--with-bs-opt`).
//!
//! Accuracy: a prediction hits when its canonical sorted reactant set
//! equals the ground truth. Invalid%: the share of rank-N hypotheses
//! that fail SMILES parsing/valence validation.
//!
//! `bench_table2 [--artifacts DIR] [--n 500] [--k 10] [--b 8] [--mock]`

use anyhow::Result;
use retroserve::benchkit::{load_test_pairs, row, warmup_model, Flags};
use retroserve::chem;
use retroserve::decoding::{beam::BeamSearch, hsbs::Hsbs, msbs::Msbs, DecodeStats, Decoder};
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::model::StepModel;
use retroserve::runtime::PjrtModel;
use retroserve::tokenizer::Vocab;

struct Outcome {
    /// per sample: rank (0-based) of the first hit, if any
    hit_rank: Vec<Option<usize>>,
    /// [rank] -> (invalid count, total count)
    invalid: Vec<(usize, usize)>,
}

fn eval_algo(
    model: &dyn StepModel,
    decoder: &dyn Decoder,
    vocab: &Vocab,
    pairs: &[retroserve::benchkit::TestPair],
    b: usize,
    k: usize,
) -> Outcome {
    let mut hit_rank = Vec::with_capacity(pairs.len());
    let mut invalid = vec![(0usize, 0usize); k];
    let mut stats = DecodeStats::default();
    for chunk in pairs.chunks(b) {
        let srcs: Vec<Vec<i32>> = chunk.iter().map(|p| vocab.encode(&p.product, true)).collect();
        let outs = decoder.generate(model, &srcs, k, &mut stats).expect("decode");
        for (p, out) in chunk.iter().zip(outs.iter()) {
            let mut hit = None;
            for (rank, h) in out.hyps.iter().take(k).enumerate() {
                invalid[rank].1 += 1;
                let text = vocab.decode(h.body());
                let mut comps = Vec::new();
                let mut ok = h.finished();
                for part in chem::split_components(&text) {
                    match chem::canonicalize(part) {
                        Ok(c) => comps.push(c),
                        Err(_) => {
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok || comps.is_empty() {
                    invalid[rank].0 += 1;
                    continue;
                }
                comps.sort();
                if hit.is_none() && comps.join(".") == p.reactants {
                    hit = Some(rank);
                }
            }
            hit_rank.push(hit);
        }
    }
    Outcome { hit_rank, invalid }
}

fn main() -> Result<()> {
    let flags = Flags::parse();
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let n = flags.usize_or("n", 500);
    let k = flags.usize_or("k", 10);
    let b = flags.usize_or("b", 8);

    let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
    let model: Box<dyn StepModel> = if flags.has("mock") {
        Box::new(MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() }))
    } else {
        Box::new(PjrtModel::load(&art)?)
    };
    let pairs = load_test_pairs(&art, n)?;
    eprintln!("table2: {} samples, K={k}, batch {b} (paper: 5007)", pairs.len());
    warmup_model(model.as_ref(), &vocab, &pairs[0].product);

    let mut algos: Vec<(&str, Box<dyn Decoder>)> = vec![
        ("BEAM SEARCH", Box::new(BeamSearch::vanilla())),
        ("HSBS", Box::new(Hsbs::for_batch_size(b))),
        ("MSBS", Box::new(Msbs::default())),
    ];
    if flags.has("with-bs-opt") {
        algos.insert(1, ("BEAM SEARCH OPT", Box::new(BeamSearch::optimized())));
    }

    let ranks = [1usize, 3, 5, 10];
    let mut acc_rows: Vec<(String, Vec<String>)> = Vec::new();
    let mut inv_rows: Vec<(String, Vec<String>)> = Vec::new();
    for (name, decoder) in &algos {
        let t0 = std::time::Instant::now();
        let o = eval_algo(model.as_ref(), decoder.as_ref(), &vocab, &pairs, b, k);
        let total = o.hit_rank.len() as f64;
        let accs: Vec<String> = ranks
            .iter()
            .map(|&r| {
                let hits = o.hit_rank.iter().filter(|h| h.map(|x| x < r).unwrap_or(false)).count();
                format!("{:.2}", 100.0 * hits as f64 / total)
            })
            .collect();
        let invs: Vec<String> = ranks
            .iter()
            .map(|&r| {
                let (bad, tot) = o.invalid[r - 1];
                format!("{:.1}", 100.0 * bad as f64 / tot.max(1) as f64)
            })
            .collect();
        eprintln!("  {name:<18} top-1 {} ({:.1}s)", accs[0], t0.elapsed().as_secs_f64());
        acc_rows.push((name.to_string(), accs));
        inv_rows.push((name.to_string(), invs));
    }

    let header: Vec<String> = ranks.iter().map(|r| format!("Top-{r}")).collect();
    println!("\nAccuracy, % (N={} samples)", pairs.len());
    println!("{}", row("", &header));
    for (name, cols) in &acc_rows {
        println!("{}", row(name, cols));
    }
    let header2: Vec<String> = ranks.iter().map(|r| format!("Pred. {r}")).collect();
    println!("\nInvalid SMILES, %");
    println!("{}", row("", &header2));
    for (name, cols) in &inv_rows {
        println!("{}", row(name, cols));
    }
    Ok(())
}
