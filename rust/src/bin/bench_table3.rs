//! Table 3 harness: multi-step planning under deadlines —
//! BS vs MSBS as the single-step engine inside DFS and Retro\*.
//!
//! Reports, per (algorithm, deadline) condition: solved molecules,
//! common solved molecules, average time per solved molecule, average
//! time per common solved molecule, and average algorithm iterations
//! per common solved molecule — the exact rows of the paper's Table 3.
//!
//! `bench_table3 [--artifacts DIR] [--n 300] [--deadline-ms 5000]
//! [--deadline2-ms 15000] [--k 10] [--max-iterations 500] [--mock]
//! [--skip-dfs] [--oracle] [--share-cache]`
//!
//! Defaults scale the paper's 10k molecules down for the single-core
//! testbed; the deadline flags let the run mirror the paper's 5 s / 15 s.
//! `--share-cache` shares one molecule-keyed expansion cache across all
//! conditions using the same decoder (warm-cache serving semantics —
//! later conditions reuse earlier decodes); off by default to keep the
//! paper-faithful cold-cache runs.

use anyhow::Result;
use retroserve::benchkit::{load_queries, warmup_model, Flags};
use retroserve::decoding::make_decoder;
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::model::StepModel;
use retroserve::runtime::PjrtModel;
use retroserve::search::policy::{
    ModelPolicy, OraclePolicy, SharedExpansionCache, DEFAULT_CACHE_CAP,
};
use retroserve::search::{
    dfs::Dfs, retrostar::RetroStar, ExpansionPolicy, Planner, SearchLimits, Stock,
};
use retroserve::tokenizer::Vocab;
use std::collections::HashMap;

struct CondResult {
    solved: Vec<bool>,
    wall: Vec<f64>,
    iterations: Vec<usize>,
}

fn make_model(flags: &Flags, art: &std::path::Path, vocab: &Vocab) -> Result<Box<dyn StepModel>> {
    Ok(if flags.has("mock") {
        Box::new(MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() }))
    } else {
        Box::new(PjrtModel::load(art)?)
    })
}

#[allow(clippy::too_many_arguments)]
fn run_condition(
    flags: &Flags,
    art: &std::path::Path,
    vocab: &Vocab,
    stock: &Stock,
    queries: &[retroserve::benchkit::QueryRow],
    planner: &dyn Planner,
    decoder_name: &str,
    limits: &SearchLimits,
    cache: Option<SharedExpansionCache>,
) -> Result<CondResult> {
    // fresh model + policy per condition (no cache bleed between rows),
    // unless --share-cache passed a condition-spanning cache in
    let mut solved = Vec::with_capacity(queries.len());
    let mut wall = Vec::with_capacity(queries.len());
    let mut iterations = Vec::with_capacity(queries.len());
    let oracle = flags.has("oracle");
    let policy: Box<dyn ExpansionPolicy> = if oracle {
        Box::new(OraclePolicy::new())
    } else {
        let model = make_model(flags, art, vocab)?;
        warmup_model(model.as_ref(), vocab, &queries[0].smiles);
        let dec = make_decoder(decoder_name, 1)?;
        match cache {
            Some(c) => Box::new(ModelPolicy::with_shared_cache(model, dec, vocab.clone(), c)),
            None => Box::new(ModelPolicy::new(model, dec, vocab.clone())),
        }
    };
    for (i, q) in queries.iter().enumerate() {
        let r = planner.solve(&q.smiles, policy.as_ref(), stock, limits)?;
        solved.push(r.solved);
        wall.push(r.wall_secs);
        iterations.push(r.iterations);
        if (i + 1) % 50 == 0 {
            eprintln!(
                "    {}/{} solved so far {}",
                i + 1,
                queries.len(),
                solved.iter().filter(|&&s| s).count()
            );
        }
    }
    Ok(CondResult { solved, wall, iterations })
}

fn report(label: &str, bs: &CondResult, msbs: &CondResult) {
    let n = bs.solved.len();
    let count = |r: &CondResult| r.solved.iter().filter(|&&s| s).count();
    let common: Vec<usize> = (0..n).filter(|&i| bs.solved[i] && msbs.solved[i]).collect();
    let avg_solved = |r: &CondResult| {
        let xs: Vec<f64> = (0..n).filter(|&i| r.solved[i]).map(|i| r.wall[i]).collect();
        retroserve::util::stats::mean(&xs)
    };
    let avg_common_time = |r: &CondResult| {
        let xs: Vec<f64> = common.iter().map(|&i| r.wall[i]).collect();
        retroserve::util::stats::mean(&xs)
    };
    let avg_common_iters = |r: &CondResult| {
        let xs: Vec<f64> = common.iter().map(|&i| r.iterations[i] as f64).collect();
        retroserve::util::stats::mean(&xs)
    };
    println!("\n{label:<50} {:>10} {:>10}", "BS", "MSBS");
    println!("{:<50} {:>10} {:>10}", "SOLVED MOLECULES", count(bs), count(msbs));
    println!("{:<50} {:>21}", "COMMON SOLVED MOLECULES", common.len());
    println!(
        "{:<50} {:>10.2} {:>10.2}",
        "AVG. TIME PER SOLVED MOLECULE, S",
        avg_solved(bs),
        avg_solved(msbs)
    );
    println!(
        "{:<50} {:>10.2} {:>10.2}",
        "AVG. TIME PER COMMON SOLVED MOLECULE, S",
        avg_common_time(bs),
        avg_common_time(msbs)
    );
    println!(
        "{:<50} {:>10.2} {:>10.2}",
        "AVG. ALG. ITERATIONS PER COMMON SOLVED MOLECULE",
        avg_common_iters(bs),
        avg_common_iters(msbs)
    );
}

fn main() -> Result<()> {
    let flags = Flags::parse();
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let n = flags.usize_or("n", 300);
    let d1 = flags.usize_or("deadline-ms", 5000);
    let d2 = flags.usize_or("deadline2-ms", 15000);
    let k = flags.usize_or("k", 10);
    let max_iter = flags.usize_or("max-iterations", 500);

    let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
    let stock = Stock::load(art.join("stock.txt"))?;
    let queries = load_queries(&art, n)?;
    eprintln!(
        "table3: {} queries, deadlines {}ms/{}ms, k={k} (paper: 10000 queries, 5s/15s)",
        queries.len(),
        d1,
        d2
    );

    let limits = |ms: usize| SearchLimits {
        deadline: std::time::Duration::from_millis(ms as u64),
        max_iterations: max_iter,
        max_depth: 5,
        expansions_per_step: k,
        ..Default::default()
    };

    // --share-cache: one molecule-keyed cache per decoder, spanning
    // every condition that decoder appears in.
    let share = flags.has("share-cache");
    let mut caches: HashMap<&str, SharedExpansionCache> = HashMap::new();
    let mut cache_for = move |dec: &'static str| {
        share.then(|| {
            caches
                .entry(dec)
                .or_insert_with(|| SharedExpansionCache::new(DEFAULT_CACHE_CAP))
                .clone()
        })
    };

    // DFS, deadline 1
    if !flags.has("skip-dfs") {
        eprintln!("condition: DFS {}ms BS", d1);
        let bs = run_condition(
            &flags, &art, &vocab, &stock, &queries, &Dfs, "bs", &limits(d1), cache_for("bs"),
        )?;
        eprintln!("condition: DFS {}ms MSBS", d1);
        let ms = run_condition(
            &flags, &art, &vocab, &stock, &queries, &Dfs, "msbs", &limits(d1),
            cache_for("msbs"),
        )?;
        report(&format!("DFS, TIME LIMIT {:.0} SECONDS", d1 as f64 / 1e3), &bs, &ms);
    }

    // Retro*, deadline 1
    eprintln!("condition: Retro* {}ms BS", d1);
    let rs = RetroStar::new(1);
    let bs1 = run_condition(
        &flags, &art, &vocab, &stock, &queries, &rs, "bs", &limits(d1), cache_for("bs"),
    )?;
    eprintln!("condition: Retro* {}ms MSBS", d1);
    let ms1 = run_condition(
        &flags, &art, &vocab, &stock, &queries, &rs, "msbs", &limits(d1), cache_for("msbs"),
    )?;
    report(&format!("RETRO*, TIME LIMIT {:.0} SECONDS", d1 as f64 / 1e3), &bs1, &ms1);

    // Retro*, deadline 2
    eprintln!("condition: Retro* {}ms BS", d2);
    let bs2 = run_condition(
        &flags, &art, &vocab, &stock, &queries, &rs, "bs", &limits(d2), cache_for("bs"),
    )?;
    eprintln!("condition: Retro* {}ms MSBS", d2);
    let ms2 = run_condition(
        &flags, &art, &vocab, &stock, &queries, &rs, "msbs", &limits(d2), cache_for("msbs"),
    )?;
    report(&format!("RETRO*, TIME LIMIT {:.0} SECONDS", d2 as f64 / 1e3), &bs2, &ms2);

    Ok(())
}
