//! Table 4 harness: forcing batching into Retro\* via beam width.
//!
//! Conditions (matching the paper's rows): BS at Bw=1, MSBS at Bw=1,
//! BS-optimized at Bw=16, MSBS at Bw=16 — reporting solved-molecule
//! percentage and total wall time, at two deadlines.
//!
//! `bench_table4 [--artifacts DIR] [--n 300] [--deadline-ms 5000]
//! [--deadline2-ms 15000] [--k 10] [--max-iterations 500] [--mock]
//! [--share-cache]`
//!
//! `--share-cache` shares one molecule-keyed expansion cache across the
//! two deadline runs of each (decoder, Bw) condition — warm-cache
//! serving semantics; off by default for paper-faithful cold runs.

use anyhow::Result;
use retroserve::benchkit::{load_queries, warmup_model, Flags};
use retroserve::decoding::make_decoder;
use retroserve::model::mock::{MockConfig, MockModel};
use retroserve::model::StepModel;
use retroserve::runtime::PjrtModel;
use retroserve::search::policy::{ModelPolicy, SharedExpansionCache, DEFAULT_CACHE_CAP};
use retroserve::search::{retrostar::RetroStar, ExpansionPolicy, Planner, SearchLimits, Stock};
use retroserve::tokenizer::Vocab;
use std::collections::HashMap;

#[allow(clippy::too_many_arguments)]
fn run_condition(
    flags: &Flags,
    art: &std::path::Path,
    vocab: &Vocab,
    stock: &Stock,
    queries: &[retroserve::benchkit::QueryRow],
    decoder_name: &str,
    bw: usize,
    limits: &SearchLimits,
    cache: Option<SharedExpansionCache>,
) -> Result<(f64, f64)> {
    let model: Box<dyn StepModel> = if flags.has("mock") {
        Box::new(MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() }))
    } else {
        Box::new(PjrtModel::load(art)?)
    };
    warmup_model(model.as_ref(), vocab, &queries[0].smiles);
    let dec = make_decoder(decoder_name, bw)?;
    let policy: Box<dyn ExpansionPolicy> = match cache {
        Some(c) => Box::new(ModelPolicy::with_shared_cache(model, dec, vocab.clone(), c)),
        None => Box::new(ModelPolicy::new(model, dec, vocab.clone())),
    };
    let planner = RetroStar::new(bw);
    let t0 = std::time::Instant::now();
    let mut solved = 0usize;
    for (i, q) in queries.iter().enumerate() {
        let r = planner.solve(&q.smiles, policy.as_ref(), stock, limits)?;
        solved += r.solved as usize;
        if (i + 1) % 50 == 0 {
            eprintln!("    {}/{} solved {}", i + 1, queries.len(), solved);
        }
    }
    let total_h = t0.elapsed().as_secs_f64() / 3600.0;
    Ok((100.0 * solved as f64 / queries.len() as f64, total_h))
}

fn main() -> Result<()> {
    let flags = Flags::parse();
    let art = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let n = flags.usize_or("n", 300);
    let d1 = flags.usize_or("deadline-ms", 5000);
    let d2 = flags.usize_or("deadline2-ms", 15000);
    let k = flags.usize_or("k", 10);
    let max_iter = flags.usize_or("max-iterations", 500);
    let bw_wide = flags.usize_or("bw", 16);

    let vocab = Vocab::load(&art.join("vocab.json")).map_err(|e| anyhow::anyhow!(e))?;
    let stock = Stock::load(art.join("stock.txt"))?;
    let queries = load_queries(&art, n)?;
    eprintln!(
        "table4: {} queries, Retro*, Bw 1 vs {}, deadlines {}ms/{}ms (paper: 10000, 5s/15s)",
        queries.len(),
        bw_wide,
        d1,
        d2
    );

    let limits = |ms: usize| SearchLimits {
        deadline: std::time::Duration::from_millis(ms as u64),
        max_iterations: max_iter,
        max_depth: 5,
        expansions_per_step: k,
        ..Default::default()
    };

    // (label, decoder, beam width)
    let conditions: Vec<(&str, &str, usize)> = vec![
        ("BS", "bs", 1),
        ("MSBS", "msbs", 1),
        ("BS OPTIMIZED", "bs-opt", bw_wide),
        ("MSBS", "msbs", bw_wide),
    ];

    // --share-cache: one cache per (decoder, Bw), spanning deadlines.
    // hsbs's draft schedule depends on the batch hint, so Bw is part of
    // the key — a cache is an equivalence claim over decode outputs.
    let share = flags.has("share-cache");
    let mut caches: HashMap<(String, usize), SharedExpansionCache> = HashMap::new();

    for (section, dl) in [("(A)", d1), ("(B)", d2)] {
        println!(
            "\n{section} {}s LIMIT INFERENCE {:<14} {:>4} {:>22} {:>16}",
            dl as f64 / 1e3,
            "",
            "Bw",
            "Solved molecules, %",
            "Total time, h"
        );
        for (label, dec, bw) in &conditions {
            eprintln!("condition: {label} Bw={bw} deadline {dl}ms");
            let cache = share.then(|| {
                caches
                    .entry((dec.to_string(), *bw))
                    .or_insert_with(|| SharedExpansionCache::new(DEFAULT_CACHE_CAP))
                    .clone()
            });
            let (pct, hours) = run_condition(
                &flags, &art, &vocab, &stock, &queries, dec, *bw, &limits(dl), cache,
            )?;
            println!("{:<32} {:>4} {:>22.2} {:>16.3}", label, bw, pct, hours);
        }
    }
    Ok(())
}
