//! Data generation entrypoint.
//!
//! Writes the SynthChem data bundle into `artifacts/`:
//!
//! * `stock.txt` — building-block stock (one canonical SMILES per line);
//! * `dataset_train.tsv` — `src \t tgt` single-step pairs (augmented);
//! * `dataset_test.tsv` — `src \t tgt \t product \t reactants \t template`;
//! * `queries10k.tsv` — `smiles \t depth \t solvable_hint` planning queries;
//! * `vocab.json` — atomwise token vocabulary (shared with Python);
//! * `data_manifest.json` — config echo + corpus statistics.
//!
//! Usage: `datagen [--out DIR] [--seed N] [--train N] [--test N]
//! [--queries N] [--stock N] [--aug N] [--quick]`

use retroserve::jsonx::Json;
use retroserve::synthchem::gen::{generate, GenConfig};
use retroserve::tokenizer::Vocab;
use std::io::Write;
use std::path::PathBuf;

fn parse_args() -> (PathBuf, GenConfig) {
    let mut out = PathBuf::from("artifacts");
    let mut cfg = GenConfig::default();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let take = |i: &mut usize| -> String {
            *i += 1;
            args.get(*i).unwrap_or_else(|| panic!("missing value for {}", args[*i - 1])).clone()
        };
        match args[i].as_str() {
            "--out" => out = PathBuf::from(take(&mut i)),
            "--seed" => cfg.seed = take(&mut i).parse().expect("seed"),
            "--train" => cfg.train_reactions = take(&mut i).parse().expect("train"),
            "--test" => cfg.test_reactions = take(&mut i).parse().expect("test"),
            "--queries" => cfg.queries = take(&mut i).parse().expect("queries"),
            "--stock" => cfg.stock_size = take(&mut i).parse().expect("stock"),
            "--aug" => cfg.augmentation = take(&mut i).parse().expect("aug"),
            "--quick" => {
                cfg.stock_size = 2000;
                cfg.shadow_blocks = 300;
                cfg.train_reactions = 1500;
                cfg.test_reactions = 500;
                cfg.queries = 1000;
            }
            other => panic!("unknown argument {other}"),
        }
        i += 1;
    }
    (out, cfg)
}

fn main() -> anyhow::Result<()> {
    let (out, cfg) = parse_args();
    std::fs::create_dir_all(&out)?;
    eprintln!(
        "datagen: stock={} train={} (x{} aug) test={} queries={} seed={}",
        cfg.stock_size, cfg.train_reactions, cfg.augmentation, cfg.test_reactions, cfg.queries,
        cfg.seed
    );
    let t0 = std::time::Instant::now();
    let bundle = generate(&cfg);
    eprintln!(
        "generated in {:.1}s: stock={} train={} test={} queries={}",
        t0.elapsed().as_secs_f64(),
        bundle.stock.len(),
        bundle.train.len(),
        bundle.test.len(),
        bundle.queries.len()
    );

    // stock
    let mut f = std::io::BufWriter::new(std::fs::File::create(out.join("stock.txt"))?);
    for s in &bundle.stock {
        writeln!(f, "{s}")?;
    }
    drop(f);

    // train/test pairs
    let mut f = std::io::BufWriter::new(std::fs::File::create(out.join("dataset_train.tsv"))?);
    for p in &bundle.train {
        writeln!(f, "{}\t{}", p.src, p.tgt)?;
    }
    drop(f);
    let mut f = std::io::BufWriter::new(std::fs::File::create(out.join("dataset_test.tsv"))?);
    for p in &bundle.test {
        writeln!(
            f,
            "{}\t{}\t{}\t{}\t{}",
            p.src,
            p.tgt,
            p.product_canonical,
            p.reactants_canonical,
            p.template.name()
        )?;
    }
    drop(f);

    // queries
    let mut f = std::io::BufWriter::new(std::fs::File::create(out.join("queries10k.tsv"))?);
    for q in &bundle.queries {
        writeln!(f, "{}\t{}\t{}", q.smiles, q.depth, q.solvable_hint as u8)?;
    }
    drop(f);

    // vocabulary over all strings the model will ever see
    let corpus: Vec<&str> = bundle
        .train
        .iter()
        .flat_map(|p| [p.src.as_str(), p.tgt.as_str()])
        .chain(bundle.test.iter().flat_map(|p| [p.src.as_str(), p.tgt.as_str()]))
        .chain(bundle.stock.iter().map(|s| s.as_str()))
        .chain(bundle.queries.iter().map(|q| q.smiles.as_str()))
        .collect();
    let vocab = Vocab::build(corpus);
    std::fs::write(out.join("vocab.json"), vocab.to_json().to_string())?;

    // statistics for the manifest (drives MAX_LEN choices downstream)
    let tok_len = |s: &str| retroserve::tokenizer::tokenize(s).len();
    let mut src_max = 0usize;
    let mut tgt_max = 0usize;
    let mut src_sum = 0usize;
    let mut tgt_sum = 0usize;
    for p in bundle.train.iter().chain(bundle.test.iter()) {
        let a = tok_len(&p.src);
        let b = tok_len(&p.tgt);
        src_max = src_max.max(a);
        tgt_max = tgt_max.max(b);
        src_sum += a;
        tgt_sum += b;
    }
    let npairs = bundle.train.len() + bundle.test.len();
    let manifest = Json::obj(vec![
        ("seed", Json::num(cfg.seed as f64)),
        ("stock", Json::num(bundle.stock.len() as f64)),
        ("train_pairs", Json::num(bundle.train.len() as f64)),
        ("test_pairs", Json::num(bundle.test.len() as f64)),
        ("queries", Json::num(bundle.queries.len() as f64)),
        ("augmentation", Json::num(cfg.augmentation as f64)),
        ("vocab_size", Json::num(vocab.len() as f64)),
        ("src_tokens_max", Json::num(src_max as f64)),
        ("tgt_tokens_max", Json::num(tgt_max as f64)),
        ("src_tokens_mean", Json::num(src_sum as f64 / npairs.max(1) as f64)),
        ("tgt_tokens_mean", Json::num(tgt_sum as f64 / npairs.max(1) as f64)),
    ]);
    std::fs::write(out.join("data_manifest.json"), manifest.to_string())?;
    eprintln!(
        "vocab={} src_max={} tgt_max={} src_mean={:.1} tgt_mean={:.1}",
        vocab.len(),
        src_max,
        tgt_max,
        src_sum as f64 / npairs.max(1) as f64,
        tgt_sum as f64 / npairs.max(1) as f64
    );
    eprintln!("datagen: wrote artifacts to {}", out.display());
    Ok(())
}
