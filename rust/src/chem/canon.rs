//! Morgan-style canonical atom ranking.
//!
//! Canonical ranks are computed by iterative partition refinement over
//! atom invariants, with deterministic tie-breaking (the classic
//! canonical-labelling loop): refine until stable; if ties remain,
//! artificially single out the lowest-index atom in the first tied class
//! and refine again. The result is a permutation `rank[atom] ∈ 0..n`
//! that is invariant under graph isomorphism, which [`super::writer`]
//! turns into a canonical SMILES string.

use super::Molecule;

/// Initial atom invariant: everything locally observable.
fn initial_invariant(m: &Molecule, v: usize, ring_atom: &[bool]) -> u64 {
    let a = &m.atoms[v];
    let h = super::valence::total_h(m, v).unwrap_or(0) as u64;
    let mut x: u64 = a.element.atomic_number() as u64;
    x = x * 2 + a.aromatic as u64;
    x = x * 16 + (a.charge as i64 + 8) as u64;
    x = x * 16 + h;
    x = x * 8 + m.degree(v) as u64;
    x = x * 2 + ring_atom[v] as u64;
    x = x * 8 + super::valence::bond_order_sum_x2(m, v) as u64 % 8;
    x
}

/// Compute canonical ranks: `rank[v]` in `[0, n)`, all distinct.
pub fn canonical_ranks(m: &Molecule) -> Vec<usize> {
    let n = m.num_atoms();
    if n == 0 {
        return Vec::new();
    }
    let ring_atom = m.ring_atoms();
    // Start from sorted initial invariants -> dense ranks.
    let inv: Vec<u64> = (0..n).map(|v| initial_invariant(m, v, &ring_atom)).collect();
    let mut rank = dense_ranks_u64(&inv);

    loop {
        rank = refine(m, rank);
        let classes = num_classes(&rank);
        if classes == n {
            return rank;
        }
        // Tie-break: find the first class with >1 member (by class rank),
        // demote the member with the lowest atom index, refine again.
        let mut chosen: Option<usize> = None;
        let mut best_class = usize::MAX;
        for v in 0..n {
            let mut count = 0;
            let mut lowest = usize::MAX;
            if rank[v] < best_class {
                for u in 0..n {
                    if rank[u] == rank[v] {
                        count += 1;
                        lowest = lowest.min(u);
                    }
                }
                if count > 1 {
                    best_class = rank[v];
                    chosen = Some(lowest);
                }
            }
        }
        let c = chosen.expect("ties imply a multi-member class");
        // Give the chosen atom a strictly smaller rank than its classmates:
        // everyone maps to 2r+1, the chosen atom to 2r.
        for r in rank.iter_mut() {
            *r = *r * 2 + 1;
        }
        rank[c] -= 1;
        rank = dense_ranks_usize(&rank);
    }
}

/// One sweep of neighborhood refinement until the partition stops
/// splitting.
fn refine(m: &Molecule, mut rank: Vec<usize>) -> Vec<usize> {
    let n = m.num_atoms();
    loop {
        // Signature: own rank + sorted (bond order, neighbor rank) pairs.
        let mut sigs: Vec<(usize, Vec<(u8, usize)>)> = Vec::with_capacity(n);
        for v in 0..n {
            let mut nb: Vec<(u8, usize)> = m
                .neighbors(v)
                .iter()
                .map(|&(u, bi)| (m.bonds[bi].order.valence_x2(), rank[u]))
                .collect();
            nb.sort_unstable();
            sigs.push((rank[v], nb));
        }
        let new_rank = dense_ranks_by(&sigs);
        let stable = new_rank == rank;
        rank = new_rank;
        if stable {
            return rank;
        }
    }
}

fn num_classes(rank: &[usize]) -> usize {
    let mut seen = vec![false; rank.len()];
    let mut c = 0;
    for &r in rank {
        if !seen[r] {
            seen[r] = true;
            c += 1;
        }
    }
    c
}

fn dense_ranks_u64(keys: &[u64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..keys.len()).collect();
    idx.sort_by_key(|&i| keys[i]);
    let mut rank = vec![0usize; keys.len()];
    let mut r = 0;
    for w in 0..idx.len() {
        if w > 0 && keys[idx[w]] != keys[idx[w - 1]] {
            r += 1;
        }
        rank[idx[w]] = r;
    }
    rank
}

fn dense_ranks_usize(keys: &[usize]) -> Vec<usize> {
    let as64: Vec<u64> = keys.iter().map(|&k| k as u64).collect();
    dense_ranks_u64(&as64)
}

fn dense_ranks_by<T: Ord>(keys: &[T]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..keys.len()).collect();
    idx.sort_by(|&a, &b| keys[a].cmp(&keys[b]));
    let mut rank = vec![0usize; keys.len()];
    let mut r = 0;
    for w in 0..idx.len() {
        if w > 0 && keys[idx[w]] != keys[idx[w - 1]] {
            r += 1;
        }
        rank[idx[w]] = r;
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chem::parse_smiles;

    #[test]
    fn ranks_are_permutation() {
        for s in ["CCO", "c1ccccc1", "CC(C)(C)OC(=O)N", "c1ccc2ccccc2c1"] {
            let m = parse_smiles(s).unwrap();
            let mut r = canonical_ranks(&m);
            r.sort_unstable();
            assert_eq!(r, (0..m.num_atoms()).collect::<Vec<_>>(), "{s}");
        }
    }

    #[test]
    fn symmetric_atoms_break_ties_deterministically() {
        // benzene: all atoms equivalent; ranks still a permutation and
        // stable across calls.
        let m = parse_smiles("c1ccccc1").unwrap();
        assert_eq!(canonical_ranks(&m), canonical_ranks(&m));
    }

    #[test]
    fn distinguishes_inequivalent_atoms() {
        // In CCO the two carbons are inequivalent; check the O always has
        // a distinct rank.
        let m = parse_smiles("CCO").unwrap();
        let r = canonical_ranks(&m);
        assert_ne!(r[0], r[1]);
        assert_ne!(r[1], r[2]);
    }
}
