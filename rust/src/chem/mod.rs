//! # chem — a from-scratch SMILES/molecular-graph substrate
//!
//! The request path needs chemistry primitives (parsing model output,
//! validity checks, canonicalization for stock lookup and deduplication)
//! and the build image ships no RDKit, so this module implements the
//! subset of cheminformatics the system needs:
//!
//! * a SMILES parser ([`parse_smiles`]) for organic-subset atoms,
//!   brackets with charge/explicit-H, branches, ring closures and
//!   aromatic lowercase notation;
//! * a molecular graph ([`Molecule`]) with valence/implicit-hydrogen
//!   accounting ([`valence`]);
//! * Morgan-style canonical ranking ([`canon`]) and a canonical/rooted
//!   SMILES writer ([`writer`]) — the pair gives us canonical SMILES
//!   (`canonical_smiles`) and R-SMILES-style root-aligned augmentation
//!   (`rooted_smiles`).
//!
//! Scope note: no stereochemistry, no isotopes — the SynthChem reaction
//! world (see [`crate::synthchem`]) does not generate them, matching how
//! the paper's USPTO-50K preprocessing strips stereo-unfriendly entries.

pub mod canon;
pub mod parser;
pub mod valence;
pub mod writer;

use std::fmt;

/// Chemical elements supported by the SynthChem world.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Element {
    B,
    C,
    N,
    O,
    S,
    P,
    F,
    Cl,
    Br,
    I,
}

impl Element {
    /// Standard atomic symbol.
    pub fn symbol(self) -> &'static str {
        match self {
            Element::B => "B",
            Element::C => "C",
            Element::N => "N",
            Element::O => "O",
            Element::S => "S",
            Element::P => "P",
            Element::F => "F",
            Element::Cl => "Cl",
            Element::Br => "Br",
            Element::I => "I",
        }
    }

    /// Allowed total valences (bond order sum + implicit H), neutral atom.
    pub fn valences(self) -> &'static [u8] {
        match self {
            Element::B => &[3],
            Element::C => &[4],
            Element::N => &[3],
            Element::O => &[2],
            Element::S => &[2, 4, 6],
            Element::P => &[3, 5],
            Element::F | Element::Cl | Element::Br | Element::I => &[1],
        }
    }

    /// Whether the element may be written in aromatic (lowercase) form.
    pub fn can_be_aromatic(self) -> bool {
        matches!(self, Element::B | Element::C | Element::N | Element::O | Element::S | Element::P)
    }

    /// Atomic number (used as a canonical-invariant component).
    pub fn atomic_number(self) -> u8 {
        match self {
            Element::B => 5,
            Element::C => 6,
            Element::N => 7,
            Element::O => 8,
            Element::P => 15,
            Element::S => 16,
            Element::F => 9,
            Element::Cl => 17,
            Element::Br => 35,
            Element::I => 53,
        }
    }
}

/// Bond order. Aromatic bonds participate in valence as 1.5 (see
/// [`valence`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BondOrder {
    Single,
    Double,
    Triple,
    Aromatic,
}

impl BondOrder {
    /// Contribution to an atom's valence, doubled to stay integral
    /// (Single=2, Double=4, Triple=6, Aromatic=3).
    pub fn valence_x2(self) -> u8 {
        match self {
            BondOrder::Single => 2,
            BondOrder::Double => 4,
            BondOrder::Triple => 6,
            BondOrder::Aromatic => 3,
        }
    }
}

/// An atom node in the molecular graph.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Atom {
    pub element: Element,
    pub aromatic: bool,
    pub charge: i8,
    /// Hydrogen count if fixed by a bracket spec (e.g. `[nH]`).
    pub explicit_h: Option<u8>,
}

impl Atom {
    pub fn new(element: Element) -> Self {
        Self { element, aromatic: false, charge: 0, explicit_h: None }
    }

    pub fn aromatic(element: Element) -> Self {
        Self { element, aromatic: true, charge: 0, explicit_h: None }
    }
}

/// An edge in the molecular graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bond {
    pub a: usize,
    pub b: usize,
    pub order: BondOrder,
}

impl Bond {
    /// The endpoint that is not `v`.
    pub fn other(&self, v: usize) -> usize {
        if self.a == v {
            self.b
        } else {
            self.a
        }
    }
}

/// A connected molecular graph.
#[derive(Clone, Debug, Default)]
pub struct Molecule {
    pub atoms: Vec<Atom>,
    pub bonds: Vec<Bond>,
    /// Adjacency: for every atom, `(neighbor_atom, bond_index)` pairs in
    /// insertion order.
    adj: Vec<Vec<(usize, usize)>>,
}

impl Molecule {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_atom(&mut self, atom: Atom) -> usize {
        self.atoms.push(atom);
        self.adj.push(Vec::new());
        self.atoms.len() - 1
    }

    /// Add a bond; endpoints must exist and be distinct, duplicate bonds
    /// are rejected.
    pub fn add_bond(&mut self, a: usize, b: usize, order: BondOrder) -> Result<usize, ChemError> {
        if a == b || a >= self.atoms.len() || b >= self.atoms.len() {
            return Err(ChemError::Graph(format!("bad bond endpoints {a}-{b}")));
        }
        if self.adj[a].iter().any(|&(n, _)| n == b) {
            return Err(ChemError::Graph(format!("duplicate bond {a}-{b}")));
        }
        let idx = self.bonds.len();
        self.bonds.push(Bond { a, b, order });
        self.adj[a].push((b, idx));
        self.adj[b].push((a, idx));
        Ok(idx)
    }

    pub fn num_atoms(&self) -> usize {
        self.atoms.len()
    }

    pub fn num_bonds(&self) -> usize {
        self.bonds.len()
    }

    /// Neighbors of atom `v` as `(neighbor, bond_index)` pairs.
    pub fn neighbors(&self, v: usize) -> &[(usize, usize)] {
        &self.adj[v]
    }

    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    /// Bond between `a` and `b` if present.
    pub fn bond_between(&self, a: usize, b: usize) -> Option<&Bond> {
        self.adj[a]
            .iter()
            .find(|&&(n, _)| n == b)
            .map(|&(_, bi)| &self.bonds[bi])
    }

    /// True if the graph is connected (single fragment). Empty = false.
    pub fn is_connected(&self) -> bool {
        if self.atoms.is_empty() {
            return false;
        }
        let mut seen = vec![false; self.atoms.len()];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &(n, _) in &self.adj[v] {
                if !seen[n] {
                    seen[n] = true;
                    count += 1;
                    stack.push(n);
                }
            }
        }
        count == self.atoms.len()
    }

    /// Bond indices that lie on at least one cycle (non-bridge edges),
    /// via bridge-finding DFS.
    pub fn ring_bonds(&self) -> Vec<bool> {
        let n = self.atoms.len();
        let mut is_ring = vec![false; self.bonds.len()];
        if n == 0 {
            return is_ring;
        }
        let mut disc = vec![usize::MAX; n];
        let mut low = vec![0usize; n];
        let mut timer = 0usize;
        // Iterative DFS computing bridges; every non-bridge edge is a ring bond.
        for start in 0..n {
            if disc[start] != usize::MAX {
                continue;
            }
            // (vertex, parent_bond, neighbor cursor)
            let mut stack: Vec<(usize, usize, usize)> = vec![(start, usize::MAX, 0)];
            disc[start] = timer;
            low[start] = timer;
            timer += 1;
            while let Some(&mut (v, pbond, ref mut cursor)) = stack.last_mut() {
                if *cursor < self.adj[v].len() {
                    let (n2, bi) = self.adj[v][*cursor];
                    *cursor += 1;
                    if bi == pbond {
                        continue;
                    }
                    if disc[n2] == usize::MAX {
                        disc[n2] = timer;
                        low[n2] = timer;
                        timer += 1;
                        stack.push((n2, bi, 0));
                    } else {
                        // back edge -> on a cycle
                        low[v] = low[v].min(disc[n2]);
                        is_ring[bi] = true;
                    }
                } else {
                    stack.pop();
                    if let Some(&mut (parent, _, _)) = stack.last_mut() {
                        low[parent] = low[parent].min(low[v]);
                        if low[v] <= disc[parent] {
                            // v..parent edge is on a cycle
                            is_ring[pbond] = true;
                        }
                    }
                }
            }
        }
        is_ring
    }

    /// Atom indices that lie on at least one cycle.
    pub fn ring_atoms(&self) -> Vec<bool> {
        let ring_bonds = self.ring_bonds();
        let mut out = vec![false; self.atoms.len()];
        for (bi, bond) in self.bonds.iter().enumerate() {
            if ring_bonds[bi] {
                out[bond.a] = true;
                out[bond.b] = true;
            }
        }
        out
    }

    /// Molecular formula-ish summary used in tests/debugging, e.g. "C6H6".
    pub fn formula(&self) -> String {
        use std::collections::BTreeMap;
        let mut counts: BTreeMap<&'static str, usize> = BTreeMap::new();
        let mut h = 0usize;
        for (i, a) in self.atoms.iter().enumerate() {
            *counts.entry(a.element.symbol()).or_insert(0) += 1;
            h += valence::implicit_h(self, i).unwrap_or(0) as usize;
            h += a.explicit_h.unwrap_or(0) as usize;
        }
        let mut s = String::new();
        for (sym, c) in counts {
            s.push_str(sym);
            if c > 1 {
                s.push_str(&c.to_string());
            }
        }
        if h > 0 {
            s.push('H');
            if h > 1 {
                s.push_str(&h.to_string());
            }
        }
        s
    }
}

/// Errors from parsing/validity/graph manipulation.
#[derive(Debug, thiserror::Error)]
pub enum ChemError {
    #[error("SMILES parse error at {pos}: {msg}")]
    Parse { pos: usize, msg: String },
    #[error("valence error on atom {atom}: {msg}")]
    Valence { atom: usize, msg: String },
    #[error("graph error: {msg}", msg = .0)]
    Graph(String),
}

impl fmt::Display for Molecule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", writer::canonical_smiles(self))
    }
}

/// Parse a single-fragment SMILES string (no `.`).
pub fn parse_smiles(s: &str) -> Result<Molecule, ChemError> {
    parser::parse(s)
}

/// Parse and fully validate: connected, valence-sane, aromatic atoms in
/// rings. This is the notion of "valid SMILES" used by the Table 2
/// metrics.
pub fn parse_validated(s: &str) -> Result<Molecule, ChemError> {
    let m = parser::parse(s)?;
    valence::validate(&m)?;
    Ok(m)
}

/// Canonical SMILES of a molecule.
pub fn canonical_smiles(m: &Molecule) -> String {
    writer::canonical_smiles(m)
}

/// Canonicalize a SMILES string (parse → validate → canonical write).
pub fn canonicalize(s: &str) -> Result<String, ChemError> {
    Ok(writer::canonical_smiles(&parse_validated(s)?))
}

/// Canonical cache key for every molecule-keyed tier (the policy and
/// hub expansion caches, the in-flight dedup registry, the persistent
/// store): canonical SMILES when the input parses as one molecule, the
/// raw string otherwise. The fallback keeps multi-fragment reactant
/// sets and unparsable probes cacheable under a stable key instead of
/// erroring, and makes the function idempotent — serving paths that
/// already canonicalized pay only a re-canonicalization that returns
/// the same string, so keyed behavior cannot fork between the server
/// (which canonicalizes requests) and offline benches (which did not).
pub fn cache_key(s: &str) -> String {
    canonicalize(s).unwrap_or_else(|_| s.to_string())
}

/// Split a reactant-set string on `.` into individual SMILES.
pub fn split_components(s: &str) -> Vec<&str> {
    s.split('.').filter(|p| !p.is_empty()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_valences() {
        assert_eq!(Element::C.valences(), &[4]);
        assert_eq!(Element::S.valences(), &[2, 4, 6]);
        assert!(!Element::F.can_be_aromatic());
    }

    #[test]
    fn graph_basics() {
        let mut m = Molecule::new();
        let a = m.add_atom(Atom::new(Element::C));
        let b = m.add_atom(Atom::new(Element::O));
        m.add_bond(a, b, BondOrder::Single).unwrap();
        assert_eq!(m.num_atoms(), 2);
        assert_eq!(m.degree(a), 1);
        assert!(m.bond_between(a, b).is_some());
        assert!(m.is_connected());
        // Duplicate bond rejected
        assert!(m.add_bond(a, b, BondOrder::Single).is_err());
        // Self-bond rejected
        assert!(m.add_bond(a, a, BondOrder::Single).is_err());
    }

    #[test]
    fn ring_detection_benzene_plus_tail() {
        // c1ccccc1C — ring bonds are the 6 aromatic ones, not the tail.
        let m = parse_smiles("c1ccccc1C").unwrap();
        let ring = m.ring_bonds();
        assert_eq!(ring.iter().filter(|&&x| x).count(), 6);
        let ring_atoms = m.ring_atoms();
        assert_eq!(ring_atoms.iter().filter(|&&x| x).count(), 6);
    }

    #[test]
    fn ring_detection_fused() {
        // naphthalene: 11 ring bonds, all 10 atoms in rings
        let m = parse_smiles("c1ccc2ccccc2c1").unwrap();
        assert_eq!(m.ring_bonds().iter().filter(|&&x| x).count(), 11);
        assert_eq!(m.ring_atoms().iter().filter(|&&x| x).count(), 10);
    }

    #[test]
    fn formula_smoke() {
        let m = parse_smiles("CCO").unwrap();
        assert_eq!(m.formula(), "C2OH6");
        let benzene = parse_smiles("c1ccccc1").unwrap();
        assert_eq!(benzene.formula(), "C6H6");
    }

    #[test]
    fn split_components_basic() {
        assert_eq!(split_components("CC.O"), vec!["CC", "O"]);
        assert_eq!(split_components("CC"), vec!["CC"]);
    }
}
