//! SMILES parser.
//!
//! Supported grammar (the subset the SynthChem world and the model's
//! vocabulary can produce):
//!
//! * organic-subset atoms: `B C N O S P F Cl Br I` and aromatic
//!   `b c n o s p`;
//! * bracket atoms `[<symbol><Hn><+/-n>]` (charge and explicit hydrogen
//!   count; no isotopes, no atom maps, no stereo `@`);
//! * bonds `- = # :` (`/` and `\` are accepted and treated as single);
//! * branches `( ... )`;
//! * ring closures `1`-`9` and `%nn`, with optional bond symbol before
//!   the digit.
//!
//! `.` (fragment separator) is rejected here; callers split reactant sets
//! with [`crate::chem::split_components`] first.

use super::{Atom, BondOrder, ChemError, Element, Molecule};

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
    mol: Molecule,
    /// Stack of "previous atom" indices for branch handling.
    stack: Vec<usize>,
    prev: Option<usize>,
    /// Pending bond symbol to apply to the next atom/ring closure.
    pending_bond: Option<BondOrder>,
    /// Open ring closures: digit -> (atom, bond override at open site).
    rings: Vec<Option<(usize, Option<BondOrder>)>>,
}

fn err(pos: usize, msg: impl Into<String>) -> ChemError {
    ChemError::Parse { pos, msg: msg.into() }
}

/// Parse a single-fragment SMILES string into a [`Molecule`].
pub fn parse(s: &str) -> Result<Molecule, ChemError> {
    if s.is_empty() {
        return Err(err(0, "empty SMILES"));
    }
    let mut p = Parser {
        src: s.as_bytes(),
        pos: 0,
        mol: Molecule::new(),
        stack: Vec::new(),
        prev: None,
        pending_bond: None,
        rings: vec![None; 100],
    };
    p.run()?;
    if p.rings.iter().any(|r| r.is_some()) {
        return Err(err(s.len(), "unclosed ring bond"));
    }
    if !p.stack.is_empty() {
        return Err(err(s.len(), "unclosed branch"));
    }
    if p.pending_bond.is_some() {
        return Err(err(s.len(), "dangling bond symbol"));
    }
    if p.mol.atoms.is_empty() {
        return Err(err(0, "no atoms"));
    }
    Ok(p.mol)
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn run(&mut self) -> Result<(), ChemError> {
        while let Some(c) = self.peek() {
            match c {
                b'(' => {
                    self.pos += 1;
                    let prev = self
                        .prev
                        .ok_or_else(|| err(self.pos, "branch before any atom"))?;
                    self.stack.push(prev);
                }
                b')' => {
                    self.pos += 1;
                    if self.pending_bond.is_some() {
                        return Err(err(self.pos, "bond symbol before ')'"));
                    }
                    let top = self
                        .stack
                        .pop()
                        .ok_or_else(|| err(self.pos, "unmatched ')'"))?;
                    self.prev = Some(top);
                }
                b'-' => {
                    self.pos += 1;
                    self.set_bond(BondOrder::Single)?;
                }
                b'=' => {
                    self.pos += 1;
                    self.set_bond(BondOrder::Double)?;
                }
                b'#' => {
                    self.pos += 1;
                    self.set_bond(BondOrder::Triple)?;
                }
                b':' => {
                    self.pos += 1;
                    self.set_bond(BondOrder::Aromatic)?;
                }
                b'/' | b'\\' => {
                    // stereo bonds degrade to single
                    self.pos += 1;
                    self.set_bond(BondOrder::Single)?;
                }
                b'0'..=b'9' => {
                    self.pos += 1;
                    self.ring_closure((c - b'0') as usize)?;
                }
                b'%' => {
                    self.pos += 1;
                    let d1 = self.bump().ok_or_else(|| err(self.pos, "EOF after %"))?;
                    let d2 = self.bump().ok_or_else(|| err(self.pos, "EOF after %d"))?;
                    if !(d1.is_ascii_digit() && d2.is_ascii_digit()) {
                        return Err(err(self.pos, "bad %nn ring index"));
                    }
                    let idx = ((d1 - b'0') as usize) * 10 + (d2 - b'0') as usize;
                    self.ring_closure(idx)?;
                }
                b'[' => {
                    self.pos += 1;
                    let atom = self.parse_bracket()?;
                    self.attach(atom)?;
                }
                b'.' => {
                    return Err(err(self.pos, "multi-fragment SMILES not allowed here"));
                }
                _ => {
                    let atom = self.parse_organic()?;
                    self.attach(atom)?;
                }
            }
        }
        Ok(())
    }

    fn set_bond(&mut self, order: BondOrder) -> Result<(), ChemError> {
        if self.pending_bond.is_some() {
            return Err(err(self.pos, "two consecutive bond symbols"));
        }
        if self.prev.is_none() {
            return Err(err(self.pos, "bond symbol before any atom"));
        }
        self.pending_bond = Some(order);
        Ok(())
    }

    /// Default bond order between two atoms: aromatic if both aromatic,
    /// else single.
    fn default_bond(&self, a: usize, b: usize) -> BondOrder {
        if self.mol.atoms[a].aromatic && self.mol.atoms[b].aromatic {
            BondOrder::Aromatic
        } else {
            BondOrder::Single
        }
    }

    fn attach(&mut self, atom: Atom) -> Result<(), ChemError> {
        let idx = self.mol.add_atom(atom);
        if let Some(prev) = self.prev {
            let order = self
                .pending_bond
                .take()
                .unwrap_or_else(|| self.default_bond(prev, idx));
            self.mol
                .add_bond(prev, idx, order)
                .map_err(|e| err(self.pos, e.to_string()))?;
        } else if self.pending_bond.is_some() {
            return Err(err(self.pos, "bond before first atom"));
        }
        self.prev = Some(idx);
        Ok(())
    }

    fn ring_closure(&mut self, digit: usize) -> Result<(), ChemError> {
        let cur = self
            .prev
            .ok_or_else(|| err(self.pos, "ring digit before any atom"))?;
        let pend = self.pending_bond.take();
        match self.rings[digit].take() {
            None => {
                self.rings[digit] = Some((cur, pend));
            }
            Some((open_atom, open_bond)) => {
                if open_atom == cur {
                    return Err(err(self.pos, "ring bond to self"));
                }
                // Bond order: explicit symbol at either site wins (they must
                // agree if both given), else default.
                let order = match (open_bond, pend) {
                    (Some(a), Some(b)) if a != b => {
                        return Err(err(self.pos, "conflicting ring bond orders"))
                    }
                    (Some(a), _) => a,
                    (_, Some(b)) => b,
                    (None, None) => self.default_bond(open_atom, cur),
                };
                self.mol
                    .add_bond(open_atom, cur, order)
                    .map_err(|e| err(self.pos, e.to_string()))?;
            }
        }
        Ok(())
    }

    fn parse_organic(&mut self) -> Result<Atom, ChemError> {
        let c = self.bump().ok_or_else(|| err(self.pos, "EOF"))?;
        let (element, aromatic) = match c {
            b'C' => {
                if self.peek() == Some(b'l') {
                    self.pos += 1;
                    (Element::Cl, false)
                } else {
                    (Element::C, false)
                }
            }
            b'B' => {
                if self.peek() == Some(b'r') {
                    self.pos += 1;
                    (Element::Br, false)
                } else {
                    (Element::B, false)
                }
            }
            b'N' => (Element::N, false),
            b'O' => (Element::O, false),
            b'S' => (Element::S, false),
            b'P' => (Element::P, false),
            b'F' => (Element::F, false),
            b'I' => (Element::I, false),
            b'c' => (Element::C, true),
            b'n' => (Element::N, true),
            b'o' => (Element::O, true),
            b's' => (Element::S, true),
            b'p' => (Element::P, true),
            b'b' => (Element::B, true),
            other => {
                return Err(err(
                    self.pos,
                    format!("unexpected character '{}'", other as char),
                ))
            }
        };
        Ok(Atom { element, aromatic, charge: 0, explicit_h: None })
    }

    fn parse_bracket(&mut self) -> Result<Atom, ChemError> {
        // symbol
        let c = self.bump().ok_or_else(|| err(self.pos, "EOF in bracket"))?;
        let (element, aromatic) = match c {
            b'C' => {
                if self.peek() == Some(b'l') {
                    self.pos += 1;
                    (Element::Cl, false)
                } else {
                    (Element::C, false)
                }
            }
            b'B' => {
                if self.peek() == Some(b'r') {
                    self.pos += 1;
                    (Element::Br, false)
                } else {
                    (Element::B, false)
                }
            }
            b'N' => (Element::N, false),
            b'O' => (Element::O, false),
            b'S' => (Element::S, false),
            b'P' => (Element::P, false),
            b'F' => (Element::F, false),
            b'I' => (Element::I, false),
            b'c' => (Element::C, true),
            b'n' => (Element::N, true),
            b'o' => (Element::O, true),
            b's' => (Element::S, true),
            b'p' => (Element::P, true),
            b'b' => (Element::B, true),
            other => {
                return Err(err(
                    self.pos,
                    format!("unsupported bracket symbol '{}'", other as char),
                ))
            }
        };
        let mut h: u8 = 0;
        let mut h_given = false;
        let mut charge: i8 = 0;
        loop {
            match self.bump().ok_or_else(|| err(self.pos, "unterminated bracket"))? {
                b']' => break,
                b'H' => {
                    h_given = true;
                    h = 1;
                    if let Some(d @ b'0'..=b'9') = self.peek() {
                        self.pos += 1;
                        h = d - b'0';
                    }
                }
                b'+' => {
                    charge = 1;
                    if let Some(d @ b'0'..=b'9') = self.peek() {
                        self.pos += 1;
                        charge = (d - b'0') as i8;
                    } else {
                        while self.peek() == Some(b'+') {
                            self.pos += 1;
                            charge += 1;
                        }
                    }
                }
                b'-' => {
                    charge = -1;
                    if let Some(d @ b'0'..=b'9') = self.peek() {
                        self.pos += 1;
                        charge = -((d - b'0') as i8);
                    } else {
                        while self.peek() == Some(b'-') {
                            self.pos += 1;
                            charge -= 1;
                        }
                    }
                }
                other => {
                    return Err(err(
                        self.pos,
                        format!("unsupported bracket token '{}'", other as char),
                    ))
                }
            }
        }
        // Bracket atoms carry no implicit hydrogens in SMILES: an absent
        // H spec means exactly zero hydrogens.
        let _ = h_given;
        Ok(Atom { element, aromatic, charge, explicit_h: Some(h) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chem::Element;

    #[test]
    fn linear_chain() {
        let m = parse("CCO").unwrap();
        assert_eq!(m.num_atoms(), 3);
        assert_eq!(m.num_bonds(), 2);
        assert_eq!(m.atoms[2].element, Element::O);
    }

    #[test]
    fn two_char_elements() {
        let m = parse("CClBrI").is_err(); // Cl has valence 1; parse is fine, just graph shape
        // parse itself should succeed (valence not checked here)
        assert!(!m || parse("CClBrI").is_ok() == false);
        let m2 = parse("CCl").unwrap();
        assert_eq!(m2.atoms[1].element, Element::Cl);
        let m3 = parse("CBr").unwrap();
        assert_eq!(m3.atoms[1].element, Element::Br);
    }

    #[test]
    fn branches() {
        let m = parse("CC(C)(C)C").unwrap();
        assert_eq!(m.num_atoms(), 5);
        assert_eq!(m.degree(1), 4);
    }

    #[test]
    fn double_triple_bonds() {
        let m = parse("C=O").unwrap();
        assert_eq!(m.bonds[0].order, BondOrder::Double);
        let m = parse("C#N").unwrap();
        assert_eq!(m.bonds[0].order, BondOrder::Triple);
    }

    #[test]
    fn aromatic_ring() {
        let m = parse("c1ccccc1").unwrap();
        assert_eq!(m.num_atoms(), 6);
        assert_eq!(m.num_bonds(), 6);
        assert!(m.bonds.iter().all(|b| b.order == BondOrder::Aromatic));
    }

    #[test]
    fn ring_closure_with_explicit_bond() {
        let m = parse("C1CCCCC1").unwrap();
        assert_eq!(m.num_bonds(), 6);
        let m = parse("C=1CCCCC=1").unwrap();
        assert_eq!(m.bonds.last().unwrap().order, BondOrder::Double);
        assert!(parse("C=1CCCCC#1").is_err()); // conflicting orders
    }

    #[test]
    fn percent_ring_index() {
        let m = parse("C%12CCCCC%12").unwrap();
        assert_eq!(m.num_bonds(), 6);
    }

    #[test]
    fn brackets() {
        let m = parse("C[NH2]C").unwrap();
        assert_eq!(m.atoms[1].explicit_h, Some(2));
        let m = parse("[O-]C").unwrap();
        assert_eq!(m.atoms[0].charge, -1);
        let m = parse("[N+2]").unwrap();
        assert_eq!(m.atoms[0].charge, 2);
        let m = parse("c1cc[nH]c1").unwrap();
        assert_eq!(m.atoms[3].explicit_h, Some(1));
        assert!(m.atoms[3].aromatic);
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("C(").is_err());
        assert!(parse("C)").is_err());
        assert!(parse("C1CC").is_err()); // unclosed ring
        assert!(parse("C=").is_err()); // dangling bond
        assert!(parse("C==C").is_err());
        assert!(parse("CC.O").is_err()); // fragments rejected
        assert!(parse("[N").is_err());
        assert!(parse("Cq").is_err());
        assert!(parse("C11").is_err()); // ring to self
        assert!(parse("(C)").is_err()); // branch before atom
    }

    #[test]
    fn stereo_degrades_to_single() {
        let m = parse("C/C=C/C").unwrap();
        assert_eq!(m.bonds[0].order, BondOrder::Single);
        assert_eq!(m.bonds[1].order, BondOrder::Double);
    }

    #[test]
    fn fused_bicyclic() {
        let m = parse("C1CC2CCC1C2").is_ok();
        assert!(m);
        let naph = parse("c1ccc2ccccc2c1").unwrap();
        assert_eq!(naph.num_atoms(), 10);
        assert_eq!(naph.num_bonds(), 11);
    }
}
