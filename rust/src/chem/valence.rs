//! Valence accounting and molecule validation.
//!
//! Aromatic bonds contribute 1.5 to valence; sums are tracked doubled so
//! everything stays integral. Implicit hydrogens on organic-subset atoms
//! fill up to the smallest allowed valence that covers the bond order sum
//! (ceiling for odd doubled sums, which arise from an odd number of
//! aromatic bonds).

use super::{ChemError, Element, Molecule};

/// Allowed total valences for an element at a given formal charge.
///
/// Covers the charge states the SynthChem world generates:
/// `[N+]` (ammonium-like, 4), `[O-]` (alkoxide, 1), `[N-]` (amide anion, 2),
/// `[S-]` (thiolate, 1), `[O+]` (oxocarbenium, 3), `[C-]`/`[C+]` (3).
pub fn allowed_valences(element: Element, charge: i8) -> Vec<u8> {
    match (element, charge) {
        (_, 0) => element.valences().to_vec(),
        (Element::N, 1) => vec![4],
        (Element::N, -1) => vec![2],
        (Element::O, -1) => vec![1],
        (Element::O, 1) => vec![3],
        (Element::S, -1) => vec![1],
        (Element::C, 1) | (Element::C, -1) => vec![3],
        (Element::B, -1) => vec![4],
        // Fallback: keep neutral valences; validation will likely fail,
        // which is the right outcome for exotic charges.
        _ => element.valences().to_vec(),
    }
}

/// Sum of bond orders at atom `v`, doubled (aromatic = 3).
pub fn bond_order_sum_x2(m: &Molecule, v: usize) -> u32 {
    m.neighbors(v)
        .iter()
        .map(|&(_, bi)| m.bonds[bi].order.valence_x2() as u32)
        .sum()
}

/// σ-framework valence used at atom `v`.
///
/// For aromatic atoms each aromatic bond counts 1 (the π system is
/// accounted separately: a π-acceptor like aromatic C contributes one
/// extra valence unit, a π-donor like pyrrole N / furan O contributes a
/// lone pair and nothing extra — see [`validate`]). For non-aromatic
/// atoms this is the exact bond order sum (aromatic bonds on such atoms
/// are rejected by validation; they'd count as 2 here).
pub fn sigma_used(m: &Molecule, v: usize) -> u32 {
    let atom = &m.atoms[v];
    if atom.aromatic {
        m.neighbors(v)
            .iter()
            .map(|&(_, bi)| match m.bonds[bi].order {
                super::BondOrder::Aromatic => 1u32,
                o => (o.valence_x2() / 2) as u32,
            })
            .sum()
    } else {
        (bond_order_sum_x2(m, v) + 1) / 2
    }
}

/// Number of implicit hydrogens on atom `v`, or an error if no allowed
/// valence can accommodate the bonded electrons.
///
/// Atoms with an explicit bracket H count have zero *implicit* hydrogens
/// by definition; their total is validated in [`validate`].
pub fn implicit_h(m: &Molecule, v: usize) -> Result<u8, ChemError> {
    let atom = &m.atoms[v];
    if atom.explicit_h.is_some() {
        return Ok(0);
    }
    let used = sigma_used(m, v);
    let allowed = allowed_valences(atom.element, atom.charge);
    if atom.aromatic {
        // Assume π participation costs one valence unit; π-donors
        // (pyrrole N, furan O) then simply clamp at zero hydrogens.
        for &val in allowed.iter() {
            if used <= val as u32 {
                return Ok((val as u32).saturating_sub(used + 1) as u8);
            }
        }
    } else {
        for &val in allowed.iter() {
            if used <= val as u32 {
                return Ok((val as u32 - used) as u8);
            }
        }
    }
    Err(ChemError::Valence {
        atom: v,
        msg: format!(
            "{}{} has bond order sum {} exceeding allowed valences",
            atom.element.symbol(),
            if atom.charge != 0 { format!("{:+}", atom.charge) } else { String::new() },
            used
        ),
    })
}

/// Total hydrogen count (implicit + explicit bracket count).
pub fn total_h(m: &Molecule, v: usize) -> Result<u8, ChemError> {
    Ok(m.atoms[v].explicit_h.unwrap_or(implicit_h(m, v)?))
}

/// Validate a parsed molecule:
///
/// 1. connected (single fragment);
/// 2. every atom's bond order sum + hydrogens fits an allowed valence;
/// 3. aromatic atoms have exactly 2 or 3 aromatic bonds and lie on a ring;
/// 4. non-aromatic atoms carry no aromatic bonds.
pub fn validate(m: &Molecule) -> Result<(), ChemError> {
    if !m.is_connected() {
        return Err(ChemError::Graph("molecule is not connected".into()));
    }
    let ring_atom = m.ring_atoms();
    for v in 0..m.num_atoms() {
        let atom = &m.atoms[v];
        let arom_bonds = m
            .neighbors(v)
            .iter()
            .filter(|&&(_, bi)| m.bonds[bi].order == super::BondOrder::Aromatic)
            .count();
        if atom.aromatic {
            if !atom.element.can_be_aromatic() {
                return Err(ChemError::Valence {
                    atom: v,
                    msg: format!("{} cannot be aromatic", atom.element.symbol()),
                });
            }
            if !(2..=3).contains(&arom_bonds) {
                return Err(ChemError::Valence {
                    atom: v,
                    msg: format!("aromatic atom with {arom_bonds} aromatic bonds"),
                });
            }
            if !ring_atom[v] {
                return Err(ChemError::Valence {
                    atom: v,
                    msg: "aromatic atom outside ring".into(),
                });
            }
        } else if arom_bonds > 0 {
            return Err(ChemError::Valence {
                atom: v,
                msg: "aromatic bond on non-aromatic atom".into(),
            });
        }
        // Valence check including explicit hydrogens. Aromatic atoms may
        // participate in the π system either as π-acceptor (total+1 must
        // be an allowed valence: aromatic C, pyridine N) or as π-donor
        // (total itself allowed: pyrrole N, furan O, thiophene S).
        let used = sigma_used(m, v);
        let h = atom.explicit_h.unwrap_or(implicit_h(m, v)?) as u32;
        let total = used + h;
        let allowed = allowed_valences(atom.element, atom.charge);
        let ok = if atom.aromatic {
            allowed.iter().any(|&val| total == val as u32 || total + 1 == val as u32)
        } else {
            allowed.iter().any(|&val| total == val as u32)
        };
        if !ok {
            return Err(ChemError::Valence {
                atom: v,
                msg: format!(
                    "total valence {total} not in allowed {allowed:?} for {}",
                    atom.element.symbol()
                ),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chem::parse_smiles;

    fn ok(s: &str) {
        let m = parse_smiles(s).unwrap_or_else(|e| panic!("{s}: parse failed: {e}"));
        validate(&m).unwrap_or_else(|e| panic!("{s}: validate failed: {e}"));
    }

    fn bad(s: &str) {
        if let Ok(m) = parse_smiles(s) {
            assert!(validate(&m).is_err(), "{s}: expected invalid");
        }
    }

    #[test]
    fn implicit_h_counts() {
        let m = parse_smiles("CCO").unwrap();
        assert_eq!(implicit_h(&m, 0).unwrap(), 3);
        assert_eq!(implicit_h(&m, 1).unwrap(), 2);
        assert_eq!(implicit_h(&m, 2).unwrap(), 1);
    }

    #[test]
    fn aromatic_h_counts() {
        let m = parse_smiles("c1ccccc1").unwrap();
        for v in 0..6 {
            assert_eq!(implicit_h(&m, v).unwrap(), 1);
        }
        // pyridine N: no H
        let m = parse_smiles("c1ccncc1").unwrap();
        let n_idx = m.atoms.iter().position(|a| a.element == Element::N).unwrap();
        assert_eq!(implicit_h(&m, n_idx).unwrap(), 0);
    }

    #[test]
    fn valid_molecules() {
        for s in [
            "C", "CC", "CCO", "C=O", "C#N", "CC(=O)O", "c1ccccc1", "c1ccncc1",
            "c1cc[nH]c1", "c1ccoc1", "c1ccsc1", "CS(=O)(=O)Cl", "CC(=O)NC",
            "C[N+](C)(C)C", "[O-]C(=O)C", "FC(F)(F)C", "ClCCl", "O=S(=O)(O)O",
            "c1ccc2ccccc2c1", "CC(C)(C)OC(=O)N", "BrCC", "IC",
        ] {
            ok(s);
        }
    }

    #[test]
    fn invalid_valence() {
        bad("C(C)(C)(C)(C)C"); // 5-valent carbon
        bad("O(C)(C)C"); // 3-valent oxygen
        bad("N(C)(C)(C)C"); // 4-valent neutral N
        bad("Cl(C)C"); // divalent chlorine
        bad("[NH4]"); // neutral N with 4 H
    }

    #[test]
    fn charged_valences() {
        ok("[NH4+]");
        ok("C[N+](C)(C)C");
        ok("[O-]C");
        bad("[O-](C)C"); // O- divalent
    }

    #[test]
    fn aromatic_sanity() {
        bad("cc"); // aromatic atoms not in a ring
        // dangling aromatic atom (1 aromatic bond... parses as single
        // bond to ring, then c alone)
        bad("c1ccccc1c");
        bad("C:C"); // aromatic bond between non-aromatic atoms
    }

    #[test]
    fn disconnected_rejected() {
        // Can't be built via parse (it rejects '.'), so construct directly.
        use crate::chem::{Atom, Molecule};
        let mut m = Molecule::new();
        m.add_atom(Atom::new(Element::C));
        m.add_atom(Atom::new(Element::C));
        assert!(validate(&m).is_err());
    }

    #[test]
    fn bracket_h_must_fit() {
        bad("[CH5]");
        ok("[CH4]");
        bad("[OH3]");
    }
}
