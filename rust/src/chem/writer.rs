//! SMILES writer: canonical, rooted, and randomized output.
//!
//! `canonical_smiles` orders the DFS by [`super::canon`] ranks from the
//! rank-0 root, giving a unique string per isomorphism class.
//! `rooted_smiles` keeps canonical neighbor ordering but starts from a
//! chosen atom — the R-SMILES-style augmentation used by the data
//! generator to maximize product/reactant string overlap (which is what
//! makes speculative drafts cheap to accept).

use super::{canon, valence, BondOrder, Molecule};

/// Canonical SMILES (unique per isomorphism class).
pub fn canonical_smiles(m: &Molecule) -> String {
    let ranks = canon::canonical_ranks(m);
    let root = (0..m.num_atoms()).min_by_key(|&v| ranks[v]).unwrap_or(0);
    write_from(m, root, &ranks)
}

/// SMILES rooted at `root`, neighbor order still canonical.
pub fn rooted_smiles(m: &Molecule, root: usize) -> String {
    let ranks = canon::canonical_ranks(m);
    write_from(m, root, &ranks)
}

/// SMILES with a random root and random neighbor order (for augmentation
/// and property tests).
pub fn random_smiles(m: &Molecule, rng: &mut crate::util::Rng) -> String {
    let n = m.num_atoms();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    // order[v] acts as the "rank" of atom v.
    let mut rank = vec![0usize; n];
    for (r, &v) in order.iter().enumerate() {
        rank[v] = r;
    }
    let root = order[0];
    write_from(m, root, &rank)
}

/// Write SMILES starting from `root`, visiting neighbors in increasing
/// `rank` order.
pub fn write_from(m: &Molecule, root: usize, rank: &[usize]) -> String {
    let n = m.num_atoms();
    assert!(root < n, "root out of range");

    // --- Pass 1: DFS to build the spanning tree and find ring bonds. ---
    let mut visited = vec![false; n];
    let mut children: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n]; // (child, bond)
    let mut parent_bond = vec![usize::MAX; n];
    let mut back_edges: Vec<usize> = Vec::new();

    // Iterative preorder DFS with rank-ordered neighbor traversal. A
    // neighbor marked visited may be an ancestor *or* a pending sibling
    // (cycles); either way the edge is a ring-closure bond, and its
    // opening/closing endpoints are decided by visit position below.
    let mut stack = vec![root];
    visited[root] = true;
    let mut visit_order = Vec::with_capacity(n);
    while let Some(v) = stack.pop() {
        visit_order.push(v);
        let mut nbrs: Vec<(usize, usize)> = m.neighbors(v).to_vec();
        nbrs.sort_by_key(|&(u, _)| rank[u]);
        // Push in reverse so the lowest-rank neighbor is processed first.
        for &(u, bi) in nbrs.iter().rev() {
            if bi == parent_bond[v] {
                continue;
            }
            if !visited[u] {
                visited[u] = true;
                parent_bond[u] = bi;
                children[v].push((u, bi));
                stack.push(u);
            } else if !back_edges.contains(&bi) {
                back_edges.push(bi);
            }
        }
        // Push order reversed the children; restore rank order.
        children[v].sort_by_key(|&(u, _)| rank[u]);
    }
    assert!(
        visit_order.len() == n,
        "write_from requires a connected molecule"
    );

    let mut visit_pos = vec![0usize; n];
    for (i, &v) in visit_order.iter().enumerate() {
        visit_pos[v] = i;
    }
    // Ring digit opens at the earlier-visited endpoint, closes at the
    // later one; openings at an atom are ordered by the closer's position
    // so digit reuse stays unambiguous.
    let mut ring_openings: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut ring_closings: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &bi in &back_edges {
        let b = &m.bonds[bi];
        let (open, close) = if visit_pos[b.a] < visit_pos[b.b] { (b.a, b.b) } else { (b.b, b.a) };
        ring_openings[open].push(bi);
        ring_closings[close].push(bi);
    }
    for v in 0..n {
        ring_openings[v].sort_by_key(|&bi| {
            let b = &m.bonds[bi];
            visit_pos[b.a].max(visit_pos[b.b])
        });
        ring_closings[v].sort_by_key(|&bi| {
            let b = &m.bonds[bi];
            visit_pos[b.a].min(visit_pos[b.b])
        });
    }

    // --- Pass 2: emit the string recursively. ---
    let mut out = String::with_capacity(n * 2);
    let mut digit_of_bond: Vec<Option<u8>> = vec![None; m.num_bonds()];
    let mut free_digits: Vec<u8> = (1..=99).rev().collect();

    // Explicit recursion on an explicit stack to avoid deep call stacks.
    enum Op {
        Visit(usize, usize), // (atom, incoming bond or MAX)
        Char(char),
    }
    let mut ops = vec![Op::Visit(root, usize::MAX)];
    while let Some(op) = ops.pop() {
        match op {
            Op::Char(c) => out.push(c),
            Op::Visit(v, in_bond) => {
                if in_bond != usize::MAX {
                    out.push_str(bond_token(m, in_bond));
                }
                write_atom(m, v, &mut out);
                // Ring digits (openings first, then closings).
                for &bi in &ring_openings[v] {
                    let d = free_digits.pop().expect("ring digit pool exhausted");
                    digit_of_bond[bi] = Some(d);
                    out.push_str(bond_token(m, bi));
                    push_digit(&mut out, d);
                }
                for &bi in &ring_closings[v] {
                    let d = digit_of_bond[bi].expect("closing unopened ring digit");
                    digit_of_bond[bi] = None;
                    free_digits.push(d);
                    // Bond token was emitted at the opening site; emitting it
                    // twice is legal but redundant.
                    push_digit(&mut out, d);
                }
                // Children: all but the last in parentheses.
                let kids = &children[v];
                for (i, &(u, bi)) in kids.iter().enumerate().rev() {
                    if i + 1 == kids.len() {
                        ops.push(Op::Visit(u, bi));
                    } else {
                        ops.push(Op::Char(')'));
                        ops.push(Op::Visit(u, bi));
                        ops.push(Op::Char('('));
                    }
                }
            }
        }
    }
    out
}

fn push_digit(out: &mut String, d: u8) {
    if d < 10 {
        out.push((b'0' + d) as char);
    } else {
        out.push('%');
        out.push((b'0' + d / 10) as char);
        out.push((b'0' + d % 10) as char);
    }
}

/// The bond symbol to print before an atom/ring digit ("" when implied).
fn bond_token(m: &Molecule, bi: usize) -> &'static str {
    let b = &m.bonds[bi];
    let both_aromatic = m.atoms[b.a].aromatic && m.atoms[b.b].aromatic;
    match b.order {
        BondOrder::Single => {
            if both_aromatic {
                "-" // single bond between aromatic atoms must be explicit
            } else {
                ""
            }
        }
        BondOrder::Aromatic => "",
        BondOrder::Double => "=",
        BondOrder::Triple => "#",
    }
}

/// Emit one atom, bracketed only when necessary.
fn write_atom(m: &Molecule, v: usize, out: &mut String) {
    let a = &m.atoms[v];
    let sym = a.element.symbol();
    let sym_str: String = if a.aromatic { sym.to_lowercase() } else { sym.to_string() };
    let needs_bracket = a.charge != 0 || bracket_needed_for_h(m, v);
    if !needs_bracket {
        out.push_str(&sym_str);
        return;
    }
    out.push('[');
    out.push_str(&sym_str);
    let h = valence::total_h(m, v).unwrap_or(0);
    if h == 1 {
        out.push('H');
    } else if h > 1 {
        out.push('H');
        out.push((b'0' + h) as char);
    }
    match a.charge.cmp(&0) {
        std::cmp::Ordering::Greater => {
            out.push('+');
            if a.charge > 1 {
                out.push((b'0' + a.charge as u8) as char);
            }
        }
        std::cmp::Ordering::Less => {
            out.push('-');
            if a.charge < -1 {
                out.push((b'0' + (-a.charge) as u8) as char);
            }
        }
        std::cmp::Ordering::Equal => {}
    }
    out.push(']');
}

/// Would an organic-subset (bracket-free) spelling reproduce this atom's
/// hydrogen count on re-parse?
fn bracket_needed_for_h(m: &Molecule, v: usize) -> bool {
    let a = &m.atoms[v];
    let Some(h) = a.explicit_h else { return false };
    // What would the parser infer for the bare symbol?
    let used = (valence::bond_order_sum_x2(m, v) + 1) / 2;
    for &val in valence::allowed_valences(a.element, a.charge).iter() {
        if used <= val as u32 {
            return (val as u32 - used) as u8 != h;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chem::{parse_smiles, parse_validated};
    use crate::util::Rng;

    fn canon(s: &str) -> String {
        canonical_smiles(&parse_smiles(s).unwrap())
    }

    #[test]
    fn roundtrip_reparses() {
        for s in [
            "CCO", "c1ccccc1", "CC(C)(C)OC(=O)N", "c1ccc2ccccc2c1",
            "CS(=O)(=O)Cl", "C[N+](C)(C)C", "c1cc[nH]c1", "O=C(O)c1ccccc1",
            "FC(F)(F)c1ccc(Br)cc1", "C#CCO",
        ] {
            let c = canon(s);
            let m2 = parse_validated(&c).unwrap_or_else(|e| panic!("{s} -> {c}: {e}"));
            assert_eq!(canonical_smiles(&m2), c, "idempotent for {s}");
        }
    }

    #[test]
    fn equivalent_spellings_converge() {
        for (a, b) in [
            ("OCC", "CCO"),
            ("c1ccccc1C", "Cc1ccccc1"),
            ("C(C)(C)C", "CC(C)C"),
            ("O=C(O)C", "CC(=O)O"),
            ("c1cc(ccc1)Br", "Brc1ccccc1"),
        ] {
            assert_eq!(canon(a), canon(b), "{a} vs {b}");
        }
    }

    #[test]
    fn inequivalent_molecules_differ() {
        assert_ne!(canon("CCO"), canon("COC"));
        assert_ne!(canon("c1ccncc1"), canon("c1ccccc1"));
    }

    #[test]
    fn random_smiles_same_canonical() {
        let mut rng = Rng::new(123);
        for s in ["CC(=O)Nc1ccccc1", "c1ccc2ccccc2c1", "CC(C)(C)OC(=O)NCCO"] {
            let m = parse_smiles(s).unwrap();
            let reference = canonical_smiles(&m);
            for _ in 0..20 {
                let r = random_smiles(&m, &mut rng);
                let m2 = parse_smiles(&r)
                    .unwrap_or_else(|e| panic!("{s}: random form {r} unparseable: {e}"));
                assert_eq!(canonical_smiles(&m2), reference, "{s} via {r}");
            }
        }
    }

    #[test]
    fn rooted_smiles_starts_at_root() {
        let m = parse_smiles("CCO").unwrap();
        // Root at the oxygen: string must start with O.
        let o = m.atoms.iter().position(|a| a.element == crate::chem::Element::O).unwrap();
        let s = rooted_smiles(&m, o);
        assert!(s.starts_with('O'), "{s}");
    }

    #[test]
    fn pyrrole_keeps_nh() {
        let c = canon("c1cc[nH]c1");
        assert!(c.contains("[nH]"), "{c}");
    }

    #[test]
    fn charges_preserved() {
        let c = canon("C[N+](C)(C)C");
        assert!(c.contains("[N+]"), "{c}");
        let c = canon("[O-]C(=O)C");
        assert!(c.contains("[O-]"), "{c}");
    }
}
