//! Configuration: a TOML-subset file format plus CLI overrides.
//!
//! The offline build has no `toml`/`serde`, so we parse the subset the
//! project needs: `[section]` headers, `key = value` lines with string
//! (quoted), integer, float and boolean values, and `#` comments.
//! Every setting can be overridden on the command line as
//! `--section.key value` (see [`Config::apply_override`]).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    fn parse(raw: &str) -> Result<Value> {
        let raw = raw.trim();
        if raw.starts_with('"') && raw.ends_with('"') && raw.len() >= 2 {
            return Ok(Value::Str(raw[1..raw.len() - 1].to_string()));
        }
        if raw == "true" {
            return Ok(Value::Bool(true));
        }
        if raw == "false" {
            return Ok(Value::Bool(false));
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = raw.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        // bare strings allowed (e.g. decoder = msbs)
        Ok(Value::Str(raw.to_string()))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat `section.key -> value` map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: bad section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            cfg.values.insert(key, Value::parse(v)?);
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path.as_ref())?)
    }

    /// CLI override: `--section.key value`.
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        self.values.insert(key.to_string(), Value::parse(value)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str().map(String::from))
            .unwrap_or_else(|| default.to_string())
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

/// Typed serving configuration assembled from a [`Config`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub artifacts: String,
    pub listen: String,
    pub decoder: String,
    pub expansions_per_step: usize,
    pub deadline_ms: u64,
    pub max_iterations: usize,
    pub max_depth: usize,
    pub beam_width: usize,
    /// Pipelined Retro\*: expansion groups kept in flight per plan
    /// (1 = sequential selection semantics). With `spec_adaptive` this
    /// is the adaptive controller's max depth.
    pub spec_depth: usize,
    /// `planner.spec_depth = "auto"`: adapt the in-flight depth to the
    /// observed speculation apply-rate, up to `planner.spec_depth_max`.
    pub spec_adaptive: bool,
    /// Max depth the adaptive controller may reach — also the cap
    /// applied when a *request* asks for `"spec_depth": "auto"` on a
    /// fixed-depth server.
    pub spec_depth_max: usize,
    pub algo: String,
    /// Continuous batcher: max requests merged into one decode task.
    pub batch_max: usize,
    /// Continuous batcher: max idle wait for more work, microseconds.
    pub batch_wait_us: u64,
    /// Deadline-based encode coalescer window, microseconds (0 = off):
    /// under load a round with queued misses is held open this long so
    /// near-arrivals share its single fused encode.
    pub batch_coalesce_us: u64,
    /// Continuous batcher: fused-call row budget per scheduler tick.
    pub batch_rows: usize,
    /// Expansion cache capacity (molecules, LRU).
    pub cache_cap: usize,
    /// Continuous batcher: session shards (hub loop threads). 1 = the
    /// classic single hub loop.
    pub shards: usize,
    /// Continuous batcher: work stealing between shards (only
    /// meaningful with `shards > 1`).
    pub steal: bool,
    /// Model replicas: independent supervised executors behind
    /// least-loaded dispatch. 1 = the classic single executor.
    pub replicas: usize,
    pub workers: usize,
    /// Request budget: policy expansion batches per plan (0 = off).
    pub max_expansions: usize,
    /// Request budget: decoder positions per plan (0 = off).
    pub max_decode_tokens: u64,
    /// Screening: targets planned concurrently per `screen` job.
    pub screen_concurrency: usize,
    /// Screening: default per-job wall-clock budget, ms (0 = off).
    pub screen_job_deadline_ms: u64,
    /// Screening: default per-job decode-token cap (0 = off).
    pub screen_job_decode_tokens: u64,
    /// Executor supervision: transient model-error retries per call.
    pub model_retries: u32,
    /// Executor supervision: base retry/restart backoff, microseconds.
    pub model_backoff_us: u64,
    /// Admission control: concurrent connection slots (0 = unlimited).
    pub max_sessions: usize,
    /// Admission control: queued-expansion shed threshold (0 = shedding
    /// off). Batch/screen requests shed at half this depth, interactive
    /// at the full depth.
    pub max_queue: usize,
    /// Drain-clean shutdown: grace window for in-flight solves before
    /// their deadlines are fenced, ms.
    pub drain_ms: u64,
    /// Suggested client backoff carried in shed responses, ms.
    pub retry_after_ms: u64,
    /// Degradation ladder: load score at/above which new requests are
    /// admitted with clamped effort.
    pub degrade_high: f64,
    /// Degradation ladder: load score at/below which full effort
    /// returns (hysteresis band between the two watermarks).
    pub degrade_low: f64,
    /// Degradation ladder: beam-width floor for degraded admissions.
    pub degraded_beam: usize,
    /// Degradation ladder: deadline clamp for degraded admissions, ms
    /// (0 = keep the request deadline).
    pub degraded_deadline_ms: u64,
    /// Persistent cache store log path (`cache.path`; empty = memory
    /// only). An unwritable path downgrades to memory-only with a
    /// warning — it never fails boot.
    pub cache_path: String,
    /// Store write-behind flush cadence, ms (`cache.flush_ms`).
    pub cache_flush_ms: u64,
    /// Store dead-record fraction triggering log compaction
    /// (`cache.compact_ratio`; clamped to [0, 1], 1.0 disables).
    pub cache_compact_ratio: f64,
}

impl ServeConfig {
    pub fn from_config(c: &Config) -> ServeConfig {
        // `spec_depth` accepts an integer (fixed depth) or the string
        // "auto" (adaptive, bounded by `planner.spec_depth_max`).
        let spec_max = c.int_or("planner.spec_depth_max", 8).max(1) as usize;
        let (spec_depth, spec_adaptive) = match c.get("planner.spec_depth") {
            Some(Value::Str(v)) if v == "auto" => (spec_max, true),
            _ => (c.int_or("planner.spec_depth", 1).max(1) as usize, false),
        };
        ServeConfig {
            artifacts: c.str_or("server.artifacts", "artifacts"),
            listen: c.str_or("server.listen", "127.0.0.1:7878"),
            decoder: c.str_or("planner.decoder", "msbs"),
            expansions_per_step: c.int_or("planner.expansions_per_step", 10) as usize,
            deadline_ms: c.int_or("planner.deadline_ms", 5000) as u64,
            max_iterations: c.int_or("planner.max_iterations", 35000) as usize,
            max_depth: c.int_or("planner.max_depth", 5) as usize,
            beam_width: c.int_or("planner.beam_width", 1) as usize,
            spec_depth,
            spec_adaptive,
            spec_depth_max: spec_max,
            algo: c.str_or("planner.algo", "retrostar"),
            batch_max: c.int_or("batcher.max_batch", 16) as usize,
            batch_wait_us: c.int_or("batcher.max_wait_us", 2000) as u64,
            batch_coalesce_us: c.int_or("batcher.coalesce_us", 0).max(0) as u64,
            batch_rows: c.int_or("batcher.max_rows", 256) as usize,
            cache_cap: c.int_or("batcher.cache_cap", 10_000) as usize,
            shards: c.int_or("batcher.shards", 1).max(1) as usize,
            steal: c.bool_or("batcher.steal", true),
            replicas: c.int_or("model.replicas", 1).max(1) as usize,
            workers: c.int_or("server.workers", 4) as usize,
            max_expansions: c.int_or("planner.max_expansions", 0).max(0) as usize,
            max_decode_tokens: c.int_or("planner.max_decode_tokens", 0).max(0) as u64,
            screen_concurrency: c.int_or("planner.screen_concurrency", 8).max(1) as usize,
            screen_job_deadline_ms: c.int_or("planner.screen_job_deadline_ms", 0).max(0) as u64,
            screen_job_decode_tokens: c.int_or("planner.screen_job_decode_tokens", 0).max(0)
                as u64,
            model_retries: c.int_or("model.retries", 0).max(0) as u32,
            model_backoff_us: c.int_or("model.backoff_us", 200).max(0) as u64,
            max_sessions: c.int_or("server.max_sessions", 0).max(0) as usize,
            max_queue: c.int_or("server.max_queue", 0).max(0) as usize,
            drain_ms: c.int_or("server.drain_ms", 1000).max(0) as u64,
            retry_after_ms: c.int_or("server.retry_after_ms", 250).max(1) as u64,
            degrade_high: c.float_or("server.degrade_high", 0.75).max(0.0),
            degrade_low: c.float_or("server.degrade_low", 0.40).max(0.0),
            degraded_beam: c.int_or("planner.degraded_beam", 1).max(1) as usize,
            degraded_deadline_ms: c.int_or("planner.degraded_deadline_ms", 0).max(0) as u64,
            cache_path: c.str_or("cache.path", ""),
            cache_flush_ms: c.int_or("cache.flush_ms", 200).max(1) as u64,
            cache_compact_ratio: c.float_or("cache.compact_ratio", 0.5).clamp(0.0, 1.0),
        }
    }

    pub fn limits(&self) -> crate::search::SearchLimits {
        crate::search::SearchLimits {
            deadline: std::time::Duration::from_millis(self.deadline_ms),
            max_iterations: self.max_iterations,
            max_depth: self.max_depth,
            expansions_per_step: self.expansions_per_step,
            max_expansions: self.max_expansions,
            max_decode_tokens: self.max_decode_tokens,
            fence: crate::search::DeadlineFence::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let text = concat!(
            "top = 1\n[server]\nlisten = \"0.0.0.0:9999\"\nworkers = 8\n",
            "# comment\n[planner]\ndecoder = msbs\nnucleus = 0.9975\nuse_cache = true\n",
        );
        let c = Config::parse(text).unwrap();
        assert_eq!(c.int_or("top", 0), 1);
        assert_eq!(c.str_or("server.listen", ""), "0.0.0.0:9999");
        assert_eq!(c.int_or("server.workers", 0), 8);
        assert_eq!(c.str_or("planner.decoder", ""), "msbs");
        assert!((c.float_or("planner.nucleus", 0.0) - 0.9975).abs() < 1e-12);
        assert!(c.bool_or("planner.use_cache", false));
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse("[server]\nworkers = 2\n").unwrap();
        c.apply_override("server.workers", "16").unwrap();
        assert_eq!(c.int_or("server.workers", 0), 16);
    }

    #[test]
    fn defaults_fill_serve_config() {
        let sc = ServeConfig::from_config(&Config::new());
        assert_eq!(sc.decoder, "msbs");
        assert_eq!(sc.deadline_ms, 5000);
        assert_eq!(sc.max_depth, 5);
        assert_eq!(sc.spec_depth, 1);
        assert_eq!(sc.limits().expansions_per_step, 10);
        assert_eq!(sc.max_expansions, 0, "work caps default to off");
        assert_eq!(sc.max_decode_tokens, 0);
        assert_eq!(sc.model_retries, 0, "retries default to fail-fast");
        assert_eq!(sc.model_backoff_us, 200);
        assert_eq!(sc.shards, 1, "sharding defaults to the classic single loop");
        assert!(sc.steal, "stealing defaults on (inert at one shard)");
        assert_eq!(sc.replicas, 1, "one executor by default");
    }

    #[test]
    fn shard_and_replica_keys_parse_and_clamp() {
        let c = Config::parse(concat!(
            "[batcher]\nshards = 4\nsteal = false\n",
            "[model]\nreplicas = 2\n",
        ))
        .unwrap();
        let sc = ServeConfig::from_config(&c);
        assert_eq!(sc.shards, 4);
        assert!(!sc.steal);
        assert_eq!(sc.replicas, 2);
        let c = Config::parse("[batcher]\nshards = 0\n[model]\nreplicas = 0\n").unwrap();
        let sc = ServeConfig::from_config(&c);
        assert_eq!(sc.shards, 1, "clamped to >= 1");
        assert_eq!(sc.replicas, 1, "clamped to >= 1");
    }

    #[test]
    fn budget_and_supervision_keys_parse() {
        let c = Config::parse(concat!(
            "[planner]\nmax_expansions = 40\nmax_decode_tokens = 9000\n",
            "[model]\nretries = 2\nbackoff_us = 50\n",
        ))
        .unwrap();
        let sc = ServeConfig::from_config(&c);
        assert_eq!(sc.max_expansions, 40);
        assert_eq!(sc.max_decode_tokens, 9000);
        assert_eq!(sc.model_retries, 2);
        assert_eq!(sc.model_backoff_us, 50);
        let l = sc.limits();
        assert_eq!(l.max_expansions, 40);
        assert_eq!(l.max_decode_tokens, 9000);
    }

    #[test]
    fn screen_keys_parse_and_clamp() {
        let sc = ServeConfig::from_config(&Config::new());
        assert_eq!(sc.screen_concurrency, 8, "default job concurrency");
        assert_eq!(sc.screen_job_deadline_ms, 0, "job budgets default to off");
        assert_eq!(sc.screen_job_decode_tokens, 0);
        let c = Config::parse(concat!(
            "[planner]\nscreen_concurrency = 16\n",
            "screen_job_deadline_ms = 30000\nscreen_job_decode_tokens = 500000\n",
        ))
        .unwrap();
        let sc = ServeConfig::from_config(&c);
        assert_eq!(sc.screen_concurrency, 16);
        assert_eq!(sc.screen_job_deadline_ms, 30000);
        assert_eq!(sc.screen_job_decode_tokens, 500000);
        let c = Config::parse("[planner]\nscreen_concurrency = 0\n").unwrap();
        assert_eq!(
            ServeConfig::from_config(&c).screen_concurrency,
            1,
            "clamped to >= 1"
        );
    }

    #[test]
    fn overload_keys_default_inert() {
        let sc = ServeConfig::from_config(&Config::new());
        assert_eq!(sc.max_sessions, 0, "session slots default to unlimited");
        assert_eq!(sc.max_queue, 0, "shedding defaults to off");
        assert_eq!(sc.drain_ms, 1000);
        assert_eq!(sc.retry_after_ms, 250);
        assert!((sc.degrade_high - 0.75).abs() < 1e-12);
        assert!((sc.degrade_low - 0.40).abs() < 1e-12);
        assert_eq!(sc.degraded_beam, 1);
        assert_eq!(sc.degraded_deadline_ms, 0, "deadline clamp defaults off");
        assert!(
            sc.limits().fence.get().is_none(),
            "limits carry an unset fence"
        );
    }

    #[test]
    fn overload_keys_parse_and_clamp() {
        let c = Config::parse(concat!(
            "[server]\nmax_sessions = 64\nmax_queue = 32\ndrain_ms = 500\n",
            "retry_after_ms = 100\ndegrade_high = 0.9\ndegrade_low = 0.5\n",
            "[planner]\ndegraded_beam = 2\ndegraded_deadline_ms = 800\n",
        ))
        .unwrap();
        let sc = ServeConfig::from_config(&c);
        assert_eq!(sc.max_sessions, 64);
        assert_eq!(sc.max_queue, 32);
        assert_eq!(sc.drain_ms, 500);
        assert_eq!(sc.retry_after_ms, 100);
        assert!((sc.degrade_high - 0.9).abs() < 1e-12);
        assert!((sc.degrade_low - 0.5).abs() < 1e-12);
        assert_eq!(sc.degraded_beam, 2);
        assert_eq!(sc.degraded_deadline_ms, 800);
        let c = Config::parse("[server]\nretry_after_ms = 0\n[planner]\ndegraded_beam = 0\n")
            .unwrap();
        let sc = ServeConfig::from_config(&c);
        assert_eq!(sc.retry_after_ms, 1, "clamped to >= 1");
        assert_eq!(sc.degraded_beam, 1, "clamped to >= 1");
    }

    #[test]
    fn spec_depth_parses_and_clamps() {
        let c = Config::parse("[planner]\nspec_depth = 4\n").unwrap();
        let sc = ServeConfig::from_config(&c);
        assert_eq!(sc.spec_depth, 4);
        assert!(!sc.spec_adaptive);
        let c = Config::parse("[planner]\nspec_depth = 0\n").unwrap();
        assert_eq!(ServeConfig::from_config(&c).spec_depth, 1, "clamped to >= 1");
    }

    #[test]
    fn spec_depth_auto_enables_the_adaptive_controller() {
        let c = Config::parse("[planner]\nspec_depth = auto\n").unwrap();
        let sc = ServeConfig::from_config(&c);
        assert!(sc.spec_adaptive);
        assert_eq!(sc.spec_depth, 8, "default adaptive max");
        let c = Config::parse("[planner]\nspec_depth = auto\nspec_depth_max = 3\n").unwrap();
        let sc = ServeConfig::from_config(&c);
        assert!(sc.spec_adaptive);
        assert_eq!(sc.spec_depth, 3);
    }

    #[test]
    fn coalesce_window_parses_with_zero_default() {
        assert_eq!(ServeConfig::from_config(&Config::new()).batch_coalesce_us, 0);
        let c = Config::parse("[batcher]\ncoalesce_us = 400\n").unwrap();
        assert_eq!(ServeConfig::from_config(&c).batch_coalesce_us, 400);
    }

    #[test]
    fn cache_keys_parse_and_clamp() {
        let sc = ServeConfig::from_config(&Config::new());
        assert_eq!(sc.cache_path, "", "persistent store defaults to off");
        assert_eq!(sc.cache_flush_ms, 200);
        assert!((sc.cache_compact_ratio - 0.5).abs() < 1e-12);
        let c = Config::parse(concat!(
            "[cache]\npath = \"/var/lib/retroserve/cache.log\"\n",
            "flush_ms = 50\ncompact_ratio = 0.8\n",
        ))
        .unwrap();
        let sc = ServeConfig::from_config(&c);
        assert_eq!(sc.cache_path, "/var/lib/retroserve/cache.log");
        assert_eq!(sc.cache_flush_ms, 50);
        assert!((sc.cache_compact_ratio - 0.8).abs() < 1e-12);
        let c = Config::parse("[cache]\nflush_ms = 0\ncompact_ratio = 7.0\n").unwrap();
        let sc = ServeConfig::from_config(&c);
        assert_eq!(sc.cache_flush_ms, 1, "clamped to >= 1");
        assert!((sc.cache_compact_ratio - 1.0).abs() < 1e-12, "ratio clamped to <= 1");
    }

    #[test]
    fn bad_section_rejected() {
        assert!(Config::parse("[oops\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
    }
}
