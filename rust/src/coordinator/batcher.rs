//! The continuous batcher: merges single-step expansion requests from
//! all in-flight planning sessions into *cycle-level* fused decoder
//! calls.
//!
//! Requests arrive on a channel. Cache hits answer immediately. Misses
//! are grouped (per drain) into one resumable decode task and submitted
//! to a [`DecodeScheduler`]; the hub thread then ticks the scheduler —
//! ONE fused `decode` per tick across *all* in-flight tasks — so a
//! request that arrives while earlier expansions are mid-decode joins
//! the very next device call instead of queueing behind a whole
//! multi-cycle `generate`. Finished tasks fan their parsed proposals
//! back out and populate the shared cache.
//!
//! The expansion cache is a bounded [`LruCache`] keyed by *molecule*
//! (not `(molecule, k)`): an entry decoded at k' serves any request with
//! k <= k' by truncation, and a larger-k request replaces the entry —
//! the same molecule is never re-decoded just because co-batched k
//! differed, and sustained traffic cannot leak memory.

use crate::decoding::scheduler::{DecodeScheduler, Finished, SchedulerConfig, TaskId};
use crate::decoding::{DecodeStats, Decoder};
use crate::metrics::Metrics;
use crate::model::StepModel;
use crate::search::policy::{proposals_from_output, Proposal, DEFAULT_CACHE_CAP};
use crate::search::ExpansionPolicy;
use crate::tokenizer::Vocab;
use crate::util::lru::LruCache;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

struct ExpandReq {
    smiles: String,
    k: usize,
    reply: mpsc::SyncSender<Result<Vec<Proposal>>>,
}

/// Shared handle to the batcher thread.
pub struct ExpansionHub {
    tx: mpsc::Sender<ExpandReq>,
    stats: Arc<Mutex<DecodeStats>>,
    pub invalid: Arc<AtomicUsize>,
    pub total_hyps: Arc<AtomicUsize>,
    /// Decode tasks submitted (each merges >= 1 request).
    batches: Arc<AtomicU64>,
    /// Requests admitted.
    merged: Arc<AtomicU64>,
    /// Fused device calls / fused logical rows (cycle-level batching).
    fused_calls: Arc<AtomicU64>,
    fused_rows: Arc<AtomicU64>,
}

/// Batcher tuning knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Most requests drained into one decode task (one encode group).
    pub max_batch: usize,
    /// How long an *idle* hub waits for stragglers before the first
    /// tick. While decoding, arrivals are drained non-blockingly and
    /// join the next tick anyway.
    pub max_wait: std::time::Duration,
    /// Fused-call row budget per scheduler tick.
    pub max_rows: usize,
    /// Expansion-cache capacity (molecules, LRU).
    pub cache_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 16,
            max_wait: std::time::Duration::from_micros(2000),
            max_rows: 256,
            cache_cap: DEFAULT_CACHE_CAP,
        }
    }
}

/// A cached expansion: proposals decoded at beam width `k` (serves any
/// request with a smaller or equal k by truncation).
struct CachedExpansion {
    k: usize,
    props: Vec<Proposal>,
}

/// In-flight bookkeeping for one submitted decode task.
struct TaskMeta {
    mols: Vec<String>,
    k: usize,
}

impl ExpansionHub {
    /// Start the hub thread. The model handle must be `Send` (use
    /// [`crate::runtime::server::SharedModel`] for PJRT models).
    pub fn start<M>(
        model: M,
        decoder: Box<dyn Decoder + Send>,
        vocab: Vocab,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Arc<ExpansionHub>
    where
        M: StepModel + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<ExpandReq>();
        let stats = Arc::new(Mutex::new(DecodeStats::default()));
        let invalid = Arc::new(AtomicUsize::new(0));
        let total = Arc::new(AtomicUsize::new(0));
        let batches = Arc::new(AtomicU64::new(0));
        let merged = Arc::new(AtomicU64::new(0));
        let fused_calls = Arc::new(AtomicU64::new(0));
        let fused_rows = Arc::new(AtomicU64::new(0));
        {
            let stats = stats.clone();
            let invalid = invalid.clone();
            let total = total.clone();
            let batches = batches.clone();
            let merged = merged.clone();
            let fused_calls = fused_calls.clone();
            let fused_rows = fused_rows.clone();
            std::thread::Builder::new()
                .name("expansion-hub".into())
                .spawn(move || {
                    hub_loop(
                        rx,
                        model,
                        decoder,
                        vocab,
                        cfg,
                        metrics,
                        HubCounters {
                            stats,
                            invalid,
                            total,
                            batches,
                            merged,
                            fused_calls,
                            fused_rows,
                        },
                    )
                })
                .expect("spawn expansion hub");
        }
        Arc::new(ExpansionHub {
            tx,
            stats,
            invalid,
            total_hyps: total,
            batches,
            merged,
            fused_calls,
            fused_rows,
        })
    }

    /// Blocking single-molecule expansion (used by the `expand` op).
    pub fn expand(&self, smiles: &str, k: usize) -> Result<Vec<Proposal>> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(ExpandReq { smiles: smiles.to_string(), k, reply: tx })
            .map_err(|_| anyhow::anyhow!("hub gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("hub gone"))?
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats.lock().unwrap().clone()
    }

    /// (decode tasks submitted, requests merged into them).
    pub fn merge_ratio(&self) -> (u64, u64) {
        (self.batches.load(Ordering::Relaxed), self.merged.load(Ordering::Relaxed))
    }

    /// (fused device calls, fused logical rows): the cycle-level
    /// batching counters; rows/calls is the serving effective batch.
    pub fn fused_ratio(&self) -> (u64, u64) {
        (
            self.fused_calls.load(Ordering::Relaxed),
            self.fused_rows.load(Ordering::Relaxed),
        )
    }
}

struct HubCounters {
    stats: Arc<Mutex<DecodeStats>>,
    invalid: Arc<AtomicUsize>,
    total: Arc<AtomicUsize>,
    batches: Arc<AtomicU64>,
    merged: Arc<AtomicU64>,
    fused_calls: Arc<AtomicU64>,
    fused_rows: Arc<AtomicU64>,
}

/// A queued requester: requested beam width + reply channel.
type Waiter = (usize, mpsc::SyncSender<Result<Vec<Proposal>>>);

/// Mutable per-loop state: waiters and in-flight coverage.
struct HubState {
    cache: LruCache<String, CachedExpansion>,
    /// Requests not yet answered, per molecule.
    waiting: HashMap<String, Vec<Waiter>>,
    /// Max beam width currently being decoded per molecule.
    covered: HashMap<String, usize>,
    /// Misses gathered this round, unique by molecule.
    to_submit: Vec<(String, usize)>,
}

impl HubState {
    /// Serve a request from cache or queue it (possibly scheduling a
    /// decode for this round).
    fn admit(&mut self, req: ExpandReq) {
        if let Some(c) = self.cache.get(&req.smiles) {
            if c.k >= req.k {
                let mut out = c.props.clone();
                out.truncate(req.k);
                let _ = req.reply.send(Ok(out));
                return;
            }
        }
        let in_flight_covers = self.covered.get(&req.smiles).is_some_and(|&ck| ck >= req.k);
        if !in_flight_covers {
            if let Some(e) = self.to_submit.iter_mut().find(|(m, _)| *m == req.smiles) {
                e.1 = e.1.max(req.k);
            } else {
                self.to_submit.push((req.smiles.clone(), req.k));
            }
        }
        self.waiting.entry(req.smiles).or_default().push((req.k, req.reply));
    }

    /// Fail every queued request (scheduler abort path).
    fn fail_all(&mut self, msg: &str) {
        for (_, ws) in self.waiting.drain() {
            for (_, reply) in ws {
                let _ = reply.send(Err(anyhow::anyhow!("decode failed: {msg}")));
            }
        }
        self.covered.clear();
    }
}

#[allow(clippy::too_many_arguments)]
fn hub_loop<M: StepModel>(
    rx: mpsc::Receiver<ExpandReq>,
    model: M,
    decoder: Box<dyn Decoder + Send>,
    vocab: Vocab,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    counters: HubCounters,
) {
    let mut scheduler = DecodeScheduler::new(SchedulerConfig { max_rows: cfg.max_rows });
    let mut state = HubState {
        cache: LruCache::new(cfg.cache_cap),
        waiting: HashMap::new(),
        covered: HashMap::new(),
        to_submit: Vec::new(),
    };
    let mut tasks_meta: HashMap<TaskId, TaskMeta> = HashMap::new();
    let mut finished: Vec<Finished> = Vec::new();
    let mut open = true;

    while open || !scheduler.is_idle() || !state.waiting.is_empty() {
        // ---- 1. gather requests ----
        state.to_submit.clear();
        if open && scheduler.is_idle() && state.waiting.is_empty() {
            // Idle: block for the next request, then give stragglers a
            // short window so simultaneous arrivals share one encode.
            match rx.recv() {
                Ok(r) => {
                    counters.merged.fetch_add(1, Ordering::Relaxed);
                    state.admit(r);
                    let deadline = std::time::Instant::now() + cfg.max_wait;
                    let mut n = 1;
                    while n < cfg.max_batch {
                        let now = std::time::Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(r) => {
                                counters.merged.fetch_add(1, Ordering::Relaxed);
                                state.admit(r);
                                n += 1;
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                }
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        } else {
            // Busy: drain without blocking — late arrivals join the
            // very next fused call.
            let mut drained = 0;
            while drained < cfg.max_batch {
                match rx.try_recv() {
                    Ok(r) => {
                        counters.merged.fetch_add(1, Ordering::Relaxed);
                        state.admit(r);
                        drained += 1;
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }

        // ---- 2. submit this round's misses as one task ----
        if !state.to_submit.is_empty() {
            let k_max = state.to_submit.iter().map(|(_, k)| *k).max().unwrap_or(1);
            let mols: Vec<String> = state.to_submit.iter().map(|(m, _)| m.clone()).collect();
            let srcs: Vec<Vec<i32>> = mols.iter().map(|s| vocab.encode(s, true)).collect();
            match decoder.start_task(&model, &srcs, k_max) {
                Ok(task) => {
                    let id = scheduler.submit(task);
                    counters.batches.fetch_add(1, Ordering::Relaxed);
                    metrics.inc("batcher.tasks", 1);
                    metrics.inc("batcher.task_molecules", mols.len() as u64);
                    for m in &mols {
                        let e = state.covered.entry(m.clone()).or_insert(0);
                        *e = (*e).max(k_max);
                    }
                    tasks_meta.insert(id, TaskMeta { mols, k: k_max });
                }
                Err(e) => {
                    // Encode failed: fail only the waiters this round's
                    // task would have served (anything still covered by
                    // an older in-flight task keeps waiting).
                    let msg = format!("{e:#}");
                    for (m, _) in std::mem::take(&mut state.to_submit) {
                        let ck = state.covered.get(&m).copied().unwrap_or(0);
                        if let Some(ws) = state.waiting.remove(&m) {
                            let mut kept = Vec::new();
                            for (wk, reply) in ws {
                                if wk > ck {
                                    let _ = reply
                                        .send(Err(anyhow::anyhow!("encode failed: {msg}")));
                                } else {
                                    kept.push((wk, reply));
                                }
                            }
                            if !kept.is_empty() {
                                state.waiting.insert(m, kept);
                            }
                        }
                    }
                }
            }
        }

        // ---- 3. one fused tick ----
        if scheduler.is_idle() {
            if !state.waiting.is_empty() {
                // Unreachable by construction (waiters always have a
                // covering task); fail loudly instead of spinning.
                state.fail_all("internal: waiters without an in-flight task");
            }
            continue;
        }
        finished.clear();
        let t_tick = std::time::Instant::now();
        match scheduler.tick(&model, &mut finished) {
            Ok(rows) => {
                if rows > 0 {
                    counters.fused_calls.fetch_add(1, Ordering::Relaxed);
                    counters.fused_rows.fetch_add(rows as u64, Ordering::Relaxed);
                    metrics.inc("batcher.fused_calls", 1);
                    metrics.inc("batcher.fused_rows", rows as u64);
                    // A rows>0 tick is dominated by its one fused device
                    // call: this histogram replaces the old whole-
                    // `generate` "batcher.decode" timing at cycle
                    // granularity.
                    metrics.observe("batcher.decode", t_tick.elapsed().as_secs_f64());
                }
                for f in finished.drain(..) {
                    let meta = tasks_meta.remove(&f.id).expect("task bookkeeping");
                    counters.stats.lock().unwrap().merge(&f.stats);
                    retire_task(&meta, &f, &vocab, &mut state, &counters);
                }
            }
            Err(e) => {
                // A fused call failed: every in-flight task shared it,
                // so fail all waiters and reset.
                let msg = format!("{e:#}");
                scheduler.abort(&model);
                tasks_meta.clear();
                state.fail_all(&msg);
            }
        }
    }
}

/// Parse a finished task's outputs, populate the cache, and answer every
/// waiter the task covers.
fn retire_task(
    meta: &TaskMeta,
    f: &Finished,
    vocab: &Vocab,
    state: &mut HubState,
    counters: &HubCounters,
) {
    for (mol, gen) in meta.mols.iter().zip(f.outputs.iter()) {
        let mut inv = 0usize;
        let mut tot = 0usize;
        let props = proposals_from_output(vocab, mol, gen, &mut inv, &mut tot);
        counters.invalid.fetch_add(inv, Ordering::Relaxed);
        counters.total.fetch_add(tot, Ordering::Relaxed);
        let stale = state.cache.get(mol).is_none_or(|c| c.k <= meta.k);
        if stale {
            state.cache.insert(mol.clone(), CachedExpansion { k: meta.k, props: props.clone() });
        }
        if let Some(ws) = state.waiting.remove(mol) {
            let mut kept = Vec::new();
            for (wk, reply) in ws {
                if wk <= meta.k {
                    let mut out = props.clone();
                    out.truncate(wk);
                    let _ = reply.send(Ok(out));
                } else {
                    // A wider request for the same molecule is covered
                    // by a younger, larger-k task still in flight.
                    kept.push((wk, reply));
                }
            }
            if !kept.is_empty() {
                state.waiting.insert(mol.clone(), kept);
            }
        }
        if state.covered.get(mol).is_some_and(|&ck| ck <= meta.k) {
            state.covered.remove(mol);
        }
    }
}

/// Per-session [`ExpansionPolicy`] view over the hub. `Send`, cheap to
/// clone — each planning session owns one.
#[derive(Clone)]
pub struct BatchedPolicy {
    hub: Arc<ExpansionHub>,
    calls: Arc<AtomicUsize>,
}

impl BatchedPolicy {
    pub fn new(hub: Arc<ExpansionHub>) -> Self {
        Self { hub, calls: Arc::new(AtomicUsize::new(0)) }
    }
}

impl ExpansionPolicy for BatchedPolicy {
    fn expand_batch(&self, molecules: &[&str], k: usize) -> Result<Vec<Vec<Proposal>>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        // fan out, then join — the hub may merge these with other
        // sessions' requests
        let mut replies = Vec::with_capacity(molecules.len());
        for m in molecules {
            let (tx, rx) = mpsc::sync_channel(1);
            self.hub
                .tx
                .send(ExpandReq { smiles: m.to_string(), k, reply: tx })
                .map_err(|_| anyhow::anyhow!("hub gone"))?;
            replies.push(rx);
        }
        replies
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::anyhow!("hub gone"))?)
            .collect()
    }

    fn decode_stats(&self) -> DecodeStats {
        self.hub.stats()
    }

    fn calls(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};

    fn hub() -> Arc<ExpansionHub> {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(5),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn hub_expands_and_caches() {
        let h = hub();
        // the mock copies its input: a reactant-set string comes back as
        // a valid 2-component proposal
        let p1 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert!(!p1.is_empty());
        let calls_before = h.stats().model_calls;
        let p2 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(h.stats().model_calls, calls_before, "cache must serve repeats");
    }

    #[test]
    fn cache_serves_smaller_k_and_redecodes_larger() {
        let h = hub();
        let p5 = h.expand("CC(=O)O.CN", 5).unwrap();
        let calls_after_first = h.stats().model_calls;
        // smaller k: truncation of the stored expansion, no decode
        let p2 = h.expand("CC(=O)O.CN", 2).unwrap();
        assert_eq!(h.stats().model_calls, calls_after_first, "k<=stored must hit");
        assert!(p2.len() <= 2);
        assert_eq!(&p5[..p2.len()], &p2[..]);
        // larger k: must re-decode
        let _p8 = h.expand("CC(=O)O.CN", 8).unwrap();
        assert!(h.stats().model_calls > calls_after_first, "k>stored must miss");
        // and the cache now stores the larger entry
        let calls = h.stats().model_calls;
        let _ = h.expand("CC(=O)O.CN", 8).unwrap();
        assert_eq!(h.stats().model_calls, calls);
    }

    #[test]
    fn cache_is_bounded() {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO", "CCN", "CCC"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig { cache_cap: 2, ..Default::default() },
            Arc::new(Metrics::new()),
        );
        for m in ["CCO", "CCN", "CCC", "CC(=O)NC"] {
            let _ = h.expand(m, 2).unwrap();
        }
        // most-recent entry still hits
        let calls = h.stats().model_calls;
        let _ = h.expand("CC(=O)NC", 2).unwrap();
        assert_eq!(h.stats().model_calls, calls);
        // evicted entry recomputes
        let _ = h.expand("CCO", 2).unwrap();
        assert!(h.stats().model_calls > calls);
    }

    #[test]
    fn concurrent_sessions_share_batches() {
        let h = hub();
        let mut joins = Vec::new();
        for _ in 0..4 {
            let hc = h.clone();
            joins.push(std::thread::spawn(move || {
                let policy = BatchedPolicy::new(hc);
                policy.expand_batch(&["CC(=O)O.CN"], 3).unwrap()
            }));
        }
        for j in joins {
            assert!(!j.join().unwrap().is_empty());
        }
        let (batches, merged) = h.merge_ratio();
        assert!(merged >= 4);
        assert!(batches <= merged, "batches {batches} merged {merged}");
    }

    #[test]
    fn concurrent_distinct_molecules_fuse_calls() {
        let h = hub();
        let mols = ["CC(=O)O.CN", "CC(=O)NC", "CCO"];
        let mut joins = Vec::new();
        for m in mols {
            let hc = h.clone();
            joins.push(std::thread::spawn(move || hc.expand(m, 3).unwrap()));
        }
        for j in joins {
            let _ = j.join().unwrap();
        }
        let (fused_calls, fused_rows) = h.fused_ratio();
        assert!(fused_calls > 0);
        assert!(fused_rows >= fused_calls, "rows {fused_rows} calls {fused_calls}");
        // Solo per-molecule decoding would have cost at least as many
        // device calls as the hub's fused path.
        assert!(h.stats().model_calls >= fused_calls);
    }

    #[test]
    fn batched_policy_counts_calls() {
        let h = hub();
        let p = BatchedPolicy::new(h);
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        assert_eq!(p.calls(), 2);
    }
}
