//! The continuous batcher: merges single-step expansion requests from
//! all in-flight planning sessions into *cycle-level* fused decoder
//! calls.
//!
//! Requests arrive on a channel — blocking ([`ExpansionHub::expand`])
//! or as futures ([`ExpansionHub::submit`] →
//! [`ExpansionFuture`]: poll / wait / cancel). Cache hits answer
//! immediately. Each missing molecule becomes **one resumable decode
//! task of its own** submitted to the [`DecodeScheduler`]; the hub
//! thread then ticks the scheduler — ONE fused `decode` per tick across
//! *all* in-flight tasks — so every molecule joins the very next device
//! call when it arrives and **retires independently** the moment its own
//! beams finish, instead of waiting out the slowest co-arrival in a
//! drained batch. Cancellation (speculative searches abandoning
//! invalidated expansions) removes a molecule's task from the scheduler
//! as soon as its last waiter goes away, releasing its fused-call rows
//! and encoder memory. A tick error fails only the waiters of the tasks
//! that were actually in the errored fused call.
//!
//! The expansion cache is a bounded [`LruCache`] keyed by *molecule*
//! (not `(molecule, k)`): an entry decoded at k' serves any request with
//! k <= k' by truncation, and a larger-k request replaces the entry —
//! the same molecule is never re-decoded just because co-batched k
//! differed, and sustained traffic cannot leak memory.

use crate::decoding::scheduler::{DecodeScheduler, Finished, SchedulerConfig, TaskId};
use crate::decoding::{DecodeStats, Decoder};
use crate::metrics::Metrics;
use crate::model::StepModel;
use crate::search::policy::{
    proposals_from_output, AsyncExpansionPolicy, ExpansionHandle, KTruncatedCache, Proposal,
    DEFAULT_CACHE_CAP,
};
use crate::search::ExpansionPolicy;
use crate::tokenizer::Vocab;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

struct ExpandReq {
    smiles: String,
    k: usize,
    ticket: u64,
    reply: mpsc::SyncSender<Result<Vec<Proposal>>>,
}

enum HubMsg {
    Expand(ExpandReq),
    /// Withdraw the waiter `ticket` registered for `smiles`; the last
    /// waiter leaving cancels the molecule's in-flight decode tasks.
    Cancel { smiles: String, ticket: u64 },
    /// Introspection: (molecules with waiters, in-flight decode tasks,
    /// scheduler in-flight count). Tests use this to pin "no leaked
    /// waiters / tasks" after cancellation.
    Debug(mpsc::SyncSender<(usize, usize, usize)>),
}

/// Shared handle to the batcher thread.
pub struct ExpansionHub {
    tx: mpsc::Sender<HubMsg>,
    next_ticket: AtomicU64,
    stats: Arc<Mutex<DecodeStats>>,
    pub invalid: Arc<AtomicUsize>,
    pub total_hyps: Arc<AtomicUsize>,
    /// Per-query decode tasks submitted.
    batches: Arc<AtomicU64>,
    /// Requests admitted.
    merged: Arc<AtomicU64>,
    /// Fused device calls / fused logical rows (cycle-level batching).
    fused_calls: Arc<AtomicU64>,
    fused_rows: Arc<AtomicU64>,
    /// In-flight tasks abandoned because every waiter cancelled.
    cancelled: Arc<AtomicU64>,
}

/// A pending single-molecule expansion: the hub's future. Dropping it
/// without consuming the result cancels the request (so abandoned
/// speculation releases its decode work automatically).
pub struct ExpansionFuture {
    smiles: String,
    ticket: u64,
    rx: mpsc::Receiver<Result<Vec<Proposal>>>,
    hub_tx: mpsc::Sender<HubMsg>,
    spent: bool,
}

impl ExpansionFuture {
    /// Non-blocking: `Some` exactly once, when the expansion retired.
    pub fn poll(&mut self) -> Option<Result<Vec<Proposal>>> {
        match self.rx.try_recv() {
            Ok(r) => {
                self.spent = true;
                Some(r)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.spent = true;
                Some(Err(anyhow::anyhow!("hub gone")))
            }
        }
    }

    /// Block until the expansion retires.
    pub fn wait(mut self) -> Result<Vec<Proposal>> {
        self.spent = true;
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("hub gone")),
        }
    }

    /// Abandon the request. If this was the molecule's last waiter, its
    /// in-flight decode task leaves the scheduler (rows + encoder
    /// memory released). Equivalent to dropping the future.
    pub fn cancel(self) {}
}

impl Drop for ExpansionFuture {
    fn drop(&mut self) {
        if !self.spent {
            let _ = self.hub_tx.send(HubMsg::Cancel {
                smiles: std::mem::take(&mut self.smiles),
                ticket: self.ticket,
            });
        }
    }
}

/// Batcher tuning knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Most requests drained per gather round.
    pub max_batch: usize,
    /// How long an *idle* hub waits for stragglers before the first
    /// tick. While decoding, arrivals are drained non-blockingly and
    /// join the next tick anyway.
    pub max_wait: std::time::Duration,
    /// Fused-call row budget per scheduler tick.
    pub max_rows: usize,
    /// Expansion-cache capacity (molecules, LRU).
    pub cache_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 16,
            max_wait: std::time::Duration::from_micros(2000),
            max_rows: 256,
            cache_cap: DEFAULT_CACHE_CAP,
        }
    }
}

/// In-flight bookkeeping for one per-query decode task.
struct TaskMeta {
    mol: String,
    k: usize,
}

impl ExpansionHub {
    /// Start the hub thread. The model handle must be `Send` (use
    /// [`crate::runtime::server::SharedModel`] for PJRT models).
    pub fn start<M>(
        model: M,
        decoder: Box<dyn Decoder + Send>,
        vocab: Vocab,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Arc<ExpansionHub>
    where
        M: StepModel + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<HubMsg>();
        let stats = Arc::new(Mutex::new(DecodeStats::default()));
        let invalid = Arc::new(AtomicUsize::new(0));
        let total = Arc::new(AtomicUsize::new(0));
        let batches = Arc::new(AtomicU64::new(0));
        let merged = Arc::new(AtomicU64::new(0));
        let fused_calls = Arc::new(AtomicU64::new(0));
        let fused_rows = Arc::new(AtomicU64::new(0));
        let cancelled = Arc::new(AtomicU64::new(0));
        {
            let stats = stats.clone();
            let invalid = invalid.clone();
            let total = total.clone();
            let batches = batches.clone();
            let merged = merged.clone();
            let fused_calls = fused_calls.clone();
            let fused_rows = fused_rows.clone();
            let cancelled = cancelled.clone();
            std::thread::Builder::new()
                .name("expansion-hub".into())
                .spawn(move || {
                    hub_loop(
                        rx,
                        model,
                        decoder,
                        vocab,
                        cfg,
                        metrics,
                        HubCounters {
                            stats,
                            invalid,
                            total,
                            batches,
                            merged,
                            fused_calls,
                            fused_rows,
                            cancelled,
                        },
                    )
                })
                .expect("spawn expansion hub");
        }
        Arc::new(ExpansionHub {
            tx,
            next_ticket: AtomicU64::new(1),
            stats,
            invalid,
            total_hyps: total,
            batches,
            merged,
            fused_calls,
            fused_rows,
            cancelled,
        })
    }

    /// Asynchronous single-molecule expansion: returns a future the
    /// caller polls, waits on, or cancels. This is the pipelined
    /// planner's entry point.
    pub fn submit(&self, smiles: &str, k: usize) -> Result<ExpansionFuture> {
        let ticket = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(HubMsg::Expand(ExpandReq { smiles: smiles.to_string(), k, ticket, reply }))
            .map_err(|_| anyhow::anyhow!("hub gone"))?;
        Ok(ExpansionFuture {
            smiles: smiles.to_string(),
            ticket,
            rx,
            hub_tx: self.tx.clone(),
            spent: false,
        })
    }

    /// Blocking single-molecule expansion (used by the `expand` op).
    pub fn expand(&self, smiles: &str, k: usize) -> Result<Vec<Proposal>> {
        self.submit(smiles, k)?.wait()
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats.lock().unwrap().clone()
    }

    /// (per-query decode tasks submitted, requests admitted): requests
    /// per task is the cache + coalescing amplification.
    pub fn merge_ratio(&self) -> (u64, u64) {
        (self.batches.load(Ordering::Relaxed), self.merged.load(Ordering::Relaxed))
    }

    /// (fused device calls, fused logical rows): the cycle-level
    /// batching counters; rows/calls is the serving effective batch.
    pub fn fused_ratio(&self) -> (u64, u64) {
        (
            self.fused_calls.load(Ordering::Relaxed),
            self.fused_rows.load(Ordering::Relaxed),
        )
    }

    /// In-flight decode tasks abandoned after their last waiter
    /// cancelled.
    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Hub-thread state snapshot for tests and diagnostics:
    /// `(molecules with waiters, in-flight decode tasks, scheduler
    /// in-flight)`. Blocks until the hub finishes its current tick.
    pub fn debug_snapshot(&self) -> Result<(usize, usize, usize)> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(HubMsg::Debug(tx))
            .map_err(|_| anyhow::anyhow!("hub gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("hub gone"))
    }
}

struct HubCounters {
    stats: Arc<Mutex<DecodeStats>>,
    invalid: Arc<AtomicUsize>,
    total: Arc<AtomicUsize>,
    batches: Arc<AtomicU64>,
    merged: Arc<AtomicU64>,
    fused_calls: Arc<AtomicU64>,
    fused_rows: Arc<AtomicU64>,
    cancelled: Arc<AtomicU64>,
}

/// A queued requester.
struct Waiter {
    ticket: u64,
    k: usize,
    reply: mpsc::SyncSender<Result<Vec<Proposal>>>,
}

/// Mutable per-loop state: waiters and in-flight coverage.
struct HubState {
    /// Molecule-keyed, k-truncating expansion cache (shared core with
    /// the offline policies — see [`KTruncatedCache`]).
    cache: KTruncatedCache,
    /// Requests not yet answered, per molecule.
    waiting: HashMap<String, Vec<Waiter>>,
    /// In-flight per-query decode tasks per molecule — usually one; a
    /// wider-k re-request adds a second while the first still flies.
    covered: HashMap<String, Vec<(TaskId, usize)>>,
    /// Misses gathered this round, unique by molecule.
    to_submit: Vec<(String, usize)>,
}

impl HubState {
    /// Serve a request from cache or queue it (possibly scheduling a
    /// decode for this round).
    fn admit(&mut self, req: ExpandReq) {
        if let Some(out) = self.cache.get(&req.smiles, req.k) {
            let _ = req.reply.send(Ok(out));
            return;
        }
        let in_flight_covers = self
            .covered
            .get(&req.smiles)
            .is_some_and(|tasks| tasks.iter().any(|&(_, ck)| ck >= req.k));
        if !in_flight_covers {
            if let Some(e) = self.to_submit.iter_mut().find(|(m, _)| *m == req.smiles) {
                e.1 = e.1.max(req.k);
            } else {
                self.to_submit.push((req.smiles.clone(), req.k));
            }
        }
        self.waiting
            .entry(req.smiles)
            .or_default()
            .push(Waiter { ticket: req.ticket, k: req.k, reply: req.reply });
    }

    /// Remove one waiter; returns true when the molecule has no waiters
    /// left (its in-flight tasks may then be abandoned).
    fn remove_waiter(&mut self, smiles: &str, ticket: u64) -> bool {
        let Some(ws) = self.waiting.get_mut(smiles) else {
            return false; // already answered (or never queued)
        };
        ws.retain(|w| w.ticket != ticket);
        if ws.is_empty() {
            self.waiting.remove(smiles);
            true
        } else {
            false
        }
    }

    /// Max beam width of the remaining in-flight tasks for a molecule.
    fn covered_k(&self, smiles: &str) -> usize {
        self.covered
            .get(smiles)
            .map(|tasks| tasks.iter().map(|&(_, k)| k).max().unwrap_or(0))
            .unwrap_or(0)
    }

    /// Fail every queued request (hub-invariant breach only; tick
    /// errors are scoped per failed task instead).
    fn fail_all(&mut self, msg: &str) {
        for (_, ws) in self.waiting.drain() {
            for w in ws {
                let _ = w.reply.send(Err(anyhow::anyhow!("decode failed: {msg}")));
            }
        }
        self.covered.clear();
    }
}

/// Fail the waiters of one failed/unstartable task, keeping any waiter
/// another in-flight task still covers.
fn fail_task_waiters(state: &mut HubState, mol: &str, task_k: usize, msg: &str) {
    let remaining_k = state.covered_k(mol);
    if let Some(ws) = state.waiting.remove(mol) {
        let mut kept = Vec::new();
        for w in ws {
            if w.k <= task_k && w.k > remaining_k {
                let _ = w.reply.send(Err(anyhow::anyhow!("decode failed: {msg}")));
            } else {
                kept.push(w);
            }
        }
        if !kept.is_empty() {
            state.waiting.insert(mol.to_string(), kept);
        }
    }
}

/// Route one inbound message: admit expansions, queue cancellations,
/// answer debug probes. Returns whether the message was an expansion
/// (the only kind counted toward the gather budget).
fn on_msg(
    msg: HubMsg,
    state: &mut HubState,
    cancels: &mut Vec<(String, u64)>,
    sched_in_flight: usize,
) -> bool {
    match msg {
        HubMsg::Expand(r) => {
            state.admit(r);
            true
        }
        HubMsg::Cancel { smiles, ticket } => {
            cancels.push((smiles, ticket));
            false
        }
        HubMsg::Debug(tx) => {
            let tasks: usize = state.covered.values().map(Vec::len).sum();
            let _ = tx.send((state.waiting.len(), tasks, sched_in_flight));
            false
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn hub_loop<M: StepModel>(
    rx: mpsc::Receiver<HubMsg>,
    model: M,
    decoder: Box<dyn Decoder + Send>,
    vocab: Vocab,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    counters: HubCounters,
) {
    let mut scheduler = DecodeScheduler::new(SchedulerConfig { max_rows: cfg.max_rows });
    let mut state = HubState {
        cache: KTruncatedCache::new(cfg.cache_cap),
        waiting: HashMap::new(),
        covered: HashMap::new(),
        to_submit: Vec::new(),
    };
    let mut tasks_meta: HashMap<TaskId, TaskMeta> = HashMap::new();
    let mut cancels: Vec<(String, u64)> = Vec::new();
    let mut finished: Vec<Finished> = Vec::new();
    let mut in_flight_hw = 0usize;
    let mut open = true;

    while open || !scheduler.is_idle() || !state.waiting.is_empty() {
        // ---- 1. gather requests ----
        state.to_submit.clear();
        if open && scheduler.is_idle() && state.waiting.is_empty() {
            // Idle: block for the next request, then give stragglers a
            // short window so simultaneous arrivals share the first
            // ticks.
            match rx.recv() {
                Ok(msg) => {
                    let mut n = 0;
                    if on_msg(msg, &mut state, &mut cancels, scheduler.in_flight()) {
                        counters.merged.fetch_add(1, Ordering::Relaxed);
                        n += 1;
                    }
                    let deadline = std::time::Instant::now() + cfg.max_wait;
                    while n < cfg.max_batch && !state.to_submit.is_empty() {
                        let now = std::time::Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(msg) => {
                                let fl = scheduler.in_flight();
                                if on_msg(msg, &mut state, &mut cancels, fl) {
                                    counters.merged.fetch_add(1, Ordering::Relaxed);
                                    n += 1;
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                }
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        } else {
            // Busy: drain without blocking — late arrivals join the
            // very next fused call.
            let mut drained = 0;
            while drained < cfg.max_batch {
                match rx.try_recv() {
                    Ok(msg) => {
                        if on_msg(msg, &mut state, &mut cancels, scheduler.in_flight()) {
                            counters.merged.fetch_add(1, Ordering::Relaxed);
                            drained += 1;
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }

        // ---- 2. apply cancellations ----
        // A molecule whose last waiter withdrew loses its queued miss
        // and its in-flight decode tasks: the scheduler frees the rows
        // and encoder memory immediately, so speculative searches that
        // changed their mind never pay for the full decode.
        for (smiles, ticket) in cancels.drain(..) {
            if state.remove_waiter(&smiles, ticket) {
                state.to_submit.retain(|(m, _)| *m != smiles);
                if let Some(tasks) = state.covered.remove(&smiles) {
                    for (id, _) in tasks {
                        if scheduler.cancel(&model, id) {
                            counters.cancelled.fetch_add(1, Ordering::Relaxed);
                            metrics.inc("batcher.tasks_cancelled", 1);
                        }
                        tasks_meta.remove(&id);
                    }
                }
            }
        }

        // ---- 3. submit this round's misses: one task per query ----
        // Per-query tasks let each molecule retire independently while
        // still fusing into the same scheduler ticks; a slow molecule
        // no longer stalls its co-arrivals' answers.
        for (mol, k) in std::mem::take(&mut state.to_submit) {
            let srcs = [vocab.encode(&mol, true)];
            match decoder.start_task(&model, &srcs, k) {
                Ok(task) => {
                    let id = scheduler.submit(task);
                    counters.batches.fetch_add(1, Ordering::Relaxed);
                    metrics.inc("batcher.tasks", 1);
                    state.covered.entry(mol.clone()).or_default().push((id, k));
                    tasks_meta.insert(id, TaskMeta { mol, k });
                }
                Err(e) => {
                    // Encode failed: fail only this molecule's waiters
                    // (anything covered by an older in-flight task
                    // keeps waiting).
                    let msg = format!("encode failed: {e:#}");
                    fail_task_waiters(&mut state, &mol, k, &msg);
                }
            }
        }

        // ---- 4. one fused tick ----
        // Publish the in-flight high-water mark only when it moves:
        // steady-state ticks must stay free of mutex/alloc traffic.
        if scheduler.in_flight() > in_flight_hw {
            in_flight_hw = scheduler.in_flight();
            metrics.gauge_max("scheduler.in_flight_tasks", in_flight_hw as u64);
        }
        if scheduler.is_idle() {
            if !state.waiting.is_empty() {
                // Unreachable by construction (waiters always have a
                // covering task); fail loudly instead of spinning.
                state.fail_all("internal: waiters without an in-flight task");
            }
            continue;
        }
        finished.clear();
        let t_tick = std::time::Instant::now();
        match scheduler.tick(&model, &mut finished) {
            Ok(rows) => {
                if rows > 0 {
                    counters.fused_calls.fetch_add(1, Ordering::Relaxed);
                    counters.fused_rows.fetch_add(rows as u64, Ordering::Relaxed);
                    metrics.inc("batcher.fused_calls", 1);
                    metrics.inc("batcher.fused_rows", rows as u64);
                    // A rows>0 tick is dominated by its one fused device
                    // call: this histogram replaces the old whole-
                    // `generate` "batcher.decode" timing at cycle
                    // granularity.
                    metrics.observe("batcher.decode", t_tick.elapsed().as_secs_f64());
                }
                for f in finished.drain(..) {
                    let meta = tasks_meta.remove(&f.id).expect("task bookkeeping");
                    counters.stats.lock().unwrap().merge(&f.stats);
                    retire_task(f.id, &meta, &f, &vocab, &mut state, &counters);
                }
            }
            Err(e) => {
                // The fused call failed: exactly the tasks staged in it
                // were dropped by the scheduler. Fail their waiters and
                // nobody else's — unstaged tasks keep flying.
                let msg = format!("{e:#}");
                for id in scheduler.drain_failed() {
                    if let Some(meta) = tasks_meta.remove(&id) {
                        if let Some(tasks) = state.covered.get_mut(&meta.mol) {
                            tasks.retain(|&(tid, _)| tid != id);
                            if tasks.is_empty() {
                                state.covered.remove(&meta.mol);
                            }
                        }
                        fail_task_waiters(&mut state, &meta.mol, meta.k, &msg);
                    }
                }
            }
        }
    }
}

/// Parse a finished per-query task's output, populate the cache, and
/// answer every waiter the task covers.
fn retire_task(
    id: TaskId,
    meta: &TaskMeta,
    f: &Finished,
    vocab: &Vocab,
    state: &mut HubState,
    counters: &HubCounters,
) {
    let gen = f.outputs.first().expect("per-query task has one output");
    let mol = &meta.mol;
    let mut inv = 0usize;
    let mut tot = 0usize;
    let props = proposals_from_output(vocab, mol, gen, &mut inv, &mut tot);
    counters.invalid.fetch_add(inv, Ordering::Relaxed);
    counters.total.fetch_add(tot, Ordering::Relaxed);
    state.cache.insert(mol.clone(), meta.k, props.clone());
    if let Some(ws) = state.waiting.remove(mol) {
        let mut kept = Vec::new();
        for w in ws {
            if w.k <= meta.k {
                let mut out = props.clone();
                out.truncate(w.k);
                let _ = w.reply.send(Ok(out));
            } else {
                // A wider request for the same molecule is covered by a
                // younger, larger-k task still in flight.
                kept.push(w);
            }
        }
        if !kept.is_empty() {
            state.waiting.insert(mol.clone(), kept);
        }
    }
    if let Some(tasks) = state.covered.get_mut(mol) {
        tasks.retain(|&(tid, _)| tid != id);
        if tasks.is_empty() {
            state.covered.remove(mol);
        }
    }
}

/// Per-session [`ExpansionPolicy`] view over the hub. `Send`, cheap to
/// clone — each planning session owns one. Also implements
/// [`AsyncExpansionPolicy`], so pipelined Retro\* rides per-query
/// futures straight into the scheduler.
#[derive(Clone)]
pub struct BatchedPolicy {
    hub: Arc<ExpansionHub>,
    calls: Arc<AtomicUsize>,
}

impl BatchedPolicy {
    pub fn new(hub: Arc<ExpansionHub>) -> Self {
        Self { hub, calls: Arc::new(AtomicUsize::new(0)) }
    }
}

/// A group of per-molecule hub futures joined into one batch handle.
struct HubHandle {
    futs: Vec<Option<ExpansionFuture>>,
    results: Vec<Option<Vec<Proposal>>>,
}

impl ExpansionHandle for HubHandle {
    fn poll(&mut self) -> Option<Result<Vec<Vec<Proposal>>>> {
        let mut pending = false;
        for (i, slot) in self.futs.iter_mut().enumerate() {
            if self.results[i].is_some() {
                continue;
            }
            let Some(f) = slot.as_mut() else { continue };
            match f.poll() {
                Some(Ok(p)) => {
                    self.results[i] = Some(p);
                    *slot = None;
                }
                // On error the handle is spent; dropping it (and the
                // remaining futures with it) cancels the rest.
                Some(Err(e)) => return Some(Err(e)),
                None => pending = true,
            }
        }
        if pending {
            return None;
        }
        Some(Ok(self
            .results
            .iter_mut()
            .map(|r| r.take().unwrap_or_default())
            .collect()))
    }

    fn wait(mut self: Box<Self>) -> Result<Vec<Vec<Proposal>>> {
        for (i, slot) in self.futs.iter_mut().enumerate() {
            if self.results[i].is_some() {
                continue;
            }
            if let Some(f) = slot.take() {
                self.results[i] = Some(f.wait()?);
            }
        }
        Ok(self
            .results
            .iter_mut()
            .map(|r| r.take().unwrap_or_default())
            .collect())
    }

    fn cancel(self: Box<Self>) {
        // Drop on the remaining futures sends the hub cancellations.
    }
}

impl ExpansionPolicy for BatchedPolicy {
    fn expand_batch(&self, molecules: &[&str], k: usize) -> Result<Vec<Vec<Proposal>>> {
        // fan out, then join — the hub may merge these with other
        // sessions' requests
        self.submit(molecules, k)?.wait()
    }

    fn decode_stats(&self) -> DecodeStats {
        self.hub.stats()
    }

    fn calls(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }
}

impl AsyncExpansionPolicy for BatchedPolicy {
    fn submit(&self, molecules: &[&str], k: usize) -> Result<Box<dyn ExpansionHandle>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut futs = Vec::with_capacity(molecules.len());
        for m in molecules {
            futs.push(Some(self.hub.submit(m, k)?));
        }
        Ok(Box::new(HubHandle { results: vec![None; futs.len()], futs }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};

    fn hub() -> Arc<ExpansionHub> {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(5),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn hub_expands_and_caches() {
        let h = hub();
        // the mock copies its input: a reactant-set string comes back as
        // a valid 2-component proposal
        let p1 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert!(!p1.is_empty());
        let calls_before = h.stats().model_calls;
        let p2 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(h.stats().model_calls, calls_before, "cache must serve repeats");
    }

    #[test]
    fn cache_serves_smaller_k_and_redecodes_larger() {
        let h = hub();
        let p5 = h.expand("CC(=O)O.CN", 5).unwrap();
        let calls_after_first = h.stats().model_calls;
        // smaller k: truncation of the stored expansion, no decode
        let p2 = h.expand("CC(=O)O.CN", 2).unwrap();
        assert_eq!(h.stats().model_calls, calls_after_first, "k<=stored must hit");
        assert!(p2.len() <= 2);
        assert_eq!(&p5[..p2.len()], &p2[..]);
        // larger k: must re-decode
        let _p8 = h.expand("CC(=O)O.CN", 8).unwrap();
        assert!(h.stats().model_calls > calls_after_first, "k>stored must miss");
        // and the cache now stores the larger entry
        let calls = h.stats().model_calls;
        let _ = h.expand("CC(=O)O.CN", 8).unwrap();
        assert_eq!(h.stats().model_calls, calls);
    }

    #[test]
    fn cache_is_bounded() {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO", "CCN", "CCC"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig { cache_cap: 2, ..Default::default() },
            Arc::new(Metrics::new()),
        );
        for m in ["CCO", "CCN", "CCC", "CC(=O)NC"] {
            let _ = h.expand(m, 2).unwrap();
        }
        // most-recent entry still hits
        let calls = h.stats().model_calls;
        let _ = h.expand("CC(=O)NC", 2).unwrap();
        assert_eq!(h.stats().model_calls, calls);
        // evicted entry recomputes
        let _ = h.expand("CCO", 2).unwrap();
        assert!(h.stats().model_calls > calls);
    }

    #[test]
    fn concurrent_sessions_share_batches() {
        let h = hub();
        let mut joins = Vec::new();
        for _ in 0..4 {
            let hc = h.clone();
            joins.push(std::thread::spawn(move || {
                let policy = BatchedPolicy::new(hc);
                policy.expand_batch(&["CC(=O)O.CN"], 3).unwrap()
            }));
        }
        for j in joins {
            assert!(!j.join().unwrap().is_empty());
        }
        let (tasks, merged) = h.merge_ratio();
        assert!(merged >= 4);
        assert!(tasks <= merged, "tasks {tasks} merged {merged}");
    }

    #[test]
    fn concurrent_distinct_molecules_fuse_calls() {
        let h = hub();
        let mols = ["CC(=O)O.CN", "CC(=O)NC", "CCO"];
        let mut joins = Vec::new();
        for m in mols {
            let hc = h.clone();
            joins.push(std::thread::spawn(move || hc.expand(m, 3).unwrap()));
        }
        for j in joins {
            let _ = j.join().unwrap();
        }
        let (fused_calls, fused_rows) = h.fused_ratio();
        assert!(fused_calls > 0);
        assert!(fused_rows >= fused_calls, "rows {fused_rows} calls {fused_calls}");
        // Solo per-molecule decoding would have cost at least as many
        // device calls as the hub's fused path.
        assert!(h.stats().model_calls >= fused_calls);
    }

    #[test]
    fn futures_poll_to_completion() {
        let h = hub();
        let mut fut = h.submit("CC(=O)O.CN", 3).unwrap();
        let mut result = None;
        for _ in 0..2000 {
            if let Some(r) = fut.poll() {
                result = Some(r);
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        let props = result.expect("future must complete").unwrap();
        assert!(!props.is_empty());
        // a second future for the same molecule hits the cache
        let calls = h.stats().model_calls;
        let p2 = h.submit("CC(=O)O.CN", 3).unwrap().wait().unwrap();
        assert_eq!(props, p2);
        assert_eq!(h.stats().model_calls, calls);
    }

    #[test]
    fn cancelled_future_leaves_no_state_behind() {
        let h = hub();
        let fut = h.submit("CC(=O)NC", 4).unwrap();
        fut.cancel();
        // settle: the hub processes the cancel between ticks
        let mut clean = false;
        for _ in 0..2000 {
            let (waiting, tasks, in_flight) = h.debug_snapshot().unwrap();
            if waiting == 0 && tasks == 0 && in_flight == 0 {
                clean = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        assert!(clean, "cancelled request must leave no waiters or tasks");
        // the hub still serves fresh work afterwards
        let p = h.expand("CC(=O)O.CN", 3).unwrap();
        assert!(!p.is_empty());
    }

    #[test]
    fn cancel_with_remaining_waiter_keeps_the_task() {
        let h = hub();
        // two futures on the same molecule: cancelling one must not
        // starve the other
        let keep = h.submit("CC(=O)O.CN", 3).unwrap();
        let drop_me = h.submit("CC(=O)O.CN", 3).unwrap();
        drop_me.cancel();
        let props = keep.wait().unwrap();
        assert!(!props.is_empty(), "surviving waiter must still be answered");
    }

    #[test]
    fn batched_policy_counts_calls() {
        let h = hub();
        let p = BatchedPolicy::new(h);
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        assert_eq!(p.calls(), 2);
    }

    #[test]
    fn async_policy_handle_round_trip() {
        let h = hub();
        let p = BatchedPolicy::new(h);
        let handle = AsyncExpansionPolicy::submit(&p, &["CC(=O)O.CN", "CCO"], 3).unwrap();
        let out = handle.wait().unwrap();
        assert_eq!(out.len(), 2);
        assert!(!out[0].is_empty());
        assert_eq!(p.calls(), 1);
    }
}
