//! The continuous batcher: merges single-step expansion requests from
//! all in-flight planning sessions into *cycle-level* fused decoder
//! calls, sharded across sessions and replicated across devices.
//!
//! Requests arrive through the [`ExpansionHub`] facade — blocking
//! ([`ExpansionHub::expand`]) or as futures ([`ExpansionHub::submit`]
//! → [`ExpansionFuture`]: poll / wait / cancel). The facade routes
//! each request to one of S **shard loops**
//! ([`super::shard::shard_loop`]), independent hub threads that each
//! own their sessions' waiter bookkeeping. Cache hits answer
//! immediately (the expansion cache is a *cross-shard* tier — a
//! molecule decoded by any shard serves every shard). Each missing
//! molecule becomes **one resumable decode task of its own** submitted
//! to a per-replica [`DecodeScheduler`]; the shard thread ticks its
//! schedulers — ONE fused `decode` per replica per tick across *all*
//! of that replica's in-flight tasks — so every molecule joins the
//! very next device call when it arrives and **retires independently**
//! the moment its own beams finish. Cancellation (speculative searches
//! abandoning invalidated expansions) removes a molecule's task from
//! its scheduler as soon as its last waiter goes away, releasing its
//! fused-call rows and encoder memory. A tick error fails only the
//! waiters of the tasks that were actually in the errored fused call.
//!
//! ## Sharding, replicas, stealing, dedup
//!
//! - **Shards** (`batcher.shards`): S independent loop threads;
//!   submits route to the least-queued shard, so admission and
//!   bookkeeping scale past the single-thread hub wall at high
//!   session counts.
//! - **Replicas** (`model.replicas`): every shard draws replicas from
//!   one shared [`ReplicaPool`] — N model executors behind
//!   least-outstanding-rows dispatch, each a supervised failure domain
//!   of its own. A replica dead past `max_restarts` drains its work
//!   back onto survivors; waiters fail only when the last replica dies.
//! - **Work stealing** (`batcher.steal`): a submit whose least-loaded
//!   shard is already a full gather round deep spills to a shared
//!   queue; whichever shard frees up first claims it.
//! - **Cross-shard dedup**: an in-flight registry maps molecule →
//!   owning shard, so two sessions expanding the same molecule from
//!   different shards join ONE decode task
//!   ([`ExpansionHub::dedup_joins`]).
//!
//! At `shards = 1, replicas = 1` (the defaults) the tier is
//! bit-identical to the single hub loop it generalizes: one thread,
//! one scheduler, routing and stealing degenerate to no-ops.
//!
//! ## Two-tier admission (interactive vs batch)
//!
//! Every request carries a [`Priority`] class. Interactive submits
//! (the default — `plan`/`expand` ops, [`ExpansionHub::submit`]) keep
//! the strict oldest-first admission they always had. Batch-class
//! submits ([`ExpansionHub::submit_batch`], used by screening jobs via
//! [`BatchedPolicy::batch_class`]) are *deferred at round formation*:
//! a batch miss waits in a shard-local backlog and only enters a
//! submission round when no interactive miss is pending, so a
//! thousand-target screening job cannot inflate interactive p95. Batch
//! cache hits and joins onto already-in-flight decodes still answer
//! immediately — sharing never waits. The steal queue is two-lane for
//! the same reason: spilled interactive requests are claimed before
//! spilled batch ones (FIFO within each class). With no interactive
//! traffic present, batch admission degenerates to exactly the
//! interactive path — a lone screening job loses nothing, and
//! single-target screening stays bit-identical to a solo plan.
//!
//! ## Fused-encode admission
//!
//! All cache-missing molecules gathered in one shard's submission
//! round share **one** [`StepModel::encode`] call
//! ([`crate::model::encode_shared`]): each molecule then decodes over
//! its own ref-counted row view ([`crate::model::MemView`]) of the
//! shared batch. Encoder cost is therefore O(submission rounds), not
//! O(misses), while retirement stays per-query. Under load,
//! `batcher.coalesce_us` optionally holds a round with queued misses
//! open for a bounded window so *near*-arrivals share the round's
//! single encode. The batch memory is released on the device exactly
//! when the round's *last* member task retires or is cancelled.
//! [`ExpansionHub::encode_ratio`] exposes the (physical encoder calls,
//! encoding rounds) counters — equal while fused encodes succeed; a
//! round whose fused encode errors falls back to per-molecule encodes,
//! so one bad source fails only its own waiters.
//!
//! ## Event-driven completion
//!
//! Retirements, failures and processed cancellations bump
//! condvar-backed completion epochs — each shard's local queue plus a
//! hub-global one; [`ExpansionHub::wait_any`] and the pipelined
//! planner's multi-group wait ([`HubHandle`]'s `wait_event`) block on
//! the narrowest queue that covers their futures instead of
//! sleep-polling, so a completion wakes its waiter immediately and an
//! idle wait burns no CPU.
//!
//! The expansion cache is a bounded [`LruCache`] keyed by *molecule*
//! (not `(molecule, k)`): an entry decoded at k' serves any request
//! with k <= k' by truncation, and a larger-k request replaces the
//! entry — the same molecule is never re-decoded just because
//! co-batched k differed, and sustained traffic cannot leak memory.
//!
//! [`DecodeScheduler`]: crate::decoding::scheduler::DecodeScheduler
//! [`LruCache`]: crate::util::lru::LruCache

use super::shard::{shard_loop, InFlightRegistry, ShardCtx, ShardEvents, StealQueue};
use crate::decoding::{DecodeStats, Decoder};
use crate::metrics::Metrics;
use crate::model::{ReplicaPool, ReplicaStats, StepModel};
use crate::search::policy::{
    AsyncExpansionPolicy, ExpansionHandle, Proposal, SyncExpansionCache, DEFAULT_CACHE_CAP,
};
use crate::search::ExpansionPolicy;
use crate::tokenizer::Vocab;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// Condvar-backed completion events: a shard bumps the epoch whenever
/// something a waiter could observe happened (a request was answered, a
/// task failed, a cancellation was processed), and waiters block on it
/// instead of sleep-polling.
///
/// The epoch protocol makes missed wakeups impossible: capture
/// [`CompletionQueue::epoch`] BEFORE polling, then
/// [`CompletionQueue::wait_past`] that value — any event after the
/// capture advances the epoch past it, so the wait returns immediately.
/// Spurious wakeups merely cost a re-poll.
pub(crate) struct CompletionQueue {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl CompletionQueue {
    pub(crate) fn new() -> Self {
        Self { epoch: Mutex::new(0), cv: Condvar::new() }
    }

    // The epoch is a bare counter, so a poisoned lock (a waiter
    // panicked while holding it) cannot leave it torn — recover the
    // guard instead of cascading the panic into every other session's
    // wait path.
    pub(crate) fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub(crate) fn notify(&self) {
        let mut e = self.epoch.lock().unwrap_or_else(|p| p.into_inner());
        *e += 1;
        self.cv.notify_all();
    }

    /// Block until the epoch advances past `seen` or `deadline` passes;
    /// returns the current epoch (feed it back in as the next `seen`).
    pub(crate) fn wait_past(&self, seen: u64, deadline: std::time::Instant) -> u64 {
        let mut e = self.epoch.lock().unwrap_or_else(|p| p.into_inner());
        while *e <= seen {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match self.cv.wait_timeout(e, deadline - now) {
                Ok((guard, _)) => e = guard,
                Err(p) => e = p.into_inner().0,
            }
        }
        *e
    }
}

/// Admission priority class. Interactive requests keep strict
/// oldest-first service; batch requests (screening jobs) defer at
/// round formation whenever an interactive miss is pending and are
/// claimed last from the steal queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    Interactive,
    Batch,
}

/// One expansion request as a shard sees it.
pub(crate) struct ExpandReq {
    pub(crate) smiles: String,
    pub(crate) k: usize,
    pub(crate) ticket: u64,
    /// Request-budget deadline: the shard expires the waiter (scoped
    /// error, task cancelled when it was the last waiter) at the first
    /// round boundary past this instant, even if the submitting thread
    /// never polls again. `None` = no deadline.
    pub(crate) deadline: Option<std::time::Instant>,
    /// Admission class: batch-class misses yield round formation to
    /// interactive ones (two-tier admission).
    pub(crate) priority: Priority,
    pub(crate) reply: mpsc::SyncSender<Result<Vec<Proposal>>>,
}

pub(crate) enum HubMsg {
    Expand(ExpandReq),
    /// Withdraw the waiter `ticket` registered for `smiles`; the last
    /// waiter leaving cancels the molecule's in-flight decode tasks.
    /// Broadcast to every shard for spilled requests — shards without
    /// the ticket no-op.
    Cancel { smiles: String, ticket: u64 },
    /// Wake an idle shard so it drains the steal queue (sent by the
    /// facade after spilling a request there).
    Poke,
    /// Introspection: (molecules with waiters, in-flight decode tasks,
    /// scheduler in-flight count, queued interactive misses, backlogged
    /// batch requests) — read together on the shard thread so the
    /// per-shard snapshot is internally consistent; the facade sums
    /// shards. Tests use this to pin "no leaked waiters / tasks" after
    /// cancellation through the stack, and the per-priority depths make
    /// two-tier admission observable.
    Debug(mpsc::SyncSender<(usize, usize, usize, usize, usize)>),
}

/// The facade's per-shard handle.
struct ShardHandle {
    tx: mpsc::Sender<HubMsg>,
    /// Queued-Expand depth of the shard's inbox (routing signal;
    /// incremented on send, decremented by the shard on drain).
    depth: Arc<AtomicUsize>,
    /// The shard's local completion queue (futures routed there wait
    /// on it — no cross-shard wakeup storms).
    events: Arc<CompletionQueue>,
}

/// Shared handle to the sharded batcher tier.
pub struct ExpansionHub {
    shards: Vec<ShardHandle>,
    pool: Arc<ReplicaPool>,
    registry: Arc<InFlightRegistry>,
    steal_q: Arc<StealQueue>,
    metrics: Arc<Metrics>,
    /// Work stealing is live (config on AND more than one shard — a
    /// single shard has nobody to steal from, so its submits never
    /// spill and parity with the unsharded hub holds).
    steal_on: bool,
    max_batch: usize,
    next_ticket: AtomicU64,
    stats: Arc<Mutex<DecodeStats>>,
    pub invalid: Arc<AtomicUsize>,
    pub total_hyps: Arc<AtomicUsize>,
    /// Per-query decode tasks submitted.
    batches: Arc<AtomicU64>,
    /// Requests admitted.
    merged: Arc<AtomicU64>,
    /// Fused device calls / fused logical rows (cycle-level batching).
    fused_calls: Arc<AtomicU64>,
    fused_rows: Arc<AtomicU64>,
    /// Physical encoder calls / submission rounds that encoded
    /// (fused-encode admission keeps these equal at any fan-in).
    encode_calls: Arc<AtomicU64>,
    encode_rounds: Arc<AtomicU64>,
    /// In-flight tasks abandoned because every waiter cancelled.
    cancelled: Arc<AtomicU64>,
    /// Spilled requests claimed by a shard (incremented by shards).
    steals: Arc<AtomicU64>,
    /// Replicas lost past `max_restarts` (incremented by shards).
    replica_deaths: Arc<AtomicU64>,
    /// Submits joined to another shard's in-flight decode.
    dedup_joins: AtomicU64,
    /// Submits spilled to the steal queue (all shards saturated).
    steal_spills: AtomicU64,
    /// Hub-global completion events (every shard bumps these too).
    events: Arc<CompletionQueue>,
}

/// Hub state snapshot (see [`ExpansionHub::debug_snapshot`]), summed
/// across shards.
#[derive(Clone, Copy, Debug)]
pub struct HubSnapshot {
    /// Molecules with registered waiters (per-shard sum; a molecule
    /// waited on from two shards counts twice).
    pub waiting_molecules: usize,
    /// In-flight per-query decode tasks the shards track.
    pub decode_tasks: usize,
    /// Tasks currently inside the schedulers.
    pub sched_in_flight: usize,
    /// Physical [`StepModel::encode`] calls issued so far.
    pub encode_calls: u64,
    /// Submission rounds that attempted an encode. Fused-encode
    /// admission means `encode_calls == encode_rounds` whenever every
    /// round's fused encode succeeded; a round whose fused encode
    /// errors falls back to per-molecule encodes (extra calls on that
    /// error path only — one bad source must not fail its co-arrivals).
    pub encode_rounds: u64,
    /// Interactive misses queued for the next submission round
    /// (per-shard sum).
    pub queued_interactive: usize,
    /// Batch-class requests deferred in shard backlogs, waiting for a
    /// round with no interactive miss pending (per-shard sum).
    pub queued_batch: usize,
    /// Spilled interactive requests waiting in the steal queue.
    pub steal_interactive: usize,
    /// Spilled batch requests waiting in the steal queue (claimed only
    /// after every spilled interactive one).
    pub steal_batch: usize,
}

/// A pending single-molecule expansion: the hub's future. Dropping it
/// without consuming the result cancels the request (so abandoned
/// speculation releases its decode work automatically).
pub struct ExpansionFuture {
    smiles: String,
    ticket: u64,
    rx: mpsc::Receiver<Result<Vec<Proposal>>>,
    /// Where a drop-cancel goes: the routed shard's channel, or every
    /// shard's for a spilled request (whichever shard claimed it acts;
    /// the rest no-op on the unknown ticket).
    cancel_txs: Vec<mpsc::Sender<HubMsg>>,
    /// The completion queue this future's retirement bumps (owner
    /// shard's local queue; the hub-global one for spilled requests).
    events: Arc<CompletionQueue>,
    /// A result pulled off the channel but not yet consumed
    /// ([`ExpansionHub::wait_any`] buffers here so readiness can be
    /// observed without consuming).
    ready: Option<Result<Vec<Proposal>>>,
    spent: bool,
}

impl ExpansionFuture {
    /// Pull a pending result into the local buffer without consuming
    /// it; `true` when one is held. A future whose result was already
    /// consumed stays not-ready forever.
    fn fill(&mut self) -> bool {
        if self.spent {
            return self.ready.is_some();
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.spent = true;
                self.ready = Some(r);
                true
            }
            Err(mpsc::TryRecvError::Empty) => false,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.spent = true;
                self.ready = Some(Err(anyhow::anyhow!("hub gone")));
                true
            }
        }
    }

    /// Non-blocking: `Some` exactly once, when the expansion retired.
    pub fn poll(&mut self) -> Option<Result<Vec<Proposal>>> {
        if self.fill() {
            self.ready.take()
        } else {
            None
        }
    }

    /// Block until the expansion retires (channel-blocking — no
    /// polling).
    pub fn wait(mut self) -> Result<Vec<Proposal>> {
        if let Some(r) = self.ready.take() {
            return r;
        }
        self.spent = true;
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("hub gone")),
        }
    }

    /// Block until the expansion retires or `deadline` passes. Expiry
    /// returns a scoped "deadline" error and withdraws the request
    /// (the drop-cancel path runs, so the hub releases the decode task
    /// if this was its last waiter) — only this waiter fails.
    pub fn wait_deadline(mut self, deadline: std::time::Instant) -> Result<Vec<Proposal>> {
        if let Some(r) = self.ready.take() {
            return r;
        }
        let now = std::time::Instant::now();
        if now >= deadline {
            return Err(anyhow::anyhow!("request deadline expired"));
        }
        match self.rx.recv_timeout(deadline - now) {
            Ok(r) => {
                self.spent = true;
                r
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // NOT spent: dropping `self` sends the hub a Cancel.
                Err(anyhow::anyhow!("request deadline expired"))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.spent = true;
                Err(anyhow::anyhow!("hub gone"))
            }
        }
    }

    /// Abandon the request. If this was the molecule's last waiter, its
    /// in-flight decode task leaves the scheduler (rows + encoder
    /// memory released). Equivalent to dropping the future.
    pub fn cancel(self) {}
}

impl Drop for ExpansionFuture {
    fn drop(&mut self) {
        if !self.spent {
            for tx in &self.cancel_txs {
                let _ = tx.send(HubMsg::Cancel {
                    smiles: self.smiles.clone(),
                    ticket: self.ticket,
                });
            }
        }
    }
}

/// Batcher tuning knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Most requests drained per gather round.
    pub max_batch: usize,
    /// How long an *idle* shard waits for stragglers before the first
    /// tick. While decoding, arrivals are drained non-blockingly and
    /// join the next tick anyway.
    pub max_wait: std::time::Duration,
    /// Deadline-based encode coalescer (`batcher.coalesce_us`; zero =
    /// off): while a shard is busy, a round that gathered at least one
    /// miss is held open this long so near-arrivals join its single
    /// fused encode instead of paying their own round. Trades a
    /// bounded admission delay for fewer encoder calls under load —
    /// visible in [`ExpansionHub::encode_ratio`].
    pub coalesce: std::time::Duration,
    /// Fused-call row budget per scheduler tick.
    pub max_rows: usize,
    /// Expansion-cache capacity (molecules, LRU, shared across shards).
    pub cache_cap: usize,
    /// Session shards (`batcher.shards`; 1 = the classic single hub
    /// loop, bit-identical to the unsharded tier).
    pub shards: usize,
    /// Work stealing between shards (`batcher.steal`; only meaningful
    /// with `shards > 1`).
    pub steal: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 16,
            max_wait: std::time::Duration::from_micros(2000),
            coalesce: std::time::Duration::ZERO,
            max_rows: 256,
            cache_cap: DEFAULT_CACHE_CAP,
            shards: 1,
            steal: true,
        }
    }
}

/// Cross-shard counters, shared by every shard loop and the facade.
#[derive(Clone)]
pub(crate) struct HubCounters {
    pub(crate) stats: Arc<Mutex<DecodeStats>>,
    pub(crate) invalid: Arc<AtomicUsize>,
    pub(crate) total: Arc<AtomicUsize>,
    pub(crate) batches: Arc<AtomicU64>,
    pub(crate) merged: Arc<AtomicU64>,
    pub(crate) fused_calls: Arc<AtomicU64>,
    pub(crate) fused_rows: Arc<AtomicU64>,
    pub(crate) encode_calls: Arc<AtomicU64>,
    pub(crate) encode_rounds: Arc<AtomicU64>,
    pub(crate) cancelled: Arc<AtomicU64>,
    pub(crate) steals: Arc<AtomicU64>,
    pub(crate) replica_deaths: Arc<AtomicU64>,
}

impl ExpansionHub {
    /// Start the tier over a single model — the classic entry point;
    /// equivalent to [`ExpansionHub::start_pool`] with a one-replica
    /// pool. The model handle must be `Send + Sync` (use
    /// [`crate::runtime::server::SharedModel`] for PJRT models).
    pub fn start<M>(
        model: M,
        decoder: Box<dyn Decoder + Send>,
        vocab: Vocab,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Arc<ExpansionHub>
    where
        M: StepModel + Send + Sync + 'static,
    {
        Self::start_pool(ReplicaPool::single(model), decoder, vocab, cfg, metrics)
    }

    /// Start the tier over a replica pool: `cfg.shards` shard threads
    /// share the pool, the cross-shard cache, the in-flight registry
    /// and the steal queue.
    pub fn start_pool(
        pool: ReplicaPool,
        decoder: Box<dyn Decoder + Send>,
        vocab: Vocab,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Arc<ExpansionHub> {
        Self::start_pool_with_store(pool, decoder, vocab, cfg, metrics, None)
    }

    /// As [`ExpansionHub::start_pool`], with an optional persistent
    /// store as the L2 tier under the cross-shard cache: shards probe
    /// it on an L1 miss (promoting hits into L1) and record every
    /// retired expansion into it. `None` is byte-identical to the
    /// store-less hub.
    pub fn start_pool_with_store(
        pool: ReplicaPool,
        decoder: Box<dyn Decoder + Send>,
        vocab: Vocab,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
        store: Option<Arc<crate::store::ExpansionStore>>,
    ) -> Arc<ExpansionHub> {
        let nshards = cfg.shards.max(1);
        let pool = Arc::new(pool);
        // `Decoder: Send + Sync` by supertrait, so the one decoder is
        // shared across shard threads without cloning model state.
        let decoder: Arc<dyn Decoder + Send> = Arc::from(decoder);
        let counters = HubCounters {
            stats: Arc::new(Mutex::new(DecodeStats::default())),
            invalid: Arc::new(AtomicUsize::new(0)),
            total: Arc::new(AtomicUsize::new(0)),
            batches: Arc::new(AtomicU64::new(0)),
            merged: Arc::new(AtomicU64::new(0)),
            fused_calls: Arc::new(AtomicU64::new(0)),
            fused_rows: Arc::new(AtomicU64::new(0)),
            encode_calls: Arc::new(AtomicU64::new(0)),
            encode_rounds: Arc::new(AtomicU64::new(0)),
            cancelled: Arc::new(AtomicU64::new(0)),
            steals: Arc::new(AtomicU64::new(0)),
            replica_deaths: Arc::new(AtomicU64::new(0)),
        };
        let events = Arc::new(CompletionQueue::new());
        let registry = Arc::new(InFlightRegistry::new());
        let steal_q = Arc::new(StealQueue::new());
        let cache = SyncExpansionCache::new(cfg.cache_cap);
        let mut shards = Vec::with_capacity(nshards);
        for s in 0..nshards {
            let (tx, rx) = mpsc::channel::<HubMsg>();
            let depth = Arc::new(AtomicUsize::new(0));
            let local = Arc::new(CompletionQueue::new());
            let ctx = ShardCtx {
                shard: s,
                pool: pool.clone(),
                decoder: decoder.clone(),
                vocab: vocab.clone(),
                cfg: cfg.clone(),
                metrics: metrics.clone(),
                counters: counters.clone(),
                events: ShardEvents { local: local.clone(), global: events.clone() },
                registry: registry.clone(),
                steal_q: steal_q.clone(),
                depth: depth.clone(),
                cache: cache.clone(),
                store: store.clone(),
            };
            std::thread::Builder::new()
                .name(format!("expansion-hub-{s}"))
                .spawn(move || shard_loop(rx, ctx))
                .expect("spawn expansion hub shard");
            shards.push(ShardHandle { tx, depth, events: local });
        }
        Arc::new(ExpansionHub {
            steal_on: cfg.steal && nshards > 1,
            max_batch: cfg.max_batch,
            shards,
            pool,
            registry,
            steal_q,
            metrics,
            next_ticket: AtomicU64::new(1),
            stats: counters.stats.clone(),
            invalid: counters.invalid.clone(),
            total_hyps: counters.total.clone(),
            batches: counters.batches.clone(),
            merged: counters.merged.clone(),
            fused_calls: counters.fused_calls.clone(),
            fused_rows: counters.fused_rows.clone(),
            encode_calls: counters.encode_calls.clone(),
            encode_rounds: counters.encode_rounds.clone(),
            cancelled: counters.cancelled.clone(),
            steals: counters.steals.clone(),
            replica_deaths: counters.replica_deaths.clone(),
            dedup_joins: AtomicU64::new(0),
            steal_spills: AtomicU64::new(0),
            events,
        })
    }

    /// Asynchronous single-molecule expansion: returns a future the
    /// caller polls, waits on, or cancels. This is the pipelined
    /// planner's entry point.
    pub fn submit(&self, smiles: &str, k: usize) -> Result<ExpansionFuture> {
        self.submit_deadline(smiles, k, None)
    }

    /// As [`ExpansionHub::submit`] with a request-budget deadline: past
    /// it the hub fails the waiter with a scoped "deadline" error at
    /// the next round boundary (within one scheduler tick) and cancels
    /// the molecule's decode task if no other waiter covers it — rows,
    /// encoder memory and decoder states are released through the
    /// existing cancel path.
    ///
    /// Routing: a molecule some shard already decodes goes to that
    /// shard (cross-shard dedup — the submit joins the in-flight
    /// task); otherwise the least-queued shard claims it. When even
    /// the least-queued shard is a full gather round deep and stealing
    /// is on, the request spills to the shared steal queue instead,
    /// for whichever shard frees up first.
    pub fn submit_deadline(
        &self,
        smiles: &str,
        k: usize,
        deadline: Option<std::time::Instant>,
    ) -> Result<ExpansionFuture> {
        self.submit_with(smiles, k, deadline, Priority::Interactive)
    }

    /// Batch-class submit (two-tier admission): identical to
    /// [`ExpansionHub::submit_deadline`] except the request defers at
    /// round formation whenever an interactive miss is pending on its
    /// shard, and is claimed last from the steal queue. Cache hits and
    /// joins onto in-flight decodes still answer immediately. With no
    /// interactive traffic present this is exactly the interactive
    /// path. Screening jobs submit through this class.
    pub fn submit_batch(
        &self,
        smiles: &str,
        k: usize,
        deadline: Option<std::time::Instant>,
    ) -> Result<ExpansionFuture> {
        self.submit_with(smiles, k, deadline, Priority::Batch)
    }

    fn submit_with(
        &self,
        smiles: &str,
        k: usize,
        deadline: Option<std::time::Instant>,
        priority: Priority,
    ) -> Result<ExpansionFuture> {
        // Canonicalize once at the hub boundary: the cache, the
        // in-flight dedup registry and the persistent store all key on
        // this string, so two spellings of one molecule must collapse
        // here rather than double-cache (and double-decode) below.
        let smiles = crate::chem::cache_key(smiles);
        let smiles = smiles.as_str();
        let ticket = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::sync_channel(1);
        let req = ExpandReq { smiles: smiles.to_string(), k, ticket, deadline, priority, reply };
        let fallback = self.least_depth_shard();
        if self.steal_on
            && self.shards[fallback].depth.load(Ordering::Relaxed) >= self.max_batch
        {
            // Saturated: even the least-loaded inbox holds a full
            // gather round. A known in-flight molecule still routes to
            // its owner (joining beats stealing); anything else spills.
            if let Some(s) = self.registry.route(smiles) {
                self.dedup_joins.fetch_add(1, Ordering::Relaxed);
                self.metrics.inc("batcher.dedup_joins", 1);
                return self.send_to(s, req, rx);
            }
            self.steal_spills.fetch_add(1, Ordering::Relaxed);
            self.metrics.inc("batcher.steal_spills", 1);
            let smiles = req.smiles.clone();
            self.steal_q.push(req);
            // Wake the least-loaded shard in case it is idle-blocked on
            // its own channel.
            let _ = self.shards[fallback].tx.send(HubMsg::Poke);
            return Ok(ExpansionFuture {
                smiles,
                ticket,
                rx,
                cancel_txs: self.shards.iter().map(|sh| sh.tx.clone()).collect(),
                events: self.events.clone(),
                ready: None,
                spent: false,
            });
        }
        let (s, joined) = self.registry.route_or_claim(smiles, fallback);
        if joined {
            self.dedup_joins.fetch_add(1, Ordering::Relaxed);
            self.metrics.inc("batcher.dedup_joins", 1);
        }
        self.send_to(s, req, rx)
    }

    fn send_to(
        &self,
        s: usize,
        req: ExpandReq,
        rx: mpsc::Receiver<Result<Vec<Proposal>>>,
    ) -> Result<ExpansionFuture> {
        let smiles = req.smiles.clone();
        let ticket = req.ticket;
        self.shards[s].depth.fetch_add(1, Ordering::Relaxed);
        if self.shards[s].tx.send(HubMsg::Expand(req)).is_err() {
            self.shards[s].depth.fetch_sub(1, Ordering::Relaxed);
            self.registry.release_if_owned(&smiles, s);
            return Err(anyhow::anyhow!("hub gone"));
        }
        Ok(ExpansionFuture {
            smiles,
            ticket,
            rx,
            cancel_txs: vec![self.shards[s].tx.clone()],
            events: self.shards[s].events.clone(),
            ready: None,
            spent: false,
        })
    }

    /// The shard with the shallowest inbox, lowest index on ties (a
    /// 1-shard tier always answers 0).
    fn least_depth_shard(&self) -> usize {
        self.shards
            .iter()
            .enumerate()
            .min_by_key(|(i, sh)| (sh.depth.load(Ordering::Relaxed), *i))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// The narrowest completion queue covering all of `futs`: their
    /// shared shard-local queue if they live on one shard, else the
    /// hub-global queue (every shard bumps it too, so it is always
    /// correct — just busier).
    fn wait_queue(&self, futs: &[ExpansionFuture]) -> Arc<CompletionQueue> {
        let Some(first) = futs.first() else {
            return self.events.clone();
        };
        if futs.iter().all(|f| Arc::ptr_eq(&f.events, &first.events)) {
            first.events.clone()
        } else {
            self.events.clone()
        }
    }

    /// Block until at least one of `futs` (futures from **this** hub)
    /// holds a result or `deadline` passes; returns the index of a
    /// ready future — its next `poll`/`wait` returns without blocking.
    /// Futures whose results were already consumed are skipped; if all
    /// are consumed (or none completes in time) this returns `None`.
    /// Condvar-backed: the wait wakes on completion events, never
    /// sleep-polls.
    pub fn wait_any(
        &self,
        futs: &mut [ExpansionFuture],
        deadline: std::time::Instant,
    ) -> Option<usize> {
        let queue = self.wait_queue(futs);
        loop {
            let seen = queue.epoch();
            for (i, f) in futs.iter_mut().enumerate() {
                if f.fill() {
                    return Some(i);
                }
            }
            if std::time::Instant::now() >= deadline {
                return None;
            }
            queue.wait_past(seen, deadline);
        }
    }

    /// Current hub-global completion-event epoch; pair with
    /// [`ExpansionHub::wait_completion_past`] for event-driven polling
    /// (capture the epoch BEFORE inspecting state, then wait past it —
    /// no event is ever missed, and no caller ever sleep-polls).
    pub fn completion_epoch(&self) -> u64 {
        self.events.epoch()
    }

    /// Block until a completion event past `seen` occurs or `deadline`
    /// passes; returns the epoch observed.
    pub fn wait_completion_past(&self, seen: u64, deadline: std::time::Instant) -> u64 {
        self.events.wait_past(seen, deadline)
    }

    /// Blocking single-molecule expansion (used by the `expand` op).
    pub fn expand(&self, smiles: &str, k: usize) -> Result<Vec<Proposal>> {
        self.submit(smiles, k)?.wait()
    }

    pub fn stats(&self) -> DecodeStats {
        // Counters only — recover from a poisoned lock rather than
        // propagating a panic into every stats reader.
        self.stats.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// (per-query decode tasks submitted, requests admitted): requests
    /// per task is the cache + coalescing amplification.
    pub fn merge_ratio(&self) -> (u64, u64) {
        (self.batches.load(Ordering::Relaxed), self.merged.load(Ordering::Relaxed))
    }

    /// (fused device calls, fused logical rows): the cycle-level
    /// batching counters; rows/calls is the serving effective batch.
    pub fn fused_ratio(&self) -> (u64, u64) {
        (
            self.fused_calls.load(Ordering::Relaxed),
            self.fused_rows.load(Ordering::Relaxed),
        )
    }

    /// (physical encoder calls, submission rounds that encoded): the
    /// fused-encode admission counters. One call per round regardless
    /// of miss count, so these are equal while fused encodes succeed
    /// (a round whose fused encode errors retries per molecule — extra
    /// calls on that recovery path only); misses per call is the
    /// encode-fusion amplification.
    pub fn encode_ratio(&self) -> (u64, u64) {
        (
            self.encode_calls.load(Ordering::Relaxed),
            self.encode_rounds.load(Ordering::Relaxed),
        )
    }

    /// In-flight decode tasks abandoned after their last waiter
    /// cancelled.
    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Number of shard loops serving this hub.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Queued expansion requests: shard-inbox depths (the routing
    /// atomics) plus both spill lanes. Non-blocking — unlike
    /// [`ExpansionHub::debug_snapshot`] this never waits on a shard
    /// tick, so the admission layer can read it per request.
    pub fn queued_requests(&self) -> usize {
        let inbox: usize = self
            .shards
            .iter()
            .map(|sh| sh.depth.load(Ordering::Relaxed))
            .sum();
        let (steal_i, steal_b) = self.steal_q.depths();
        inbox + steal_i + steal_b
    }

    /// Load score: [`ExpansionHub::queued_requests`] normalized by the
    /// tier's gather capacity (`shards × max_batch`). 1.0 means every
    /// shard has one full gather round queued — the same saturation
    /// point at which routing starts spilling to the steal queue, so
    /// scores at or beyond 1.0 mean requests are already waiting out
    /// whole model rounds.
    pub fn load_score(&self) -> f64 {
        let cap = (self.shards.len().max(1)) * self.max_batch.max(1);
        self.queued_requests() as f64 / cap as f64
    }

    /// Point-in-time per-replica counters (alive, outstanding rows,
    /// fused calls, rows dispatched) — benches print utilization from
    /// these.
    pub fn replica_stats(&self) -> Vec<ReplicaStats> {
        self.pool.stats()
    }

    /// Replicas lost past `max_restarts` since startup.
    pub fn replica_deaths(&self) -> u64 {
        self.replica_deaths.load(Ordering::Relaxed)
    }

    /// Submits that joined another shard's in-flight decode of the
    /// same molecule (cross-shard dedup).
    pub fn dedup_joins(&self) -> u64 {
        self.dedup_joins.load(Ordering::Relaxed)
    }

    /// (requests spilled to the steal queue, spilled requests claimed
    /// by a shard). Equal at quiescence — a spilled request is always
    /// eventually claimed.
    pub fn steal_stats(&self) -> (u64, u64) {
        (
            self.steal_spills.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
        )
    }

    /// Hub state snapshot for tests and diagnostics, summed across
    /// shards; blocks until every shard finishes its current tick. The
    /// encoder counters ride along so tests can pin
    /// one-encode-per-round through the full stack.
    pub fn debug_snapshot(&self) -> Result<HubSnapshot> {
        let mut waiting_molecules = 0usize;
        let mut decode_tasks = 0usize;
        let mut sched_in_flight = 0usize;
        let mut queued_interactive = 0usize;
        let mut queued_batch = 0usize;
        for sh in &self.shards {
            let (tx, rx) = mpsc::sync_channel(1);
            sh.tx.send(HubMsg::Debug(tx)).map_err(|_| anyhow::anyhow!("hub gone"))?;
            let (w, t, fl, qi, qb) = rx.recv().map_err(|_| anyhow::anyhow!("hub gone"))?;
            waiting_molecules += w;
            decode_tasks += t;
            sched_in_flight += fl;
            queued_interactive += qi;
            queued_batch += qb;
        }
        let (steal_interactive, steal_batch) = self.steal_q.depths();
        Ok(HubSnapshot {
            waiting_molecules,
            decode_tasks,
            sched_in_flight,
            encode_calls: self.encode_calls.load(Ordering::Relaxed),
            encode_rounds: self.encode_rounds.load(Ordering::Relaxed),
            queued_interactive,
            queued_batch,
            steal_interactive,
            steal_batch,
        })
    }
}

/// Per-session [`ExpansionPolicy`] view over the hub. `Send`, cheap to
/// clone — each planning session owns one. Also implements
/// [`AsyncExpansionPolicy`], so pipelined Retro\* rides per-query
/// futures straight into the scheduler.
#[derive(Clone)]
pub struct BatchedPolicy {
    hub: Arc<ExpansionHub>,
    calls: Arc<AtomicUsize>,
    priority: Priority,
}

impl BatchedPolicy {
    pub fn new(hub: Arc<ExpansionHub>) -> Self {
        Self { hub, calls: Arc::new(AtomicUsize::new(0)), priority: Priority::Interactive }
    }

    /// A batch-class view over the hub: every submit carries
    /// [`Priority::Batch`], so planning sessions driven through it
    /// (screening jobs) yield round formation to interactive traffic.
    pub fn batch_class(hub: Arc<ExpansionHub>) -> Self {
        Self { hub, calls: Arc::new(AtomicUsize::new(0)), priority: Priority::Batch }
    }
}

/// A group of per-molecule hub futures joined into one batch handle.
struct HubHandle {
    futs: Vec<Option<ExpansionFuture>>,
    results: Vec<Option<Vec<Proposal>>>,
    /// The completion queue covering every future in the group (their
    /// shared shard-local queue, else the hub-global one).
    events: Arc<CompletionQueue>,
    /// Epoch captured at the start of the last `poll`: `wait_event`
    /// blocks past it, so an event landing between that poll and the
    /// wait is never missed.
    seen: u64,
}

impl ExpansionHandle for HubHandle {
    fn poll(&mut self) -> Option<Result<Vec<Vec<Proposal>>>> {
        self.seen = self.events.epoch();
        let mut pending = false;
        for (i, slot) in self.futs.iter_mut().enumerate() {
            if self.results[i].is_some() {
                continue;
            }
            let Some(f) = slot.as_mut() else { continue };
            match f.poll() {
                Some(Ok(p)) => {
                    self.results[i] = Some(p);
                    *slot = None;
                }
                // On error the handle is spent; dropping it (and the
                // remaining futures with it) cancels the rest.
                Some(Err(e)) => return Some(Err(e)),
                None => pending = true,
            }
        }
        if pending {
            return None;
        }
        Some(Ok(self
            .results
            .iter_mut()
            .map(|r| r.take().unwrap_or_default())
            .collect()))
    }

    fn wait(mut self: Box<Self>) -> Result<Vec<Vec<Proposal>>> {
        for (i, slot) in self.futs.iter_mut().enumerate() {
            if self.results[i].is_some() {
                continue;
            }
            if let Some(f) = slot.take() {
                self.results[i] = Some(f.wait()?);
            }
        }
        Ok(self
            .results
            .iter_mut()
            .map(|r| r.take().unwrap_or_default())
            .collect())
    }

    fn wait_event(&mut self, deadline: std::time::Instant) {
        // Any covered completion (not just this batch's) wakes the
        // wait; the caller re-polls. Condvar-backed — no sleep-polling.
        self.events.wait_past(self.seen, deadline);
    }

    fn cancel(self: Box<Self>) {
        // Drop on the remaining futures sends the hub cancellations.
    }
}

impl ExpansionPolicy for BatchedPolicy {
    fn expand_batch(&self, molecules: &[&str], k: usize) -> Result<Vec<Vec<Proposal>>> {
        // fan out, then join — the hub may merge these with other
        // sessions' requests
        self.submit(molecules, k)?.wait()
    }

    fn decode_stats(&self) -> DecodeStats {
        self.hub.stats()
    }

    fn calls(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }
}

impl AsyncExpansionPolicy for BatchedPolicy {
    fn submit(&self, molecules: &[&str], k: usize) -> Result<Box<dyn ExpansionHandle>> {
        self.submit_inner(molecules, k, None)
    }

    fn submit_deadline(
        &self,
        molecules: &[&str],
        k: usize,
        deadline: std::time::Instant,
    ) -> Result<Box<dyn ExpansionHandle>> {
        self.submit_inner(molecules, k, Some(deadline))
    }
}

impl BatchedPolicy {
    fn submit_inner(
        &self,
        molecules: &[&str],
        k: usize,
        deadline: Option<std::time::Instant>,
    ) -> Result<Box<dyn ExpansionHandle>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut futs = Vec::with_capacity(molecules.len());
        for m in molecules {
            futs.push(Some(self.hub.submit_with(m, k, deadline, self.priority)?));
        }
        let events = {
            let flat: Vec<&ExpansionFuture> =
                futs.iter().filter_map(|f| f.as_ref()).collect();
            match flat.first() {
                Some(first)
                    if flat.iter().all(|f| Arc::ptr_eq(&f.events, &first.events)) =>
                {
                    first.events.clone()
                }
                _ => self.hub.events.clone(),
            }
        };
        Ok(Box::new(HubHandle {
            results: vec![None; futs.len()],
            futs,
            events,
            seen: 0,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};

    fn hub() -> Arc<ExpansionHub> {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(5),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn hub_expands_and_caches() {
        let h = hub();
        // the mock copies its input: a reactant-set string comes back as
        // a valid 2-component proposal
        let p1 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert!(!p1.is_empty());
        let calls_before = h.stats().model_calls;
        let p2 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(h.stats().model_calls, calls_before, "cache must serve repeats");
    }

    #[test]
    fn cache_serves_smaller_k_and_redecodes_larger() {
        let h = hub();
        let p5 = h.expand("CC(=O)O.CN", 5).unwrap();
        let calls_after_first = h.stats().model_calls;
        // smaller k: truncation of the stored expansion, no decode
        let p2 = h.expand("CC(=O)O.CN", 2).unwrap();
        assert_eq!(h.stats().model_calls, calls_after_first, "k<=stored must hit");
        assert!(p2.len() <= 2);
        assert_eq!(&p5[..p2.len()], &p2[..]);
        // larger k: must re-decode
        let _p8 = h.expand("CC(=O)O.CN", 8).unwrap();
        assert!(h.stats().model_calls > calls_after_first, "k>stored must miss");
        // and the cache now stores the larger entry
        let calls = h.stats().model_calls;
        let _ = h.expand("CC(=O)O.CN", 8).unwrap();
        assert_eq!(h.stats().model_calls, calls);
    }

    #[test]
    fn cache_is_bounded() {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO", "CCN", "CCC"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig { cache_cap: 2, ..Default::default() },
            Arc::new(Metrics::new()),
        );
        for m in ["CCO", "CCN", "CCC", "CC(=O)NC"] {
            let _ = h.expand(m, 2).unwrap();
        }
        // most-recent entry still hits
        let calls = h.stats().model_calls;
        let _ = h.expand("CC(=O)NC", 2).unwrap();
        assert_eq!(h.stats().model_calls, calls);
        // evicted entry recomputes
        let _ = h.expand("CCO", 2).unwrap();
        assert!(h.stats().model_calls > calls);
    }

    #[test]
    fn concurrent_sessions_share_batches() {
        let h = hub();
        let mut joins = Vec::new();
        for _ in 0..4 {
            let hc = h.clone();
            joins.push(std::thread::spawn(move || {
                let policy = BatchedPolicy::new(hc);
                policy.expand_batch(&["CC(=O)O.CN"], 3).unwrap()
            }));
        }
        for j in joins {
            assert!(!j.join().unwrap().is_empty());
        }
        let (tasks, merged) = h.merge_ratio();
        assert!(merged >= 4);
        assert!(tasks <= merged, "tasks {tasks} merged {merged}");
    }

    #[test]
    fn concurrent_distinct_molecules_fuse_calls() {
        let h = hub();
        let mols = ["CC(=O)O.CN", "CC(=O)NC", "CCO"];
        let mut joins = Vec::new();
        for m in mols {
            let hc = h.clone();
            joins.push(std::thread::spawn(move || hc.expand(m, 3).unwrap()));
        }
        for j in joins {
            let _ = j.join().unwrap();
        }
        let (fused_calls, fused_rows) = h.fused_ratio();
        assert!(fused_calls > 0);
        assert!(fused_rows >= fused_calls, "rows {fused_rows} calls {fused_calls}");
        // Solo per-molecule decoding would have cost at least as many
        // device calls as the hub's fused path.
        assert!(h.stats().model_calls >= fused_calls);
        // Fused-encode admission: exactly one encoder call per
        // submission round, never one per miss.
        let (encode_calls, encode_rounds) = h.encode_ratio();
        assert_eq!(encode_calls, encode_rounds, "one encode per round");
        assert!(encode_calls >= 1 && encode_calls <= mols.len() as u64);
    }

    #[test]
    fn futures_poll_to_completion() {
        let h = hub();
        let mut fut = h.submit("CC(=O)O.CN", 3).unwrap();
        // Event-driven wait: poll, then block on the completion epoch —
        // no sleeps. The epoch is captured BEFORE the poll so a
        // completion landing in between wakes the wait immediately.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut result = None;
        loop {
            let seen = h.completion_epoch();
            if let Some(r) = fut.poll() {
                result = Some(r);
                break;
            }
            if std::time::Instant::now() >= deadline {
                break;
            }
            h.wait_completion_past(seen, deadline);
        }
        let props = result.expect("future must complete").unwrap();
        assert!(!props.is_empty());
        // a second future for the same molecule hits the cache
        let calls = h.stats().model_calls;
        let p2 = h.submit("CC(=O)O.CN", 3).unwrap().wait().unwrap();
        assert_eq!(props, p2);
        assert_eq!(h.stats().model_calls, calls);
    }

    #[test]
    fn wait_any_buffers_first_completion() {
        let h = hub();
        let mut futs = vec![
            h.submit("CC(=O)O.CN", 3).unwrap(),
            h.submit("CC(=O)NC", 3).unwrap(),
        ];
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut answered = 0;
        while !futs.is_empty() {
            let i = h.wait_any(&mut futs, deadline).expect("a future must complete");
            let fut = futs.remove(i);
            // wait_any buffered the result: this wait returns instantly.
            let _ = fut.wait().unwrap();
            answered += 1;
        }
        assert_eq!(answered, 2);
        // All consumed: wait_any on an empty/spent set yields None at
        // the deadline rather than blocking forever.
        let soon = std::time::Instant::now() + std::time::Duration::from_millis(5);
        assert!(h.wait_any(&mut [], soon).is_none());
    }

    #[test]
    fn cancelled_future_leaves_no_state_behind() {
        let h = hub();
        let fut = h.submit("CC(=O)NC", 4).unwrap();
        fut.cancel();
        // settle: the hub processes the cancel between ticks; each
        // processed cancel bumps the completion epoch, so this blocks
        // instead of sleep-polling.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut clean = false;
        loop {
            let seen = h.completion_epoch();
            let s = h.debug_snapshot().unwrap();
            if s.waiting_molecules == 0 && s.decode_tasks == 0 && s.sched_in_flight == 0 {
                clean = true;
                break;
            }
            if std::time::Instant::now() >= deadline {
                break;
            }
            h.wait_completion_past(seen, deadline);
        }
        assert!(clean, "cancelled request must leave no waiters or tasks");
        // the hub still serves fresh work afterwards
        let p = h.expand("CC(=O)O.CN", 3).unwrap();
        assert!(!p.is_empty());
    }

    #[test]
    fn cancel_with_remaining_waiter_keeps_the_task() {
        let h = hub();
        // two futures on the same molecule: cancelling one must not
        // starve the other
        let keep = h.submit("CC(=O)O.CN", 3).unwrap();
        let drop_me = h.submit("CC(=O)O.CN", 3).unwrap();
        drop_me.cancel();
        let props = keep.wait().unwrap();
        assert!(!props.is_empty(), "surviving waiter must still be answered");
    }

    #[test]
    fn fused_encode_failure_keeps_per_molecule_blast_radius() {
        use crate::benchkit::InstrumentedModel;
        let vocab = Vocab::build(["CC(=O)O.CN", "CCO"]);
        // Any encode batch containing the poisoned source errors —
        // exercising the fused-encode failure fallback.
        let poison = vocab.encode("CCO", true);
        let model = InstrumentedModel::new(MockModel::new(MockConfig {
            vocab: vocab.len(),
            ..Default::default()
        }))
        .with_encode_failure(move |src| src.iter().any(|s| *s == poison));
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                // Wide straggler window: both submissions land in one
                // round, so the ROUND's fused encode fails and the
                // per-molecule fallback must rescue the healthy one.
                max_wait: std::time::Duration::from_millis(10),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        );
        let healthy = h.submit("CC(=O)O.CN", 3).unwrap();
        let poisoned = h.submit("CCO", 3).unwrap();
        let p = healthy
            .wait()
            .expect("healthy co-arrival must survive a sibling's encode failure");
        assert!(!p.is_empty());
        let err = poisoned.wait().expect_err("poisoned molecule must fail");
        assert!(format!("{err:#}").contains("encode failed"), "{err:#}");
    }

    #[test]
    fn deadline_coalescer_fuses_near_arrivals_under_load() {
        use crate::benchkit::InstrumentedModel;
        use std::sync::atomic::AtomicBool;
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let hold = Arc::new(AtomicBool::new(true));
        let model = InstrumentedModel::new(MockModel::new(MockConfig {
            vocab: vocab.len(),
            ..Default::default()
        }))
        .with_gate(hold.clone());
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                max_wait: std::time::Duration::from_micros(500),
                // Generous coalesce window: while molecule A keeps the
                // scheduler busy, B's round stays open long enough for
                // C (submitted well after B) to join it.
                coalesce: std::time::Duration::from_millis(120),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        );
        // Round 1: A alone. Its first fused tick blocks on the gate,
        // so B and C below arrive while the hub is demonstrably busy.
        let fa = h.submit("CC(=O)O.CN", 3).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let fb = h.submit("CC(=O)NC", 3).unwrap();
        hold.store(false, Ordering::SeqCst);
        // C arrives only after the gate opened — past any same-drain
        // co-arrival window, inside the coalesce hold for B's round.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let fc = h.submit("CCO", 3).unwrap();
        assert!(!fa.wait().unwrap().is_empty());
        assert!(!fb.wait().unwrap().is_empty());
        assert!(!fc.wait().unwrap().is_empty());
        let (encode_calls, encode_rounds) = h.encode_ratio();
        assert_eq!(encode_calls, encode_rounds, "one encode per round");
        assert_eq!(
            encode_rounds, 2,
            "coalescer must fold the near-arrival into the held round (A | B+C)"
        );
    }

    #[test]
    fn cross_shard_submits_join_one_in_flight_decode() {
        use crate::benchkit::InstrumentedModel;
        use std::sync::atomic::AtomicBool;
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let hold = Arc::new(AtomicBool::new(true));
        let model = InstrumentedModel::new(MockModel::new(MockConfig {
            vocab: vocab.len(),
            ..Default::default()
        }))
        .with_gate(hold.clone());
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                shards: 2,
                max_wait: std::time::Duration::from_millis(2),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        );
        assert_eq!(h.shard_count(), 2);
        // The first submit claims the molecule in the in-flight
        // registry; the gate keeps its decode in flight while the
        // second submit arrives, so the router must join it to the
        // SAME shard — one decode task, one fused encode — instead of
        // decoding the molecule twice on two shards.
        let f1 = h.submit("CC(=O)O.CN", 3).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let f2 = h.submit("CC(=O)O.CN", 3).unwrap();
        hold.store(false, Ordering::SeqCst);
        let p1 = f1.wait().unwrap();
        let p2 = f2.wait().unwrap();
        assert_eq!(p1, p2, "joined submit must see the same expansion");
        assert_eq!(h.dedup_joins(), 1, "second submit must join the first's decode");
        let (encode_calls, _) = h.encode_ratio();
        assert_eq!(encode_calls, 1, "one decode task => one fused encode");
    }

    #[test]
    fn saturated_shards_spill_and_steal_without_losing_requests() {
        use crate::benchkit::InstrumentedModel;
        let mols = ["CC(=O)O.CN", "CC(=O)NC", "CCO", "CCN", "CCC", "CCCC"];
        let vocab = Vocab::build(mols);
        let model = InstrumentedModel::new(MockModel::new(MockConfig {
            vocab: vocab.len(),
            ..Default::default()
        }))
        .with_decode_delay(std::time::Duration::from_millis(2));
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                shards: 2,
                // One-deep inboxes + slowed ticks: concurrent submits
                // exceed every shard's gather round and must spill.
                max_batch: 1,
                max_wait: std::time::Duration::from_micros(200),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        );
        let mut joins = Vec::new();
        for i in 0..12 {
            let hc = h.clone();
            let m = mols[i % mols.len()].to_string();
            joins.push(std::thread::spawn(move || hc.expand(&m, 2).unwrap()));
        }
        for j in joins {
            assert!(!j.join().unwrap().is_empty());
        }
        // Work-stealing conservation: every spilled request was claimed
        // by some shard (a spilled-but-never-claimed request would have
        // hung this test inside `expand`), and nothing leaked.
        let (spills, steals) = h.steal_stats();
        assert_eq!(spills, steals, "spills {spills} steals {steals}");
        let s = h.debug_snapshot().unwrap();
        assert_eq!(
            (s.waiting_molecules, s.decode_tasks, s.sched_in_flight),
            (0, 0, 0),
            "no leaked waiters or tasks after the burst"
        );
    }

    #[test]
    fn batched_policy_counts_calls() {
        let h = hub();
        let p = BatchedPolicy::new(h);
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        assert_eq!(p.calls(), 2);
    }

    #[test]
    fn async_policy_handle_round_trip() {
        let h = hub();
        let p = BatchedPolicy::new(h);
        let handle = AsyncExpansionPolicy::submit(&p, &["CC(=O)O.CN", "CCO"], 3).unwrap();
        let out = handle.wait().unwrap();
        assert_eq!(out.len(), 2);
        assert!(!out[0].is_empty());
        assert_eq!(p.calls(), 1);
    }
}
