//! The dynamic batcher: merges single-step expansion requests from all
//! in-flight planning sessions into batched decoder calls.
//!
//! Requests arrive on a channel; the hub thread drains up to
//! `max_batch` of them (waiting at most `max_wait` for stragglers),
//! deduplicates identical molecules, runs ONE decoder group call, and
//! fans the parsed proposals back out. A shared expansion cache
//! short-circuits repeat molecules across sessions.

use crate::decoding::{DecodeStats, Decoder};
use crate::metrics::Metrics;
use crate::model::StepModel;
use crate::search::policy::{proposals_from_output, Proposal};
use crate::search::ExpansionPolicy;
use crate::tokenizer::Vocab;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

struct ExpandReq {
    smiles: String,
    k: usize,
    reply: mpsc::SyncSender<Result<Vec<Proposal>>>,
}

/// Shared handle to the batcher thread.
pub struct ExpansionHub {
    tx: mpsc::Sender<ExpandReq>,
    stats: Arc<Mutex<DecodeStats>>,
    pub invalid: Arc<AtomicUsize>,
    pub total_hyps: Arc<AtomicUsize>,
    batches: Arc<AtomicU64>,
    merged: Arc<AtomicU64>,
}

/// Batcher tuning knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: std::time::Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 16, max_wait: std::time::Duration::from_micros(2000) }
    }
}

impl ExpansionHub {
    /// Start the hub thread. The model handle must be `Send` (use
    /// [`crate::runtime::server::SharedModel`] for PJRT models).
    pub fn start<M>(
        model: M,
        decoder: Box<dyn Decoder + Send>,
        vocab: Vocab,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Arc<ExpansionHub>
    where
        M: StepModel + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<ExpandReq>();
        let stats = Arc::new(Mutex::new(DecodeStats::default()));
        let invalid = Arc::new(AtomicUsize::new(0));
        let total = Arc::new(AtomicUsize::new(0));
        let batches = Arc::new(AtomicU64::new(0));
        let merged = Arc::new(AtomicU64::new(0));
        {
            let stats = stats.clone();
            let invalid = invalid.clone();
            let total = total.clone();
            let batches = batches.clone();
            let merged = merged.clone();
            std::thread::Builder::new()
                .name("expansion-hub".into())
                .spawn(move || {
                    let mut cache: HashMap<(String, usize), Vec<Proposal>> = HashMap::new();
                    while let Ok(first) = rx.recv() {
                        // gather a batch
                        let mut batch = vec![first];
                        let deadline = std::time::Instant::now() + cfg.max_wait;
                        while batch.len() < cfg.max_batch {
                            let now = std::time::Instant::now();
                            if now >= deadline {
                                break;
                            }
                            match rx.recv_timeout(deadline - now) {
                                Ok(r) => batch.push(r),
                                Err(_) => break,
                            }
                        }
                        batches.fetch_add(1, Ordering::Relaxed);
                        merged.fetch_add(batch.len() as u64, Ordering::Relaxed);
                        // serve from cache / dedupe
                        let k_max = batch.iter().map(|r| r.k).max().unwrap_or(1);
                        let mut unique: Vec<String> = Vec::new();
                        let mut slot_of: HashMap<String, usize> = HashMap::new();
                        for r in &batch {
                            if cache.contains_key(&(r.smiles.clone(), k_max)) {
                                continue;
                            }
                            if !slot_of.contains_key(&r.smiles) {
                                slot_of.insert(r.smiles.clone(), unique.len());
                                unique.push(r.smiles.clone());
                            }
                        }
                        if !unique.is_empty() {
                            let srcs: Vec<Vec<i32>> =
                                unique.iter().map(|s| vocab.encode(s, true)).collect();
                            let mut st = stats.lock().unwrap();
                            metrics.inc("batcher.model_batches", 1);
                            metrics.inc("batcher.model_rows", unique.len() as u64);
                            let t0 = std::time::Instant::now();
                            let result = decoder.generate(&model, &srcs, k_max, &mut st);
                            drop(st);
                            metrics.observe("batcher.decode", t0.elapsed().as_secs_f64());
                            match result {
                                Ok(outs) => {
                                    for (s, gen) in unique.iter().zip(outs.iter()) {
                                        let mut inv = 0usize;
                                        let mut tot = 0usize;
                                        let props = proposals_from_output(
                                            &vocab, s, gen, &mut inv, &mut tot,
                                        );
                                        invalid.fetch_add(inv, Ordering::Relaxed);
                                        total.fetch_add(tot, Ordering::Relaxed);
                                        cache.insert((s.clone(), k_max), props);
                                    }
                                }
                                Err(e) => {
                                    let msg = format!("{e:#}");
                                    for r in batch {
                                        let _ = r
                                            .reply
                                            .send(Err(anyhow::anyhow!("decode failed: {msg}")));
                                    }
                                    continue;
                                }
                            }
                        }
                        for r in batch {
                            let props = cache
                                .get(&(r.smiles.clone(), k_max))
                                .cloned()
                                .unwrap_or_default();
                            let mut out = props;
                            out.truncate(r.k);
                            let _ = r.reply.send(Ok(out));
                        }
                    }
                })
                .expect("spawn expansion hub");
        }
        Arc::new(ExpansionHub { tx, stats, invalid, total_hyps: total, batches, merged })
    }

    /// Blocking single-molecule expansion (used by the `expand` op).
    pub fn expand(&self, smiles: &str, k: usize) -> Result<Vec<Proposal>> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(ExpandReq { smiles: smiles.to_string(), k, reply: tx })
            .map_err(|_| anyhow::anyhow!("hub gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("hub gone"))?
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats.lock().unwrap().clone()
    }

    /// (model batches run, requests merged into them).
    pub fn merge_ratio(&self) -> (u64, u64) {
        (self.batches.load(Ordering::Relaxed), self.merged.load(Ordering::Relaxed))
    }
}

/// Per-session [`ExpansionPolicy`] view over the hub. `Send`, cheap to
/// clone — each planning session owns one.
#[derive(Clone)]
pub struct BatchedPolicy {
    hub: Arc<ExpansionHub>,
    calls: Arc<AtomicUsize>,
}

impl BatchedPolicy {
    pub fn new(hub: Arc<ExpansionHub>) -> Self {
        Self { hub, calls: Arc::new(AtomicUsize::new(0)) }
    }
}

impl ExpansionPolicy for BatchedPolicy {
    fn expand_batch(&self, molecules: &[&str], k: usize) -> Result<Vec<Vec<Proposal>>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        // fan out, then join — the hub may merge these with other
        // sessions' requests
        let mut replies = Vec::with_capacity(molecules.len());
        for m in molecules {
            let (tx, rx) = mpsc::sync_channel(1);
            self.hub
                .tx
                .send(ExpandReq { smiles: m.to_string(), k, reply: tx })
                .map_err(|_| anyhow::anyhow!("hub gone"))?;
            replies.push(rx);
        }
        replies
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::anyhow!("hub gone"))?)
            .collect()
    }

    fn decode_stats(&self) -> DecodeStats {
        self.hub.stats()
    }

    fn calls(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};

    fn hub() -> Arc<ExpansionHub> {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig { max_batch: 8, max_wait: std::time::Duration::from_millis(5) },
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn hub_expands_and_caches() {
        let h = hub();
        // the mock copies its input: a reactant-set string comes back as
        // a valid 2-component proposal
        let p1 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert!(!p1.is_empty());
        let calls_before = h.stats().model_calls;
        let p2 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(h.stats().model_calls, calls_before, "cache must serve repeats");
    }

    #[test]
    fn concurrent_sessions_share_batches() {
        let h = hub();
        let mut joins = Vec::new();
        for _ in 0..4 {
            let hc = h.clone();
            joins.push(std::thread::spawn(move || {
                let policy = BatchedPolicy::new(hc);
                policy.expand_batch(&["CC(=O)O.CN"], 3).unwrap()
            }));
        }
        for j in joins {
            assert!(!j.join().unwrap().is_empty());
        }
        let (batches, merged) = h.merge_ratio();
        assert!(merged >= 4);
        assert!(batches <= merged, "batches {batches} merged {merged}");
    }

    #[test]
    fn batched_policy_counts_calls() {
        let h = hub();
        let p = BatchedPolicy::new(h);
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        assert_eq!(p.calls(), 2);
    }
}
