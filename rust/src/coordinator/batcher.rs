//! The continuous batcher: merges single-step expansion requests from
//! all in-flight planning sessions into *cycle-level* fused decoder
//! calls.
//!
//! Requests arrive on a channel — blocking ([`ExpansionHub::expand`])
//! or as futures ([`ExpansionHub::submit`] →
//! [`ExpansionFuture`]: poll / wait / cancel). Cache hits answer
//! immediately. Each missing molecule becomes **one resumable decode
//! task of its own** submitted to the [`DecodeScheduler`]; the hub
//! thread then ticks the scheduler — ONE fused `decode` per tick across
//! *all* in-flight tasks — so every molecule joins the very next device
//! call when it arrives and **retires independently** the moment its own
//! beams finish, instead of waiting out the slowest co-arrival in a
//! drained batch. Cancellation (speculative searches abandoning
//! invalidated expansions) removes a molecule's task from the scheduler
//! as soon as its last waiter goes away, releasing its fused-call rows
//! and encoder memory. A tick error fails only the waiters of the tasks
//! that were actually in the errored fused call.
//!
//! ## Fused-encode admission
//!
//! All cache-missing molecules gathered in one submission round share
//! **one** [`StepModel::encode`] call
//! ([`crate::model::encode_shared`]): each molecule then decodes over
//! its own ref-counted row view ([`crate::model::MemView`]) of the
//! shared batch, handed to the engine through
//! [`Decoder::start_task_on`]. Encoder cost is therefore O(submission
//! rounds), not O(misses) — at fan-in N one call does the work of N —
//! while retirement stays per-query. Under load, `batcher.coalesce_us`
//! optionally holds a round with queued misses open for a bounded
//! window so *near*-arrivals (not just co-arrivals) share the round's
//! single encode — the ROADMAP's deadline-based encode coalescer.
//! The batch memory is released on
//! the device exactly when the round's *last* member task retires or is
//! cancelled, so abandoning one speculative expansion never strands its
//! co-arrivals' memory. [`ExpansionHub::encode_ratio`] exposes the
//! (physical encoder calls, encoding rounds) counters — equal while
//! fused encodes succeed; a round whose fused encode errors falls back
//! to per-molecule encodes, so one bad source fails only its own
//! waiters.
//!
//! ## Event-driven completion
//!
//! Retirements, failures and processed cancellations bump a
//! condvar-backed completion epoch; [`ExpansionHub::wait_any`] and the
//! pipelined planner's multi-group wait ([`HubHandle`]'s `wait_event`)
//! block on it instead of sleep-polling, so a completion wakes its
//! waiter immediately and an idle wait burns no CPU.
//!
//! The expansion cache is a bounded [`LruCache`] keyed by *molecule*
//! (not `(molecule, k)`): an entry decoded at k' serves any request with
//! k <= k' by truncation, and a larger-k request replaces the entry —
//! the same molecule is never re-decoded just because co-batched k
//! differed, and sustained traffic cannot leak memory.
//!
//! [`LruCache`]: crate::util::lru::LruCache

use crate::decoding::scheduler::{DecodeScheduler, Finished, SchedulerConfig, TaskId};
use crate::decoding::{DecodeStats, Decoder};
use crate::metrics::Metrics;
use crate::model::{encode_shared, MemView, StepModel};
use crate::search::policy::{
    proposals_from_output, AsyncExpansionPolicy, ExpansionHandle, KTruncatedCache, Proposal,
    DEFAULT_CACHE_CAP,
};
use crate::search::ExpansionPolicy;
use crate::tokenizer::Vocab;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// Condvar-backed completion events: the hub bumps the epoch whenever
/// something a waiter could observe happened (a request was answered, a
/// task failed, a cancellation was processed), and waiters block on it
/// instead of sleep-polling.
///
/// The epoch protocol makes missed wakeups impossible: capture
/// [`CompletionQueue::epoch`] BEFORE polling, then
/// [`CompletionQueue::wait_past`] that value — any event after the
/// capture advances the epoch past it, so the wait returns immediately.
/// Spurious wakeups merely cost a re-poll.
pub(crate) struct CompletionQueue {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl CompletionQueue {
    fn new() -> Self {
        Self { epoch: Mutex::new(0), cv: Condvar::new() }
    }

    // The epoch is a bare counter, so a poisoned lock (a waiter
    // panicked while holding it) cannot leave it torn — recover the
    // guard instead of cascading the panic into every other session's
    // wait path.
    pub(crate) fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn notify(&self) {
        let mut e = self.epoch.lock().unwrap_or_else(|p| p.into_inner());
        *e += 1;
        self.cv.notify_all();
    }

    /// Block until the epoch advances past `seen` or `deadline` passes;
    /// returns the current epoch (feed it back in as the next `seen`).
    pub(crate) fn wait_past(&self, seen: u64, deadline: std::time::Instant) -> u64 {
        let mut e = self.epoch.lock().unwrap_or_else(|p| p.into_inner());
        while *e <= seen {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match self.cv.wait_timeout(e, deadline - now) {
                Ok((guard, _)) => e = guard,
                Err(p) => e = p.into_inner().0,
            }
        }
        *e
    }
}

struct ExpandReq {
    smiles: String,
    k: usize,
    ticket: u64,
    /// Request-budget deadline: the hub expires the waiter (scoped
    /// error, task cancelled when it was the last waiter) at the first
    /// round boundary past this instant, even if the submitting thread
    /// never polls again. `None` = no deadline.
    deadline: Option<std::time::Instant>,
    reply: mpsc::SyncSender<Result<Vec<Proposal>>>,
}

enum HubMsg {
    Expand(ExpandReq),
    /// Withdraw the waiter `ticket` registered for `smiles`; the last
    /// waiter leaving cancels the molecule's in-flight decode tasks.
    Cancel { smiles: String, ticket: u64 },
    /// Introspection: (molecules with waiters, in-flight decode tasks,
    /// scheduler in-flight count, encoder calls, encoding rounds) —
    /// read together on the hub thread so the snapshot is internally
    /// consistent. Tests use this to pin "no leaked waiters / tasks"
    /// after cancellation and one-encode-per-round through the stack.
    Debug(mpsc::SyncSender<(usize, usize, usize, u64, u64)>),
}

/// Shared handle to the batcher thread.
pub struct ExpansionHub {
    tx: mpsc::Sender<HubMsg>,
    next_ticket: AtomicU64,
    stats: Arc<Mutex<DecodeStats>>,
    pub invalid: Arc<AtomicUsize>,
    pub total_hyps: Arc<AtomicUsize>,
    /// Per-query decode tasks submitted.
    batches: Arc<AtomicU64>,
    /// Requests admitted.
    merged: Arc<AtomicU64>,
    /// Fused device calls / fused logical rows (cycle-level batching).
    fused_calls: Arc<AtomicU64>,
    fused_rows: Arc<AtomicU64>,
    /// Physical encoder calls / submission rounds that encoded
    /// (fused-encode admission keeps these equal at any fan-in).
    encode_calls: Arc<AtomicU64>,
    encode_rounds: Arc<AtomicU64>,
    /// In-flight tasks abandoned because every waiter cancelled.
    cancelled: Arc<AtomicU64>,
    /// Completion events waiters block on (no sleep-polling).
    events: Arc<CompletionQueue>,
}

/// Hub-thread state snapshot (see [`ExpansionHub::debug_snapshot`]).
#[derive(Clone, Copy, Debug)]
pub struct HubSnapshot {
    /// Molecules with registered waiters.
    pub waiting_molecules: usize,
    /// In-flight per-query decode tasks the hub tracks.
    pub decode_tasks: usize,
    /// Tasks currently inside the scheduler.
    pub sched_in_flight: usize,
    /// Physical [`StepModel::encode`] calls issued so far.
    pub encode_calls: u64,
    /// Submission rounds that attempted an encode. Fused-encode
    /// admission means `encode_calls == encode_rounds` whenever every
    /// round's fused encode succeeded; a round whose fused encode
    /// errors falls back to per-molecule encodes (extra calls on that
    /// error path only — one bad source must not fail its co-arrivals).
    pub encode_rounds: u64,
}

/// A pending single-molecule expansion: the hub's future. Dropping it
/// without consuming the result cancels the request (so abandoned
/// speculation releases its decode work automatically).
pub struct ExpansionFuture {
    smiles: String,
    ticket: u64,
    rx: mpsc::Receiver<Result<Vec<Proposal>>>,
    hub_tx: mpsc::Sender<HubMsg>,
    /// A result pulled off the channel but not yet consumed
    /// ([`ExpansionHub::wait_any`] buffers here so readiness can be
    /// observed without consuming).
    ready: Option<Result<Vec<Proposal>>>,
    spent: bool,
}

impl ExpansionFuture {
    /// Pull a pending result into the local buffer without consuming
    /// it; `true` when one is held. A future whose result was already
    /// consumed stays not-ready forever.
    fn fill(&mut self) -> bool {
        if self.spent {
            return self.ready.is_some();
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.spent = true;
                self.ready = Some(r);
                true
            }
            Err(mpsc::TryRecvError::Empty) => false,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.spent = true;
                self.ready = Some(Err(anyhow::anyhow!("hub gone")));
                true
            }
        }
    }

    /// Non-blocking: `Some` exactly once, when the expansion retired.
    pub fn poll(&mut self) -> Option<Result<Vec<Proposal>>> {
        if self.fill() {
            self.ready.take()
        } else {
            None
        }
    }

    /// Block until the expansion retires (channel-blocking — no
    /// polling).
    pub fn wait(mut self) -> Result<Vec<Proposal>> {
        if let Some(r) = self.ready.take() {
            return r;
        }
        self.spent = true;
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("hub gone")),
        }
    }

    /// Block until the expansion retires or `deadline` passes. Expiry
    /// returns a scoped "deadline" error and withdraws the request
    /// (the drop-cancel path runs, so the hub releases the decode task
    /// if this was its last waiter) — only this waiter fails.
    pub fn wait_deadline(mut self, deadline: std::time::Instant) -> Result<Vec<Proposal>> {
        if let Some(r) = self.ready.take() {
            return r;
        }
        let now = std::time::Instant::now();
        if now >= deadline {
            return Err(anyhow::anyhow!("request deadline expired"));
        }
        match self.rx.recv_timeout(deadline - now) {
            Ok(r) => {
                self.spent = true;
                r
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // NOT spent: dropping `self` sends the hub a Cancel.
                Err(anyhow::anyhow!("request deadline expired"))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.spent = true;
                Err(anyhow::anyhow!("hub gone"))
            }
        }
    }

    /// Abandon the request. If this was the molecule's last waiter, its
    /// in-flight decode task leaves the scheduler (rows + encoder
    /// memory released). Equivalent to dropping the future.
    pub fn cancel(self) {}
}

impl Drop for ExpansionFuture {
    fn drop(&mut self) {
        if !self.spent {
            let _ = self.hub_tx.send(HubMsg::Cancel {
                smiles: std::mem::take(&mut self.smiles),
                ticket: self.ticket,
            });
        }
    }
}

/// Batcher tuning knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Most requests drained per gather round.
    pub max_batch: usize,
    /// How long an *idle* hub waits for stragglers before the first
    /// tick. While decoding, arrivals are drained non-blockingly and
    /// join the next tick anyway.
    pub max_wait: std::time::Duration,
    /// Deadline-based encode coalescer (`batcher.coalesce_us`; zero =
    /// off): while the scheduler is busy, a round that gathered at
    /// least one miss is held open this long so near-arrivals join its
    /// single fused encode instead of paying their own round. Trades a
    /// bounded admission delay for fewer encoder calls under load —
    /// visible in [`ExpansionHub::encode_ratio`].
    pub coalesce: std::time::Duration,
    /// Fused-call row budget per scheduler tick.
    pub max_rows: usize,
    /// Expansion-cache capacity (molecules, LRU).
    pub cache_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 16,
            max_wait: std::time::Duration::from_micros(2000),
            coalesce: std::time::Duration::ZERO,
            max_rows: 256,
            cache_cap: DEFAULT_CACHE_CAP,
        }
    }
}

/// In-flight bookkeeping for one per-query decode task.
struct TaskMeta {
    mol: String,
    k: usize,
}

impl ExpansionHub {
    /// Start the hub thread. The model handle must be `Send` (use
    /// [`crate::runtime::server::SharedModel`] for PJRT models).
    pub fn start<M>(
        model: M,
        decoder: Box<dyn Decoder + Send>,
        vocab: Vocab,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Arc<ExpansionHub>
    where
        M: StepModel + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<HubMsg>();
        let stats = Arc::new(Mutex::new(DecodeStats::default()));
        let invalid = Arc::new(AtomicUsize::new(0));
        let total = Arc::new(AtomicUsize::new(0));
        let batches = Arc::new(AtomicU64::new(0));
        let merged = Arc::new(AtomicU64::new(0));
        let fused_calls = Arc::new(AtomicU64::new(0));
        let fused_rows = Arc::new(AtomicU64::new(0));
        let encode_calls = Arc::new(AtomicU64::new(0));
        let encode_rounds = Arc::new(AtomicU64::new(0));
        let cancelled = Arc::new(AtomicU64::new(0));
        let events = Arc::new(CompletionQueue::new());
        {
            let stats = stats.clone();
            let invalid = invalid.clone();
            let total = total.clone();
            let batches = batches.clone();
            let merged = merged.clone();
            let fused_calls = fused_calls.clone();
            let fused_rows = fused_rows.clone();
            let encode_calls = encode_calls.clone();
            let encode_rounds = encode_rounds.clone();
            let cancelled = cancelled.clone();
            let events = events.clone();
            std::thread::Builder::new()
                .name("expansion-hub".into())
                .spawn(move || {
                    hub_loop(
                        rx,
                        model,
                        decoder,
                        vocab,
                        cfg,
                        metrics,
                        HubCounters {
                            stats,
                            invalid,
                            total,
                            batches,
                            merged,
                            fused_calls,
                            fused_rows,
                            encode_calls,
                            encode_rounds,
                            cancelled,
                        },
                        events,
                    )
                })
                .expect("spawn expansion hub");
        }
        Arc::new(ExpansionHub {
            tx,
            next_ticket: AtomicU64::new(1),
            stats,
            invalid,
            total_hyps: total,
            batches,
            merged,
            fused_calls,
            fused_rows,
            encode_calls,
            encode_rounds,
            cancelled,
            events,
        })
    }

    /// Asynchronous single-molecule expansion: returns a future the
    /// caller polls, waits on, or cancels. This is the pipelined
    /// planner's entry point.
    pub fn submit(&self, smiles: &str, k: usize) -> Result<ExpansionFuture> {
        self.submit_deadline(smiles, k, None)
    }

    /// As [`ExpansionHub::submit`] with a request-budget deadline: past
    /// it the hub fails the waiter with a scoped "deadline" error at
    /// the next round boundary (within one scheduler tick) and cancels
    /// the molecule's decode task if no other waiter covers it — rows,
    /// encoder memory and decoder states are released through the
    /// existing cancel path.
    pub fn submit_deadline(
        &self,
        smiles: &str,
        k: usize,
        deadline: Option<std::time::Instant>,
    ) -> Result<ExpansionFuture> {
        let ticket = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(HubMsg::Expand(ExpandReq {
                smiles: smiles.to_string(),
                k,
                ticket,
                deadline,
                reply,
            }))
            .map_err(|_| anyhow::anyhow!("hub gone"))?;
        Ok(ExpansionFuture {
            smiles: smiles.to_string(),
            ticket,
            rx,
            hub_tx: self.tx.clone(),
            ready: None,
            spent: false,
        })
    }

    /// Block until at least one of `futs` (futures from **this** hub)
    /// holds a result or `deadline` passes; returns the index of a
    /// ready future — its next `poll`/`wait` returns without blocking.
    /// Futures whose results were already consumed are skipped; if all
    /// are consumed (or none completes in time) this returns `None`.
    /// Condvar-backed: the wait wakes on hub completion events, never
    /// sleep-polls.
    pub fn wait_any(
        &self,
        futs: &mut [ExpansionFuture],
        deadline: std::time::Instant,
    ) -> Option<usize> {
        loop {
            let seen = self.events.epoch();
            for (i, f) in futs.iter_mut().enumerate() {
                if f.fill() {
                    return Some(i);
                }
            }
            if std::time::Instant::now() >= deadline {
                return None;
            }
            self.events.wait_past(seen, deadline);
        }
    }

    /// Current completion-event epoch; pair with
    /// [`ExpansionHub::wait_completion_past`] for event-driven polling
    /// (capture the epoch BEFORE inspecting state, then wait past it —
    /// no event is ever missed, and no caller ever sleep-polls).
    pub fn completion_epoch(&self) -> u64 {
        self.events.epoch()
    }

    /// Block until a completion event past `seen` occurs or `deadline`
    /// passes; returns the epoch observed.
    pub fn wait_completion_past(&self, seen: u64, deadline: std::time::Instant) -> u64 {
        self.events.wait_past(seen, deadline)
    }

    /// Blocking single-molecule expansion (used by the `expand` op).
    pub fn expand(&self, smiles: &str, k: usize) -> Result<Vec<Proposal>> {
        self.submit(smiles, k)?.wait()
    }

    pub fn stats(&self) -> DecodeStats {
        // Counters only — recover from a poisoned lock rather than
        // propagating a panic into every stats reader.
        self.stats.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// (per-query decode tasks submitted, requests admitted): requests
    /// per task is the cache + coalescing amplification.
    pub fn merge_ratio(&self) -> (u64, u64) {
        (self.batches.load(Ordering::Relaxed), self.merged.load(Ordering::Relaxed))
    }

    /// (fused device calls, fused logical rows): the cycle-level
    /// batching counters; rows/calls is the serving effective batch.
    pub fn fused_ratio(&self) -> (u64, u64) {
        (
            self.fused_calls.load(Ordering::Relaxed),
            self.fused_rows.load(Ordering::Relaxed),
        )
    }

    /// (physical encoder calls, submission rounds that encoded): the
    /// fused-encode admission counters. One call per round regardless
    /// of miss count, so these are equal while fused encodes succeed
    /// (a round whose fused encode errors retries per molecule — extra
    /// calls on that recovery path only); misses per call is the
    /// encode-fusion amplification.
    pub fn encode_ratio(&self) -> (u64, u64) {
        (
            self.encode_calls.load(Ordering::Relaxed),
            self.encode_rounds.load(Ordering::Relaxed),
        )
    }

    /// In-flight decode tasks abandoned after their last waiter
    /// cancelled.
    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Hub-thread state snapshot for tests and diagnostics; blocks
    /// until the hub finishes its current tick. The encoder counters
    /// ride along so tests can pin one-encode-per-round through the
    /// full stack.
    pub fn debug_snapshot(&self) -> Result<HubSnapshot> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(HubMsg::Debug(tx))
            .map_err(|_| anyhow::anyhow!("hub gone"))?;
        let (waiting_molecules, decode_tasks, sched_in_flight, encode_calls, encode_rounds) =
            rx.recv().map_err(|_| anyhow::anyhow!("hub gone"))?;
        Ok(HubSnapshot {
            waiting_molecules,
            decode_tasks,
            sched_in_flight,
            encode_calls,
            encode_rounds,
        })
    }
}

struct HubCounters {
    stats: Arc<Mutex<DecodeStats>>,
    invalid: Arc<AtomicUsize>,
    total: Arc<AtomicUsize>,
    batches: Arc<AtomicU64>,
    merged: Arc<AtomicU64>,
    fused_calls: Arc<AtomicU64>,
    fused_rows: Arc<AtomicU64>,
    encode_calls: Arc<AtomicU64>,
    encode_rounds: Arc<AtomicU64>,
    cancelled: Arc<AtomicU64>,
}

/// A queued requester.
struct Waiter {
    ticket: u64,
    k: usize,
    /// Request-budget deadline; the hub expires the waiter past it.
    deadline: Option<std::time::Instant>,
    reply: mpsc::SyncSender<Result<Vec<Proposal>>>,
}

/// Mutable per-loop state: waiters and in-flight coverage.
struct HubState {
    /// Molecule-keyed, k-truncating expansion cache (shared core with
    /// the offline policies — see [`KTruncatedCache`]).
    cache: KTruncatedCache,
    /// Requests not yet answered, per molecule.
    waiting: HashMap<String, Vec<Waiter>>,
    /// In-flight per-query decode tasks per molecule — usually one; a
    /// wider-k re-request adds a second while the first still flies.
    covered: HashMap<String, Vec<(TaskId, usize)>>,
    /// Misses gathered this round in admission order — the row order of
    /// the round's fused encode. `None` marks a slot whose molecule was
    /// cancelled before submit.
    to_submit: Vec<Option<(String, usize)>>,
    /// Molecule -> index into `to_submit`: the per-request merge and
    /// the per-cancel removal are O(1) map operations instead of a
    /// linear scan over the round (O(n²) at high fan-in before).
    to_submit_idx: HashMap<String, usize>,
}

impl HubState {
    /// Serve a request from cache or queue it (possibly scheduling a
    /// decode for this round). Returns whether the request was answered
    /// immediately (cache hit) — the caller signals completion events
    /// only then.
    fn admit(&mut self, req: ExpandReq) -> bool {
        if let Some(out) = self.cache.get(&req.smiles, req.k) {
            let _ = req.reply.send(Ok(out));
            return true;
        }
        let in_flight_covers = self
            .covered
            .get(&req.smiles)
            .is_some_and(|tasks| tasks.iter().any(|&(_, ck)| ck >= req.k));
        if !in_flight_covers {
            use std::collections::hash_map::Entry;
            match self.to_submit_idx.entry(req.smiles.clone()) {
                Entry::Occupied(o) => {
                    let slot =
                        self.to_submit[*o.get()].as_mut().expect("indexed slots are live");
                    slot.1 = slot.1.max(req.k);
                }
                Entry::Vacant(v) => {
                    v.insert(self.to_submit.len());
                    self.to_submit.push(Some((req.smiles.clone(), req.k)));
                }
            }
        }
        self.waiting.entry(req.smiles).or_default().push(Waiter {
            ticket: req.ticket,
            k: req.k,
            deadline: req.deadline,
            reply: req.reply,
        });
        false
    }

    /// Expire every waiter whose deadline passed: each gets a scoped
    /// "deadline" error, and a molecule left with no waiters releases
    /// its queued miss. Returns the expired molecules so the caller can
    /// cancel their now-unwatched decode tasks (needs the scheduler,
    /// which the state doesn't own).
    fn expire_deadlines(&mut self, now: std::time::Instant) -> Vec<String> {
        let mut orphaned = Vec::new();
        self.waiting.retain(|mol, ws| {
            ws.retain(|w| {
                let expired = w.deadline.is_some_and(|d| now >= d);
                if expired {
                    let _ = w.reply.send(Err(anyhow::anyhow!("request deadline expired")));
                }
                !expired
            });
            if ws.is_empty() {
                orphaned.push(mol.clone());
                false
            } else {
                true
            }
        });
        for mol in &orphaned {
            self.drop_queued_miss(mol);
        }
        orphaned
    }

    /// Drop a molecule's queued miss (its last waiter cancelled before
    /// submit). O(1): the slot is tombstoned, not compacted.
    fn drop_queued_miss(&mut self, smiles: &str) {
        if let Some(i) = self.to_submit_idx.remove(smiles) {
            self.to_submit[i] = None;
        }
    }

    /// Whether any miss is still queued for this round.
    fn has_queued_misses(&self) -> bool {
        !self.to_submit_idx.is_empty()
    }

    /// Take this round's misses in admission order, clearing the queue.
    fn take_submit_round(&mut self) -> Vec<(String, usize)> {
        self.to_submit_idx.clear();
        self.to_submit.drain(..).flatten().collect()
    }

    /// Remove one waiter; returns true when the molecule has no waiters
    /// left (its in-flight tasks may then be abandoned).
    fn remove_waiter(&mut self, smiles: &str, ticket: u64) -> bool {
        let Some(ws) = self.waiting.get_mut(smiles) else {
            return false; // already answered (or never queued)
        };
        ws.retain(|w| w.ticket != ticket);
        if ws.is_empty() {
            self.waiting.remove(smiles);
            true
        } else {
            false
        }
    }

    /// Max beam width of the remaining in-flight tasks for a molecule.
    fn covered_k(&self, smiles: &str) -> usize {
        self.covered
            .get(smiles)
            .map(|tasks| tasks.iter().map(|&(_, k)| k).max().unwrap_or(0))
            .unwrap_or(0)
    }

    /// Fail every queued request (hub-invariant breach only; tick
    /// errors are scoped per failed task instead).
    fn fail_all(&mut self, msg: &str) {
        for (_, ws) in self.waiting.drain() {
            for w in ws {
                let _ = w.reply.send(Err(anyhow::anyhow!("decode failed: {msg}")));
            }
        }
        self.covered.clear();
    }
}

/// Fail the waiters of one failed/unstartable task, keeping any waiter
/// another in-flight task still covers.
fn fail_task_waiters(state: &mut HubState, mol: &str, task_k: usize, msg: &str) {
    let remaining_k = state.covered_k(mol);
    if let Some(ws) = state.waiting.remove(mol) {
        let mut kept = Vec::new();
        for w in ws {
            if w.k <= task_k && w.k > remaining_k {
                let _ = w.reply.send(Err(anyhow::anyhow!("decode failed: {msg}")));
            } else {
                kept.push(w);
            }
        }
        if !kept.is_empty() {
            state.waiting.insert(mol.to_string(), kept);
        }
    }
}

/// Start one molecule's per-query decode task over its pre-encoded
/// view and wire the hub bookkeeping. On failure (`start_task_on` has
/// already released the view) the molecule's waiters are failed —
/// anything covered by an older in-flight task keeps waiting, and the
/// round's siblings are untouched. Returns whether the task started.
#[allow(clippy::too_many_arguments)]
fn start_round_task(
    model: &dyn StepModel,
    decoder: &(dyn Decoder + Send),
    scheduler: &mut DecodeScheduler,
    state: &mut HubState,
    tasks_meta: &mut HashMap<TaskId, TaskMeta>,
    counters: &HubCounters,
    metrics: &Metrics,
    mol: String,
    k: usize,
    view: MemView,
    srcs: &[Vec<i32>],
) -> bool {
    match decoder.start_task_on(model, vec![view], srcs, k) {
        Ok(task) => {
            let id = scheduler.submit(task);
            counters.batches.fetch_add(1, Ordering::Relaxed);
            metrics.inc("batcher.tasks", 1);
            state.covered.entry(mol.clone()).or_default().push((id, k));
            tasks_meta.insert(id, TaskMeta { mol, k });
            true
        }
        Err(e) => {
            let msg = format!("start decode failed: {e:#}");
            fail_task_waiters(state, &mol, k, &msg);
            false
        }
    }
}

/// Route one inbound message: admit expansions, queue cancellations,
/// answer debug probes. Returns whether the message was an expansion
/// (the only kind counted toward the gather budget); sets `answered`
/// when an expansion was served immediately from cache (the only
/// gather outcome that warrants a completion event).
fn on_msg(
    msg: HubMsg,
    state: &mut HubState,
    cancels: &mut Vec<(String, u64)>,
    sched_in_flight: usize,
    encode: (u64, u64),
    answered: &mut bool,
) -> bool {
    match msg {
        HubMsg::Expand(r) => {
            *answered |= state.admit(r);
            true
        }
        HubMsg::Cancel { smiles, ticket } => {
            cancels.push((smiles, ticket));
            false
        }
        HubMsg::Debug(tx) => {
            let tasks: usize = state.covered.values().map(Vec::len).sum();
            let _ = tx.send((state.waiting.len(), tasks, sched_in_flight, encode.0, encode.1));
            false
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn hub_loop<M: StepModel>(
    rx: mpsc::Receiver<HubMsg>,
    model: M,
    decoder: Box<dyn Decoder + Send>,
    vocab: Vocab,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    counters: HubCounters,
    events: Arc<CompletionQueue>,
) {
    let mut scheduler = DecodeScheduler::new(SchedulerConfig { max_rows: cfg.max_rows });
    let mut state = HubState {
        cache: KTruncatedCache::new(cfg.cache_cap),
        waiting: HashMap::new(),
        covered: HashMap::new(),
        to_submit: Vec::new(),
        to_submit_idx: HashMap::new(),
    };
    let mut tasks_meta: HashMap<TaskId, TaskMeta> = HashMap::new();
    let mut cancels: Vec<(String, u64)> = Vec::new();
    let mut finished: Vec<Finished> = Vec::new();
    let mut in_flight_hw = 0usize;
    let mut open = true;

    while open || !scheduler.is_idle() || !state.waiting.is_empty() {
        // ---- 1. gather requests ----
        state.to_submit.clear();
        state.to_submit_idx.clear();
        let mut gathered = 0usize;
        let mut answered = false;
        let encode_now = (
            counters.encode_calls.load(Ordering::Relaxed),
            counters.encode_rounds.load(Ordering::Relaxed),
        );
        if open && scheduler.is_idle() && state.waiting.is_empty() {
            // Idle: block for the next request, then give stragglers a
            // short window so simultaneous arrivals share the first
            // ticks (and the round's single fused encode).
            match rx.recv() {
                Ok(msg) => {
                    let fl = scheduler.in_flight();
                    if on_msg(msg, &mut state, &mut cancels, fl, encode_now, &mut answered) {
                        counters.merged.fetch_add(1, Ordering::Relaxed);
                        gathered += 1;
                    }
                    let deadline = std::time::Instant::now() + cfg.max_wait;
                    while gathered < cfg.max_batch && state.has_queued_misses() {
                        let now = std::time::Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(msg) => {
                                let fl = scheduler.in_flight();
                                let expand = on_msg(
                                    msg,
                                    &mut state,
                                    &mut cancels,
                                    fl,
                                    encode_now,
                                    &mut answered,
                                );
                                if expand {
                                    counters.merged.fetch_add(1, Ordering::Relaxed);
                                    gathered += 1;
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                }
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        } else {
            // Busy: drain without blocking — late arrivals join the
            // very next fused call.
            while gathered < cfg.max_batch {
                match rx.try_recv() {
                    Ok(msg) => {
                        let fl = scheduler.in_flight();
                        let expand =
                            on_msg(msg, &mut state, &mut cancels, fl, encode_now, &mut answered);
                        if expand {
                            counters.merged.fetch_add(1, Ordering::Relaxed);
                            gathered += 1;
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            // Deadline-based encode coalescer: the round already has a
            // miss and the device is busy with in-flight work, so
            // holding the round open briefly lets near-arrivals share
            // its ONE fused encode instead of paying their own round.
            // The hold delays the next tick by at most `coalesce` — a
            // bounded latency trade, off by default.
            if !cfg.coalesce.is_zero()
                && open
                && !scheduler.is_idle()
                && state.has_queued_misses()
            {
                // Hits answered by the drain above must not wait out
                // the hold — their replies are already on the wire.
                if answered {
                    events.notify();
                    answered = false;
                }
                let deadline = std::time::Instant::now() + cfg.coalesce;
                while gathered < cfg.max_batch {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(msg) => {
                            let fl = scheduler.in_flight();
                            let expand = on_msg(
                                msg,
                                &mut state,
                                &mut cancels,
                                fl,
                                encode_now,
                                &mut answered,
                            );
                            if expand {
                                counters.merged.fetch_add(1, Ordering::Relaxed);
                                gathered += 1;
                            }
                            // A cache hit answered inside the hold: wake
                            // its waiter now, not when the window ends.
                            if answered {
                                events.notify();
                                answered = false;
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
        }
        if answered {
            // At least one request was answered from cache inside
            // `admit`: wake blocked `wait_any`/`wait_event` callers.
            // Miss-only rounds deliver nothing, so they wake nobody.
            events.notify();
        }

        // ---- 2. apply cancellations ----
        // A molecule whose last waiter withdrew loses its queued miss
        // and its in-flight decode tasks: the scheduler frees the rows
        // and encoder memory immediately (a task's claim on a shared
        // encode batch drops; siblings keep the memory alive), so
        // speculative searches that changed their mind never pay for
        // the full decode.
        let had_cancels = !cancels.is_empty();
        for (smiles, ticket) in cancels.drain(..) {
            if state.remove_waiter(&smiles, ticket) {
                state.drop_queued_miss(&smiles);
                if let Some(tasks) = state.covered.remove(&smiles) {
                    for (id, _) in tasks {
                        if scheduler.cancel(&model, id) {
                            counters.cancelled.fetch_add(1, Ordering::Relaxed);
                            metrics.inc("batcher.tasks_cancelled", 1);
                        }
                        tasks_meta.remove(&id);
                    }
                }
            }
        }
        if had_cancels {
            events.notify();
        }

        // ---- 2b. expire request deadlines ----
        // Budget enforcement on the hub side: waiters whose deadline
        // passed get a scoped error NOW (round boundary — within one
        // scheduler tick of expiry), and a molecule left with no
        // waiters releases its decode task exactly like a cancel. The
        // submitting thread normally beats us to it (its waits are
        // deadline-aware), but a stuck client must not pin device work.
        let orphaned = state.expire_deadlines(std::time::Instant::now());
        if !orphaned.is_empty() {
            for mol in &orphaned {
                if let Some(tasks) = state.covered.remove(mol) {
                    for (id, _) in tasks {
                        if scheduler.cancel(&model, id) {
                            counters.cancelled.fetch_add(1, Ordering::Relaxed);
                            metrics.inc("batcher.tasks_cancelled", 1);
                        }
                        tasks_meta.remove(&id);
                    }
                }
            }
            metrics.inc("batcher.deadline_expired", orphaned.len() as u64);
            events.notify();
        }

        // ---- 3 + 4: the model-facing phases, panic-contained ----
        // Everything below calls into the model (fused encode, fused
        // decode tick). A model panic must not take the hub thread — and
        // with it every session — down: catch it, abort the scheduler
        // (releasing rows, views and decoder states through the tasks'
        // `finish` path), fail the current waiters with a scoped error,
        // and keep serving the next round on a clean slate.
        let round_panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model_phases(
                &model,
                decoder.as_ref(),
                &vocab,
                &mut scheduler,
                &mut state,
                &mut tasks_meta,
                &mut finished,
                &mut in_flight_hw,
                &counters,
                &metrics,
                &events,
            )
        }));
        if round_panicked.is_err() {
            // A panic unwound out of the model mid-round. Release every
            // in-flight task (their `finish` paths free rows, memory
            // views and decoder states; a second panic during cleanup
            // is swallowed — the thread must survive), fail the waiters
            // scoped to this hub, and continue on a clean slate.
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                scheduler.abort(&model);
            }));
            let _ = scheduler.drain_failed();
            tasks_meta.clear();
            state.fail_all("hub round panicked (model fault); request failed, hub restarted");
            metrics.inc("batcher.hub_panics", 1);
            events.notify();
        }
    }

    // Shutdown: drop the request channel and remaining state first so
    // every outstanding reply sender is gone, THEN wake waiters — they
    // observe the disconnect instead of sleeping to their deadline.
    drop(rx);
    drop(state);
    events.notify();
}

/// Phases 3+4 of one hub round: submit this round's misses behind ONE
/// fused encode, then run one fused decode tick. These are the only
/// phases that call into the model, so `hub_loop` runs this function
/// inside `catch_unwind` — a model panic is contained here and the
/// bookkeeping phases (gather / cancel / deadline sweep) stay outside
/// the failure domain.
#[allow(clippy::too_many_arguments)]
fn model_phases(
    model: &dyn StepModel,
    decoder: &(dyn Decoder + Send),
    vocab: &Vocab,
    scheduler: &mut DecodeScheduler,
    state: &mut HubState,
    tasks_meta: &mut HashMap<TaskId, TaskMeta>,
    finished: &mut Vec<Finished>,
    in_flight_hw: &mut usize,
    counters: &HubCounters,
    metrics: &Metrics,
    events: &CompletionQueue,
) {
    // ---- 3. submit this round's misses: ONE fused encode ----
    // Every cache-missing molecule gathered this round shares a
    // single `StepModel::encode` call; each then gets its own
    // per-query decode task over its row view of the shared batch
    // (released when the round's last member retires or is
    // cancelled). Encoder cost is O(rounds), not O(misses), while
    // retirement semantics stay per-query: a slow molecule neither
    // stalls its co-arrivals' answers nor pins their memory.
    let round = state.take_submit_round();
    if !round.is_empty() {
        let srcs: Vec<Vec<i32>> = round.iter().map(|(mol, _)| vocab.encode(mol, true)).collect();
        counters.encode_rounds.fetch_add(1, Ordering::Relaxed);
        metrics.inc("batcher.encode_rounds", 1);
        let mut failed_any = false;
        match encode_shared(model, &srcs) {
            Ok(views) => {
                counters.encode_calls.fetch_add(1, Ordering::Relaxed);
                metrics.inc("batcher.encode_calls", 1);
                for (((mol, k), view), src) in round.into_iter().zip(views).zip(srcs.iter()) {
                    let one = std::slice::from_ref(src);
                    failed_any |= !start_round_task(
                        model, decoder, scheduler, state, tasks_meta, counters, metrics, mol, k,
                        view, one,
                    );
                }
            }
            Err(fused_err) => {
                // The round's ONE fused encode failed. Don't fail
                // the whole round — one bad source must not take
                // down every co-arriving session's expansion.
                // Retry each molecule alone (the pre-fusion blast
                // radius): healthy co-arrivals still fly, only the
                // truly failing molecule's waiters error, and the
                // per-molecule encode cost is paid on this error
                // path only.
                for ((mol, k), src) in round.into_iter().zip(srcs.iter()) {
                    let one = std::slice::from_ref(src);
                    match encode_shared(model, one) {
                        Ok(views) => {
                            counters.encode_calls.fetch_add(1, Ordering::Relaxed);
                            metrics.inc("batcher.encode_calls", 1);
                            let view = views.into_iter().next().expect("one view per source");
                            failed_any |= !start_round_task(
                                model, decoder, scheduler, state, tasks_meta, counters, metrics,
                                mol, k, view, one,
                            );
                        }
                        Err(e) => {
                            let msg = format!("encode failed: {e:#} (fused: {fused_err:#})");
                            fail_task_waiters(state, &mol, k, &msg);
                            failed_any = true;
                        }
                    }
                }
            }
        }
        if failed_any {
            events.notify();
        }
    }

    // ---- 4. one fused tick ----
    // Publish the in-flight high-water mark only when it moves:
    // steady-state ticks must stay free of mutex/alloc traffic.
    if scheduler.in_flight() > *in_flight_hw {
        *in_flight_hw = scheduler.in_flight();
        metrics.gauge_max("scheduler.in_flight_tasks", *in_flight_hw as u64);
    }
    if scheduler.is_idle() {
        if !state.waiting.is_empty() {
            // Unreachable by construction (waiters always have a
            // covering task); fail loudly instead of spinning.
            state.fail_all("internal: waiters without an in-flight task");
            events.notify();
        }
        return; // nothing in flight: the round ends here
    }
    finished.clear();
    let t_tick = std::time::Instant::now();
    match scheduler.tick(model, finished) {
        Ok(rows) => {
            if rows > 0 {
                counters.fused_calls.fetch_add(1, Ordering::Relaxed);
                counters.fused_rows.fetch_add(rows as u64, Ordering::Relaxed);
                metrics.inc("batcher.fused_calls", 1);
                metrics.inc("batcher.fused_rows", rows as u64);
                // A rows>0 tick is dominated by its one fused device
                // call: this histogram replaces the old whole-
                // `generate` "batcher.decode" timing at cycle
                // granularity.
                metrics.observe("batcher.decode", t_tick.elapsed().as_secs_f64());
            }
            let retired_any = !finished.is_empty();
            for f in finished.drain(..) {
                // A task without bookkeeping (cancelled in the same
                // round it finished) has no waiters to answer —
                // skip it instead of panicking the hub thread.
                let Some(meta) = tasks_meta.remove(&f.id) else {
                    continue;
                };
                counters.stats.lock().unwrap_or_else(|p| p.into_inner()).merge(&f.stats);
                retire_task(f.id, &meta, &f, vocab, state, counters);
            }
            if retired_any {
                // Answers are on their channels: wake blocked
                // wait_any / wait_event callers.
                events.notify();
            }
        }
        Err(e) => {
            // The fused call failed: exactly the tasks staged in it
            // were dropped by the scheduler. Fail their waiters and
            // nobody else's — unstaged tasks keep flying.
            let msg = format!("{e:#}");
            for id in scheduler.drain_failed() {
                if let Some(meta) = tasks_meta.remove(&id) {
                    if let Some(tasks) = state.covered.get_mut(&meta.mol) {
                        tasks.retain(|&(tid, _)| tid != id);
                        if tasks.is_empty() {
                            state.covered.remove(&meta.mol);
                        }
                    }
                    fail_task_waiters(state, &meta.mol, meta.k, &msg);
                }
            }
            events.notify();
        }
    }
}

/// Parse a finished per-query task's output, populate the cache, and
/// answer every waiter the task covers.
fn retire_task(
    id: TaskId,
    meta: &TaskMeta,
    f: &Finished,
    vocab: &Vocab,
    state: &mut HubState,
    counters: &HubCounters,
) {
    let mol = &meta.mol;
    let Some(gen) = f.outputs.first() else {
        // A per-query task always has one output; if the invariant ever
        // breaks, fail this task's waiters (scoped) instead of
        // panicking the hub thread out from under every session.
        fail_task_waiters(state, mol, meta.k, "internal: task finished without output");
        if let Some(tasks) = state.covered.get_mut(mol) {
            tasks.retain(|&(tid, _)| tid != id);
            if tasks.is_empty() {
                state.covered.remove(mol);
            }
        }
        return;
    };
    let mut inv = 0usize;
    let mut tot = 0usize;
    let props = proposals_from_output(vocab, mol, gen, &mut inv, &mut tot);
    counters.invalid.fetch_add(inv, Ordering::Relaxed);
    counters.total.fetch_add(tot, Ordering::Relaxed);
    state.cache.insert(mol.clone(), meta.k, props.clone());
    if let Some(ws) = state.waiting.remove(mol) {
        let mut kept = Vec::new();
        for w in ws {
            if w.k <= meta.k {
                let mut out = props.clone();
                out.truncate(w.k);
                let _ = w.reply.send(Ok(out));
            } else {
                // A wider request for the same molecule is covered by a
                // younger, larger-k task still in flight.
                kept.push(w);
            }
        }
        if !kept.is_empty() {
            state.waiting.insert(mol.clone(), kept);
        }
    }
    if let Some(tasks) = state.covered.get_mut(mol) {
        tasks.retain(|&(tid, _)| tid != id);
        if tasks.is_empty() {
            state.covered.remove(mol);
        }
    }
}

/// Per-session [`ExpansionPolicy`] view over the hub. `Send`, cheap to
/// clone — each planning session owns one. Also implements
/// [`AsyncExpansionPolicy`], so pipelined Retro\* rides per-query
/// futures straight into the scheduler.
#[derive(Clone)]
pub struct BatchedPolicy {
    hub: Arc<ExpansionHub>,
    calls: Arc<AtomicUsize>,
}

impl BatchedPolicy {
    pub fn new(hub: Arc<ExpansionHub>) -> Self {
        Self { hub, calls: Arc::new(AtomicUsize::new(0)) }
    }
}

/// A group of per-molecule hub futures joined into one batch handle.
struct HubHandle {
    futs: Vec<Option<ExpansionFuture>>,
    results: Vec<Option<Vec<Proposal>>>,
    /// The hub's completion events, for `wait_event`.
    events: Arc<CompletionQueue>,
    /// Epoch captured at the start of the last `poll`: `wait_event`
    /// blocks past it, so an event landing between that poll and the
    /// wait is never missed.
    seen: u64,
}

impl ExpansionHandle for HubHandle {
    fn poll(&mut self) -> Option<Result<Vec<Vec<Proposal>>>> {
        self.seen = self.events.epoch();
        let mut pending = false;
        for (i, slot) in self.futs.iter_mut().enumerate() {
            if self.results[i].is_some() {
                continue;
            }
            let Some(f) = slot.as_mut() else { continue };
            match f.poll() {
                Some(Ok(p)) => {
                    self.results[i] = Some(p);
                    *slot = None;
                }
                // On error the handle is spent; dropping it (and the
                // remaining futures with it) cancels the rest.
                Some(Err(e)) => return Some(Err(e)),
                None => pending = true,
            }
        }
        if pending {
            return None;
        }
        Some(Ok(self
            .results
            .iter_mut()
            .map(|r| r.take().unwrap_or_default())
            .collect()))
    }

    fn wait(mut self: Box<Self>) -> Result<Vec<Vec<Proposal>>> {
        for (i, slot) in self.futs.iter_mut().enumerate() {
            if self.results[i].is_some() {
                continue;
            }
            if let Some(f) = slot.take() {
                self.results[i] = Some(f.wait()?);
            }
        }
        Ok(self
            .results
            .iter_mut()
            .map(|r| r.take().unwrap_or_default())
            .collect())
    }

    fn wait_event(&mut self, deadline: std::time::Instant) {
        // Any hub completion (not just this batch's) wakes the wait;
        // the caller re-polls. Condvar-backed — no sleep-polling.
        self.events.wait_past(self.seen, deadline);
    }

    fn cancel(self: Box<Self>) {
        // Drop on the remaining futures sends the hub cancellations.
    }
}

impl ExpansionPolicy for BatchedPolicy {
    fn expand_batch(&self, molecules: &[&str], k: usize) -> Result<Vec<Vec<Proposal>>> {
        // fan out, then join — the hub may merge these with other
        // sessions' requests
        self.submit(molecules, k)?.wait()
    }

    fn decode_stats(&self) -> DecodeStats {
        self.hub.stats()
    }

    fn calls(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }
}

impl AsyncExpansionPolicy for BatchedPolicy {
    fn submit(&self, molecules: &[&str], k: usize) -> Result<Box<dyn ExpansionHandle>> {
        self.submit_inner(molecules, k, None)
    }

    fn submit_deadline(
        &self,
        molecules: &[&str],
        k: usize,
        deadline: std::time::Instant,
    ) -> Result<Box<dyn ExpansionHandle>> {
        self.submit_inner(molecules, k, Some(deadline))
    }
}

impl BatchedPolicy {
    fn submit_inner(
        &self,
        molecules: &[&str],
        k: usize,
        deadline: Option<std::time::Instant>,
    ) -> Result<Box<dyn ExpansionHandle>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut futs = Vec::with_capacity(molecules.len());
        for m in molecules {
            futs.push(Some(self.hub.submit_deadline(m, k, deadline)?));
        }
        Ok(Box::new(HubHandle {
            results: vec![None; futs.len()],
            futs,
            events: self.hub.events.clone(),
            seen: 0,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};

    fn hub() -> Arc<ExpansionHub> {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(5),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn hub_expands_and_caches() {
        let h = hub();
        // the mock copies its input: a reactant-set string comes back as
        // a valid 2-component proposal
        let p1 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert!(!p1.is_empty());
        let calls_before = h.stats().model_calls;
        let p2 = h.expand("CC(=O)O.CN", 3).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(h.stats().model_calls, calls_before, "cache must serve repeats");
    }

    #[test]
    fn cache_serves_smaller_k_and_redecodes_larger() {
        let h = hub();
        let p5 = h.expand("CC(=O)O.CN", 5).unwrap();
        let calls_after_first = h.stats().model_calls;
        // smaller k: truncation of the stored expansion, no decode
        let p2 = h.expand("CC(=O)O.CN", 2).unwrap();
        assert_eq!(h.stats().model_calls, calls_after_first, "k<=stored must hit");
        assert!(p2.len() <= 2);
        assert_eq!(&p5[..p2.len()], &p2[..]);
        // larger k: must re-decode
        let _p8 = h.expand("CC(=O)O.CN", 8).unwrap();
        assert!(h.stats().model_calls > calls_after_first, "k>stored must miss");
        // and the cache now stores the larger entry
        let calls = h.stats().model_calls;
        let _ = h.expand("CC(=O)O.CN", 8).unwrap();
        assert_eq!(h.stats().model_calls, calls);
    }

    #[test]
    fn cache_is_bounded() {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO", "CCN", "CCC"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig { cache_cap: 2, ..Default::default() },
            Arc::new(Metrics::new()),
        );
        for m in ["CCO", "CCN", "CCC", "CC(=O)NC"] {
            let _ = h.expand(m, 2).unwrap();
        }
        // most-recent entry still hits
        let calls = h.stats().model_calls;
        let _ = h.expand("CC(=O)NC", 2).unwrap();
        assert_eq!(h.stats().model_calls, calls);
        // evicted entry recomputes
        let _ = h.expand("CCO", 2).unwrap();
        assert!(h.stats().model_calls > calls);
    }

    #[test]
    fn concurrent_sessions_share_batches() {
        let h = hub();
        let mut joins = Vec::new();
        for _ in 0..4 {
            let hc = h.clone();
            joins.push(std::thread::spawn(move || {
                let policy = BatchedPolicy::new(hc);
                policy.expand_batch(&["CC(=O)O.CN"], 3).unwrap()
            }));
        }
        for j in joins {
            assert!(!j.join().unwrap().is_empty());
        }
        let (tasks, merged) = h.merge_ratio();
        assert!(merged >= 4);
        assert!(tasks <= merged, "tasks {tasks} merged {merged}");
    }

    #[test]
    fn concurrent_distinct_molecules_fuse_calls() {
        let h = hub();
        let mols = ["CC(=O)O.CN", "CC(=O)NC", "CCO"];
        let mut joins = Vec::new();
        for m in mols {
            let hc = h.clone();
            joins.push(std::thread::spawn(move || hc.expand(m, 3).unwrap()));
        }
        for j in joins {
            let _ = j.join().unwrap();
        }
        let (fused_calls, fused_rows) = h.fused_ratio();
        assert!(fused_calls > 0);
        assert!(fused_rows >= fused_calls, "rows {fused_rows} calls {fused_calls}");
        // Solo per-molecule decoding would have cost at least as many
        // device calls as the hub's fused path.
        assert!(h.stats().model_calls >= fused_calls);
        // Fused-encode admission: exactly one encoder call per
        // submission round, never one per miss.
        let (encode_calls, encode_rounds) = h.encode_ratio();
        assert_eq!(encode_calls, encode_rounds, "one encode per round");
        assert!(encode_calls >= 1 && encode_calls <= mols.len() as u64);
    }

    #[test]
    fn futures_poll_to_completion() {
        let h = hub();
        let mut fut = h.submit("CC(=O)O.CN", 3).unwrap();
        // Event-driven wait: poll, then block on the completion epoch —
        // no sleeps. The epoch is captured BEFORE the poll so a
        // completion landing in between wakes the wait immediately.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut result = None;
        loop {
            let seen = h.completion_epoch();
            if let Some(r) = fut.poll() {
                result = Some(r);
                break;
            }
            if std::time::Instant::now() >= deadline {
                break;
            }
            h.wait_completion_past(seen, deadline);
        }
        let props = result.expect("future must complete").unwrap();
        assert!(!props.is_empty());
        // a second future for the same molecule hits the cache
        let calls = h.stats().model_calls;
        let p2 = h.submit("CC(=O)O.CN", 3).unwrap().wait().unwrap();
        assert_eq!(props, p2);
        assert_eq!(h.stats().model_calls, calls);
    }

    #[test]
    fn wait_any_buffers_first_completion() {
        let h = hub();
        let mut futs = vec![
            h.submit("CC(=O)O.CN", 3).unwrap(),
            h.submit("CC(=O)NC", 3).unwrap(),
        ];
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut answered = 0;
        while !futs.is_empty() {
            let i = h.wait_any(&mut futs, deadline).expect("a future must complete");
            let fut = futs.remove(i);
            // wait_any buffered the result: this wait returns instantly.
            let _ = fut.wait().unwrap();
            answered += 1;
        }
        assert_eq!(answered, 2);
        // All consumed: wait_any on an empty/spent set yields None at
        // the deadline rather than blocking forever.
        let soon = std::time::Instant::now() + std::time::Duration::from_millis(5);
        assert!(h.wait_any(&mut [], soon).is_none());
    }

    #[test]
    fn cancelled_future_leaves_no_state_behind() {
        let h = hub();
        let fut = h.submit("CC(=O)NC", 4).unwrap();
        fut.cancel();
        // settle: the hub processes the cancel between ticks; each
        // processed cancel bumps the completion epoch, so this blocks
        // instead of sleep-polling.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut clean = false;
        loop {
            let seen = h.completion_epoch();
            let s = h.debug_snapshot().unwrap();
            if s.waiting_molecules == 0 && s.decode_tasks == 0 && s.sched_in_flight == 0 {
                clean = true;
                break;
            }
            if std::time::Instant::now() >= deadline {
                break;
            }
            h.wait_completion_past(seen, deadline);
        }
        assert!(clean, "cancelled request must leave no waiters or tasks");
        // the hub still serves fresh work afterwards
        let p = h.expand("CC(=O)O.CN", 3).unwrap();
        assert!(!p.is_empty());
    }

    #[test]
    fn cancel_with_remaining_waiter_keeps_the_task() {
        let h = hub();
        // two futures on the same molecule: cancelling one must not
        // starve the other
        let keep = h.submit("CC(=O)O.CN", 3).unwrap();
        let drop_me = h.submit("CC(=O)O.CN", 3).unwrap();
        drop_me.cancel();
        let props = keep.wait().unwrap();
        assert!(!props.is_empty(), "surviving waiter must still be answered");
    }

    #[test]
    fn fused_encode_failure_keeps_per_molecule_blast_radius() {
        use crate::benchkit::InstrumentedModel;
        let vocab = Vocab::build(["CC(=O)O.CN", "CCO"]);
        // Any encode batch containing the poisoned source errors —
        // exercising the fused-encode failure fallback.
        let poison = vocab.encode("CCO", true);
        let model = InstrumentedModel::new(MockModel::new(MockConfig {
            vocab: vocab.len(),
            ..Default::default()
        }))
        .with_encode_failure(move |src| src.iter().any(|s| *s == poison));
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                // Wide straggler window: both submissions land in one
                // round, so the ROUND's fused encode fails and the
                // per-molecule fallback must rescue the healthy one.
                max_wait: std::time::Duration::from_millis(10),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        );
        let healthy = h.submit("CC(=O)O.CN", 3).unwrap();
        let poisoned = h.submit("CCO", 3).unwrap();
        let p = healthy
            .wait()
            .expect("healthy co-arrival must survive a sibling's encode failure");
        assert!(!p.is_empty());
        let err = poisoned.wait().expect_err("poisoned molecule must fail");
        assert!(format!("{err:#}").contains("encode failed"), "{err:#}");
    }

    #[test]
    fn deadline_coalescer_fuses_near_arrivals_under_load() {
        use crate::benchkit::InstrumentedModel;
        use std::sync::atomic::AtomicBool;
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let hold = Arc::new(AtomicBool::new(true));
        let model = InstrumentedModel::new(MockModel::new(MockConfig {
            vocab: vocab.len(),
            ..Default::default()
        }))
        .with_gate(hold.clone());
        let h = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig {
                max_wait: std::time::Duration::from_micros(500),
                // Generous coalesce window: while molecule A keeps the
                // scheduler busy, B's round stays open long enough for
                // C (submitted well after B) to join it.
                coalesce: std::time::Duration::from_millis(120),
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        );
        // Round 1: A alone. Its first fused tick blocks on the gate,
        // so B and C below arrive while the hub is demonstrably busy.
        let fa = h.submit("CC(=O)O.CN", 3).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let fb = h.submit("CC(=O)NC", 3).unwrap();
        hold.store(false, Ordering::SeqCst);
        // C arrives only after the gate opened — past any same-drain
        // co-arrival window, inside the coalesce hold for B's round.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let fc = h.submit("CCO", 3).unwrap();
        assert!(!fa.wait().unwrap().is_empty());
        assert!(!fb.wait().unwrap().is_empty());
        assert!(!fc.wait().unwrap().is_empty());
        let (encode_calls, encode_rounds) = h.encode_ratio();
        assert_eq!(encode_calls, encode_rounds, "one encode per round");
        assert_eq!(
            encode_rounds, 2,
            "coalescer must fold the near-arrival into the held round (A | B+C)"
        );
    }

    #[test]
    fn batched_policy_counts_calls() {
        let h = hub();
        let p = BatchedPolicy::new(h);
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        let _ = p.expand_batch(&["CCO"], 2).unwrap();
        assert_eq!(p.calls(), 2);
    }

    #[test]
    fn async_policy_handle_round_trip() {
        let h = hub();
        let p = BatchedPolicy::new(h);
        let handle = AsyncExpansionPolicy::submit(&p, &["CC(=O)O.CN", "CCO"], 3).unwrap();
        let out = handle.wait().unwrap();
        assert_eq!(out.len(), 2);
        assert!(!out[0].is_empty());
        assert_eq!(p.calls(), 1);
    }
}
