//! L3 serving coordinator.
//!
//! The request path (all Rust, Python never appears):
//!
//! ```text
//! TCP clients ──► server (thread per connection)
//!                    │  plan/expand requests
//!                    ▼
//!              ExpansionHub (continuous batcher): expansion requests
//!                    │  become resumable decode tasks; a
//!                    │  DecodeScheduler fuses all in-flight tasks'
//!                    │  rows into ONE device call per decode cycle
//!                    ▼
//!              SharedModel (model-executor thread)
//!                    ▼
//!              PJRT CPU client over the AOT HLO artifacts
//! ```
//!
//! Cross-tree batching is the paper's closing "future work" realized:
//! AiZynthFinder calls its model with batch size 1; here concurrent
//! planning sessions share *decode cycles*, not just request batches —
//! a request that arrives mid-decode joins the very next device call,
//! so the effective batch stays high even as earlier requests' beams
//! finish (Table 1's scalability column is the mechanism; Table 1C's
//! effective-batch decay is what the fusion removes).

pub mod batcher;
pub mod protocol;
pub mod server;

pub use batcher::{BatchedPolicy, ExpansionHub};
pub use server::Server;
