//! L3 serving coordinator.
//!
//! The request path (all Rust, Python never appears):
//!
//! ```text
//! TCP clients ──► server (thread per connection)
//!                    │  admission (overload::OverloadController):
//!                    │  connections beyond server.max_sessions and
//!                    │  requests past the server.max_queue watermark
//!                    │  are SHED with {code:"overloaded",
//!                    │  retry_after_ms} — batch/screen class sheds at
//!                    │  half the interactive threshold, so screening
//!                    │  floods never starve interactive plans. Above
//!                    │  the degrade_high load watermark NEW requests
//!                    │  are admitted DEGRADED (beam → degraded_beam,
//!                    │  spec_depth → 1, optional tighter deadline;
//!                    │  response carries degraded:true) until load
//!                    │  falls back through degrade_low (hysteresis —
//!                    │  in-flight requests are never touched). A
//!                    │  draining server refuses new work with
//!                    │  {code:"draining"} while in-flight solves run
//!                    │  out a fenced drain deadline and return
//!                    │  anytime partials; healthz reports readiness
//!                    │  (alive replicas, load score, draining flag)
//!                    │  ───
//!                    │  plan: pipelined Retro* keeps up to spec_depth
//!                    │  expansion groups in flight as futures; waits
//!                    │  block on the hub's completion events (condvar),
//!                    │  never sleep-poll. Every plan carries a Budget
//!                    │  (deadline + optional expansion/token caps),
//!                    │  checked at the selection cadence and threaded
//!                    │  into every blocking wait — expiry breaks the
//!                    │  loop with a stop_reason and the anytime
//!                    │  best-so-far partial route, never a hang.
//!                    │  screen: a ScreeningJob plans a whole target
//!                    │  list as ONE job — up to screen_concurrency
//!                    │  batch-class sessions over the same hub,
//!                    │  per-job deadline / decode-token budgets
//!                    │  (each claim carves its limits from the job's
//!                    │  remaining allowance; early exits reclaim
//!                    │  theirs), per-target results streamed in
//!                    │  completion order
//!                    ▼
//!              job scheduler / priority classes (two-tier admission)
//!                    │  every request carries Interactive | Batch.
//!                    │  A shard serves batch MISSES only from rounds
//!                    │  with no interactive miss pending (batch
//!                    │  backlog, round phase 2c) and the steal queue
//!                    │  claims interactive spills first — bulk
//!                    │  screening rides the same fused rounds
//!                    │  without inflating interactive p95. Cache
//!                    │  hits and in-flight joins answer immediately
//!                    │  for BOTH classes, so cross-target sharing
//!                    │  never waits
//!                    ▼
//!              ExpansionHub (facade over the sharded batcher tier)
//!                    │  submit(smiles, k) / submit_deadline(.., at)
//!                    │  -> ExpansionFuture (poll / wait / wait_deadline
//!                    │  / cancel); routes each request to the least-
//!                    │  queued of S shard loops (batcher.shards). A
//!                    │  molecule some shard already decodes routes to
//!                    │  that shard instead — cross-shard in-flight
//!                    │  dedup: both sessions join ONE decode task. A
//!                    │  submit finding every inbox a full gather round
//!                    │  deep spills to a shared steal queue
//!                    │  (batcher.steal); whichever shard frees up
//!                    │  first claims it
//!                    ▼
//!              shard loop ×S (session-sharded continuous batcher;
//!                    │  shards share the L1 expansion cache — a
//!                    │  molecule decoded anywhere serves everywhere.
//!                    │  With cache.path set, an L1 miss probes the L2
//!                    │  persistent store (store::ExpansionStore, a
//!                    │  pure in-memory map probe — the log replayed
//!                    │  at open lives in RAM) and PROMOTES a hit into
//!                    │  L1 at its full stored width (cache.l2_hits /
//!                    │  cache.l2_promotions); retired expansions are
//!                    │  recorded into the store write-behind. Only a
//!                    │  molecule missing BOTH tiers becomes ONE
//!                    │  per-query decode task — it retires the moment
//!                    │  its own
//!                    │  beams finish, and cancellation (dropped
//!                    │  future, expired deadline: both sweep phase
//!                    │  2/2b of the round loop) drops it from its
//!                    │  scheduler, releasing rows, encoder memory
//!                    │  and decoder states through one shared path
//!                    ▼
//!              encode admission: ALL of a round's misses share ONE
//!                    │  StepModel::encode call; each task decodes over
//!                    │  its own ref-counted row view (MemView) of the
//!                    │  shared batch — encoder cost is O(rounds), not
//!                    │  O(misses). Under load, batcher.coalesce_us
//!                    │  holds a round open briefly so NEAR-arrivals
//!                    │  join the same fused encode too
//!                    ▼
//!              DecodeScheduler ×N per shard: ONE fused device call
//!                    │  per replica per decode cycle over ALL that
//!                    │  replica's in-flight tasks' rows (delta rows:
//!                    │  each row is a cached StateId + only its new
//!                    │  tokens, so decode cost is O(fresh positions)
//!                    │  per cycle); a tick error fails only the tasks
//!                    │  in that call
//!                    ▼
//!              ReplicaPool (model.replicas): least-outstanding-rows
//!                    │  dispatch over N replicas, shared by all
//!                    │  shards; each replica is its own supervised
//!                    │  failure domain
//!                    ▼
//!              SharedModel ×N (supervised model-executor threads;
//!                    │  startup Meta ships the device's row-bucketing
//!                    │  rule)
//!                    ▼
//!              PJRT CPU client over the AOT HLO artifacts
//! ```
//!
//! **Supervision failure domains** (each fault is contained one level
//! up, never escalated to the process):
//!
//! ```text
//! model call Err ──► SharedModel retries within policy (model.retries,
//!                    capped exponential backoff); exhausted retries
//!                    fail that one call, scoped
//! model call panic ► caught on the executor thread; the in-flight
//!                    call errs scoped, the factory rebuilds the model
//!                    (capped backoff, model.panics / model.restarts
//!                    metrics); StateCommit is never retried (a blind
//!                    second commit could double-claim)
//! replica death ───► a replica erring "model thread gone" (its
//!                    supervisor gave up past max_restarts) is marked
//!                    dead pool-wide; the observing shard requeues its
//!                    in-flight work onto survivors (replica.deaths
//!                    metric) — waiters fail scoped only when the LAST
//!                    replica dies
//! hub round panic ─► caught around the model phases of the round
//!                    loop (encode + tick); the shard's schedulers
//!                    abort their in-flight tasks, every registered
//!                    waiter fails scoped, batcher.hub_panics
//!                    increments, the shard thread lives on to serve
//!                    the next round (other shards never notice)
//! request deadline ► phase 2b fails just-expired waiters and cancels
//!                    tasks nobody still covers; the planner's Budget
//!                    turns the scoped error into stop_reason=deadline
//!                    with partial stats (anytime result)
//! ```
//!
//! `tests/chaos_soak.rs` drives all four domains at once: 110 seeded
//! random fault schedules (errors / panics / spikes / stalls from
//! `benchkit::ChaosModel`) against mixed impatient / abandoning /
//! patient waiters, asserting the hub still answers afterwards and
//! that waiters, memory views and decoder-state claims drain to zero.
//! Its overload-storm tests add connection floods over a real TCP
//! server (latency spikes + a replica death mid-storm): every request
//! must get a terminal structured answer — shed, draining, degraded,
//! anytime or solved — and the hub must drain to zero both after the
//! storm and after a mid-storm `drain` shutdown.
//!
//! **MemView ownership rule:** a round's shared encoder batch is freed
//! on the device exactly when the *last* member task retires or is
//! cancelled — each task holds one ref-counted row view, released in
//! its `finish` on every path (retirement, cancellation, tick error),
//! so speculative cancellation never strands a sibling's memory and no
//! task can free memory a sibling still decodes from
//! (`tests/parity_encode_fusion.rs` pins both directions).
//!
//! **Decoder-state ownership rule (fork / commit / release):** cached
//! decoder states ([`crate::model::StateId`]) follow the same lifetime
//! discipline one level deeper. A task *commits* a state only for
//! positions the decode call it just absorbed processed; beam
//! reordering is explicit *forking* — every surviving beam takes its
//! own claim on the anchor it extends (siblings share the committed
//! state); rejected draft positions are never committed and unadopted
//! commits are *released* at the end of the cycle (rollback is free).
//! A task's whole chain is released when it retires or is cancelled —
//! `tests/parity_decoding.rs` pins zero leaked states through
//! mid-phase cancellation, and `decode_tokens` in `DecodeStats` makes
//! the payoff measurable (positions processed per generated token stays
//! a small constant instead of growing with prefix length).
//!
//! **Store flusher ownership rule:** after [`crate::store`] open, the
//! log file is owned by exactly ONE thread — the store's flusher.
//! Shards, planners and the server never perform disk I/O on any
//! request path: an L2 read is a mutex-guarded map probe, and an L2
//! write is a channel send the flusher drains, buffers and fsyncs on
//! the `cache.flush_ms` cadence (`cache.flush_lag` gauges records not
//! yet durable). A crash therefore loses at most the last flush
//! window and can only tear the TAIL of the log, which open-time
//! recovery truncates (`cache.recovered_records`) —
//! `tests/store_crash.rs` pins the recovery shapes and the warm
//! restart; `benches/warm_cache.rs` pins the no-blocking-disk-I/O hot
//! path.
//!
//! Cross-tree batching is the paper's closing "future work" realized:
//! AiZynthFinder calls its model with batch size 1; here concurrent
//! planning sessions share *decode cycles*, not just request batches —
//! a request that arrives mid-decode joins the very next device call,
//! so the effective batch stays high even as earlier requests' beams
//! finish (Table 1's scalability column is the mechanism; Table 1C's
//! effective-batch decay is what the fusion removes). Per-query tasks
//! plus speculative pipelined search extend the same lever *inside* a
//! single planning session: a solo session no longer degenerates to
//! effective batch 1, because its own next-best expansions ride the
//! same fused ticks.
//!
//! **Speculation-determinism contract:** `spec_depth = 1` plans are
//! bit-identical to the sequential planner (same selections, graph,
//! route, iteration counts and per-task decode stats —
//! `tests/parity_search.rs`). `spec_depth > 1` may expand extra
//! molecules (absorbed in completion-arrival order) and cancels
//! invalidated speculations; every applied expansion is real model
//! output, and cancelled tasks free their scheduler rows and encoder
//! memory immediately.

pub mod batcher;
pub mod overload;
pub mod protocol;
pub mod server;
pub(crate) mod shard;

pub use batcher::{BatchedPolicy, ExpansionFuture, ExpansionHub};
pub use overload::{Admission, OverloadConfig, OverloadController};
pub use server::Server;
