//! L3 serving coordinator.
//!
//! The request path (all Rust, Python never appears):
//!
//! ```text
//! TCP clients ──► server (thread per connection)
//!                    │  plan/expand requests
//!                    ▼
//!              ExpansionHub (dynamic batcher): merges single-step
//!                    │  expansion calls from all in-flight planning
//!                    │  sessions into batched decoder calls
//!                    ▼
//!              SharedModel (model-executor thread)
//!                    ▼
//!              PJRT CPU client over the AOT HLO artifacts
//! ```
//!
//! Cross-tree batching is the paper's closing "future work" realized:
//! AiZynthFinder calls its model with batch size 1; here concurrent
//! planning sessions share model batches, so the effective batch grows
//! with server load (and MSBS keeps its advantage at those batch sizes —
//! Table 1's scalability column is the mechanism).

pub mod batcher;
pub mod protocol;
pub mod server;

pub use batcher::{BatchedPolicy, ExpansionHub};
pub use server::Server;
