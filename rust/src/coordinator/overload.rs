//! Overload protection: admission control, the graceful-degradation
//! ladder, and drain-clean shutdown state.
//!
//! The serving tier must degrade answer *quality* before it degrades
//! *availability* (the paper's pitch is strict per-request latency for
//! screening, and the anytime `StopReason` machinery already gives us
//! honest partial answers). This module keeps all of that policy in one
//! deterministic, lock-free object so the server and tests share it:
//!
//! * **Session slots** — `max_sessions` bounds concurrent connections;
//!   excess connects receive a structured shed response instead of an
//!   unbounded thread.
//! * **Queue shedding** — `max_queue` bounds queued hub work; batch /
//!   screen requests shed at half the threshold, interactive at the
//!   full threshold (interactive last, per the north star).
//! * **Degradation ladder** — when the hub load score crosses
//!   `degrade_high`, new requests are admitted with clamped effort
//!   (beam toward `degraded_beam`, speculation toward 1, optionally a
//!   tighter deadline); the flag clears only when load falls to
//!   `degrade_low`, so the ladder recovers hysteretically instead of
//!   flapping around one watermark. In-flight requests are never
//!   touched.
//! * **Draining** — once [`OverloadController::begin_drain`] runs, new
//!   work is refused with `code:"draining"` and every in-flight solve's
//!   [`DeadlineFence`] is fenced to `now + drain_ms`, after which the
//!   solves return anytime partials through the ordinary budget path.
//!
//! All decisions are pure functions of (config, load, queued, class,
//! state bits), so the ladder is unit-testable without a hub.

use crate::search::DeadlineFence;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Knobs for the controller; defaults are inert (no session bound, no
/// shedding, degradation watermarks unreachable without real load).
#[derive(Clone, Debug)]
pub struct OverloadConfig {
    /// Concurrent connection slots (0 = unlimited).
    pub max_sessions: usize,
    /// Queued-request shed threshold (0 = shedding off). Batch-class
    /// requests shed at `max(1, max_queue / 2)`, interactive at
    /// `max_queue`.
    pub max_queue: usize,
    /// Load score at/above which new requests degrade.
    pub degrade_high: f64,
    /// Load score at/below which full effort returns.
    pub degrade_low: f64,
    /// Beam-width floor applied to degraded admissions.
    pub degraded_beam: usize,
    /// Deadline clamp for degraded admissions, ms (0 = keep request
    /// deadline).
    pub degraded_deadline_ms: u64,
    /// Backoff hint carried in shed responses, ms.
    pub retry_after_ms: u64,
    /// Drain grace window for in-flight solves, ms.
    pub drain_ms: u64,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        Self {
            max_sessions: 0,
            max_queue: 0,
            degrade_high: 0.75,
            degrade_low: 0.40,
            degraded_beam: 1,
            degraded_deadline_ms: 0,
            retry_after_ms: 250,
            drain_ms: 1000,
        }
    }
}

/// Outcome of one admission decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Run the request; `degraded` means clamp its effort knobs.
    Admit { degraded: bool },
    /// Refuse with `code:"overloaded"` and this backoff hint.
    Shed { retry_after_ms: u64 },
    /// Refuse with `code:"draining"` — the server is shutting down.
    Draining,
}

/// Decrements the in-flight request count on drop, so every exit path
/// out of a handler (including panics unwinding into the connection
/// thread) releases its slot.
pub struct RequestGuard<'a> {
    ctrl: &'a OverloadController,
}

impl Drop for RequestGuard<'_> {
    fn drop(&mut self) {
        self.ctrl.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Shared overload state for one [`crate::coordinator::Server`].
#[derive(Debug, Default)]
pub struct OverloadController {
    pub cfg: OverloadConfig,
    /// Connections currently holding a session slot.
    sessions: AtomicUsize,
    /// Requests currently inside a handler (plan / expand / screen).
    inflight: AtomicUsize,
    draining: AtomicBool,
    /// Ladder state bit (hysteresis memory between the watermarks).
    degraded: AtomicBool,
    /// Shared with every admitted solve's `SearchLimits`; set once at
    /// drain time.
    fence: DeadlineFence,
}

impl OverloadController {
    pub fn new(cfg: OverloadConfig) -> Self {
        Self { cfg, ..Default::default() }
    }

    /// Admission decision for one new request. `load` and `queued` are
    /// the hub's non-blocking probes; `batch` marks the batch/screen
    /// class (sheds first). Also advances the hysteretic ladder bit:
    /// `load >= degrade_high` sets it, `load <= degrade_low` clears it,
    /// anything between leaves it unchanged.
    pub fn admit(&self, load: f64, queued: usize, batch: bool) -> Admission {
        if self.draining.load(Ordering::SeqCst) {
            return Admission::Draining;
        }
        if self.cfg.max_queue > 0 {
            let threshold = if batch {
                (self.cfg.max_queue / 2).max(1)
            } else {
                self.cfg.max_queue
            };
            if queued >= threshold {
                return Admission::Shed { retry_after_ms: self.cfg.retry_after_ms };
            }
        }
        if load >= self.cfg.degrade_high {
            self.degraded.store(true, Ordering::SeqCst);
        } else if load <= self.cfg.degrade_low {
            self.degraded.store(false, Ordering::SeqCst);
        }
        Admission::Admit { degraded: self.degraded.load(Ordering::SeqCst) }
    }

    /// Claim a connection slot; `false` means shed the connection.
    /// Compare-and-swap so racing accepts cannot overshoot the bound.
    pub fn try_acquire_session(&self) -> bool {
        if self.cfg.max_sessions == 0 {
            self.sessions.fetch_add(1, Ordering::SeqCst);
            return true;
        }
        loop {
            let cur = self.sessions.load(Ordering::SeqCst);
            if cur >= self.cfg.max_sessions {
                return false;
            }
            if self
                .sessions
                .compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                return true;
            }
        }
    }

    pub fn release_session(&self) {
        self.sessions.fetch_sub(1, Ordering::SeqCst);
    }

    pub fn sessions(&self) -> usize {
        self.sessions.load(Ordering::SeqCst)
    }

    /// Mark one request in flight; the guard releases it on drop.
    pub fn request_begin(&self) -> RequestGuard<'_> {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        RequestGuard { ctrl: self }
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Enter draining: refuse new work and fence every in-flight
    /// solve's deadline to `now + drain_ms`. Idempotent — the fence
    /// keeps the earliest instant, so repeated drains only tighten.
    /// Returns the drain deadline.
    pub fn begin_drain(&self, now: Instant) -> Instant {
        self.draining.store(true, Ordering::SeqCst);
        let at = now + Duration::from_millis(self.cfg.drain_ms);
        self.fence.set(at);
        at
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::SeqCst)
    }

    /// The shared fence; clones installed into admitted requests'
    /// `SearchLimits` all point at the same cell.
    pub fn fence(&self) -> DeadlineFence {
        self.fence.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctrl(cfg: OverloadConfig) -> OverloadController {
        OverloadController::new(cfg)
    }

    #[test]
    fn defaults_admit_everything_undegraded() {
        let c = ctrl(OverloadConfig::default());
        for queued in [0usize, 10, 10_000] {
            assert_eq!(
                c.admit(0.0, queued, false),
                Admission::Admit { degraded: false },
                "max_queue = 0 disables shedding"
            );
            assert_eq!(c.admit(0.0, queued, true), Admission::Admit { degraded: false });
        }
        for _ in 0..100 {
            assert!(c.try_acquire_session(), "max_sessions = 0 is unlimited");
        }
    }

    #[test]
    fn batch_class_sheds_before_interactive() {
        let c = ctrl(OverloadConfig { max_queue: 8, ..Default::default() });
        // Below the batch threshold: everyone admitted.
        assert_eq!(c.admit(0.0, 3, true), Admission::Admit { degraded: false });
        assert_eq!(c.admit(0.0, 3, false), Admission::Admit { degraded: false });
        // Between max_queue/2 and max_queue: batch sheds, interactive
        // still gets in.
        assert_eq!(c.admit(0.0, 4, true), Admission::Shed { retry_after_ms: 250 });
        assert_eq!(c.admit(0.0, 4, false), Admission::Admit { degraded: false });
        // At the full threshold: interactive sheds too.
        assert_eq!(c.admit(0.0, 8, false), Admission::Shed { retry_after_ms: 250 });
    }

    #[test]
    fn shed_carries_the_configured_retry_hint() {
        let c = ctrl(OverloadConfig { max_queue: 2, retry_after_ms: 77, ..Default::default() });
        assert_eq!(c.admit(0.0, 2, false), Admission::Shed { retry_after_ms: 77 });
    }

    #[test]
    fn ladder_sets_at_high_and_clears_only_at_low() {
        let c = ctrl(OverloadConfig::default()); // high 0.75, low 0.40
        assert_eq!(c.admit(0.5, 0, false), Admission::Admit { degraded: false });
        // Crossing the high watermark flips the bit for NEW requests.
        assert_eq!(c.admit(0.8, 0, false), Admission::Admit { degraded: true });
        // In the hysteresis band the bit holds — no flapping at 0.74/0.76.
        assert_eq!(c.admit(0.6, 0, false), Admission::Admit { degraded: true });
        assert_eq!(c.admit(0.41, 0, false), Admission::Admit { degraded: true });
        // Only at/below the low watermark does full effort return.
        assert_eq!(c.admit(0.40, 0, false), Admission::Admit { degraded: false });
        assert_eq!(c.admit(0.6, 0, false), Admission::Admit { degraded: false });
    }

    #[test]
    fn draining_outranks_everything() {
        let c = ctrl(OverloadConfig { max_queue: 4, ..Default::default() });
        let before = Instant::now();
        let deadline = c.begin_drain(before);
        assert!(c.is_draining());
        assert_eq!(deadline, before + Duration::from_millis(1000));
        assert_eq!(c.admit(0.0, 0, false), Admission::Draining);
        assert_eq!(c.admit(9.9, 999, true), Admission::Draining);
        // The fence is installed for in-flight solves.
        assert_eq!(c.fence().get(), Some(deadline));
        // A second drain can only tighten the fence.
        let earlier = before - Duration::from_millis(900);
        c.begin_drain(earlier);
        assert_eq!(c.fence().get(), Some(earlier + Duration::from_millis(1000)));
    }

    #[test]
    fn session_slots_bound_and_release() {
        let c = ctrl(OverloadConfig { max_sessions: 2, ..Default::default() });
        assert!(c.try_acquire_session());
        assert!(c.try_acquire_session());
        assert!(!c.try_acquire_session(), "third connection sheds");
        assert_eq!(c.sessions(), 2);
        c.release_session();
        assert!(c.try_acquire_session(), "freed slot is reusable");
    }

    #[test]
    fn request_guard_releases_on_drop() {
        let c = ctrl(OverloadConfig::default());
        {
            let _g1 = c.request_begin();
            let _g2 = c.request_begin();
            assert_eq!(c.inflight(), 2);
        }
        assert_eq!(c.inflight(), 0);
    }
}
