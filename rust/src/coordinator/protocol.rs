//! Line-delimited JSON protocol of the serving coordinator.
//!
//! Requests (one JSON object per line):
//!
//! ```json
//! {"id": 1, "op": "plan", "smiles": "...", "algo": "retrostar",
//!  "deadline_ms": 5000, "beam_width": 1, "spec_depth": 1}
//! {"id": 2, "op": "expand", "smiles": "...", "k": 10}
//! {"id": 3, "op": "metrics"}
//! {"id": 4, "op": "ping"}
//! ```
//!
//! `spec_depth` sets how many expansion groups pipelined Retro\* keeps
//! in flight: an integer pins it (1 = sequential selection), the string
//! `"auto"` enables the adaptive controller (depth follows the observed
//! speculation apply-rate up to the server's configured max). The
//! default comes from `planner.spec_depth`. Plan responses report the
//! speculation accounting under `speculation`, including the
//! `depth_trajectory` the adaptive controller walked.
//!
//! Plan requests may also carry a work budget: `max_expansions` (policy
//! batches) and `max_decode_tokens` (decoder positions), both 0/absent
//! = unlimited. Every plan response reports `stop_reason`
//! (`solved | exhausted | deadline | budget | error`); an unsolved plan
//! that stopped on deadline/budget/error additionally ships the anytime
//! `partial_route` best-so-far skeleton (when one exists) and, for
//! `error`, the policy failure message under `plan_error` — the request
//! itself still answers `ok = true` with its partial statistics.
//!
//! The `screen` op plans a whole target list as one batch-class job:
//!
//! ```json
//! {"id": 5, "op": "screen", "targets": ["...", "..."],
//!  "concurrency": 8, "job_deadline_ms": 30000,
//!  "job_max_decode_tokens": 500000, "deadline_ms": 2000}
//! ```
//!
//! plus the per-target limit overrides a `plan` accepts. Unlike every
//! other op it streams: one `{"event": "target", "index": ...}` line
//! per target **in completion order** (stop reason, timing, decode
//! usage, route or anytime partial route), then a final
//! `{"event": "done", ...}` line with the job summary — targets
//! solved / stopped per reason, and the cross-target sharing rates
//! (job-scoped cache-hit and dedup-join fractions, decode tokens per
//! solved target).
//!
//! Responses mirror the `id` and carry `ok`/`error` plus op-specific
//! fields; routes serialize as nested `{smiles, logp?, children?}`.
//!
//! Overload protection adds three structured refusals and two ops. A
//! shed request answers `{"ok": false, "code": "overloaded",
//! "retry_after_ms": ...}` (retry after backing off); a draining server
//! answers `{"ok": false, "code": "draining"}` (do not retry here). A
//! plan/screen admitted under the degradation ladder carries
//! `"degraded": true` — at full effort the key is absent, so low-load
//! responses are byte-identical to the pre-overload protocol. The
//! `healthz` op reports liveness/readiness (alive replicas, load score,
//! sessions, draining flag) and `drain` starts a drain-clean shutdown.

use crate::jsonx::Json;
use crate::search::{Proposal, Route, ScreenSummary, SolveResult};

/// Serialize a route tree.
pub fn route_to_json(r: &Route) -> Json {
    match r {
        Route::Leaf { smiles } => Json::obj(vec![
            ("smiles", Json::str(smiles.clone())),
            ("in_stock", Json::Bool(true)),
        ]),
        Route::Step { smiles, logp, children } => Json::obj(vec![
            ("smiles", Json::str(smiles.clone())),
            ("logp", Json::num(*logp)),
            ("children", Json::Arr(children.iter().map(route_to_json).collect())),
        ]),
    }
}

/// Parse a route tree (used by clients/tests).
pub fn route_from_json(j: &Json) -> Option<Route> {
    let smiles = j.get("smiles")?.as_str()?.to_string();
    match j.get("children") {
        None => Some(Route::Leaf { smiles }),
        Some(ch) => {
            let children = ch
                .as_arr()?
                .iter()
                .map(route_from_json)
                .collect::<Option<Vec<_>>>()?;
            Some(Route::Step {
                smiles,
                logp: j.get("logp").and_then(|x| x.as_f64()).unwrap_or(0.0),
                children,
            })
        }
    }
}

/// Build a `plan` response.
pub fn plan_response(id: i64, r: &SolveResult) -> Json {
    let mut fields = vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("solved", Json::Bool(r.solved)),
        ("stop_reason", Json::str(r.stop_reason.as_str())),
        ("iterations", Json::num(r.iterations as f64)),
        ("expansions", Json::num(r.expansions as f64)),
        ("wall_ms", Json::num(r.wall_secs * 1e3)),
        ("model_calls", Json::num(r.decode_stats.model_calls as f64)),
        (
            "acceptance_rate",
            Json::num(r.decode_stats.acceptance_rate()),
        ),
        (
            "speculation",
            Json::obj(vec![
                ("submitted", Json::num(r.spec.groups_submitted as f64)),
                ("applied", Json::num(r.spec.groups_applied as f64)),
                ("cancelled", Json::num(r.spec.groups_cancelled as f64)),
                ("hits", Json::num(r.spec.spec_hits as f64)),
                ("max_in_flight", Json::num(r.spec.max_in_flight as f64)),
                (
                    "depth_trajectory",
                    Json::Arr(
                        r.spec
                            .depth_trajectory
                            .iter()
                            .map(|&d| Json::num(d as f64))
                            .collect(),
                    ),
                ),
            ]),
        ),
    ];
    if let Some(route) = &r.route {
        fields.push(("route", route_to_json(route)));
        fields.push(("route_depth", Json::num(route.depth() as f64)));
    }
    // Anytime result: an unsolved plan that stopped on deadline/budget/
    // error still ships its best-so-far skeleton (not-yet-expanded
    // molecules appear as leaves).
    if let Some(partial) = &r.partial_route {
        fields.push(("partial_route", route_to_json(partial)));
    }
    if let Some(err) = &r.error {
        fields.push(("plan_error", Json::str(err)));
    }
    Json::obj(fields)
}

/// Build an `expand` response.
pub fn expand_response(id: i64, proposals: &[Proposal]) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        (
            "proposals",
            Json::Arr(
                proposals
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            (
                                "reactants",
                                Json::Arr(
                                    p.reactants.iter().map(|r| Json::str(r.clone())).collect(),
                                ),
                            ),
                            ("logp", Json::num(p.logp)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Build one streamed per-target line of a `screen` response.
pub fn screen_target_response(id: i64, index: usize, smiles: &str, r: &SolveResult) -> Json {
    let mut fields = vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("event", Json::str("target")),
        ("index", Json::num(index as f64)),
        ("target", Json::str(smiles)),
        ("solved", Json::Bool(r.solved)),
        ("stop_reason", Json::str(r.stop_reason.as_str())),
        ("iterations", Json::num(r.iterations as f64)),
        ("expansions", Json::num(r.expansions as f64)),
        ("wall_ms", Json::num(r.wall_secs * 1e3)),
        ("model_calls", Json::num(r.decode_stats.model_calls as f64)),
        ("decode_tokens", Json::num(r.decode_stats.decode_tokens as f64)),
    ];
    if let Some(route) = &r.route {
        fields.push(("route", route_to_json(route)));
        fields.push(("route_depth", Json::num(route.depth() as f64)));
    }
    if let Some(partial) = &r.partial_route {
        fields.push(("partial_route", route_to_json(partial)));
    }
    if let Some(err) = &r.error {
        fields.push(("plan_error", Json::str(err)));
    }
    Json::obj(fields)
}

/// Build the final job-summary line of a `screen` response.
pub fn screen_summary_response(id: i64, s: &ScreenSummary) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("event", Json::str("done")),
        ("targets", Json::num(s.targets as f64)),
        ("solved", Json::num(s.solved as f64)),
        ("stop_deadline", Json::num(s.stop_deadline as f64)),
        ("stop_budget", Json::num(s.stop_budget as f64)),
        ("stop_exhausted", Json::num(s.stop_exhausted as f64)),
        ("stop_error", Json::num(s.stop_error as f64)),
        ("wall_ms", Json::num(s.wall_secs * 1e3)),
        ("requests", Json::num(s.requests as f64)),
        ("decode_tasks", Json::num(s.decode_tasks as f64)),
        ("dedup_joins", Json::num(s.dedup_joins as f64)),
        ("decode_tokens", Json::num(s.decode_tokens as f64)),
        ("model_calls", Json::num(s.model_calls as f64)),
        ("cache_hit_rate", Json::num(s.cache_hit_rate)),
        ("dedup_join_rate", Json::num(s.dedup_join_rate)),
        ("tokens_per_solved", Json::num(s.tokens_per_solved)),
        ("skipped_warm", Json::num(s.skipped_warm as f64)),
    ])
}

/// Build a `routes` response: the persisted k-best routes for one
/// target (empty `routes` when the store holds none).
pub fn routes_response(id: i64, target: &str, routes: &[crate::store::StoredRoute]) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("target", Json::str(target)),
        (
            "routes",
            Json::Arr(
                routes
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("cost", Json::num(r.cost)),
                            ("depth", Json::num(r.route.depth() as f64)),
                            ("route", route_to_json(&r.route)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Build an error response.
pub fn error_response(id: i64, msg: &str) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
}

/// Build an admission-control shed response: the server refused the
/// request because it is overloaded. Unlike [`error_response`] it
/// carries a machine-readable `code` and a client backoff hint, so
/// callers can distinguish "retry later" from "your request is bad".
pub fn shed_response(id: i64, retry_after_ms: u64) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(false)),
        ("code", Json::str("overloaded")),
        ("error", Json::str("server overloaded; retry later")),
        ("retry_after_ms", Json::num(retry_after_ms as f64)),
    ])
}

/// Build a drain refusal: the server is shutting down and no longer
/// accepts new work. There is no point retrying against this server.
pub fn draining_response(id: i64) -> Json {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(false)),
        ("code", Json::str("draining")),
        ("error", Json::str("server draining; no new work accepted")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_json_roundtrip() {
        let r = Route::Step {
            smiles: "CC(=O)NC".into(),
            logp: -0.5,
            children: vec![
                Route::Leaf { smiles: "CC(=O)O".into() },
                Route::Leaf { smiles: "CN".into() },
            ],
        };
        let j = route_to_json(&r);
        let back = route_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn plan_response_reports_stop_reason_and_partial_route() {
        use crate::search::StopReason;
        let r = SolveResult {
            solved: false,
            route: None,
            stop_reason: StopReason::Deadline,
            partial_route: Some(Route::Step {
                smiles: "CC(=O)NC".into(),
                logp: -0.5,
                children: vec![Route::Leaf { smiles: "CN".into() }],
            }),
            error: None,
            iterations: 3,
            expansions: 2,
            wall_secs: 0.01,
            decode_stats: Default::default(),
            spec: Default::default(),
        };
        let j = plan_response(9, &r);
        assert_eq!(j.get("stop_reason").unwrap().as_str(), Some("deadline"));
        assert!(j.get("route").is_none(), "no closed route on a deadline stop");
        let partial = j.get("partial_route").expect("anytime skeleton present");
        assert_eq!(partial.get("smiles").unwrap().as_str(), Some("CC(=O)NC"));
        // A solved plan reports `solved` and no partial.
        let solved = SolveResult {
            solved: true,
            route: Some(Route::Leaf { smiles: "CCO".into() }),
            stop_reason: StopReason::Solved,
            partial_route: None,
            error: None,
            iterations: 1,
            expansions: 0,
            wall_secs: 0.001,
            decode_stats: Default::default(),
            spec: Default::default(),
        };
        let j = plan_response(10, &solved);
        assert_eq!(j.get("stop_reason").unwrap().as_str(), Some("solved"));
        assert!(j.get("partial_route").is_none());
    }

    #[test]
    fn screen_target_line_carries_stop_reason_and_partial() {
        use crate::search::StopReason;
        let r = SolveResult {
            solved: false,
            route: None,
            stop_reason: StopReason::Deadline,
            partial_route: Some(Route::Leaf { smiles: "CN".into() }),
            error: None,
            iterations: 2,
            expansions: 1,
            wall_secs: 0.02,
            decode_stats: Default::default(),
            spec: Default::default(),
        };
        let j = screen_target_response(3, 7, "CC(=O)NC", &r);
        assert_eq!(j.get("event").unwrap().as_str(), Some("target"));
        assert_eq!(j.get("index").unwrap().as_i64(), Some(7));
        assert_eq!(j.get("target").unwrap().as_str(), Some("CC(=O)NC"));
        assert_eq!(j.get("stop_reason").unwrap().as_str(), Some("deadline"));
        assert!(j.get("route").is_none());
        assert!(j.get("partial_route").is_some(), "anytime partial streamed");
    }

    #[test]
    fn screen_summary_line_reports_sharing_rates() {
        let s = ScreenSummary {
            targets: 4,
            solved: 3,
            stop_deadline: 1,
            requests: 10,
            decode_tasks: 5,
            dedup_joins: 2,
            decode_tokens: 900,
            cache_hit_rate: 0.3,
            dedup_join_rate: 0.2,
            tokens_per_solved: 300.0,
            ..Default::default()
        };
        let j = screen_summary_response(3, &s);
        assert_eq!(j.get("event").unwrap().as_str(), Some("done"));
        assert_eq!(j.get("targets").unwrap().as_i64(), Some(4));
        assert_eq!(j.get("solved").unwrap().as_i64(), Some(3));
        assert_eq!(j.get("decode_tasks").unwrap().as_i64(), Some(5));
        assert!((j.get("cache_hit_rate").unwrap().as_f64().unwrap() - 0.3).abs() < 1e-12);
        assert!((j.get("tokens_per_solved").unwrap().as_f64().unwrap() - 300.0).abs() < 1e-12);
    }

    #[test]
    fn shed_and_draining_shapes() {
        let s = shed_response(5, 250);
        assert_eq!(s.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(s.get("code").unwrap().as_str(), Some("overloaded"));
        assert_eq!(s.get("retry_after_ms").unwrap().as_i64(), Some(250));
        let d = draining_response(6);
        assert_eq!(d.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(d.get("code").unwrap().as_str(), Some("draining"));
        assert!(d.get("retry_after_ms").is_none(), "drains are not retryable");
    }

    /// Parity pin: the exact serialized bytes of an undegraded plan
    /// response. The overload layer must not perturb low-load responses
    /// — in particular no `degraded` key may appear unless the server
    /// actually clamped the request. Keys serialize sorted (BTreeMap),
    /// so this string is deterministic.
    #[test]
    fn undegraded_plan_response_bytes_are_pinned() {
        use crate::search::StopReason;
        let r = SolveResult {
            solved: false,
            route: None,
            stop_reason: StopReason::Exhausted,
            partial_route: None,
            error: None,
            iterations: 3,
            expansions: 2,
            wall_secs: 0.0,
            decode_stats: Default::default(),
            spec: Default::default(),
        };
        let j = plan_response(42, &r);
        assert_eq!(
            j.to_string(),
            concat!(
                "{\"acceptance_rate\":0,\"expansions\":2,\"id\":42,\"iterations\":3,",
                "\"model_calls\":0,\"ok\":true,\"solved\":false,\"speculation\":",
                "{\"applied\":0,\"cancelled\":0,\"depth_trajectory\":[],\"hits\":0,",
                "\"max_in_flight\":0,\"submitted\":0},\"stop_reason\":\"exhausted\",",
                "\"wall_ms\":0}"
            )
        );
        assert!(j.get("degraded").is_none(), "no degraded key at full effort");
    }

    #[test]
    fn error_shape() {
        let e = error_response(7, "bad smiles");
        assert_eq!(e.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(e.get("id").unwrap().as_i64(), Some(7));
        assert!(e.get("error").unwrap().as_str().unwrap().contains("bad"));
    }

    #[test]
    fn expand_shape() {
        let e = expand_response(
            1,
            &[Proposal { reactants: vec!["CC".into(), "O".into()], logp: -1.0 }],
        );
        let arr = e.get("proposals").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(
            arr[0].get("reactants").unwrap().as_arr().unwrap()[0].as_str(),
            Some("CC")
        );
    }
}
