//! TCP server: thread-per-connection over the line-delimited JSON
//! protocol, planning sessions sharing the expansion hub.

use super::batcher::{BatchedPolicy, ExpansionHub};
use super::protocol;
use crate::jsonx::Json;
use crate::metrics::Metrics;
use crate::search::{
    dfs::Dfs, retrostar::RetroStar, Planner, ScreenConfig, ScreeningJob, SearchLimits, Stock,
    TargetResult,
};
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running coordinator server.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Everything a connection handler needs.
pub struct ServerCtx {
    pub hub: Arc<ExpansionHub>,
    pub stock: Arc<Stock>,
    pub metrics: Arc<Metrics>,
    pub default_limits: SearchLimits,
    pub default_algo: String,
    pub default_beam_width: usize,
    /// Default in-flight expansion depth for pipelined Retro\* (1 =
    /// sequential selection; requests may override via `spec_depth`,
    /// either an integer or `"auto"`). When `default_spec_adaptive` is
    /// set this is the adaptive controller's max depth.
    pub default_spec_depth: usize,
    /// `planner.spec_depth = "auto"`: adapt depth to the observed
    /// speculation apply-rate.
    pub default_spec_adaptive: bool,
    /// Adaptive-depth cap (`planner.spec_depth_max`), used when either
    /// the server default or the request selects `"auto"`.
    pub default_spec_max: usize,
    /// Defaults for the `screen` op (config `planner.screen_*`).
    pub screen: ScreenDefaults,
}

/// Server-side defaults for bulk screening jobs; requests may override
/// each field (`concurrency`, `job_deadline_ms`, `job_max_decode_tokens`).
#[derive(Clone, Copy, Debug)]
pub struct ScreenDefaults {
    /// Targets planned concurrently per job.
    pub concurrency: usize,
    /// Per-job wall-clock budget, ms (0 = off).
    pub job_deadline_ms: u64,
    /// Per-job decode-token cap (0 = off).
    pub job_decode_tokens: u64,
}

impl Default for ScreenDefaults {
    fn default() -> Self {
        Self { concurrency: 8, job_deadline_ms: 0, job_decode_tokens: 0 }
    }
}

impl Server {
    /// Bind and start serving on a background thread. Use port 0 for an
    /// ephemeral port (tests); `addr()` reports the bound address.
    pub fn start(listen: &str, ctx: ServerCtx) -> Result<Server> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let ctx = Arc::new(ctx);
        let join = std::thread::Builder::new()
            .name("coordinator-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let ctx = ctx.clone();
                            let _ = std::thread::Builder::new()
                                .name("coordinator-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(stream, &ctx);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr, stop, join: Some(join) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn handle_connection(stream: TcpStream, ctx: &ServerCtx) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        // `screen` is the one streaming op: many lines per request, so
        // it writes directly instead of going through handle_line's
        // one-request-one-response shape.
        let is_screen = Json::parse(&line)
            .ok()
            .and_then(|j| j.get("op").and_then(|o| o.as_str()).map(|o| o == "screen"))
            .unwrap_or(false);
        if is_screen {
            handle_screen(&line, ctx, &mut writer)?;
            continue;
        }
        let response = handle_line(&line, ctx);
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    let _ = peer;
    Ok(())
}

/// Dispatch one request line to a response (exposed for direct testing).
pub fn handle_line(line: &str, ctx: &ServerCtx) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return protocol::error_response(-1, &format!("bad json: {e}")),
    };
    let id = req.get("id").and_then(|x| x.as_i64()).unwrap_or(-1);
    let op = req.get("op").and_then(|x| x.as_str()).unwrap_or("");
    ctx.metrics.inc(&format!("op.{op}"), 1);
    match op {
        "ping" => Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("ok", Json::Bool(true)),
            ("pong", Json::Bool(true)),
        ]),
        "metrics" => {
            let mut m = ctx.metrics.snapshot();
            if let Json::Obj(ref mut o) = m {
                o.insert("id".into(), Json::num(id as f64));
                o.insert("ok".into(), Json::Bool(true));
                let (batches, merged) = ctx.hub.merge_ratio();
                o.insert("batcher_batches".into(), Json::num(batches as f64));
                o.insert("batcher_merged".into(), Json::num(merged as f64));
                let (fused_calls, fused_rows) = ctx.hub.fused_ratio();
                o.insert("batcher_fused_calls".into(), Json::num(fused_calls as f64));
                o.insert("batcher_fused_rows".into(), Json::num(fused_rows as f64));
                o.insert("batcher_shards".into(), Json::num(ctx.hub.shard_count() as f64));
                o.insert(
                    "batcher_dedup_joins".into(),
                    Json::num(ctx.hub.dedup_joins() as f64),
                );
                let (spills, steals) = ctx.hub.steal_stats();
                o.insert("batcher_steal_spills".into(), Json::num(spills as f64));
                o.insert("batcher_steals".into(), Json::num(steals as f64));
                let replicas = ctx.hub.replica_stats();
                o.insert("model_replicas".into(), Json::num(replicas.len() as f64));
                o.insert(
                    "model_replicas_alive".into(),
                    Json::num(replicas.iter().filter(|r| r.alive).count() as f64),
                );
                o.insert(
                    "model_replica_deaths".into(),
                    Json::num(ctx.hub.replica_deaths() as f64),
                );
            }
            m
        }
        "expand" => {
            let Some(smiles) = req.get("smiles").and_then(|x| x.as_str()) else {
                return protocol::error_response(id, "missing smiles");
            };
            let k = req.get("k").and_then(|x| x.as_usize()).unwrap_or(10);
            let canonical = match crate::chem::canonicalize(smiles) {
                Ok(c) => c,
                Err(e) => return protocol::error_response(id, &format!("bad smiles: {e}")),
            };
            match ctx
                .metrics
                .time("request.expand", || ctx.hub.expand(&canonical, k))
            {
                Ok(p) => protocol::expand_response(id, &p),
                Err(e) => protocol::error_response(id, &format!("{e:#}")),
            }
        }
        "plan" => {
            let Some(smiles) = req.get("smiles").and_then(|x| x.as_str()) else {
                return protocol::error_response(id, "missing smiles");
            };
            let limits = limits_from_req(&req, &ctx.default_limits);
            let algo = req
                .get("algo")
                .and_then(|x| x.as_str())
                .unwrap_or(&ctx.default_algo)
                .to_string();
            let bw = req
                .get("beam_width")
                .and_then(|x| x.as_usize())
                .unwrap_or(ctx.default_beam_width);
            let (sd, sd_auto) = spec_from_req(&req, ctx);
            let policy = BatchedPolicy::new(ctx.hub.clone());
            // Retro* plans ride the async path: per-query expansion
            // futures into the hub's scheduler. spec_depth = 1 keeps
            // sequential selection semantics (pinned bit-identical by
            // the parity suite); deeper keeps that many expansion
            // groups in flight speculatively.
            let result = match algo.as_str() {
                "dfs" => ctx
                    .metrics
                    .time("request.plan", || Dfs.solve(smiles, &policy, &ctx.stock, &limits)),
                "retrostar" | "retro*" => ctx.metrics.time("request.plan", || {
                    let rs = if sd_auto {
                        RetroStar::new(bw).with_adaptive_spec_depth(sd)
                    } else {
                        RetroStar::new(bw).with_spec_depth(sd)
                    };
                    rs.solve_pipelined(smiles, &policy, &ctx.stock, &limits)
                }),
                other => return protocol::error_response(id, &format!("unknown algo {other}")),
            };
            match result {
                Ok(r) => {
                    ctx.metrics.inc(if r.solved { "plan.solved" } else { "plan.unsolved" }, 1);
                    ctx.metrics.inc(&format!("plan.stop.{}", r.stop_reason), 1);
                    ctx.metrics.gauge_max("plan.spec_in_flight", r.spec.max_in_flight);
                    ctx.metrics.inc("plan.spec_submitted", r.spec.groups_submitted);
                    ctx.metrics.inc("plan.spec_cancelled", r.spec.groups_cancelled);
                    ctx.metrics.inc("plan.spec_hits", r.spec.spec_hits);
                    protocol::plan_response(id, &r)
                }
                Err(e) => protocol::error_response(id, &format!("{e:#}")),
            }
        }
        // Streaming op: handled by `handle_screen` upstream of this
        // dispatcher; reachable here only when called directly.
        "screen" => protocol::error_response(
            id,
            "screen streams multiple response lines; send it over a connection",
        ),
        other => protocol::error_response(id, &format!("unknown op {other:?}")),
    }
}

/// Apply a request's shared per-target limit overrides onto the server
/// defaults (used by both `plan` and `screen`).
fn limits_from_req(req: &Json, base: &SearchLimits) -> SearchLimits {
    let mut limits = base.clone();
    if let Some(ms) = req.get("deadline_ms").and_then(|x| x.as_usize()) {
        limits.deadline = std::time::Duration::from_millis(ms as u64);
    }
    if let Some(d) = req.get("max_depth").and_then(|x| x.as_usize()) {
        limits.max_depth = d;
    }
    if let Some(k) = req.get("k").and_then(|x| x.as_usize()) {
        limits.expansions_per_step = k;
    }
    // Per-request work budget (0/absent = server default).
    if let Some(n) = req.get("max_expansions").and_then(|x| x.as_usize()) {
        limits.max_expansions = n;
    }
    if let Some(n) = req.get("max_decode_tokens").and_then(|x| x.as_usize()) {
        limits.max_decode_tokens = n as u64;
    }
    limits
}

/// `spec_depth` accepts an integer or "auto" (adaptive up to the
/// server's configured max depth). Returns `(depth, adaptive)`.
fn spec_from_req(req: &Json, ctx: &ServerCtx) -> (usize, bool) {
    match req.get("spec_depth") {
        Some(v) if v.as_str() == Some("auto") => (ctx.default_spec_max.max(1), true),
        Some(v) => (v.as_usize().unwrap_or(ctx.default_spec_depth).max(1), false),
        None => (ctx.default_spec_depth.max(1), ctx.default_spec_adaptive),
    }
}

/// Handle one `screen` request: stream a `target` line per completed
/// target in completion order, then the terminal `done` (or error)
/// line. Write failures stop the streaming but let the job drain.
pub fn handle_screen(line: &str, ctx: &ServerCtx, writer: &mut dyn Write) -> Result<()> {
    let final_line = run_screen(line, ctx, writer);
    writer.write_all(final_line.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

fn run_screen(line: &str, ctx: &ServerCtx, writer: &mut dyn Write) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return protocol::error_response(-1, &format!("bad json: {e}")),
    };
    let id = req.get("id").and_then(|x| x.as_i64()).unwrap_or(-1);
    ctx.metrics.inc("op.screen", 1);
    let Some(arr) = req.get("targets").and_then(|t| t.as_arr()) else {
        return protocol::error_response(id, "missing targets");
    };
    let targets: Vec<String> = arr
        .iter()
        .filter_map(|t| t.as_str().map(String::from))
        .collect();
    if targets.is_empty() {
        return protocol::error_response(id, "empty targets");
    }
    let concurrency = req
        .get("concurrency")
        .and_then(|x| x.as_usize())
        .unwrap_or(ctx.screen.concurrency)
        .max(1);
    let job_deadline_ms = req
        .get("job_deadline_ms")
        .and_then(|x| x.as_usize())
        .map(|n| n as u64)
        .unwrap_or(ctx.screen.job_deadline_ms);
    let job_decode_tokens = req
        .get("job_max_decode_tokens")
        .and_then(|x| x.as_usize())
        .map(|n| n as u64)
        .unwrap_or(ctx.screen.job_decode_tokens);
    let (sd, sd_auto) = spec_from_req(&req, ctx);
    let cfg = ScreenConfig {
        concurrency,
        job_deadline: (job_deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(job_deadline_ms)),
        job_decode_tokens,
        beam_width: req
            .get("beam_width")
            .and_then(|x| x.as_usize())
            .unwrap_or(ctx.default_beam_width),
        spec_depth: sd,
        spec_adaptive: sd_auto,
        limits: limits_from_req(&req, &ctx.default_limits),
    };
    let job = ScreeningJob::new(cfg);
    let mut write_ok = true;
    let mut on_result = |tr: TargetResult| {
        if !write_ok {
            return;
        }
        let j = protocol::screen_target_response(id, tr.index, &tr.smiles, &tr.result);
        write_ok = writer.write_all(j.to_string().as_bytes()).is_ok()
            && writer.write_all(b"\n").is_ok()
            && writer.flush().is_ok();
    };
    let res = ctx.metrics.time("request.screen", || {
        job.run(&ctx.hub, &ctx.stock, &targets, &ctx.metrics, &mut on_result)
    });
    match res {
        Ok(s) => protocol::screen_summary_response(id, &s),
        Err(e) => protocol::error_response(id, &format!("{e:#}")),
    }
}

/// Blocking client helper (used by examples/tests/benches).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: i64,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// Send a request object (id is filled in) and wait for the reply.
    pub fn call(&mut self, mut req: Json) -> Result<Json> {
        let id = self.next_id;
        self.next_id += 1;
        if let Json::Obj(ref mut o) = req {
            o.insert("id".into(), Json::num(id as f64));
        }
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    /// Send a request whose response streams (the `screen` op) and
    /// collect every line through the terminal one (`event == "done"`
    /// or `ok == false`).
    pub fn call_stream(&mut self, mut req: Json) -> Result<Vec<Json>> {
        let id = self.next_id;
        self.next_id += 1;
        if let Json::Obj(ref mut o) = req {
            o.insert("id".into(), Json::num(id as f64));
        }
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut out = Vec::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                anyhow::bail!("connection closed mid-stream");
            }
            let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
            let done = j.get("event").and_then(|e| e.as_str()) == Some("done")
                || j.get("ok").and_then(|o| o.as_bool()) == Some(false);
            out.push(j);
            if done {
                return Ok(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};
    use crate::tokenizer::Vocab;

    fn test_ctx() -> ServerCtx {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        let metrics = Arc::new(Metrics::new());
        let hub = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig::default(),
            metrics.clone(),
        );
        ServerCtx {
            hub,
            stock: Arc::new(Stock::from_iter([
                crate::chem::canonicalize("CC(=O)O").unwrap(),
                crate::chem::canonicalize("CN").unwrap(),
            ])),
            metrics,
            default_limits: SearchLimits {
                deadline: std::time::Duration::from_millis(500),
                max_iterations: 50,
                max_depth: 3,
                expansions_per_step: 5,
                ..Default::default()
            },
            default_algo: "retrostar".into(),
            default_beam_width: 1,
            default_spec_depth: 1,
            default_spec_adaptive: false,
            default_spec_max: 8,
            screen: ScreenDefaults::default(),
        }
    }

    #[test]
    fn ping_and_unknown_op() {
        let ctx = test_ctx();
        let r = handle_line("{\"id\":1,\"op\":\"ping\"}", &ctx);
        assert_eq!(r.get("pong").unwrap().as_bool(), Some(true));
        let r = handle_line("{\"id\":2,\"op\":\"nope\"}", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        let r = handle_line("not json", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn expand_via_protocol() {
        let ctx = test_ctx();
        let r = handle_line("{\"id\":1,\"op\":\"expand\",\"smiles\":\"CC(=O)O.CN\",\"k\":3}", &ctx);
        // multi-fragment input is rejected at canonicalization
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        let r = handle_line("{\"id\":2,\"op\":\"expand\",\"smiles\":\"CC(=O)NC\",\"k\":3}", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert!(r.get("proposals").unwrap().as_arr().is_some());
    }

    #[test]
    fn plan_via_tcp_roundtrip() {
        let ctx = test_ctx();
        let server = Server::start("127.0.0.1:0", ctx).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let pong = client.call(Json::obj(vec![("op", Json::str("ping"))])).unwrap();
        assert_eq!(pong.get("pong").unwrap().as_bool(), Some(true));
        let plan = client
            .call(Json::obj(vec![
                ("op", Json::str("plan")),
                ("smiles", Json::str("CC(=O)NC")),
                ("deadline_ms", Json::num(300.0)),
            ]))
            .unwrap();
        assert_eq!(plan.get("ok").unwrap().as_bool(), Some(true), "{plan:?}");
        // mock model cannot really plan; solved may be false — shape is
        // what matters here
        assert!(plan.get("solved").is_some());
        let m = client.call(Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
        assert!(m.get("counters").is_some());
        server.shutdown();
    }

    #[test]
    fn plan_accepts_spec_depth() {
        let ctx = test_ctx();
        let r = handle_line(
            "{\"id\":1,\"op\":\"plan\",\"smiles\":\"CC(=O)NC\",\"deadline_ms\":200,\
             \"spec_depth\":4}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert!(r.get("speculation").is_some(), "plan response must report speculation");
    }

    #[test]
    fn plan_accepts_spec_depth_auto() {
        let ctx = test_ctx();
        let r = handle_line(
            "{\"id\":1,\"op\":\"plan\",\"smiles\":\"CC(=O)NC\",\"deadline_ms\":200,\
             \"spec_depth\":\"auto\"}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        let spec = r.get("speculation").expect("speculation reported");
        assert!(
            spec.get("depth_trajectory").and_then(|t| t.as_arr()).is_some(),
            "adaptive plans must report the depth trajectory: {spec:?}"
        );
    }

    #[test]
    fn plan_reports_stop_reason_over_protocol() {
        let ctx = test_ctx();
        // An expired deadline answers within one scheduler tick with the
        // `deadline` stop reason — not an error, not a hang.
        let r = handle_line(
            "{\"id\":1,\"op\":\"plan\",\"smiles\":\"CC(=O)NCC\",\"deadline_ms\":0}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert_eq!(r.get("solved").unwrap().as_bool(), Some(false));
        assert_eq!(r.get("stop_reason").unwrap().as_str(), Some("deadline"));
        assert_eq!(ctx.metrics.counter("plan.stop.deadline"), 1);
        // A request-level expansion budget stops with `budget` and still
        // reports full statistics.
        let r = handle_line(
            "{\"id\":2,\"op\":\"plan\",\"smiles\":\"CC(=O)NCC\",\"deadline_ms\":2000,\
             \"max_expansions\":1}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        let reason = r.get("stop_reason").unwrap().as_str().unwrap().to_string();
        assert!(
            reason == "budget" || reason == "solved",
            "1-expansion budget must trip unless the mock solves instantly: {r:?}"
        );
        assert!(r.get("expansions").unwrap().as_usize().unwrap_or(99) <= 1, "{r:?}");
    }

    #[test]
    fn screen_streams_per_target_then_summary() {
        let ctx = test_ctx();
        let server = Server::start("127.0.0.1:0", ctx).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let lines = client
            .call_stream(Json::obj(vec![
                ("op", Json::str("screen")),
                (
                    "targets",
                    Json::Arr(vec![Json::str("CC(=O)NC"), Json::str("CC(=O)NC")]),
                ),
                ("deadline_ms", Json::num(300.0)),
                ("concurrency", Json::num(2.0)),
            ]))
            .unwrap();
        assert_eq!(lines.len(), 3, "2 target lines + 1 summary: {lines:?}");
        for l in &lines[..2] {
            assert_eq!(l.get("ok").unwrap().as_bool(), Some(true), "{l:?}");
            assert_eq!(l.get("event").unwrap().as_str(), Some("target"));
            assert_eq!(l.get("target").unwrap().as_str(), Some("CC(=O)NC"));
            assert!(l.get("stop_reason").is_some());
        }
        let done = &lines[2];
        assert_eq!(done.get("event").unwrap().as_str(), Some("done"));
        assert_eq!(done.get("targets").unwrap().as_i64(), Some(2));
        assert!(done.get("cache_hit_rate").is_some());
        // Both indices streamed, in some completion order.
        let mut idx: Vec<i64> = lines[..2]
            .iter()
            .map(|l| l.get("index").unwrap().as_i64().unwrap())
            .collect();
        idx.sort_unstable();
        assert_eq!(idx, vec![0, 1]);
        server.shutdown();
    }

    #[test]
    fn screen_rejects_missing_targets_and_handle_line_hints() {
        let ctx = test_ctx();
        let server = Server::start("127.0.0.1:0", ctx).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let lines = client
            .call_stream(Json::obj(vec![("op", Json::str("screen"))]))
            .unwrap();
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].get("ok").unwrap().as_bool(), Some(false));
        server.shutdown();
        // Direct handle_line use gets a hint, not a hang.
        let ctx = test_ctx();
        let r = handle_line("{\"id\":1,\"op\":\"screen\",\"targets\":[\"CCO\"]}", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("stream"));
    }

    #[test]
    fn concurrent_clients() {
        let ctx = test_ctx();
        let server = Server::start("127.0.0.1:0", ctx).unwrap();
        let addr = server.addr();
        let mut joins = Vec::new();
        for _ in 0..3 {
            joins.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let r = c
                    .call(Json::obj(vec![
                        ("op", Json::str("expand")),
                        ("smiles", Json::str("CC(=O)NC")),
                    ]))
                    .unwrap();
                r.get("ok").unwrap().as_bool()
            }));
        }
        for j in joins {
            assert_eq!(j.join().unwrap(), Some(true));
        }
        server.shutdown();
    }
}
