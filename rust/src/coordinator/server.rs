//! TCP server: thread-per-connection over the line-delimited JSON
//! protocol, planning sessions sharing the expansion hub.
//!
//! Every connection and request passes through the
//! [`OverloadController`]: connections beyond `max_sessions` and
//! requests beyond the queue watermarks receive structured shed
//! responses, requests admitted above the load watermark run with
//! clamped effort (`degraded: true`), and shutdown drains — in-flight
//! solves get a fenced deadline and return anytime partials before the
//! listener, connection threads and session slots are all reclaimed.

use super::batcher::{BatchedPolicy, ExpansionHub};
use super::overload::{Admission, OverloadConfig, OverloadController};
use super::protocol;
use crate::jsonx::Json;
use crate::metrics::Metrics;
use crate::search::{
    dfs::Dfs, retrostar::RetroStar, Planner, ScreenConfig, ScreeningJob, SearchLimits, Stock,
    TargetResult,
};
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One tracked connection: the stream (force-closed at shutdown so a
/// reader blocked in `lines()` wakes), the thread handle (joined at
/// shutdown) and a completion flag (lets the accept loop reap finished
/// entries without joining live ones).
struct ConnEntry {
    stream: TcpStream,
    join: Option<std::thread::JoinHandle<()>>,
    done: Arc<AtomicBool>,
}

/// A running coordinator server.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<ConnEntry>>>,
    overload: Arc<OverloadController>,
}

/// Everything a connection handler needs.
pub struct ServerCtx {
    pub hub: Arc<ExpansionHub>,
    pub stock: Arc<Stock>,
    pub metrics: Arc<Metrics>,
    pub default_limits: SearchLimits,
    pub default_algo: String,
    pub default_beam_width: usize,
    /// Default in-flight expansion depth for pipelined Retro\* (1 =
    /// sequential selection; requests may override via `spec_depth`,
    /// either an integer or `"auto"`). When `default_spec_adaptive` is
    /// set this is the adaptive controller's max depth.
    pub default_spec_depth: usize,
    /// `planner.spec_depth = "auto"`: adapt depth to the observed
    /// speculation apply-rate.
    pub default_spec_adaptive: bool,
    /// Adaptive-depth cap (`planner.spec_depth_max`), used when either
    /// the server default or the request selects `"auto"`.
    pub default_spec_max: usize,
    /// Defaults for the `screen` op (config `planner.screen_*`).
    pub screen: ScreenDefaults,
    /// Overload protection: admission control, the degradation ladder
    /// and drain state. `Default` is fully inert (no session bound, no
    /// shedding, watermarks unreachable at zero load).
    pub overload: Arc<OverloadController>,
    /// Persistent expansion/route store (the cache's L2 tier). The
    /// same handle the hub shards were started with: the server uses
    /// it to persist solved plan/screen routes and to answer the
    /// `routes` op. `None` = memory-only serving, exactly as before
    /// the store existed.
    pub store: Option<Arc<crate::store::ExpansionStore>>,
}

/// Server-side defaults for bulk screening jobs; requests may override
/// each field (`concurrency`, `job_deadline_ms`, `job_max_decode_tokens`).
#[derive(Clone, Copy, Debug)]
pub struct ScreenDefaults {
    /// Targets planned concurrently per job.
    pub concurrency: usize,
    /// Per-job wall-clock budget, ms (0 = off).
    pub job_deadline_ms: u64,
    /// Per-job decode-token cap (0 = off).
    pub job_decode_tokens: u64,
}

impl Default for ScreenDefaults {
    fn default() -> Self {
        Self { concurrency: 8, job_deadline_ms: 0, job_decode_tokens: 0 }
    }
}

impl Server {
    /// Bind and start serving on a background thread. Use port 0 for an
    /// ephemeral port (tests); `addr()` reports the bound address.
    pub fn start(listen: &str, ctx: ServerCtx) -> Result<Server> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let overload = ctx.overload.clone();
        let overload2 = overload.clone();
        let conns: Arc<Mutex<Vec<ConnEntry>>> = Arc::new(Mutex::new(Vec::new()));
        let conns2 = conns.clone();
        let ctx = Arc::new(ctx);
        let join = std::thread::Builder::new()
            .name("coordinator-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // The listener is nonblocking; make sure the
                            // accepted socket is not (platform-dependent
                            // inheritance), or blocking reads would spin.
                            let _ = stream.set_nonblocking(false);
                            reap_finished(&conns2);
                            if overload2.is_draining() {
                                deny(stream, protocol::draining_response(-1));
                                continue;
                            }
                            if !overload2.try_acquire_session() {
                                ctx.metrics.inc("serve.shed.sessions", 1);
                                deny(
                                    stream,
                                    protocol::shed_response(-1, overload2.cfg.retry_after_ms),
                                );
                                continue;
                            }
                            let tracked = match stream.try_clone() {
                                Ok(t) => t,
                                Err(_) => {
                                    overload2.release_session();
                                    continue;
                                }
                            };
                            let ctx = ctx.clone();
                            let ov = overload2.clone();
                            let done = Arc::new(AtomicBool::new(false));
                            let done2 = done.clone();
                            let spawned = std::thread::Builder::new()
                                .name("coordinator-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(stream, &ctx);
                                    ov.release_session();
                                    done2.store(true, Ordering::SeqCst);
                                });
                            match spawned {
                                Ok(join) => conns2
                                    .lock()
                                    .unwrap_or_else(|p| p.into_inner())
                                    .push(ConnEntry { stream: tracked, join: Some(join), done }),
                                Err(_) => overload2.release_session(),
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        // Transient accept failures (interrupted, a
                        // connection that reset before accept completed)
                        // must not kill the listener.
                        Err(e) if accept_error_is_transient(e.kind()) => {
                            ctx.metrics.inc("serve.accept_transient", 1);
                        }
                        // Anything else means the listener itself is gone
                        // — exit instead of sleep-spinning on the error.
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr, stop, join: Some(join), conns, overload })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// True once a drain was requested (the `drain` protocol op or a
    /// local shutdown); serve loops poll this to exit cleanly.
    pub fn draining(&self) -> bool {
        self.overload.is_draining()
    }

    /// Drain-clean shutdown: stop accepting, fence in-flight solves'
    /// deadlines (they return anytime partials via the budget path),
    /// wait for them bounded by the drain window, then force-close and
    /// join every connection thread. Idempotent via `Drop`.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        let drain_deadline = self.overload.begin_drain(Instant::now());
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        // Let in-flight requests finish writing their responses. The
        // fence guarantees solves stop by the drain deadline; the extra
        // slack covers response serialization and a wedged model tick,
        // after which we force-close rather than hang shutdown forever.
        let hard_cap = drain_deadline + Duration::from_secs(5);
        while self.overload.inflight() > 0 && Instant::now() < hard_cap {
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut conns = self.conns.lock().unwrap_or_else(|p| p.into_inner());
        // Close first: readers blocked in `lines()` wake with EOF, so
        // the joins below cannot hang on an idle client.
        for entry in conns.iter() {
            let _ = entry.stream.shutdown(std::net::Shutdown::Both);
        }
        for entry in conns.iter_mut() {
            if let Some(j) = entry.join.take() {
                let _ = j.join();
            }
        }
        conns.clear();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.do_shutdown();
    }
}

/// Accept errors that should be retried rather than treated as a dead
/// listener.
fn accept_error_is_transient(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::Interrupted
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::TimedOut
    )
}

/// Refuse a connection with one structured line, then drop it.
fn deny(mut stream: TcpStream, response: Json) {
    let _ = stream.write_all(response.to_string().as_bytes());
    let _ = stream.write_all(b"\n");
    let _ = stream.flush();
}

/// Drop entries whose connection thread already finished (joining a
/// finished thread is immediate), so long-lived servers do not grow the
/// registry without bound.
fn reap_finished(conns: &Mutex<Vec<ConnEntry>>) {
    let mut conns = conns.lock().unwrap_or_else(|p| p.into_inner());
    conns.retain_mut(|entry| {
        if entry.done.load(Ordering::SeqCst) {
            if let Some(j) = entry.join.take() {
                let _ = j.join();
            }
            false
        } else {
            true
        }
    });
}

fn handle_connection(stream: TcpStream, ctx: &ServerCtx) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        // `screen` is the one streaming op: many lines per request, so
        // it writes directly instead of going through handle_line's
        // one-request-one-response shape.
        let is_screen = Json::parse(&line)
            .ok()
            .and_then(|j| j.get("op").and_then(|o| o.as_str()).map(|o| o == "screen"))
            .unwrap_or(false);
        if is_screen {
            handle_screen(&line, ctx, &mut writer)?;
            continue;
        }
        let response = handle_line(&line, ctx);
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    let _ = peer;
    Ok(())
}

/// Dispatch one request line to a response (exposed for direct testing).
pub fn handle_line(line: &str, ctx: &ServerCtx) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return protocol::error_response(-1, &format!("bad json: {e}")),
    };
    let id = req.get("id").and_then(|x| x.as_i64()).unwrap_or(-1);
    let op = req.get("op").and_then(|x| x.as_str()).unwrap_or("");
    ctx.metrics.inc(&format!("op.{op}"), 1);
    match op {
        "ping" => Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("ok", Json::Bool(true)),
            ("pong", Json::Bool(true)),
        ]),
        "metrics" => {
            let mut m = ctx.metrics.snapshot();
            if let Json::Obj(ref mut o) = m {
                o.insert("id".into(), Json::num(id as f64));
                o.insert("ok".into(), Json::Bool(true));
                let (batches, merged) = ctx.hub.merge_ratio();
                o.insert("batcher_batches".into(), Json::num(batches as f64));
                o.insert("batcher_merged".into(), Json::num(merged as f64));
                let (fused_calls, fused_rows) = ctx.hub.fused_ratio();
                o.insert("batcher_fused_calls".into(), Json::num(fused_calls as f64));
                o.insert("batcher_fused_rows".into(), Json::num(fused_rows as f64));
                o.insert("batcher_shards".into(), Json::num(ctx.hub.shard_count() as f64));
                o.insert(
                    "batcher_dedup_joins".into(),
                    Json::num(ctx.hub.dedup_joins() as f64),
                );
                let (spills, steals) = ctx.hub.steal_stats();
                o.insert("batcher_steal_spills".into(), Json::num(spills as f64));
                o.insert("batcher_steals".into(), Json::num(steals as f64));
                let replicas = ctx.hub.replica_stats();
                o.insert("model_replicas".into(), Json::num(replicas.len() as f64));
                o.insert(
                    "model_replicas_alive".into(),
                    Json::num(replicas.iter().filter(|r| r.alive).count() as f64),
                );
                o.insert(
                    "model_replica_deaths".into(),
                    Json::num(ctx.hub.replica_deaths() as f64),
                );
            }
            m
        }
        "healthz" => {
            let replicas = ctx.hub.replica_stats();
            let alive = replicas.iter().filter(|r| r.alive).count();
            let draining = ctx.overload.is_draining();
            Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("ok", Json::Bool(true)),
                ("alive", Json::num(alive as f64)),
                ("replicas", Json::num(replicas.len() as f64)),
                ("load", Json::num(ctx.hub.load_score())),
                ("queued", Json::num(ctx.hub.queued_requests() as f64)),
                ("sessions", Json::num(ctx.overload.sessions() as f64)),
                ("inflight", Json::num(ctx.overload.inflight() as f64)),
                ("degraded", Json::Bool(ctx.overload.is_degraded())),
                ("draining", Json::Bool(draining)),
                // Readiness for load balancers: route traffic here only
                // while the server accepts work and can serve a model.
                ("ready", Json::Bool(!draining && alive > 0)),
            ])
        }
        "drain" => {
            ctx.overload.begin_drain(Instant::now());
            ctx.metrics.inc("serve.drain", 1);
            Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("ok", Json::Bool(true)),
                ("draining", Json::Bool(true)),
                ("drain_ms", Json::num(ctx.overload.cfg.drain_ms as f64)),
            ])
        }
        "expand" => {
            let _guard = ctx.overload.request_begin();
            match admit_request(ctx, false) {
                Admission::Shed { retry_after_ms } => {
                    return protocol::shed_response(id, retry_after_ms)
                }
                Admission::Draining => return protocol::draining_response(id),
                Admission::Admit { .. } => {}
            }
            let Some(smiles) = req.get("smiles").and_then(|x| x.as_str()) else {
                return protocol::error_response(id, "missing smiles");
            };
            let k = req.get("k").and_then(|x| x.as_usize()).unwrap_or(10);
            let canonical = match crate::chem::canonicalize(smiles) {
                Ok(c) => c,
                Err(e) => return protocol::error_response(id, &format!("bad smiles: {e}")),
            };
            match ctx
                .metrics
                .time("request.expand", || ctx.hub.expand(&canonical, k))
            {
                Ok(p) => protocol::expand_response(id, &p),
                Err(e) => protocol::error_response(id, &format!("{e:#}")),
            }
        }
        "plan" => {
            let _guard = ctx.overload.request_begin();
            let degraded = match admit_request(ctx, false) {
                Admission::Shed { retry_after_ms } => {
                    return protocol::shed_response(id, retry_after_ms)
                }
                Admission::Draining => return protocol::draining_response(id),
                Admission::Admit { degraded } => degraded,
            };
            let Some(smiles) = req.get("smiles").and_then(|x| x.as_str()) else {
                return protocol::error_response(id, "missing smiles");
            };
            let mut limits = limits_from_req(&req, &ctx.default_limits);
            // Every admitted solve shares the drain fence, so a later
            // shutdown tightens its deadline mid-flight.
            limits.fence = ctx.overload.fence();
            let algo = req
                .get("algo")
                .and_then(|x| x.as_str())
                .unwrap_or(&ctx.default_algo)
                .to_string();
            let mut bw = req
                .get("beam_width")
                .and_then(|x| x.as_usize())
                .unwrap_or(ctx.default_beam_width);
            let (mut sd, mut sd_auto) = spec_from_req(&req, ctx);
            if degraded {
                let (dbw, dsd, dsd_auto, ddl) =
                    degrade_clamps(&ctx.overload.cfg, bw, limits.deadline);
                bw = dbw;
                sd = dsd;
                sd_auto = dsd_auto;
                limits.deadline = ddl;
                ctx.metrics.inc("serve.degrade.plans", 1);
            }
            let policy = BatchedPolicy::new(ctx.hub.clone());
            // Retro* plans ride the async path: per-query expansion
            // futures into the hub's scheduler. spec_depth = 1 keeps
            // sequential selection semantics (pinned bit-identical by
            // the parity suite); deeper keeps that many expansion
            // groups in flight speculatively.
            let result = match algo.as_str() {
                "dfs" => ctx
                    .metrics
                    .time("request.plan", || Dfs.solve(smiles, &policy, &ctx.stock, &limits)),
                "retrostar" | "retro*" => ctx.metrics.time("request.plan", || {
                    let rs = if sd_auto {
                        RetroStar::new(bw).with_adaptive_spec_depth(sd)
                    } else {
                        RetroStar::new(bw).with_spec_depth(sd)
                    };
                    rs.solve_pipelined(smiles, &policy, &ctx.stock, &limits)
                }),
                other => return protocol::error_response(id, &format!("unknown algo {other}")),
            };
            match result {
                Ok(r) => {
                    ctx.metrics.inc(if r.solved { "plan.solved" } else { "plan.unsolved" }, 1);
                    ctx.metrics.inc(&format!("plan.stop.{}", r.stop_reason), 1);
                    ctx.metrics.gauge_max("plan.spec_in_flight", r.spec.max_in_flight);
                    ctx.metrics.inc("plan.spec_submitted", r.spec.groups_submitted);
                    ctx.metrics.inc("plan.spec_cancelled", r.spec.groups_cancelled);
                    ctx.metrics.inc("plan.spec_hits", r.spec.spec_hits);
                    if let (Some(store), Some(route)) = (&ctx.store, r.route.as_ref()) {
                        if r.solved {
                            // Persist the solved route (memory merge +
                            // flusher-thread write-behind) so warm
                            // restarts and the `routes` op can serve it.
                            store.put_route(smiles, route);
                        }
                    }
                    let mut resp = protocol::plan_response(id, &r);
                    // The key is present only on degraded admissions, so
                    // full-effort responses stay byte-identical (pinned).
                    if degraded {
                        if let Json::Obj(ref mut o) = resp {
                            o.insert("degraded".into(), Json::Bool(true));
                        }
                    }
                    resp
                }
                Err(e) => protocol::error_response(id, &format!("{e:#}")),
            }
        }
        // Streaming op: handled by `handle_screen` upstream of this
        // dispatcher; reachable here only when called directly.
        "screen" => protocol::error_response(
            id,
            "screen streams multiple response lines; send it over a connection",
        ),
        "routes" => {
            let Some(store) = &ctx.store else {
                return protocol::error_response(id, "no persistent store (cache.path unset)");
            };
            let Some(target) = req.get("smiles").and_then(|x| x.as_str()) else {
                return protocol::error_response(id, "missing smiles");
            };
            // Keyed exactly as the store keys writes, so any spelling
            // of the molecule finds its persisted routes.
            let key = crate::chem::cache_key(target);
            protocol::routes_response(id, &key, &store.routes(target))
        }
        other => protocol::error_response(id, &format!("unknown op {other:?}")),
    }
}

/// One admission decision against the hub's live queue probes; bumps
/// the serving gauges and shed/degrade counters as a side effect.
/// `batch` marks the batch/screen class, which sheds first.
fn admit_request(ctx: &ServerCtx, batch: bool) -> Admission {
    let queued = ctx.hub.queued_requests();
    let load = ctx.hub.load_score();
    ctx.metrics.gauge_set("serve.queue_depth", queued as u64);
    ctx.metrics.gauge_set("serve.load_x1000", (load * 1000.0) as u64);
    let adm = ctx.overload.admit(load, queued, batch);
    match adm {
        Admission::Shed { .. } => ctx
            .metrics
            .inc(if batch { "serve.shed.batch" } else { "serve.shed.interactive" }, 1),
        Admission::Draining => ctx.metrics.inc("serve.shed.draining", 1),
        Admission::Admit { .. } => {}
    }
    adm
}

/// Effort clamps for a degraded admission: beam width down to the
/// configured floor, speculation back to sequential, and (when
/// `degraded_deadline_ms` is set) a tighter implicit deadline. Pure —
/// the ladder's effect on NEW requests is unit-testable without a hub,
/// and in-flight requests are untouched by construction (clamps apply
/// only at admission). Returns `(beam_width, spec_depth, spec_auto,
/// deadline)`.
fn degrade_clamps(
    cfg: &OverloadConfig,
    bw: usize,
    deadline: Duration,
) -> (usize, usize, bool, Duration) {
    let bw = bw.min(cfg.degraded_beam.max(1)).max(1);
    let deadline = if cfg.degraded_deadline_ms > 0 {
        deadline.min(Duration::from_millis(cfg.degraded_deadline_ms))
    } else {
        deadline
    };
    (bw, 1, false, deadline)
}

/// Apply a request's shared per-target limit overrides onto the server
/// defaults (used by both `plan` and `screen`).
fn limits_from_req(req: &Json, base: &SearchLimits) -> SearchLimits {
    let mut limits = base.clone();
    if let Some(ms) = req.get("deadline_ms").and_then(|x| x.as_usize()) {
        limits.deadline = std::time::Duration::from_millis(ms as u64);
    }
    if let Some(d) = req.get("max_depth").and_then(|x| x.as_usize()) {
        limits.max_depth = d;
    }
    if let Some(k) = req.get("k").and_then(|x| x.as_usize()) {
        limits.expansions_per_step = k;
    }
    // Per-request work budget (0/absent = server default).
    if let Some(n) = req.get("max_expansions").and_then(|x| x.as_usize()) {
        limits.max_expansions = n;
    }
    if let Some(n) = req.get("max_decode_tokens").and_then(|x| x.as_usize()) {
        limits.max_decode_tokens = n as u64;
    }
    limits
}

/// `spec_depth` accepts an integer or "auto" (adaptive up to the
/// server's configured max depth). Returns `(depth, adaptive)`.
fn spec_from_req(req: &Json, ctx: &ServerCtx) -> (usize, bool) {
    match req.get("spec_depth") {
        Some(v) if v.as_str() == Some("auto") => (ctx.default_spec_max.max(1), true),
        Some(v) => (v.as_usize().unwrap_or(ctx.default_spec_depth).max(1), false),
        None => (ctx.default_spec_depth.max(1), ctx.default_spec_adaptive),
    }
}

/// Handle one `screen` request: stream a `target` line per completed
/// target in completion order, then the terminal `done` (or error)
/// line. Write failures stop the streaming but let the job drain.
pub fn handle_screen(line: &str, ctx: &ServerCtx, writer: &mut dyn Write) -> Result<()> {
    let final_line = run_screen(line, ctx, writer);
    writer.write_all(final_line.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

fn run_screen(line: &str, ctx: &ServerCtx, writer: &mut dyn Write) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return protocol::error_response(-1, &format!("bad json: {e}")),
    };
    let id = req.get("id").and_then(|x| x.as_i64()).unwrap_or(-1);
    ctx.metrics.inc("op.screen", 1);
    // Screening is batch-class: it sheds at half the interactive
    // threshold and degrades under the same ladder.
    let _guard = ctx.overload.request_begin();
    let degraded = match admit_request(ctx, true) {
        Admission::Shed { retry_after_ms } => return protocol::shed_response(id, retry_after_ms),
        Admission::Draining => return protocol::draining_response(id),
        Admission::Admit { degraded } => degraded,
    };
    let Some(arr) = req.get("targets").and_then(|t| t.as_arr()) else {
        return protocol::error_response(id, "missing targets");
    };
    let targets: Vec<String> = arr
        .iter()
        .filter_map(|t| t.as_str().map(String::from))
        .collect();
    if targets.is_empty() {
        return protocol::error_response(id, "empty targets");
    }
    let concurrency = req
        .get("concurrency")
        .and_then(|x| x.as_usize())
        .unwrap_or(ctx.screen.concurrency)
        .max(1);
    let job_deadline_ms = req
        .get("job_deadline_ms")
        .and_then(|x| x.as_usize())
        .map(|n| n as u64)
        .unwrap_or(ctx.screen.job_deadline_ms);
    let job_decode_tokens = req
        .get("job_max_decode_tokens")
        .and_then(|x| x.as_usize())
        .map(|n| n as u64)
        .unwrap_or(ctx.screen.job_decode_tokens);
    let (mut sd, mut sd_auto) = spec_from_req(&req, ctx);
    let mut beam_width = req
        .get("beam_width")
        .and_then(|x| x.as_usize())
        .unwrap_or(ctx.default_beam_width);
    let mut limits = limits_from_req(&req, &ctx.default_limits);
    limits.fence = ctx.overload.fence();
    if degraded {
        let (dbw, dsd, dsd_auto, ddl) =
            degrade_clamps(&ctx.overload.cfg, beam_width, limits.deadline);
        beam_width = dbw;
        sd = dsd;
        sd_auto = dsd_auto;
        limits.deadline = ddl;
        ctx.metrics.inc("serve.degrade.screens", 1);
    }
    let cfg = ScreenConfig {
        concurrency,
        job_deadline: (job_deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(job_deadline_ms)),
        job_decode_tokens,
        beam_width,
        spec_depth: sd,
        spec_adaptive: sd_auto,
        limits,
    };
    let warm = req.get("warm").and_then(|x| x.as_bool()).unwrap_or(false);
    let mut job = ScreeningJob::new(cfg);
    if let Some(store) = &ctx.store {
        job = job.with_store(store.clone()).warm_start(warm);
    }
    let mut write_ok = true;
    let mut on_result = |tr: TargetResult| {
        if !write_ok {
            return;
        }
        let j = protocol::screen_target_response(id, tr.index, &tr.smiles, &tr.result);
        write_ok = writer.write_all(j.to_string().as_bytes()).is_ok()
            && writer.write_all(b"\n").is_ok()
            && writer.flush().is_ok();
    };
    let res = ctx.metrics.time("request.screen", || {
        job.run(&ctx.hub, &ctx.stock, &targets, &ctx.metrics, &mut on_result)
    });
    match res {
        Ok(s) => {
            let mut resp = protocol::screen_summary_response(id, &s);
            if degraded {
                if let Json::Obj(ref mut o) = resp {
                    o.insert("degraded".into(), Json::Bool(true));
                }
            }
            resp
        }
        Err(e) => protocol::error_response(id, &format!("{e:#}")),
    }
}

/// Blocking client helper (used by examples/tests/benches).
pub struct Client {
    addr: std::net::SocketAddr,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: i64,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { addr, reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// As [`Client::connect`], with up to `attempts` tries under
    /// exponential backoff plus deterministic jitter (seeded from the
    /// target port so concurrent clients do not retry in lockstep).
    /// Covers transient connect failures AND session-slot sheds: a
    /// server that answers `code:"overloaded"` on accept closes the
    /// connection, which surfaces here as an early EOF on first use —
    /// so the shed line is consumed eagerly and converted to a retry.
    pub fn connect_retry(addr: std::net::SocketAddr, attempts: u32) -> Result<Client> {
        let mut rng = crate::util::Rng::new(0xC0FFEE ^ addr.port() as u64);
        let mut backoff_ms = 10u64;
        let mut last_err: Option<anyhow::Error> = None;
        for _ in 0..attempts.max(1) {
            match Self::connect(addr) {
                Ok(mut c) => {
                    // A sheds-on-accept server writes one refusal line
                    // before closing; probe for it without blocking a
                    // healthy connection (ping is answered by every
                    // non-shed server).
                    match c.call(Json::obj(vec![("op", Json::str("ping"))])) {
                        Ok(resp) => {
                            let code = resp.get("code").and_then(|x| x.as_str());
                            match code {
                                Some("overloaded") => {
                                    let wait = resp
                                        .get("retry_after_ms")
                                        .and_then(|x| x.as_usize())
                                        .unwrap_or(backoff_ms as usize)
                                        as u64;
                                    last_err = Some(anyhow::anyhow!("connection shed: overloaded"));
                                    std::thread::sleep(Duration::from_millis(
                                        wait.min(1_000) + rng.gen_range(10) as u64,
                                    ));
                                }
                                Some("draining") => {
                                    anyhow::bail!("server draining; not retryable here")
                                }
                                _ => return Ok(c),
                            }
                        }
                        Err(e) => {
                            last_err = Some(e);
                            std::thread::sleep(Duration::from_millis(
                                backoff_ms + rng.gen_range(10) as u64,
                            ));
                        }
                    }
                }
                Err(e) => {
                    last_err = Some(e);
                    std::thread::sleep(Duration::from_millis(
                        backoff_ms + rng.gen_range(10) as u64,
                    ));
                }
            }
            backoff_ms = (backoff_ms * 2).min(500);
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("connect failed")))
    }

    /// As [`Client::call`], with bounded resilience: transport errors
    /// reconnect and retry under jittered exponential backoff, and an
    /// `overloaded` reply honors its `retry_after_ms` hint. A
    /// `draining` reply returns as-is (retrying the same server is
    /// pointless — it is shutting down), as does any other structured
    /// answer.
    pub fn call_retry(&mut self, req: Json, max_retries: u32) -> Result<Json> {
        let mut rng = crate::util::Rng::new(0xBACC0FF ^ self.addr.port() as u64);
        let mut backoff_ms = 10u64;
        let mut attempt = 0u32;
        loop {
            match self.call(req.clone()) {
                Ok(resp) => {
                    let code = resp.get("code").and_then(|x| x.as_str());
                    if code == Some("overloaded") && attempt < max_retries {
                        attempt += 1;
                        let wait = resp
                            .get("retry_after_ms")
                            .and_then(|x| x.as_usize())
                            .unwrap_or(backoff_ms as usize) as u64;
                        std::thread::sleep(Duration::from_millis(
                            wait.min(1_000) + rng.gen_range(10) as u64,
                        ));
                        backoff_ms = (backoff_ms * 2).min(500);
                        continue;
                    }
                    return Ok(resp);
                }
                Err(e) => {
                    if attempt >= max_retries {
                        return Err(e);
                    }
                    attempt += 1;
                    std::thread::sleep(Duration::from_millis(
                        backoff_ms + rng.gen_range(10) as u64,
                    ));
                    backoff_ms = (backoff_ms * 2).min(500);
                    // Reconnect; a dead server fails here and the next
                    // loop iteration either retries or gives up.
                    if let Ok(fresh) = Client::connect(self.addr) {
                        *self = fresh;
                    }
                }
            }
        }
    }

    /// Send a request object (id is filled in) and wait for the reply.
    pub fn call(&mut self, mut req: Json) -> Result<Json> {
        let id = self.next_id;
        self.next_id += 1;
        if let Json::Obj(ref mut o) = req {
            o.insert("id".into(), Json::num(id as f64));
        }
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    /// Send a request whose response streams (the `screen` op) and
    /// collect every line through the terminal one (`event == "done"`
    /// or `ok == false`).
    pub fn call_stream(&mut self, mut req: Json) -> Result<Vec<Json>> {
        let id = self.next_id;
        self.next_id += 1;
        if let Json::Obj(ref mut o) = req {
            o.insert("id".into(), Json::num(id as f64));
        }
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut out = Vec::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                anyhow::bail!("connection closed mid-stream");
            }
            let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
            let done = j.get("event").and_then(|e| e.as_str()) == Some("done")
                || j.get("ok").and_then(|o| o.as_bool()) == Some(false);
            out.push(j);
            if done {
                return Ok(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};
    use crate::tokenizer::Vocab;

    fn test_ctx() -> ServerCtx {
        let vocab = Vocab::build(["CC(=O)O.CN", "CC(=O)NC", "CCO"]);
        let model = MockModel::new(MockConfig { vocab: vocab.len(), ..Default::default() });
        let metrics = Arc::new(Metrics::new());
        let hub = ExpansionHub::start(
            model,
            Box::new(BeamSearch::optimized()),
            vocab,
            BatcherConfig::default(),
            metrics.clone(),
        );
        ServerCtx {
            hub,
            stock: Arc::new(Stock::from_iter([
                crate::chem::canonicalize("CC(=O)O").unwrap(),
                crate::chem::canonicalize("CN").unwrap(),
            ])),
            metrics,
            default_limits: SearchLimits {
                deadline: std::time::Duration::from_millis(500),
                max_iterations: 50,
                max_depth: 3,
                expansions_per_step: 5,
                ..Default::default()
            },
            default_algo: "retrostar".into(),
            default_beam_width: 1,
            default_spec_depth: 1,
            default_spec_adaptive: false,
            default_spec_max: 8,
            screen: ScreenDefaults::default(),
            overload: Arc::new(OverloadController::default()),
            store: None,
        }
    }

    #[test]
    fn ping_and_unknown_op() {
        let ctx = test_ctx();
        let r = handle_line("{\"id\":1,\"op\":\"ping\"}", &ctx);
        assert_eq!(r.get("pong").unwrap().as_bool(), Some(true));
        let r = handle_line("{\"id\":2,\"op\":\"nope\"}", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        let r = handle_line("not json", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn expand_via_protocol() {
        let ctx = test_ctx();
        let r = handle_line("{\"id\":1,\"op\":\"expand\",\"smiles\":\"CC(=O)O.CN\",\"k\":3}", &ctx);
        // multi-fragment input is rejected at canonicalization
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        let r = handle_line("{\"id\":2,\"op\":\"expand\",\"smiles\":\"CC(=O)NC\",\"k\":3}", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert!(r.get("proposals").unwrap().as_arr().is_some());
    }

    #[test]
    fn plan_via_tcp_roundtrip() {
        let ctx = test_ctx();
        let server = Server::start("127.0.0.1:0", ctx).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let pong = client.call(Json::obj(vec![("op", Json::str("ping"))])).unwrap();
        assert_eq!(pong.get("pong").unwrap().as_bool(), Some(true));
        let plan = client
            .call(Json::obj(vec![
                ("op", Json::str("plan")),
                ("smiles", Json::str("CC(=O)NC")),
                ("deadline_ms", Json::num(300.0)),
            ]))
            .unwrap();
        assert_eq!(plan.get("ok").unwrap().as_bool(), Some(true), "{plan:?}");
        // mock model cannot really plan; solved may be false — shape is
        // what matters here
        assert!(plan.get("solved").is_some());
        let m = client.call(Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
        assert!(m.get("counters").is_some());
        server.shutdown();
    }

    #[test]
    fn plan_accepts_spec_depth() {
        let ctx = test_ctx();
        let r = handle_line(
            "{\"id\":1,\"op\":\"plan\",\"smiles\":\"CC(=O)NC\",\"deadline_ms\":200,\
             \"spec_depth\":4}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert!(r.get("speculation").is_some(), "plan response must report speculation");
    }

    #[test]
    fn plan_accepts_spec_depth_auto() {
        let ctx = test_ctx();
        let r = handle_line(
            "{\"id\":1,\"op\":\"plan\",\"smiles\":\"CC(=O)NC\",\"deadline_ms\":200,\
             \"spec_depth\":\"auto\"}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        let spec = r.get("speculation").expect("speculation reported");
        assert!(
            spec.get("depth_trajectory").and_then(|t| t.as_arr()).is_some(),
            "adaptive plans must report the depth trajectory: {spec:?}"
        );
    }

    #[test]
    fn plan_reports_stop_reason_over_protocol() {
        let ctx = test_ctx();
        // An expired deadline answers within one scheduler tick with the
        // `deadline` stop reason — not an error, not a hang.
        let r = handle_line(
            "{\"id\":1,\"op\":\"plan\",\"smiles\":\"CC(=O)NCC\",\"deadline_ms\":0}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert_eq!(r.get("solved").unwrap().as_bool(), Some(false));
        assert_eq!(r.get("stop_reason").unwrap().as_str(), Some("deadline"));
        assert_eq!(ctx.metrics.counter("plan.stop.deadline"), 1);
        // A request-level expansion budget stops with `budget` and still
        // reports full statistics.
        let r = handle_line(
            "{\"id\":2,\"op\":\"plan\",\"smiles\":\"CC(=O)NCC\",\"deadline_ms\":2000,\
             \"max_expansions\":1}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        let reason = r.get("stop_reason").unwrap().as_str().unwrap().to_string();
        assert!(
            reason == "budget" || reason == "solved",
            "1-expansion budget must trip unless the mock solves instantly: {r:?}"
        );
        assert!(r.get("expansions").unwrap().as_usize().unwrap_or(99) <= 1, "{r:?}");
    }

    #[test]
    fn screen_streams_per_target_then_summary() {
        let ctx = test_ctx();
        let server = Server::start("127.0.0.1:0", ctx).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let lines = client
            .call_stream(Json::obj(vec![
                ("op", Json::str("screen")),
                (
                    "targets",
                    Json::Arr(vec![Json::str("CC(=O)NC"), Json::str("CC(=O)NC")]),
                ),
                ("deadline_ms", Json::num(300.0)),
                ("concurrency", Json::num(2.0)),
            ]))
            .unwrap();
        assert_eq!(lines.len(), 3, "2 target lines + 1 summary: {lines:?}");
        for l in &lines[..2] {
            assert_eq!(l.get("ok").unwrap().as_bool(), Some(true), "{l:?}");
            assert_eq!(l.get("event").unwrap().as_str(), Some("target"));
            assert_eq!(l.get("target").unwrap().as_str(), Some("CC(=O)NC"));
            assert!(l.get("stop_reason").is_some());
        }
        let done = &lines[2];
        assert_eq!(done.get("event").unwrap().as_str(), Some("done"));
        assert_eq!(done.get("targets").unwrap().as_i64(), Some(2));
        assert!(done.get("cache_hit_rate").is_some());
        // Both indices streamed, in some completion order.
        let mut idx: Vec<i64> = lines[..2]
            .iter()
            .map(|l| l.get("index").unwrap().as_i64().unwrap())
            .collect();
        idx.sort_unstable();
        assert_eq!(idx, vec![0, 1]);
        server.shutdown();
    }

    #[test]
    fn screen_rejects_missing_targets_and_handle_line_hints() {
        let ctx = test_ctx();
        let server = Server::start("127.0.0.1:0", ctx).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let lines = client
            .call_stream(Json::obj(vec![("op", Json::str("screen"))]))
            .unwrap();
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].get("ok").unwrap().as_bool(), Some(false));
        server.shutdown();
        // Direct handle_line use gets a hint, not a hang.
        let ctx = test_ctx();
        let r = handle_line("{\"id\":1,\"op\":\"screen\",\"targets\":[\"CCO\"]}", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("stream"));
    }

    #[test]
    fn degrade_clamps_are_pure_and_floor_at_one() {
        let cfg =
            OverloadConfig { degraded_beam: 2, degraded_deadline_ms: 100, ..Default::default() };
        let (bw, sd, sd_auto, ddl) = degrade_clamps(&cfg, 8, Duration::from_millis(500));
        assert_eq!(bw, 2, "beam clamps to the configured floor");
        assert_eq!(sd, 1, "speculation collapses to sequential");
        assert!(!sd_auto);
        assert_eq!(ddl, Duration::from_millis(100), "deadline tightens");
        // Requests already under the floor keep their own settings.
        let (bw, _, _, ddl) = degrade_clamps(&cfg, 1, Duration::from_millis(50));
        assert_eq!(bw, 1);
        assert_eq!(ddl, Duration::from_millis(50), "never loosened");
        // degraded_deadline_ms = 0 keeps the request deadline.
        let cfg = OverloadConfig { degraded_beam: 1, ..Default::default() };
        let (bw, _, _, ddl) = degrade_clamps(&cfg, 4, Duration::from_secs(5));
        assert_eq!(bw, 1);
        assert_eq!(ddl, Duration::from_secs(5));
    }

    #[test]
    fn healthz_reports_readiness() {
        let ctx = test_ctx();
        let r = handle_line("{\"id\":1,\"op\":\"healthz\"}", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert_eq!(r.get("ready").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("draining").unwrap().as_bool(), Some(false));
        assert_eq!(r.get("degraded").unwrap().as_bool(), Some(false));
        assert!(r.get("alive").unwrap().as_usize().unwrap() >= 1);
        assert!(r.get("load").unwrap().as_f64().is_some());
    }

    #[test]
    fn drain_op_refuses_new_plans_but_answers_probes() {
        let ctx = test_ctx();
        let r = handle_line("{\"id\":1,\"op\":\"drain\"}", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert_eq!(r.get("draining").unwrap().as_bool(), Some(true));
        // New plans are refused with the draining code...
        let r = handle_line("{\"id\":2,\"op\":\"plan\",\"smiles\":\"CC(=O)NC\"}", &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(r.get("code").unwrap().as_str(), Some("draining"));
        // ...while probes keep working, and healthz flips not-ready.
        let r = handle_line("{\"id\":3,\"op\":\"ping\"}", &ctx);
        assert_eq!(r.get("pong").unwrap().as_bool(), Some(true));
        let r = handle_line("{\"id\":4,\"op\":\"healthz\"}", &ctx);
        assert_eq!(r.get("draining").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("ready").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn degraded_admission_marks_the_plan_response() {
        let mut ctx = test_ctx();
        // Watermarks that an idle hub (load = 0) can never leave: high
        // at 0.0 trips immediately, low below 0 never recovers — so the
        // server-side clamp path runs deterministically in-process.
        ctx.overload = Arc::new(OverloadController::new(OverloadConfig {
            degrade_high: 0.0,
            degrade_low: -1.0,
            degraded_deadline_ms: 5_000,
            ..Default::default()
        }));
        let r = handle_line(
            "{\"id\":1,\"op\":\"plan\",\"smiles\":\"CC(=O)NC\",\"deadline_ms\":200,\
             \"beam_width\":4,\"spec_depth\":4}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert_eq!(r.get("degraded").unwrap().as_bool(), Some(true));
        // Speculation was clamped to sequential for the NEW request.
        let max_in_flight = r
            .get("speculation")
            .and_then(|s| s.get("max_in_flight"))
            .and_then(|x| x.as_usize())
            .unwrap();
        assert!(max_in_flight <= 1, "degraded plans run sequentially: {r:?}");
        assert_eq!(ctx.metrics.counter("serve.degrade.plans"), 1);
    }

    #[test]
    fn undegraded_responses_carry_no_degraded_key() {
        let ctx = test_ctx();
        let r = handle_line(
            "{\"id\":1,\"op\":\"plan\",\"smiles\":\"CC(=O)NC\",\"deadline_ms\":200}",
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert!(
            r.get("degraded").is_none(),
            "full-effort responses must stay byte-identical to the pre-overload protocol"
        );
        assert_eq!(ctx.metrics.counter("serve.degrade.plans"), 0);
    }

    #[test]
    fn shutdown_with_idle_connected_clients_returns_promptly() {
        let ctx = test_ctx();
        let server = Server::start("127.0.0.1:0", ctx).unwrap();
        let addr = server.addr();
        // Two idle clients block in the server's line reader; shutdown
        // must force-close and join their threads, not hang.
        let _c1 = Client::connect(addr).unwrap();
        let _c2 = Client::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(30)); // let accepts land
        let t0 = Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "drain-clean shutdown must not wait on idle readers"
        );
        // The listener is gone: new connects are refused (or reset).
        std::thread::sleep(Duration::from_millis(20));
        let mut c = match TcpStream::connect(addr) {
            Err(_) => return, // refused outright — fine
            Ok(s) => s,
        };
        // If the OS still accepts (TIME_WAIT edge), any IO must fail.
        let _ = c.write_all(b"{\"op\":\"ping\"}\n");
        let mut buf = String::new();
        let n = BufReader::new(c).read_line(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "no server behind the socket after shutdown: {buf:?}");
    }

    #[test]
    fn concurrent_clients() {
        let ctx = test_ctx();
        let server = Server::start("127.0.0.1:0", ctx).unwrap();
        let addr = server.addr();
        let mut joins = Vec::new();
        for _ in 0..3 {
            joins.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let r = c
                    .call(Json::obj(vec![
                        ("op", Json::str("expand")),
                        ("smiles", Json::str("CC(=O)NC")),
                    ]))
                    .unwrap();
                r.get("ok").unwrap().as_bool()
            }));
        }
        for j in joins {
            assert_eq!(j.join().unwrap(), Some(true));
        }
        server.shutdown();
    }
}
