//! The sharded serving tier: S session-sharded hub loops over one
//! replica pool.
//!
//! One hub thread serializes admission, bookkeeping and retirement for
//! every session — fine at small fan-in, a wall at 64+ concurrent
//! sessions. This module splits the hub into S **shards**, each an
//! independent [`shard_loop`] thread with its own request channel,
//! waiter table, submission round and per-replica
//! [`DecodeScheduler`]s. Sessions are routed to shards by the facade
//! ([`super::batcher::ExpansionHub`]); a shard never touches another
//! shard's waiters.
//!
//! What *is* shared is deliberately narrow and lock-cheap:
//!
//! - the [`crate::model::ReplicaPool`] — N model executors behind
//!   least-outstanding-rows dispatch; every shard draws replicas from
//!   the same pool, so load balances across devices regardless of
//!   which shard a session landed on;
//! - the cross-shard expansion cache
//!   ([`crate::search::policy::SyncExpansionCache`]) — a molecule
//!   decoded by any shard serves every shard's cache hits;
//! - the [`InFlightRegistry`] — molecule → owning shard, so two
//!   sessions expanding the same molecule from different shards join
//!   ONE decode task instead of paying two;
//! - the [`StealQueue`] — when a routed shard's inbox is saturated,
//!   the facade spills the request here and any shard with gather
//!   budget left claims it (work stealing).
//!
//! **Replica failure domain**: a replica whose executor died past
//! `max_restarts` answers calls with a "model thread gone" error. The
//! shard that observes it marks the replica dead pool-wide and
//! re-queues the dead replica's unanswered work onto survivors;
//! waiters are failed only when the *last* replica dies. A panic that
//! unwinds out of a model call is contained to the shard that made it
//! — other shards keep serving.

use super::batcher::{BatcherConfig, CompletionQueue, ExpandReq, HubCounters, HubMsg, Priority};
use crate::decoding::scheduler::{DecodeScheduler, Finished, SchedulerConfig, TaskId};
use crate::decoding::Decoder;
use crate::metrics::Metrics;
use crate::model::{encode_shared, is_replica_gone, MemView, ReplicaPool, StepModel};
use crate::search::policy::{proposals_from_output, Proposal, SyncExpansionCache};
use crate::tokenizer::Vocab;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};

/// Cross-shard in-flight decode registry: molecule → owning shard.
///
/// The facade routes a submit for a molecule some shard already
/// decodes to THAT shard, where the existing waiter/covered machinery
/// merges it into the in-flight task — cross-shard deduplication with
/// one small map lookup on the submit path. Claims are released by the
/// owning shard when the molecule's last waiter and task are gone.
pub(crate) struct InFlightRegistry {
    map: Mutex<HashMap<String, usize>>,
}

impl InFlightRegistry {
    pub(crate) fn new() -> Self {
        Self { map: Mutex::new(HashMap::new()) }
    }

    // Plain map under the lock: a poisoned guard (a shard panicked
    // mid-release) cannot leave it torn — recover instead of taking
    // every submit path down.
    fn lock(&self) -> MutexGuard<'_, HashMap<String, usize>> {
        self.map.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The shard currently decoding `mol`, if any.
    pub(crate) fn route(&self, mol: &str) -> Option<usize> {
        self.lock().get(mol).copied()
    }

    /// Route to the owning shard, or claim `mol` for `fallback` in the
    /// same critical section. Returns `(shard, joined)` — `joined` is
    /// true when an existing owner was found (a cross-shard dedup).
    pub(crate) fn route_or_claim(&self, mol: &str, fallback: usize) -> (usize, bool) {
        let mut m = self.lock();
        if let Some(&s) = m.get(mol) {
            (s, true)
        } else {
            m.insert(mol.to_string(), fallback);
            (fallback, false)
        }
    }

    /// Idempotent claim: the first owner wins (a stolen request's
    /// processing shard claims at admission; a concurrent router that
    /// claimed first keeps ownership).
    pub(crate) fn claim(&self, mol: &str, shard: usize) {
        self.lock().entry(mol.to_string()).or_insert(shard);
    }

    /// Release `mol` only if `shard` owns it.
    pub(crate) fn release_if_owned(&self, mol: &str, shard: usize) {
        let mut m = self.lock();
        if m.get(mol) == Some(&shard) {
            m.remove(mol);
        }
    }

    /// Release every molecule `shard` owns (shard shutdown / panic
    /// recovery — its claims must not strand future submits).
    pub(crate) fn release_all_owned(&self, shard: usize) {
        self.lock().retain(|_, &mut s| s != shard);
    }

    #[cfg(test)]
    pub(crate) fn len(&self) -> usize {
        self.lock().len()
    }
}

/// Spill-over queue for work stealing: requests whose routed shard was
/// saturated wait here, and any shard with gather budget left claims
/// them at its next round boundary. The queue is **two-lane by
/// priority class**: every spilled interactive request is claimed
/// before any spilled batch one (FIFO within each lane), so a
/// screening job whose spills flood the queue cannot starve an
/// interactive plan that spilled after it.
pub(crate) struct StealQueue {
    q: Mutex<StealLanes>,
}

#[derive(Default)]
struct StealLanes {
    interactive: VecDeque<ExpandReq>,
    batch: VecDeque<ExpandReq>,
}

impl StealQueue {
    pub(crate) fn new() -> Self {
        Self { q: Mutex::new(StealLanes::default()) }
    }

    fn lock(&self) -> MutexGuard<'_, StealLanes> {
        self.q.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub(crate) fn push(&self, req: ExpandReq) {
        let mut lanes = self.lock();
        match req.priority {
            Priority::Interactive => lanes.interactive.push_back(req),
            Priority::Batch => lanes.batch.push_back(req),
        }
    }

    /// Claim the oldest spilled request, interactive lane first.
    pub(crate) fn pop(&self) -> Option<ExpandReq> {
        let mut lanes = self.lock();
        lanes.interactive.pop_front().or_else(|| lanes.batch.pop_front())
    }

    pub(crate) fn is_empty(&self) -> bool {
        let lanes = self.lock();
        lanes.interactive.is_empty() && lanes.batch.is_empty()
    }

    /// (spilled interactive, spilled batch) lane depths.
    pub(crate) fn depths(&self) -> (usize, usize) {
        let lanes = self.lock();
        (lanes.interactive.len(), lanes.batch.len())
    }
}

/// A shard's completion events: the shard-local queue (its own
/// sessions' futures wait here — no cross-shard wakeup storms) plus
/// the hub-global queue (mixed-shard waits and spilled futures).
pub(crate) struct ShardEvents {
    pub(crate) local: Arc<CompletionQueue>,
    pub(crate) global: Arc<CompletionQueue>,
}

impl ShardEvents {
    fn notify(&self) {
        self.local.notify();
        self.global.notify();
    }
}

/// A queued requester.
struct Waiter {
    ticket: u64,
    k: usize,
    /// Request-budget deadline; the shard expires the waiter past it.
    deadline: Option<std::time::Instant>,
    reply: mpsc::SyncSender<anyhow::Result<Vec<Proposal>>>,
}

/// In-flight bookkeeping for one per-query decode task.
struct TaskMeta {
    mol: String,
    k: usize,
    /// Which pool replica runs this task (its rows were charged there).
    replica: usize,
}

/// Mutable per-shard state: waiters and in-flight coverage. The cache
/// is the shared cross-shard tier — every other field is shard-local.
struct HubState {
    /// Cross-shard, molecule-keyed, k-truncating expansion cache.
    cache: SyncExpansionCache,
    /// Requests not yet answered, per molecule.
    waiting: HashMap<String, Vec<Waiter>>,
    /// In-flight per-query decode tasks per molecule — usually one; a
    /// wider-k re-request adds a second while the first still flies.
    covered: HashMap<String, Vec<(TaskId, usize)>>,
    /// Misses gathered this round in admission order — the row order of
    /// the round's fused encode. `None` marks a slot whose molecule was
    /// cancelled before submit. Survives across rounds: replica-death
    /// re-queues land here for the NEXT round's fused encode.
    to_submit: Vec<Option<(String, usize)>>,
    /// Molecule -> index into `to_submit` (O(1) merge and removal).
    to_submit_idx: HashMap<String, usize>,
    /// Two-tier admission: batch-class requests that missed the cache
    /// AND found no in-flight task to join wait here, FIFO, until a
    /// round forms with no interactive miss pending. Entries are full
    /// requests (not yet waiters) — they have claimed nothing but a
    /// facade-side registry entry.
    batch_backlog: VecDeque<ExpandReq>,
}

impl HubState {
    /// Serve a request from cache or queue it (possibly scheduling a
    /// decode for this round). Returns whether the request was answered
    /// immediately (cache hit).
    fn admit(&mut self, req: ExpandReq) -> bool {
        if let Some(out) = self.cache.get(&req.smiles, req.k) {
            let _ = req.reply.send(Ok(out));
            return true;
        }
        let in_flight_covers = self
            .covered
            .get(&req.smiles)
            .is_some_and(|tasks| tasks.iter().any(|&(_, ck)| ck >= req.k));
        if !in_flight_covers {
            self.requeue(req.smiles.clone(), req.k);
        }
        self.waiting.entry(req.smiles).or_default().push(Waiter {
            ticket: req.ticket,
            k: req.k,
            deadline: req.deadline,
            reply: req.reply,
        });
        false
    }

    /// Queue `mol` for the next submission round, merging into an
    /// existing slot by max-k. Used by admission AND by replica-death
    /// recovery (a dead replica's work re-enters the next round).
    fn requeue(&mut self, mol: String, k: usize) {
        use std::collections::hash_map::Entry;
        match self.to_submit_idx.entry(mol) {
            Entry::Occupied(o) => {
                let slot = self.to_submit[*o.get()].as_mut().expect("indexed slots are live");
                slot.1 = slot.1.max(k);
            }
            Entry::Vacant(v) => {
                let mol = v.key().clone();
                v.insert(self.to_submit.len());
                self.to_submit.push(Some((mol, k)));
            }
        }
    }

    /// Expire every waiter whose deadline passed; returns the expired
    /// molecules so the caller can cancel their decode tasks.
    fn expire_deadlines(&mut self, now: std::time::Instant) -> Vec<String> {
        let mut orphaned = Vec::new();
        self.waiting.retain(|mol, ws| {
            ws.retain(|w| {
                let expired = w.deadline.is_some_and(|d| now >= d);
                if expired {
                    let _ = w.reply.send(Err(anyhow::anyhow!("request deadline expired")));
                }
                !expired
            });
            if ws.is_empty() {
                orphaned.push(mol.clone());
                false
            } else {
                true
            }
        });
        for mol in &orphaned {
            self.drop_queued_miss(mol);
        }
        orphaned
    }

    /// Expire backlogged batch requests whose deadline passed (they
    /// have no task to cancel — they never entered a round). Returns
    /// the expired molecules so the caller can release any facade-side
    /// registry claim.
    fn expire_batch_backlog(&mut self, now: std::time::Instant) -> Vec<String> {
        let mut expired = Vec::new();
        self.batch_backlog.retain(|r| {
            let out = r.deadline.is_some_and(|d| now >= d);
            if out {
                let _ = r.reply.send(Err(anyhow::anyhow!("request deadline expired")));
                expired.push(r.smiles.clone());
            }
            !out
        });
        expired
    }

    /// Withdraw a backlogged batch request by (molecule, ticket);
    /// returns whether one was removed (it never became a waiter, so
    /// the regular cancel path does not apply).
    fn remove_backlogged(&mut self, smiles: &str, ticket: u64) -> bool {
        let before = self.batch_backlog.len();
        self.batch_backlog.retain(|r| !(r.ticket == ticket && r.smiles == smiles));
        self.batch_backlog.len() != before
    }

    /// Drop a molecule's queued miss (its last waiter left before
    /// submit). O(1): the slot is tombstoned, not compacted.
    fn drop_queued_miss(&mut self, smiles: &str) {
        if let Some(i) = self.to_submit_idx.remove(smiles) {
            self.to_submit[i] = None;
        }
    }

    /// Whether any miss is still queued for the next round.
    fn has_queued_misses(&self) -> bool {
        !self.to_submit_idx.is_empty()
    }

    /// Take this round's misses in admission order, clearing the queue.
    fn take_submit_round(&mut self) -> Vec<(String, usize)> {
        self.to_submit_idx.clear();
        self.to_submit.drain(..).flatten().collect()
    }

    /// Remove one waiter; returns true when the molecule has no waiters
    /// left (its in-flight tasks may then be abandoned).
    fn remove_waiter(&mut self, smiles: &str, ticket: u64) -> bool {
        let Some(ws) = self.waiting.get_mut(smiles) else {
            return false; // already answered (or queued on another shard)
        };
        ws.retain(|w| w.ticket != ticket);
        if ws.is_empty() {
            self.waiting.remove(smiles);
            true
        } else {
            false
        }
    }

    /// Max beam width of the remaining in-flight tasks for a molecule.
    fn covered_k(&self, smiles: &str) -> usize {
        self.covered
            .get(smiles)
            .map(|tasks| tasks.iter().map(|&(_, k)| k).max().unwrap_or(0))
            .unwrap_or(0)
    }

    /// Fail every queued request (shard-invariant breach only; tick
    /// errors are scoped per failed task instead).
    fn fail_all(&mut self, msg: &str) {
        for (_, ws) in self.waiting.drain() {
            for w in ws {
                let _ = w.reply.send(Err(anyhow::anyhow!("decode failed: {msg}")));
            }
        }
        self.covered.clear();
    }
}

/// Everything a shard loop shares with the facade and its sibling
/// shards. Built once per shard by `ExpansionHub::start_pool`.
pub(crate) struct ShardCtx {
    /// This shard's index (registry ownership, scheduler id striding).
    pub(crate) shard: usize,
    pub(crate) pool: Arc<ReplicaPool>,
    pub(crate) decoder: Arc<dyn Decoder + Send>,
    pub(crate) vocab: Vocab,
    pub(crate) cfg: BatcherConfig,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) counters: HubCounters,
    pub(crate) events: ShardEvents,
    pub(crate) registry: Arc<InFlightRegistry>,
    pub(crate) steal_q: Arc<StealQueue>,
    /// Queued-Expand depth of this shard's inbox (facade routing and
    /// spill decisions read it; the shard decrements on drain).
    pub(crate) depth: Arc<AtomicUsize>,
    /// The shared cross-shard cache handle (cloned into `HubState`).
    pub(crate) cache: SyncExpansionCache,
    /// Optional persistent L2 tier under the cache: probed on L1
    /// misses (hits promote into L1), fed on retirement. `None` keeps
    /// the shard byte-identical to the store-less hub. Reads and the
    /// put are pure memory + a channel send — the store's flusher
    /// thread owns all disk I/O.
    pub(crate) store: Option<Arc<crate::store::ExpansionStore>>,
}

/// One shard's running state: per-replica schedulers plus the waiter
/// bookkeeping. All methods run on the shard thread.
struct ShardRt {
    ctx: ShardCtx,
    /// One scheduler per pool replica; TaskIds are strided so ids are
    /// unique within the shard (base = replica + 1, stride = N).
    scheds: Vec<DecodeScheduler>,
    state: HubState,
    tasks_meta: HashMap<TaskId, TaskMeta>,
    /// Reusable tick-output buffer.
    finished: Vec<Finished>,
    in_flight_hw: usize,
}

impl ShardRt {
    fn in_flight(&self) -> usize {
        self.scheds.iter().map(DecodeScheduler::in_flight).sum()
    }

    fn all_idle(&self) -> bool {
        self.scheds.iter().all(DecodeScheduler::is_idle)
    }

    fn steal_pending(&self) -> bool {
        self.ctx.cfg.steal && !self.ctx.steal_q.is_empty()
    }

    /// Release this shard's registry claim on `mol` once nothing local
    /// references it (no waiters, no in-flight task). Safe to call
    /// eagerly — it checks before releasing, and only releases claims
    /// this shard owns.
    fn registry_release(&self, mol: &str) {
        if !self.state.waiting.contains_key(mol) && !self.state.covered.contains_key(mol) {
            self.ctx.registry.release_if_owned(mol, self.ctx.shard);
        }
    }

    /// L2 probe: when the persistent store holds `mol` at `>= k` and
    /// L1 does not, promote the stored entry into L1 at its FULL
    /// stored width so the normal admission path (and every later
    /// request, wider ones included up to the stored k) hits memory.
    /// An L2 hit can therefore never yield fewer proposals than were
    /// persisted — L1 truncates to the requested k on read, exactly as
    /// it does for freshly decoded entries.
    fn promote_l2(&mut self, mol: &str, k: usize) {
        let Some(store) = &self.ctx.store else { return };
        let mol_key = mol.to_string();
        if self.state.cache.get(&mol_key, k).is_some() {
            return;
        }
        if let Some((stored_k, props)) = store.get_expansion(mol, k) {
            self.state.cache.insert(mol_key, stored_k, props);
            self.ctx.metrics.inc("cache.l2_hits", 1);
            self.ctx.metrics.inc("cache.l2_promotions", 1);
        }
    }

    /// Admit one request: cache hit answers and releases any registry
    /// claim; a miss claims the molecule for this shard (idempotent —
    /// covers stolen requests the router never claimed).
    fn admit(&mut self, req: ExpandReq) -> bool {
        self.promote_l2(&req.smiles, req.k);
        let mol = req.smiles.clone();
        let hit = self.state.admit(req);
        if hit {
            self.registry_release(&mol);
        } else {
            self.ctx.registry.claim(&mol, self.ctx.shard);
        }
        hit
    }

    /// Priority-routed admission. Interactive requests take the strict
    /// oldest-first path. Batch requests answer immediately on a cache
    /// hit or by joining an in-flight decode that already covers their
    /// k (sharing never waits); a batch *miss* is deferred to the
    /// shard's backlog until a round forms with no interactive miss
    /// pending — so screening traffic cannot displace interactive work
    /// from a round, only fill rounds interactive traffic left empty.
    fn admit_any(&mut self, req: ExpandReq) -> bool {
        if req.priority == Priority::Interactive {
            return self.admit(req);
        }
        self.promote_l2(&req.smiles, req.k);
        if let Some(out) = self.state.cache.get(&req.smiles, req.k) {
            let _ = req.reply.send(Ok(out));
            self.registry_release(&req.smiles);
            return true;
        }
        let covers = self
            .state
            .covered
            .get(&req.smiles)
            .is_some_and(|tasks| tasks.iter().any(|&(_, ck)| ck >= req.k));
        if covers {
            // Join the in-flight task as a plain waiter: no new decode
            // work is created, so this cannot inflate interactive p95.
            self.ctx.registry.claim(&req.smiles, self.ctx.shard);
            self.state.waiting.entry(req.smiles).or_default().push(Waiter {
                ticket: req.ticket,
                k: req.k,
                deadline: req.deadline,
                reply: req.reply,
            });
            return false;
        }
        self.state.batch_backlog.push_back(req);
        false
    }

    /// Two-tier round formation: admit deferred batch requests into
    /// this round only when no interactive miss is pending, up to one
    /// gather round's worth. Returns whether any was answered from
    /// cache (a sibling's retirement may have populated it meanwhile).
    fn admit_batch_round(&mut self) -> bool {
        if self.state.has_queued_misses() || self.state.batch_backlog.is_empty() {
            return false;
        }
        let mut answered = false;
        for _ in 0..self.ctx.cfg.max_batch {
            let Some(req) = self.state.batch_backlog.pop_front() else { break };
            answered |= self.admit(req);
        }
        answered
    }

    /// Route one inbound message. Returns whether it was an expansion
    /// (the only kind counted toward the gather budget); sets
    /// `answered` when one was served immediately from cache.
    fn on_msg(
        &mut self,
        msg: HubMsg,
        cancels: &mut Vec<(String, u64)>,
        answered: &mut bool,
    ) -> bool {
        match msg {
            HubMsg::Expand(r) => {
                self.ctx.depth.fetch_sub(1, Ordering::Relaxed);
                *answered |= self.admit_any(r);
                true
            }
            HubMsg::Cancel { smiles, ticket } => {
                cancels.push((smiles, ticket));
                false
            }
            HubMsg::Poke => false,
            HubMsg::Debug(tx) => {
                let tasks: usize = self.state.covered.values().map(Vec::len).sum();
                let _ = tx.send((
                    self.state.waiting.len(),
                    tasks,
                    self.in_flight(),
                    self.state.to_submit_idx.len(),
                    self.state.batch_backlog.len(),
                ));
                false
            }
        }
    }

    /// Remove one task from a molecule's coverage.
    fn drop_covered(&mut self, mol: &str, id: TaskId) {
        if let Some(tasks) = self.state.covered.get_mut(mol) {
            tasks.retain(|&(tid, _)| tid != id);
            if tasks.is_empty() {
                self.state.covered.remove(mol);
            }
        }
    }

    /// Cancel every in-flight task of `mol` (its last waiter left):
    /// rows and encoder memory release through the scheduler, and the
    /// owning replica's outstanding charge drops.
    fn cancel_tasks_of(&mut self, mol: &str) {
        if let Some(tasks) = self.state.covered.remove(mol) {
            for (id, _) in tasks {
                let Some(meta) = self.tasks_meta.remove(&id) else { continue };
                let model = self.ctx.pool.model(meta.replica);
                if self.scheds[meta.replica].cancel(model, id) {
                    self.ctx.pool.discharge(meta.replica, meta.k);
                    self.ctx.counters.cancelled.fetch_add(1, Ordering::Relaxed);
                    self.ctx.metrics.inc("batcher.tasks_cancelled", 1);
                }
            }
        }
    }

    /// Fail the waiters of one failed/unstartable task, keeping any
    /// waiter another in-flight task still covers.
    fn fail_task_waiters(&mut self, mol: &str, task_k: usize, msg: &str) {
        let remaining_k = self.state.covered_k(mol);
        if let Some(ws) = self.state.waiting.remove(mol) {
            let mut kept = Vec::new();
            for w in ws {
                if w.k <= task_k && w.k > remaining_k {
                    let _ = w.reply.send(Err(anyhow::anyhow!("decode failed: {msg}")));
                } else {
                    kept.push(w);
                }
            }
            if !kept.is_empty() {
                self.state.waiting.insert(mol.to_string(), kept);
            }
        }
        self.registry_release(mol);
    }

    /// Start one molecule's per-query decode task on replica `r` over
    /// its pre-encoded view. On failure (`start_task_on` has already
    /// released the view) the molecule's waiters are failed — the
    /// round's siblings are untouched. Returns whether it started.
    fn start_round_task(
        &mut self,
        r: usize,
        mol: String,
        k: usize,
        view: MemView,
        srcs: &[Vec<i32>],
    ) -> bool {
        let started =
            self.ctx.decoder.start_task_on(self.ctx.pool.model(r), vec![view], srcs, k);
        match started {
            Ok(task) => {
                let id = self.scheds[r].submit(task);
                self.ctx.pool.charge(r, k);
                self.ctx.counters.batches.fetch_add(1, Ordering::Relaxed);
                self.ctx.metrics.inc("batcher.tasks", 1);
                self.state.covered.entry(mol.clone()).or_default().push((id, k));
                self.tasks_meta.insert(id, TaskMeta { mol, k, replica: r });
                true
            }
            Err(e) => {
                let msg = format!("start decode failed: {e:#}");
                self.fail_task_waiters(&mol, k, &msg);
                false
            }
        }
    }

    /// Take replica `r` out of the pool (its executor is gone past
    /// `max_restarts`) and move its unanswered work to survivors: each
    /// lost task's molecule re-enters the next submission round if a
    /// waiter still wants it. Waiters are failed only when this was
    /// the last live replica.
    fn kill_replica(&mut self, r: usize) {
        // Count the death once pool-wide even when several shards
        // observe it; every shard still tears down its own scheduler
        // and requeues its own lost tasks below.
        if self.ctx.pool.mark_dead(r) {
            self.ctx.counters.replica_deaths.fetch_add(1, Ordering::Relaxed);
            self.ctx.metrics.inc("replica.deaths", 1);
        }
        // Tear the dead replica's scheduler down; its executor is gone,
        // so teardown calls are fire-and-forget (a panic here must not
        // take the shard with it). mark_dead zeroed the outstanding
        // charge — no per-task discharge.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.scheds[r].abort(self.ctx.pool.model(r));
        }));
        let _ = self.scheds[r].drain_failed();
        let lost: Vec<TaskId> = self
            .tasks_meta
            .iter()
            .filter(|(_, m)| m.replica == r)
            .map(|(id, _)| *id)
            .collect();
        let survivors = self.ctx.pool.alive_count() > 0;
        for id in lost {
            let Some(meta) = self.tasks_meta.remove(&id) else { continue };
            self.drop_covered(&meta.mol, id);
            if survivors && self.state.waiting.contains_key(&meta.mol) {
                if self.state.covered_k(&meta.mol) < meta.k {
                    self.state.requeue(meta.mol.clone(), meta.k);
                }
            } else {
                self.fail_task_waiters(&meta.mol, meta.k, "all model replicas lost");
            }
            self.registry_release(&meta.mol);
        }
    }

    /// Submit one round of misses behind ONE fused encode on the
    /// least-loaded live replica, failing over to survivors on replica
    /// death. Returns whether any molecule's waiters were failed.
    fn submit_round(&mut self, round: Vec<(String, usize)>) -> bool {
        let srcs: Vec<Vec<i32>> =
            round.iter().map(|(mol, _)| self.ctx.vocab.encode(mol, true)).collect();
        self.ctx.counters.encode_rounds.fetch_add(1, Ordering::Relaxed);
        self.ctx.metrics.inc("batcher.encode_rounds", 1);
        let mut failed_any = false;
        let fused_err = loop {
            let Some(r) = self.ctx.pool.pick() else {
                for (mol, k) in round {
                    self.fail_task_waiters(&mol, k, "all model replicas lost");
                }
                return true;
            };
            match encode_shared(self.ctx.pool.model(r), &srcs) {
                Ok(views) => {
                    self.ctx.counters.encode_calls.fetch_add(1, Ordering::Relaxed);
                    self.ctx.metrics.inc("batcher.encode_calls", 1);
                    for (((mol, k), view), src) in
                        round.into_iter().zip(views).zip(srcs.iter())
                    {
                        let one = std::slice::from_ref(src);
                        failed_any |= !self.start_round_task(r, mol, k, view, one);
                    }
                    return failed_any;
                }
                // The replica's executor is gone — a property of the
                // replica, not the round. Fail over, don't fail waiters.
                Err(e) if is_replica_gone(&e) => self.kill_replica(r),
                Err(e) => break e,
            }
        };
        // The round's ONE fused encode failed on a live replica. Don't
        // fail the whole round — one bad source must not take down
        // every co-arriving session's expansion. Retry each molecule
        // alone (the pre-fusion blast radius), still failing over if a
        // replica dies mid-fallback.
        for ((mol, k), src) in round.into_iter().zip(srcs.iter()) {
            let one = std::slice::from_ref(src);
            let mut pending = Some((mol, k));
            while let Some((m, mk)) = pending.take() {
                let Some(r) = self.ctx.pool.pick() else {
                    self.fail_task_waiters(&m, mk, "all model replicas lost");
                    failed_any = true;
                    break;
                };
                match encode_shared(self.ctx.pool.model(r), one) {
                    Ok(views) => {
                        self.ctx.counters.encode_calls.fetch_add(1, Ordering::Relaxed);
                        self.ctx.metrics.inc("batcher.encode_calls", 1);
                        let view = views.into_iter().next().expect("one view per source");
                        failed_any |= !self.start_round_task(r, m, mk, view, one);
                    }
                    Err(e) if is_replica_gone(&e) => {
                        self.kill_replica(r);
                        pending = Some((m, mk));
                    }
                    Err(e) => {
                        let msg = format!("encode failed: {e:#} (fused: {fused_err:#})");
                        self.fail_task_waiters(&m, mk, &msg);
                        failed_any = true;
                    }
                }
            }
        }
        failed_any
    }

    /// One fused decode tick on replica `r`: retire finished tasks,
    /// scope tick errors to the staged tasks, fail over on replica
    /// death.
    fn tick_replica(&mut self, r: usize) {
        let mut finished = std::mem::take(&mut self.finished);
        finished.clear();
        let t_tick = std::time::Instant::now();
        match self.scheds[r].tick(self.ctx.pool.model(r), &mut finished) {
            Ok(rows) => {
                if rows > 0 {
                    self.ctx.pool.note_fused_call(r, rows);
                    self.ctx.counters.fused_calls.fetch_add(1, Ordering::Relaxed);
                    self.ctx.counters.fused_rows.fetch_add(rows as u64, Ordering::Relaxed);
                    self.ctx.metrics.inc("batcher.fused_calls", 1);
                    self.ctx.metrics.inc("batcher.fused_rows", rows as u64);
                    self.ctx.metrics.observe("batcher.decode", t_tick.elapsed().as_secs_f64());
                }
                let retired_any = !finished.is_empty();
                for f in finished.drain(..) {
                    // A task without bookkeeping (cancelled in the same
                    // round it finished) has no waiters to answer.
                    let Some(meta) = self.tasks_meta.remove(&f.id) else {
                        continue;
                    };
                    self.ctx.pool.discharge(meta.replica, meta.k);
                    self.ctx
                        .counters
                        .stats
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .merge(&f.stats);
                    self.retire_task(&meta, &f);
                }
                if retired_any {
                    self.ctx.events.notify();
                }
            }
            Err(e) if is_replica_gone(&e) => {
                self.kill_replica(r);
                self.ctx.events.notify();
            }
            Err(e) => {
                // The fused call failed on a live replica: exactly the
                // tasks staged in it were dropped by the scheduler.
                // Fail their waiters and nobody else's.
                let msg = format!("{e:#}");
                for id in self.scheds[r].drain_failed() {
                    let Some(meta) = self.tasks_meta.remove(&id) else { continue };
                    self.ctx.pool.discharge(meta.replica, meta.k);
                    self.drop_covered(&meta.mol, id);
                    self.fail_task_waiters(&meta.mol, meta.k, &msg);
                }
                self.ctx.events.notify();
            }
        }
        self.finished = finished;
    }

    /// Parse a finished per-query task's output, populate the shared
    /// cache, and answer every waiter the task covers.
    fn retire_task(&mut self, meta: &TaskMeta, f: &Finished) {
        let mol = &meta.mol;
        let Some(gen) = f.outputs.first() else {
            // A per-query task always has one output; if the invariant
            // ever breaks, fail this task's waiters (scoped) instead of
            // panicking the shard thread out from under its sessions.
            self.fail_task_waiters(mol, meta.k, "internal: task finished without output");
            self.drop_covered(mol, f.id);
            self.registry_release(mol);
            return;
        };
        let mut inv = 0usize;
        let mut tot = 0usize;
        let props = proposals_from_output(&self.ctx.vocab, mol, gen, &mut inv, &mut tot);
        self.ctx.counters.invalid.fetch_add(inv, Ordering::Relaxed);
        self.ctx.counters.total.fetch_add(tot, Ordering::Relaxed);
        self.state.cache.insert(mol.clone(), meta.k, props.clone());
        if let Some(store) = &self.ctx.store {
            // Write-behind into the L2 tier: memory insert + channel
            // send; the store's flusher thread does the disk write.
            store.put_expansion(mol, meta.k, &props);
        }
        if let Some(ws) = self.state.waiting.remove(mol) {
            let mut kept = Vec::new();
            for w in ws {
                if w.k <= meta.k {
                    let mut out = props.clone();
                    out.truncate(w.k);
                    let _ = w.reply.send(Ok(out));
                } else {
                    // A wider request for the same molecule is covered
                    // by a younger, larger-k task still in flight.
                    kept.push(w);
                }
            }
            if !kept.is_empty() {
                self.state.waiting.insert(mol.clone(), kept);
            }
        }
        self.drop_covered(mol, f.id);
        self.registry_release(mol);
    }

    /// Phases 3+4 of one shard round: submit this round's misses
    /// behind one fused encode, then one fused tick per busy replica.
    /// The only phases that call into the model — run under
    /// `catch_unwind` by `shard_loop`.
    fn model_phases(&mut self) {
        let round = self.state.take_submit_round();
        if !round.is_empty() && self.submit_round(round) {
            self.ctx.events.notify();
        }
        // Publish the in-flight high-water mark only when it moves:
        // steady-state ticks must stay free of mutex/alloc traffic.
        let fl = self.in_flight();
        if fl > self.in_flight_hw {
            self.in_flight_hw = fl;
            self.ctx.metrics.gauge_max("scheduler.in_flight_tasks", fl as u64);
        }
        if self.all_idle() {
            // Waiters whose molecule is re-queued (replica failover)
            // are covered by the NEXT round — only a waiter with
            // neither a task nor a queued miss is an invariant breach.
            if !self.state.waiting.is_empty() && !self.state.has_queued_misses() {
                self.state.fail_all("internal: waiters without an in-flight task");
                self.ctx.registry.release_all_owned(self.ctx.shard);
                self.ctx.events.notify();
            }
            return;
        }
        for r in 0..self.scheds.len() {
            if !self.scheds[r].is_idle() {
                self.tick_replica(r);
            }
        }
    }

    /// A panic unwound out of the model mid-round. Release every
    /// in-flight task on every replica (a second panic during cleanup
    /// is swallowed — the shard thread must survive), fail the waiters
    /// scoped to this shard, and continue on a clean slate.
    fn recover_from_panic(&mut self) {
        for r in 0..self.scheds.len() {
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.scheds[r].abort(self.ctx.pool.model(r));
            }));
            let _ = self.scheds[r].drain_failed();
        }
        for (_, meta) in self.tasks_meta.drain() {
            // A dead replica's charge was zeroed by mark_dead; only
            // live replicas carry outstanding rows to release.
            if self.ctx.pool.is_alive(meta.replica) {
                self.ctx.pool.discharge(meta.replica, meta.k);
            }
        }
        self.state.to_submit.clear();
        self.state.to_submit_idx.clear();
        self.state.fail_all("hub round panicked (model fault); request failed, hub restarted");
        self.ctx.registry.release_all_owned(self.ctx.shard);
        self.ctx.metrics.inc("batcher.hub_panics", 1);
        self.ctx.events.notify();
    }
}

/// One shard's serving loop: gather → cancel → deadline sweep →
/// (panic-contained) submit + tick. Structurally the single-hub loop,
/// with three sharding deltas: per-replica schedulers with strided
/// TaskIds, a work-steal drain after local gather, and re-queues from
/// replica failover surviving into the next round.
pub(crate) fn shard_loop(rx: mpsc::Receiver<HubMsg>, ctx: ShardCtx) {
    let nrep = ctx.pool.len();
    let scheds: Vec<DecodeScheduler> = (0..nrep)
        .map(|r| {
            DecodeScheduler::with_ids(
                SchedulerConfig { max_rows: ctx.cfg.max_rows },
                r as u64 + 1,
                nrep as u64,
            )
        })
        .collect();
    let state = HubState {
        cache: ctx.cache.clone(),
        waiting: HashMap::new(),
        covered: HashMap::new(),
        to_submit: Vec::new(),
        to_submit_idx: HashMap::new(),
        batch_backlog: VecDeque::new(),
    };
    let mut rt = ShardRt {
        ctx,
        scheds,
        state,
        tasks_meta: HashMap::new(),
        finished: Vec::new(),
        in_flight_hw: 0,
    };
    let mut cancels: Vec<(String, u64)> = Vec::new();
    let mut open = true;

    while open
        || !rt.all_idle()
        || !rt.state.waiting.is_empty()
        || !rt.state.batch_backlog.is_empty()
        || rt.steal_pending()
    {
        // ---- 1. gather requests ----
        let mut gathered = 0usize;
        let mut answered = false;
        let idle = rt.all_idle()
            && rt.state.waiting.is_empty()
            && !rt.state.has_queued_misses()
            && rt.state.batch_backlog.is_empty();
        if open && idle && !rt.steal_pending() {
            // Idle: block for the next request (a spill Poke also wakes
            // us), then give stragglers a short window so simultaneous
            // arrivals share the first ticks and the round's single
            // fused encode.
            match rx.recv() {
                Ok(msg) => {
                    if rt.on_msg(msg, &mut cancels, &mut answered) {
                        rt.ctx.counters.merged.fetch_add(1, Ordering::Relaxed);
                        gathered += 1;
                    }
                    let deadline = std::time::Instant::now() + rt.ctx.cfg.max_wait;
                    // The straggler window also covers a backlogged
                    // batch miss: co-arriving screening submits fuse
                    // into one round exactly like interactive ones.
                    while gathered < rt.ctx.cfg.max_batch
                        && (rt.state.has_queued_misses()
                            || !rt.state.batch_backlog.is_empty())
                    {
                        let now = std::time::Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(msg) => {
                                if rt.on_msg(msg, &mut cancels, &mut answered) {
                                    rt.ctx.counters.merged.fetch_add(1, Ordering::Relaxed);
                                    gathered += 1;
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                }
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        } else {
            // Busy: drain without blocking — late arrivals join the
            // very next fused call.
            while gathered < rt.ctx.cfg.max_batch {
                match rx.try_recv() {
                    Ok(msg) => {
                        if rt.on_msg(msg, &mut cancels, &mut answered) {
                            rt.ctx.counters.merged.fetch_add(1, Ordering::Relaxed);
                            gathered += 1;
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            // Deadline-based encode coalescer: hold a round with queued
            // misses open while the shard is busy so near-arrivals
            // share its ONE fused encode (bounded latency trade).
            if !rt.ctx.cfg.coalesce.is_zero()
                && open
                && !rt.all_idle()
                && rt.state.has_queued_misses()
            {
                if answered {
                    rt.ctx.events.notify();
                    answered = false;
                }
                let deadline = std::time::Instant::now() + rt.ctx.cfg.coalesce;
                while gathered < rt.ctx.cfg.max_batch {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(msg) => {
                            if rt.on_msg(msg, &mut cancels, &mut answered) {
                                rt.ctx.counters.merged.fetch_add(1, Ordering::Relaxed);
                                gathered += 1;
                            }
                            // A cache hit answered inside the hold:
                            // wake its waiter now, not at window end.
                            if answered {
                                rt.ctx.events.notify();
                                answered = false;
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
        }
        // ---- 1b. work stealing: claim spilled requests ----
        // Requests whose routed shard was saturated sit in the shared
        // spill queue; any shard with gather budget left claims them
        // FIFO, so a hot shard sheds load to its idle siblings instead
        // of queueing it behind its own backlog.
        if rt.ctx.cfg.steal {
            while gathered < rt.ctx.cfg.max_batch {
                let Some(req) = rt.ctx.steal_q.pop() else { break };
                rt.ctx.counters.merged.fetch_add(1, Ordering::Relaxed);
                rt.ctx.counters.steals.fetch_add(1, Ordering::Relaxed);
                rt.ctx.metrics.inc("batcher.steals", 1);
                answered |= rt.admit_any(req);
                gathered += 1;
            }
        }
        if answered {
            rt.ctx.events.notify();
        }

        // ---- 2. apply cancellations ----
        // Cancels are broadcast to every shard (a spilled future does
        // not know which shard claimed it); shards without the ticket
        // no-op. A molecule whose last waiter withdrew loses its queued
        // miss, its in-flight tasks and its registry claim.
        let had_cancels = !cancels.is_empty();
        for (smiles, ticket) in cancels.drain(..) {
            // A backlogged batch request never became a waiter or a
            // queued miss — withdrawing it only needs the facade-side
            // registry claim released.
            if rt.state.remove_backlogged(&smiles, ticket) {
                rt.registry_release(&smiles);
                continue;
            }
            if rt.state.remove_waiter(&smiles, ticket) {
                rt.state.drop_queued_miss(&smiles);
                rt.cancel_tasks_of(&smiles);
                rt.registry_release(&smiles);
            }
        }
        if had_cancels {
            rt.ctx.events.notify();
        }

        // ---- 2b. expire request deadlines ----
        let now = std::time::Instant::now();
        let orphaned = rt.state.expire_deadlines(now);
        if !orphaned.is_empty() {
            for mol in &orphaned {
                rt.cancel_tasks_of(mol);
                rt.registry_release(mol);
            }
            rt.ctx.metrics.inc("batcher.deadline_expired", orphaned.len() as u64);
            rt.ctx.events.notify();
        }
        let expired_batch = rt.state.expire_batch_backlog(now);
        if !expired_batch.is_empty() {
            for mol in &expired_batch {
                rt.registry_release(mol);
            }
            rt.ctx.metrics.inc("batcher.deadline_expired", expired_batch.len() as u64);
            rt.ctx.events.notify();
        }

        // ---- 2c. two-tier admission: form a batch round ----
        // Deferred batch misses enter a round only when no interactive
        // miss is pending (after cancels and expiries pruned both).
        if rt.admit_batch_round() {
            rt.ctx.events.notify();
        }

        // ---- 3 + 4: the model-facing phases, panic-contained ----
        // A model panic must not take the shard thread — and with it
        // every session routed here — down.
        let round_panicked =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| rt.model_phases()));
        if round_panicked.is_err() {
            rt.recover_from_panic();
        }
    }

    // Shutdown: release registry claims and drop remaining state first
    // so every outstanding reply sender is gone, THEN wake waiters —
    // they observe the disconnect instead of sleeping to the deadline.
    let ShardRt { state, ctx, .. } = rt;
    ctx.registry.release_all_owned(ctx.shard);
    drop(rx);
    drop(state);
    ctx.events.notify();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(mol: &str, k: usize, ticket: u64, priority: Priority) -> ExpandReq {
        let (reply, _rx) = mpsc::sync_channel(1);
        ExpandReq { smiles: mol.to_string(), k, ticket, deadline: None, priority, reply }
    }

    #[test]
    fn registry_routes_joins_and_releases_by_owner() {
        let reg = InFlightRegistry::new();
        assert_eq!(reg.route("CCO"), None);
        assert_eq!(reg.route_or_claim("CCO", 2), (2, false), "first claim takes fallback");
        assert_eq!(reg.route_or_claim("CCO", 5), (2, true), "second submit joins the owner");
        assert_eq!(reg.route("CCO"), Some(2));
        reg.release_if_owned("CCO", 1);
        assert_eq!(reg.route("CCO"), Some(2), "non-owner release is a no-op");
        reg.release_if_owned("CCO", 2);
        assert_eq!(reg.route("CCO"), None);
    }

    #[test]
    fn registry_claim_is_first_owner_wins() {
        let reg = InFlightRegistry::new();
        reg.claim("CCN", 3);
        reg.claim("CCN", 0);
        assert_eq!(reg.route("CCN"), Some(3));
        reg.claim("CCC", 0);
        reg.release_all_owned(3);
        assert_eq!(reg.route("CCN"), None);
        assert_eq!(reg.route("CCC"), Some(0), "other shards' claims survive");
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn steal_queue_claims_interactive_first_fifo_within_class() {
        let q = StealQueue::new();
        assert!(q.is_empty());
        // Batch spills arrive first; a later interactive spill must
        // still be claimed before either of them.
        q.push(req("B1", 1, 1, Priority::Batch));
        q.push(req("B2", 2, 2, Priority::Batch));
        q.push(req("I1", 3, 3, Priority::Interactive));
        q.push(req("I2", 4, 4, Priority::Interactive));
        assert!(!q.is_empty());
        assert_eq!(q.depths(), (2, 2));
        assert_eq!(q.pop().unwrap().smiles, "I1", "interactive lane drains first");
        assert_eq!(q.pop().unwrap().smiles, "I2", "FIFO within the interactive lane");
        assert_eq!(q.pop().unwrap().smiles, "B1", "then the batch lane, FIFO");
        assert_eq!(q.pop().unwrap().smiles, "B2");
        assert!(q.pop().is_none());
        assert_eq!(q.depths(), (0, 0));
    }

    fn empty_state() -> HubState {
        HubState {
            cache: SyncExpansionCache::new(4),
            waiting: HashMap::new(),
            covered: HashMap::new(),
            to_submit: Vec::new(),
            to_submit_idx: HashMap::new(),
            batch_backlog: VecDeque::new(),
        }
    }

    #[test]
    fn requeue_merges_by_max_k_and_tombstones_survive() {
        let state = &mut empty_state();
        state.requeue("CCO".into(), 3);
        state.requeue("CCN".into(), 2);
        state.requeue("CCO".into(), 5);
        state.drop_queued_miss("CCN");
        let round = state.take_submit_round();
        assert_eq!(round, vec![("CCO".to_string(), 5)]);
        assert!(!state.has_queued_misses());
    }

    #[test]
    fn batch_backlog_cancel_and_expiry_prune_by_ticket_and_deadline() {
        let state = &mut empty_state();
        let past = std::time::Instant::now() - std::time::Duration::from_millis(1);
        let mut expiring = req("CCO", 2, 7, Priority::Batch);
        expiring.deadline = Some(past);
        state.batch_backlog.push_back(expiring);
        state.batch_backlog.push_back(req("CCN", 2, 8, Priority::Batch));
        state.batch_backlog.push_back(req("CCC", 2, 9, Priority::Batch));
        assert!(state.remove_backlogged("CCN", 8), "cancel removes by (mol, ticket)");
        assert!(!state.remove_backlogged("CCN", 8), "second removal is a no-op");
        let expired = state.expire_batch_backlog(std::time::Instant::now());
        assert_eq!(expired, vec!["CCO".to_string()]);
        assert_eq!(state.batch_backlog.len(), 1, "undated entry survives the sweep");
        assert_eq!(state.batch_backlog[0].smiles, "CCC");
    }
}
