//! Shared-prefix token arena: beam prefixes as parent-pointer trie nodes.
//!
//! Every decoder used to carry each beam as an owned `Vec<i32>` and
//! clone it on every candidate push — O(len) heap traffic per candidate,
//! thousands of times per decode cycle. The arena replaces that with a
//! parent-pointer trie: extending a beam is one `push` (an O(1) append
//! to a flat `Vec<Node>`), candidates share their common prefix
//! structurally, and full token sequences are materialized only when a
//! model call or `finalize` actually needs the bytes.
//!
//! Each node also carries a *chain hash* of its token sequence
//! (`mix(parent_hash, tok)`), so two nodes spell the same sequence iff
//! their hashes match (collisions are resolved exactly via
//! [`TokenArena::seq_eq`]). This is what lets [`super::CandidatePool`]
//! deduplicate candidates without ever materializing or cloning a token
//! vector.
//!
//! The arena is append-only *within* a decode cycle: nodes of discarded
//! candidates are retained (24 bytes each) until either the arena drops
//! or the owning task runs a **compaction** between cycles
//! ([`TokenArena::compact_begin`] / [`TokenArena::compact_mark`] /
//! [`TokenArena::compact_finish`]): live chains — the current beams and
//! their ancestors — are copied into a fresh node table (ancestor-first,
//! so parents always precede children), ids are remapped through a
//! reusable [`CompactScratch`], and everything else is dropped in bulk.
//! Chain hashes, lengths and tokens are preserved verbatim, so dedup and
//! parity semantics are unaffected; the swap keeps both buffers'
//! capacity, so steady-state compaction allocates nothing. This bounds
//! arena growth on long sequences / huge K instead of retaining every
//! discarded candidate for a whole `generate`/task lifetime.

/// Index of a node in a [`TokenArena`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NodeId(u32);

const NIL: u32 = u32::MAX;

#[derive(Clone, Copy)]
struct Node {
    parent: u32,
    tok: i32,
    len: u32,
    hash: u64,
}

/// Append-only parent-pointer trie over token ids.
pub struct TokenArena {
    nodes: Vec<Node>,
}

#[inline]
fn mix(parent_hash: u64, tok: i32) -> u64 {
    // SplitMix64-style finalizer over (parent chain, token): order-
    // sensitive, so distinct sequences get distinct hashes w.h.p.
    let mut x = parent_hash
        .rotate_left(5)
        .wrapping_add(tok as u32 as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^ (x >> 27)
}

impl TokenArena {
    pub fn new() -> Self {
        Self { nodes: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { nodes: Vec::with_capacity(n) }
    }

    /// Number of nodes allocated so far (diagnostics).
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Start a new chain (a length-1 sequence holding `tok`, usually BOS).
    pub fn root(&mut self, tok: i32) -> NodeId {
        self.alloc(NIL, tok, 1, mix(0x5EED_F00D_CAFE_D00D, tok))
    }

    /// Extend `parent`'s sequence by one token. O(1).
    pub fn push(&mut self, parent: NodeId, tok: i32) -> NodeId {
        let p = &self.nodes[parent.0 as usize];
        let (len, hash) = (p.len + 1, mix(p.hash, tok));
        self.alloc(parent.0, tok, len, hash)
    }

    #[inline]
    fn alloc(&mut self, parent: u32, tok: i32, len: u32, hash: u64) -> NodeId {
        let id = self.nodes.len() as u32;
        debug_assert!(id != NIL, "arena overflow");
        self.nodes.push(Node { parent, tok, len, hash });
        NodeId(id)
    }

    /// Sequence length of the chain ending at `id`.
    #[inline]
    pub fn len(&self, id: NodeId) -> usize {
        self.nodes[id.0 as usize].len as usize
    }

    /// Last token of the chain ending at `id`.
    #[inline]
    pub fn last_tok(&self, id: NodeId) -> i32 {
        self.nodes[id.0 as usize].tok
    }

    /// Order-sensitive hash of the full token sequence at `id`.
    #[inline]
    pub fn seq_hash(&self, id: NodeId) -> u64 {
        self.nodes[id.0 as usize].hash
    }

    /// Exact sequence equality (used to resolve rare hash collisions).
    pub fn seq_eq(&self, a: NodeId, b: NodeId) -> bool {
        if a == b {
            return true;
        }
        let (mut x, mut y) = (a.0, b.0);
        if self.nodes[x as usize].len != self.nodes[y as usize].len {
            return false;
        }
        while x != y {
            // x == NIL implies y == NIL here because lengths match.
            if x == NIL {
                return true;
            }
            let (nx, ny) = (&self.nodes[x as usize], &self.nodes[y as usize]);
            if nx.tok != ny.tok {
                return false;
            }
            x = nx.parent;
            y = ny.parent;
        }
        true
    }

    /// Write the full token sequence at `id` into `out` (cleared first).
    /// Reuses `out`'s capacity, so steady-state calls allocate nothing.
    pub fn materialize_into(&self, id: NodeId, out: &mut Vec<i32>) {
        self.materialize_suffix_into(id, 0, out);
    }

    /// Write tokens `[from..len)` of the chain at `id` into `out`
    /// (cleared first); `from >= len` yields an empty suffix. This is
    /// the delta-row builder: a row whose cached state covers the first
    /// `from` tokens sends only this suffix to the model.
    pub fn materialize_suffix_into(&self, id: NodeId, from: usize, out: &mut Vec<i32>) {
        out.clear();
        let mut cur = id.0;
        while cur != NIL {
            let n = &self.nodes[cur as usize];
            if (n.len as usize) <= from {
                break;
            }
            out.push(n.tok);
            cur = n.parent;
        }
        out.reverse();
    }

    /// Allocate and return the token sequence at `id`.
    pub fn tokens(&self, id: NodeId) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.len(id));
        self.materialize_into(id, &mut out);
        out
    }

    /// Start a compaction pass: reset `scratch`'s remap table for this
    /// arena's current node count.
    pub fn compact_begin(&self, scratch: &mut CompactScratch) {
        scratch.remap.clear();
        scratch.remap.resize(self.nodes.len(), NIL);
        scratch.nodes.clear();
        scratch.stack.clear();
    }

    /// Mark the chain ending at `id` (the node and all its ancestors) as
    /// live, assigning new ids ancestor-first. Idempotent per node:
    /// chains shared between marked beams are copied once.
    pub fn compact_mark(&self, scratch: &mut CompactScratch, id: NodeId) {
        let mut cur = id.0;
        while cur != NIL && scratch.remap[cur as usize] == NIL {
            scratch.stack.push(cur);
            cur = self.nodes[cur as usize].parent;
        }
        while let Some(old) = scratch.stack.pop() {
            let n = self.nodes[old as usize];
            let parent = if n.parent == NIL { NIL } else { scratch.remap[n.parent as usize] };
            scratch.remap[old as usize] = scratch.nodes.len() as u32;
            scratch.nodes.push(Node { parent, ..n });
        }
    }

    /// Swap the compacted node table in. Old ids stay translatable via
    /// [`CompactScratch::remapped`] until the next `compact_begin`; the
    /// old buffer becomes the scratch's spare (capacity retained).
    pub fn compact_finish(&mut self, scratch: &mut CompactScratch) {
        std::mem::swap(&mut self.nodes, &mut scratch.nodes);
    }
}

/// Reusable buffers for [`TokenArena`] compaction. One per decode task;
/// all three vectors keep their capacity across passes.
#[derive(Default)]
pub struct CompactScratch {
    /// old node id -> new node id (`NIL` = dead).
    remap: Vec<u32>,
    stack: Vec<u32>,
    nodes: Vec<Node>,
}

impl CompactScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Translate a pre-compaction id to its post-compaction id. The id
    /// must have been marked live in the pass that just finished.
    #[inline]
    pub fn remapped(&self, id: NodeId) -> NodeId {
        let new = self.remap[id.0 as usize];
        debug_assert!(new != NIL, "remapping a dead node");
        NodeId(new)
    }
}

impl Default for TokenArena {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_materialize() {
        let mut a = TokenArena::new();
        let r = a.root(1);
        let n1 = a.push(r, 5);
        let n2 = a.push(n1, 6);
        let sib = a.push(n1, 7);
        assert_eq!(a.tokens(n2), vec![1, 5, 6]);
        assert_eq!(a.tokens(sib), vec![1, 5, 7]);
        assert_eq!(a.tokens(r), vec![1]);
        assert_eq!(a.len(n2), 3);
        assert_eq!(a.last_tok(n2), 6);
        assert_eq!(a.node_count(), 4);
    }

    #[test]
    fn materialize_suffix_slices_the_chain() {
        let mut a = TokenArena::new();
        let r = a.root(1);
        let n1 = a.push(r, 5);
        let n2 = a.push(n1, 6);
        let mut buf = Vec::new();
        a.materialize_suffix_into(n2, 0, &mut buf);
        assert_eq!(buf, vec![1, 5, 6]);
        a.materialize_suffix_into(n2, 1, &mut buf);
        assert_eq!(buf, vec![5, 6]);
        a.materialize_suffix_into(n2, 2, &mut buf);
        assert_eq!(buf, vec![6]);
        a.materialize_suffix_into(n2, 3, &mut buf);
        assert!(buf.is_empty());
        a.materialize_suffix_into(n2, 9, &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn materialize_reuses_buffer() {
        let mut a = TokenArena::new();
        let r = a.root(1);
        let n = a.push(r, 9);
        let mut buf = Vec::with_capacity(8);
        a.materialize_into(n, &mut buf);
        assert_eq!(buf, vec![1, 9]);
        let ptr = buf.as_ptr();
        a.materialize_into(r, &mut buf);
        assert_eq!(buf, vec![1]);
        assert_eq!(ptr, buf.as_ptr(), "no reallocation for shorter sequences");
    }

    #[test]
    fn equal_sequences_equal_hashes() {
        let mut a = TokenArena::new();
        let r = a.root(1);
        // Two different paths spelling [1, 5, 6].
        let p1 = a.push(r, 5);
        let x = a.push(p1, 6);
        let p2 = a.push(r, 5);
        let y = a.push(p2, 6);
        assert_ne!(x, y);
        assert_eq!(a.seq_hash(x), a.seq_hash(y));
        assert!(a.seq_eq(x, y));
        // Distinct sequences: distinct hash (w.h.p.) and !seq_eq.
        let z = a.push(p1, 7);
        assert_ne!(a.seq_hash(x), a.seq_hash(z));
        assert!(!a.seq_eq(x, z));
        // Same multiset, different order.
        let r2 = a.root(1);
        let q = a.push(r2, 6);
        let w = a.push(q, 5);
        assert_ne!(a.seq_hash(x), a.seq_hash(w));
        assert!(!a.seq_eq(x, w));
        // Different lengths never compare equal.
        assert!(!a.seq_eq(x, p1));
    }

    #[test]
    fn compact_keeps_live_chains_and_drops_the_rest() {
        let mut a = TokenArena::new();
        let r = a.root(1);
        let keep1 = a.push(r, 5);
        let keep2 = a.push(keep1, 6);
        let dead = a.push(r, 7);
        let _dead2 = a.push(dead, 8);
        let keep3 = a.push(r, 9); // second live branch sharing the root
        assert_eq!(a.node_count(), 6);
        let (h2, h3) = (a.seq_hash(keep2), a.seq_hash(keep3));

        let mut s = CompactScratch::new();
        a.compact_begin(&mut s);
        a.compact_mark(&mut s, keep2);
        a.compact_mark(&mut s, keep3);
        a.compact_finish(&mut s);

        // live: root, keep1, keep2, keep3 — dead branch gone
        assert_eq!(a.node_count(), 4);
        let k2 = s.remapped(keep2);
        let k3 = s.remapped(keep3);
        assert_eq!(a.tokens(k2), vec![1, 5, 6]);
        assert_eq!(a.tokens(k3), vec![1, 9]);
        assert_eq!(a.seq_hash(k2), h2, "chain hashes preserved");
        assert_eq!(a.seq_hash(k3), h3);
        assert_eq!(a.len(k2), 3);
        assert_eq!(a.last_tok(k2), 6);
        // the arena stays usable: push onto a remapped node
        let grown = a.push(k2, 11);
        assert_eq!(a.tokens(grown), vec![1, 5, 6, 11]);
    }

    #[test]
    fn compact_is_idempotent_for_shared_prefixes() {
        let mut a = TokenArena::new();
        let r = a.root(1);
        let x = a.push(r, 5);
        let y = a.push(x, 6);
        let mut s = CompactScratch::new();
        a.compact_begin(&mut s);
        a.compact_mark(&mut s, y);
        a.compact_mark(&mut s, y); // double-mark: copied once
        a.compact_mark(&mut s, x); // ancestor already live
        a.compact_finish(&mut s);
        assert_eq!(a.node_count(), 3);
        assert_eq!(a.tokens(s.remapped(y)), vec![1, 5, 6]);
        assert_eq!(a.tokens(s.remapped(x)), vec![1, 5]);
    }

    #[test]
    fn compact_scratch_buffers_are_reused() {
        let mut a = TokenArena::new();
        let r = a.root(1);
        let mut tip = r;
        for t in 0..32 {
            tip = a.push(tip, t);
        }
        let mut s = CompactScratch::new();
        a.compact_begin(&mut s);
        a.compact_mark(&mut s, tip);
        a.compact_finish(&mut s);
        tip = s.remapped(tip);
        let remap_ptr = s.remap.as_ptr();
        // A second pass over a same-sized arena must not reallocate.
        a.compact_begin(&mut s);
        a.compact_mark(&mut s, tip);
        a.compact_finish(&mut s);
        assert_eq!(remap_ptr, s.remap.as_ptr());
        assert_eq!(a.node_count(), 33);
    }

    #[test]
    fn roots_are_independent_chains() {
        let mut a = TokenArena::new();
        let r1 = a.root(1);
        let r2 = a.root(1);
        assert!(a.seq_eq(r1, r2));
        assert_eq!(a.seq_hash(r1), a.seq_hash(r2));
        let r3 = a.root(2);
        assert!(!a.seq_eq(r1, r3));
    }
}
