//! Beam search: the vanilla baseline and the "optimized" variant.
//!
//! Vanilla ("beam search" in Table 1): every query contributes K rows to
//! every decode call until the *whole group* finishes — finished beams
//! keep occupying rows, which is exactly the inefficiency the paper's
//! "beam search optimized" baseline removes (finished beams are put
//! aside, shrinking the effective batch).
//!
//! Runs on the zero-allocation decoding core: beams live in a
//! [`TokenArena`], scoring goes through a reusable
//! [`ScoringScratch`], and candidate rows recycle their buffers via
//! [`RowBuf`] — steady-state cycles perform no heap allocation on the
//! host side.

use super::arena::TokenArena;
use super::{finalize, Beam, CandidatePool, DecodeStats, Decoder, GenOutput, RowBuf};
use crate::model::scratch::ScoringScratch;
use crate::model::StepModel;
use crate::tokenizer::EOS;
use anyhow::Result;

/// Beam search configuration.
#[derive(Clone, Debug)]
pub struct BeamSearch {
    /// Put finished beams aside (the "optimized" variant).
    pub optimized: bool,
}

impl BeamSearch {
    pub fn vanilla() -> Self {
        Self { optimized: false }
    }

    pub fn optimized() -> Self {
        Self { optimized: true }
    }
}

impl Decoder for BeamSearch {
    fn name(&self) -> &'static str {
        if self.optimized {
            "beam-search-optimized"
        } else {
            "beam-search"
        }
    }

    fn generate(
        &self,
        model: &dyn StepModel,
        srcs: &[Vec<i32>],
        k: usize,
        stats: &mut DecodeStats,
    ) -> Result<Vec<GenOutput>> {
        let t0 = std::time::Instant::now();
        let mem = model.encode(srcs)?;
        stats.encode_calls += 1;
        let max_len = model.max_tgt();

        // Per query: K beams. Step 0 starts from a single root beam; the
        // vanilla variant still submits K duplicate rows to keep the
        // effective batch at B*K from the start (naive-implementation
        // faithful).
        let mut arena = TokenArena::with_capacity(srcs.len() * k * 16);
        let root = Beam::root(&mut arena);
        let mut beams: Vec<Vec<Beam>> = srcs.iter().map(|_| vec![root]).collect();
        let mut done: Vec<bool> = vec![false; srcs.len()];

        let mut scratch = ScoringScratch::new();
        let mut rowbuf = RowBuf::new();
        // (query, beam index) per row, for scatter-back.
        let mut row_of: Vec<(usize, usize)> = Vec::new();
        let mut pools: Vec<CandidatePool> =
            (0..srcs.len()).map(|_| CandidatePool::new(k)).collect();
        let mut next: Vec<Beam> = Vec::with_capacity(k);

        while !done.iter().all(|&d| d) {
            // Build rows.
            rowbuf.begin();
            row_of.clear();
            for (q, qbeams) in beams.iter().enumerate() {
                if done[q] && self.optimized {
                    continue;
                }
                for (bi, b) in qbeams.iter().enumerate() {
                    if self.optimized && b.finished {
                        continue;
                    }
                    let live_row = !b.finished;
                    // Vanilla: submit rows even for finished beams/queries.
                    if !self.optimized || live_row {
                        rowbuf.push_row(&arena, mem, q, b.node, &[]);
                        row_of.push((q, bi));
                    }
                }
                // Vanilla duplicates the root beam K times on the first step.
                if !self.optimized && qbeams.len() == 1 && !qbeams[0].finished {
                    for _ in 1..k {
                        rowbuf.push_row(&arena, mem, q, qbeams[0].node, &[]);
                        row_of.push((q, usize::MAX)); // duplicate; ignored
                    }
                }
            }
            if rowbuf.is_empty() {
                break;
            }
            let out = model.decode(&rowbuf.rows, 1)?;
            stats.model_calls += 1;
            stats.rows_logical += rowbuf.len() as u64;
            stats.rows_padded += out.padded_rows as u64;

            // Expand each query.
            for pool in pools.iter_mut() {
                pool.reset();
            }
            // carry forward finished beams as candidates
            for (q, qbeams) in beams.iter().enumerate() {
                for b in qbeams {
                    if b.finished {
                        pools[q].push(*b);
                    }
                }
            }
            for (r, &(q, bi)) in row_of.iter().enumerate() {
                if bi == usize::MAX {
                    continue; // first-step duplicate row
                }
                let b = beams[q][bi];
                if b.finished {
                    continue; // vanilla submitted it; result ignored
                }
                let j = out
                    .offset_of(r, arena.len(b.node) - 1)
                    .expect("window covers last position");
                scratch.top_k_log_softmax(out.logits(r, j, 0), k);
                for &tok in &scratch.topk {
                    let node = arena.push(b.node, tok as i32);
                    let finished = tok as i32 == EOS || arena.len(node) >= max_len;
                    pools[q].push(Beam { node, logp: b.logp + scratch.lsm[tok], finished });
                }
            }
            for (q, pool) in pools.iter_mut().enumerate() {
                if done[q] {
                    continue;
                }
                pool.take_into(&arena, &mut next);
                if !next.is_empty() {
                    std::mem::swap(&mut beams[q], &mut next);
                }
                done[q] = beams[q].iter().all(|b| b.finished);
            }
        }
        model.release(mem);
        stats.wall_secs += t0.elapsed().as_secs_f64();
        Ok(beams.iter().map(|qb| finalize(&arena, qb)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mock::{MockConfig, MockModel};
    use crate::tokenizer::BOS;

    fn src(tokens: &[i32]) -> Vec<i32> {
        let mut v = vec![BOS];
        v.extend_from_slice(tokens);
        v.push(EOS);
        v
    }

    #[test]
    fn top1_is_copy_of_source() {
        let model = MockModel::new(MockConfig::default());
        let mut stats = DecodeStats::default();
        let out = BeamSearch::vanilla()
            .generate(&model, &[src(&[5, 6, 7, 8])], 4, &mut stats)
            .unwrap();
        assert_eq!(out[0].hyps[0].body(), &[5, 6, 7, 8]);
        assert!(out[0].hyps[0].finished());
        assert_eq!(out[0].hyps.len(), 4);
        // hypotheses sorted by logp
        for w in out[0].hyps.windows(2) {
            assert!(w[0].logp >= w[1].logp);
        }
    }

    #[test]
    fn optimized_matches_vanilla_results_with_fewer_rows() {
        let model = MockModel::new(MockConfig::default());
        let srcs = vec![src(&[5, 6, 7]), src(&[9, 10, 11, 12, 13])];
        let mut s1 = DecodeStats::default();
        let out1 = BeamSearch::vanilla().generate(&model, &srcs, 3, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        let out2 = BeamSearch::optimized().generate(&model, &srcs, 3, &mut s2).unwrap();
        for (a, b) in out1.iter().zip(out2.iter()) {
            assert_eq!(a.hyps[0].tokens, b.hyps[0].tokens);
            assert!((a.hyps[0].logp - b.hyps[0].logp).abs() < 1e-9);
        }
        assert!(
            s2.rows_logical < s1.rows_logical,
            "optimized {} !< vanilla {}",
            s2.rows_logical,
            s1.rows_logical
        );
    }

    #[test]
    fn vanilla_effective_batch_is_constant_bk() {
        let model = MockModel::new(MockConfig::default());
        let srcs = vec![src(&[5, 6, 7]), src(&[9, 10, 11, 12, 13])];
        let mut s = DecodeStats::default();
        BeamSearch::vanilla().generate(&model, &srcs, 5, &mut s).unwrap();
        assert_eq!(s.avg_effective_batch(), 10.0); // B=2, K=5
    }

    #[test]
    fn beams_are_distinct() {
        let model = MockModel::new(MockConfig::default());
        let mut stats = DecodeStats::default();
        let out = BeamSearch::vanilla()
            .generate(&model, &[src(&[5, 6, 7, 8, 9])], 5, &mut stats)
            .unwrap();
        let mut seen = std::collections::HashSet::new();
        for h in &out[0].hyps {
            assert!(seen.insert(h.tokens.clone()), "duplicate {:?}", h.tokens);
        }
    }

    #[test]
    fn respects_max_len() {
        let model = MockModel::new(MockConfig { max_tgt: 6, ..Default::default() });
        let mut stats = DecodeStats::default();
        let out = BeamSearch::vanilla()
            .generate(&model, &[src(&[5, 6, 7, 8, 9, 10, 11, 12])], 2, &mut stats)
            .unwrap();
        for h in &out[0].hyps {
            assert!(h.tokens.len() < 6);
        }
    }
}
