//! Beam search: the vanilla baseline and the "optimized" variant.
//!
//! Vanilla ("beam search" in Table 1): every query contributes K rows to
//! every decode call until the *whole group* finishes — finished beams
//! keep occupying rows, which is exactly the inefficiency the paper's
//! "beam search optimized" baseline removes (finished beams are put
//! aside, shrinking the effective batch).
//!
//! Runs on the zero-allocation decoding core: beams live in a
//! [`TokenArena`], scoring goes through a reusable
//! [`ScoringScratch`], and candidate rows recycle their buffers via
//! [`RowBuf`] — steady-state cycles perform no heap allocation on the
//! host side.
//!
//! The algorithm itself lives in [`BeamTask`], a resumable
//! [`DecodeTask`]: one `next_rows`/`absorb` round trip per beam step.
//! `BeamSearch::generate` is the solo driver over it; the fused
//! [`super::scheduler::DecodeScheduler`] interleaves many such tasks.

use super::arena::{CompactScratch, TokenArena};
use super::{
    adopt_beams, compact_beams, delta_spec, finalize, release_beam_states, release_state, Beam,
    CandidatePool, DecodeStats, DecodeTask, Decoder, ForkBatch, GenOutput, RowBuf, TaskState,
    COMPACT_MIN,
};
use crate::model::scratch::ScoringScratch;
use crate::model::{DecodeOut, MemView, StateId, StateParent, StepModel};
use crate::tokenizer::EOS;
use anyhow::Result;

/// Beam search configuration.
#[derive(Clone, Debug)]
pub struct BeamSearch {
    /// Put finished beams aside (the "optimized" variant).
    pub optimized: bool,
}

impl BeamSearch {
    pub fn vanilla() -> Self {
        Self { optimized: false }
    }

    pub fn optimized() -> Self {
        Self { optimized: true }
    }
}

impl Decoder for BeamSearch {
    fn name(&self) -> &'static str {
        if self.optimized {
            "beam-search-optimized"
        } else {
            "beam-search"
        }
    }

    fn start_task_on(
        &self,
        model: &dyn StepModel,
        views: Vec<MemView>,
        srcs: &[Vec<i32>],
        k: usize,
    ) -> Result<Box<dyn DecodeTask>> {
        debug_assert_eq!(views.len(), srcs.len(), "one memory view per query");
        // Per query: K beams. Step 0 starts from a single root beam; the
        // vanilla variant still submits K duplicate rows to keep the
        // effective batch at B*K from the start (naive-implementation
        // faithful).
        let mut arena = TokenArena::with_capacity(srcs.len() * k * 16);
        let root = Beam::root(&mut arena);
        Ok(Box::new(BeamTask {
            optimized: self.optimized,
            k,
            max_len: model.max_tgt(),
            inc: model.supports_incremental(),
            views,
            arena,
            beams: srcs.iter().map(|_| vec![root]).collect(),
            done: vec![false; srcs.len()],
            scratch: ScoringScratch::new(),
            row_of: Vec::new(),
            pools: (0..srcs.len()).map(|_| CandidatePool::new(k)).collect(),
            next: Vec::with_capacity(k),
            stats: DecodeStats { encode_calls: 1, ..Default::default() },
            compact: CompactScratch::new(),
            compact_at: COMPACT_MIN,
            cycle_states: Vec::new(),
            fork_batch: ForkBatch::new(),
        }))
    }
}

/// Resumable beam-search state: one `next_rows`/`absorb` round trip per
/// beam step.
pub struct BeamTask {
    optimized: bool,
    k: usize,
    max_len: usize,
    /// Delta rows over cached decoder state (the model supports the
    /// incremental protocol); otherwise classic full-prefix rows.
    inc: bool,
    /// One ref-counted encoder-memory view per query (possibly rows of
    /// a batch shared with other tasks).
    views: Vec<MemView>,
    arena: TokenArena,
    beams: Vec<Vec<Beam>>,
    done: Vec<bool>,
    scratch: ScoringScratch,
    /// (query, beam index) per row, for scatter-back.
    row_of: Vec<(usize, usize)>,
    pools: Vec<CandidatePool>,
    next: Vec<Beam>,
    stats: DecodeStats,
    compact: CompactScratch,
    compact_at: usize,
    /// Claims from this cycle's `state_commit`s, released once
    /// survivors have adopted theirs.
    cycle_states: Vec<StateId>,
    /// The cycle's fork commits, batched into one model call.
    fork_batch: ForkBatch,
}

impl DecodeTask for BeamTask {
    fn next_rows(&mut self, rows: &mut RowBuf) -> TaskState {
        if self.done.iter().all(|&d| d) {
            return TaskState::Done;
        }
        self.row_of.clear();
        let before = rows.len();
        for (q, qbeams) in self.beams.iter().enumerate() {
            if self.done[q] && self.optimized {
                continue;
            }
            for (bi, b) in qbeams.iter().enumerate() {
                if self.optimized && b.finished {
                    continue;
                }
                let live_row = !b.finished;
                // Vanilla: submit rows even for finished beams/queries.
                if !self.optimized || live_row {
                    let v = &self.views[q];
                    let (state, from) = delta_spec(&self.arena, b, self.inc);
                    rows.push_row_delta(&self.arena, v.mem(), v.row(), state, b.node, from, &[]);
                    self.row_of.push((q, bi));
                }
            }
            // Vanilla duplicates the root beam K times on the first step.
            if !self.optimized && qbeams.len() == 1 && !qbeams[0].finished {
                for _ in 1..self.k {
                    let b = qbeams[0];
                    let v = &self.views[q];
                    let (state, from) = delta_spec(&self.arena, &b, self.inc);
                    rows.push_row_delta(&self.arena, v.mem(), v.row(), state, b.node, from, &[]);
                    self.row_of.push((q, usize::MAX)); // duplicate; ignored
                }
            }
        }
        if rows.len() == before {
            TaskState::Done
        } else {
            TaskState::Need { win: 1 }
        }
    }

    fn absorb(&mut self, model: &dyn StepModel, out: &DecodeOut, range: std::ops::Range<usize>) {
        debug_assert_eq!(range.len(), self.row_of.len());
        // Expand each query.
        for pool in self.pools.iter_mut() {
            pool.reset();
        }
        // carry forward finished beams as candidates
        for (q, qbeams) in self.beams.iter().enumerate() {
            for b in qbeams {
                if b.finished {
                    self.pools[q].push(*b);
                }
            }
        }
        self.cycle_states.clear();
        // Pass 1: queue one fork per expanding row — this call
        // processed each beam's last token, so `prefix ++ [last]` is
        // committable now — then commit the whole cycle in ONE batch.
        self.fork_batch.clear();
        if self.inc {
            for &(q, bi) in self.row_of.iter() {
                if bi == usize::MAX {
                    continue;
                }
                let b = self.beams[q][bi];
                if b.finished {
                    continue;
                }
                self.fork_batch.push(
                    &self.views[q],
                    StateParent::Id(b.state),
                    self.arena.last_tok(b.node),
                );
            }
        }
        self.fork_batch.flush(model, &mut self.inc, &mut self.cycle_states);
        // Pass 2: expand; every surviving child anchors on the state
        // committed for its parent's row. The slot counter walks the
        // same rows pass 1 queued (same skip conditions).
        let mut slot = 0usize;
        for (r, &(q, bi)) in self.row_of.iter().enumerate() {
            if bi == usize::MAX {
                continue; // first-step duplicate row
            }
            let b = self.beams[q][bi];
            if b.finished {
                continue; // vanilla submitted it; result ignored
            }
            let gr = range.start + r;
            let j = out
                .offset_of(gr, self.arena.len(b.node) - 1)
                .expect("window covers last position");
            let anchor = self.fork_batch.id(slot);
            slot += 1;
            self.scratch.top_k_log_softmax(out.logits(gr, j, 0), self.k);
            for &tok in &self.scratch.topk {
                let node = self.arena.push(b.node, tok as i32);
                let finished = tok as i32 == EOS || self.arena.len(node) >= self.max_len;
                self.pools[q].push(Beam {
                    node,
                    logp: b.logp + self.scratch.lsm[tok],
                    finished,
                    state: anchor,
                });
            }
        }
        for (q, pool) in self.pools.iter_mut().enumerate() {
            if self.done[q] {
                continue;
            }
            pool.take_into(&self.arena, &mut self.next);
            if !self.next.is_empty() {
                adopt_beams(model, &mut self.beams[q], &mut self.next);
            }
            self.done[q] = self.beams[q].iter().all(|b| b.finished);
        }
        // Commits nobody adopted die here (rollback); adopted anchors
        // survive on the beams' own claims.
        for s in self.cycle_states.drain(..) {
            release_state(model, s);
        }
        compact_beams(&mut self.arena, &mut self.compact, &mut self.beams, &mut self.compact_at);
    }

    fn stats_mut(&mut self) -> &mut DecodeStats {
        &mut self.stats
    }

    fn arena_nodes(&self) -> usize {
        self.arena.node_count()
    }

    fn finish(self: Box<Self>, model: &dyn StepModel) -> (Vec<GenOutput>, DecodeStats) {
        let this = *self;
        release_beam_states(model, &this.beams);
        crate::model::release_views(model, this.views);
        let outs = this.beams.iter().map(|qb| finalize(&this.arena, qb)).collect();
        (outs, this.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mock::{MockConfig, MockModel};
    use crate::tokenizer::BOS;

    fn src(tokens: &[i32]) -> Vec<i32> {
        let mut v = vec![BOS];
        v.extend_from_slice(tokens);
        v.push(EOS);
        v
    }

    #[test]
    fn top1_is_copy_of_source() {
        let model = MockModel::new(MockConfig::default());
        let mut stats = DecodeStats::default();
        let out = BeamSearch::vanilla()
            .generate(&model, &[src(&[5, 6, 7, 8])], 4, &mut stats)
            .unwrap();
        assert_eq!(out[0].hyps[0].body(), &[5, 6, 7, 8]);
        assert!(out[0].hyps[0].finished());
        assert_eq!(out[0].hyps.len(), 4);
        // hypotheses sorted by logp
        for w in out[0].hyps.windows(2) {
            assert!(w[0].logp >= w[1].logp);
        }
    }

    #[test]
    fn optimized_matches_vanilla_results_with_fewer_rows() {
        let model = MockModel::new(MockConfig::default());
        let srcs = vec![src(&[5, 6, 7]), src(&[9, 10, 11, 12, 13])];
        let mut s1 = DecodeStats::default();
        let out1 = BeamSearch::vanilla().generate(&model, &srcs, 3, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        let out2 = BeamSearch::optimized().generate(&model, &srcs, 3, &mut s2).unwrap();
        for (a, b) in out1.iter().zip(out2.iter()) {
            assert_eq!(a.hyps[0].tokens, b.hyps[0].tokens);
            assert!((a.hyps[0].logp - b.hyps[0].logp).abs() < 1e-9);
        }
        assert!(
            s2.rows_logical < s1.rows_logical,
            "optimized {} !< vanilla {}",
            s2.rows_logical,
            s1.rows_logical
        );
    }

    #[test]
    fn vanilla_effective_batch_is_constant_bk() {
        let model = MockModel::new(MockConfig::default());
        let srcs = vec![src(&[5, 6, 7]), src(&[9, 10, 11, 12, 13])];
        let mut s = DecodeStats::default();
        BeamSearch::vanilla().generate(&model, &srcs, 5, &mut s).unwrap();
        assert_eq!(s.avg_effective_batch(), 10.0); // B=2, K=5
    }

    #[test]
    fn beams_are_distinct() {
        let model = MockModel::new(MockConfig::default());
        let mut stats = DecodeStats::default();
        let out = BeamSearch::vanilla()
            .generate(&model, &[src(&[5, 6, 7, 8, 9])], 5, &mut stats)
            .unwrap();
        let mut seen = std::collections::HashSet::new();
        for h in &out[0].hyps {
            assert!(seen.insert(h.tokens.clone()), "duplicate {:?}", h.tokens);
        }
    }

    #[test]
    fn respects_max_len() {
        let model = MockModel::new(MockConfig { max_tgt: 6, ..Default::default() });
        let mut stats = DecodeStats::default();
        let out = BeamSearch::vanilla()
            .generate(&model, &[src(&[5, 6, 7, 8, 9, 10, 11, 12])], 2, &mut stats)
            .unwrap();
        for h in &out[0].hyps {
            assert!(h.tokens.len() < 6);
        }
    }
}
