//! HSBS: speculative beam search with heuristic drafting.
//!
//! Drafts are fragments of the *query* SMILES (the SBS paper's insight:
//! large parts of the product string reappear verbatim in the
//! reactants). The "smart" variant extracts fragments starting right
//! after positions whose token matches the beam's last generated token;
//! remaining draft slots are filled with evenly spaced windows.
//!
//! Per step, every live beam submits `n_drafts` rows (prefix ++ draft).
//! Verification is greedy-consistent: draft tokens are accepted while
//! they equal the main head's argmax. Candidates are harvested at every
//! accepted length from the best draft, ranked by cumulative
//! log-probability, and the top K become the next beams. This trades a
//! larger effective batch (`O(B*K*n_drafts)`) for fewer sequential model
//! calls — the scalability ceiling the paper's Medusa variant removes.

use super::{finalize, Beam, CandidatePool, Decoder, DecodeStats, GenOutput};
use crate::model::{argmax, log_softmax, DecodeRow, StepModel};
use crate::tokenizer::EOS;
use anyhow::Result;

/// Heuristic-drafting speculative beam search.
#[derive(Clone, Debug)]
pub struct Hsbs {
    pub n_drafts: usize,
    pub draft_len: usize,
}

impl Hsbs {
    pub fn new(n_drafts: usize, draft_len: usize) -> Self {
        Self { n_drafts: n_drafts.max(1), draft_len: draft_len.max(1) }
    }

    /// The paper's per-batch-size draft schedule (Table 1 caption):
    /// B=1 -> 10x10, B<=4 -> 3x10, else 1x20.
    pub fn for_batch_size(b: usize) -> Self {
        if b <= 1 {
            Self::new(10, 10)
        } else if b <= 4 {
            Self::new(3, 10)
        } else {
            Self::new(1, 20)
        }
    }

    /// Extract drafts from the source for a beam whose last token is
    /// `last`. Returns up to `n_drafts` non-empty token windows.
    fn make_drafts(&self, src_body: &[i32], last: i32, budget: usize) -> Vec<Vec<i32>> {
        let mut out: Vec<Vec<i32>> = Vec::with_capacity(self.n_drafts);
        if budget == 0 || src_body.is_empty() {
            return out;
        }
        let dlen = self.draft_len.min(budget);
        // smart: windows following a token equal to `last`
        for (i, &t) in src_body.iter().enumerate() {
            if out.len() >= self.n_drafts {
                break;
            }
            if t == last && i + 1 < src_body.len() {
                let w: Vec<i32> =
                    src_body[i + 1..(i + 1 + dlen).min(src_body.len())].to_vec();
                if !w.is_empty() && !out.contains(&w) {
                    out.push(w);
                }
            }
        }
        // fill: evenly spaced windows
        let stride = (src_body.len() / self.n_drafts.max(1)).max(1);
        let mut start = 0;
        while out.len() < self.n_drafts && start < src_body.len() {
            let w: Vec<i32> = src_body[start..(start + dlen).min(src_body.len())].to_vec();
            if !w.is_empty() && !out.contains(&w) {
                out.push(w);
            }
            start += stride;
        }
        out
    }
}

impl Decoder for Hsbs {
    fn name(&self) -> &'static str {
        "hsbs"
    }

    fn generate(
        &self,
        model: &dyn StepModel,
        srcs: &[Vec<i32>],
        k: usize,
        stats: &mut DecodeStats,
    ) -> Result<Vec<GenOutput>> {
        let t0 = std::time::Instant::now();
        let mem = model.encode(srcs)?;
        stats.encode_calls += 1;
        let max_len = model.max_tgt();
        let win = self.draft_len + 1;

        // Source bodies (without BOS/EOS) for drafting.
        let bodies: Vec<&[i32]> = srcs
            .iter()
            .map(|s| {
                let inner = &s[1..];
                match inner.split_last() {
                    Some((&last, rest)) if last == EOS => rest,
                    _ => inner,
                }
            })
            .collect();

        let mut beams: Vec<Vec<Beam>> = srcs.iter().map(|_| vec![Beam::root()]).collect();
        let mut done: Vec<bool> = vec![false; srcs.len()];

        while !done.iter().all(|&d| d) {
            // Build (beam, draft) rows for all live beams.
            let mut rows: Vec<DecodeRow> = Vec::new();
            // (query, beam, draft tokens)
            let mut row_meta: Vec<(usize, usize, Vec<i32>)> = Vec::new();
            for (q, qbeams) in beams.iter().enumerate() {
                if done[q] {
                    continue;
                }
                for (bi, b) in qbeams.iter().enumerate() {
                    if b.finished {
                        continue;
                    }
                    let budget = max_len.saturating_sub(b.tokens.len());
                    let last = *b.tokens.last().unwrap();
                    let mut drafts = self.make_drafts(bodies[q], last, budget);
                    if drafts.is_empty() {
                        drafts.push(Vec::new()); // plain one-token step
                    }
                    for d in drafts {
                        let mut tgt = b.tokens.clone();
                        tgt.extend_from_slice(&d);
                        rows.push(DecodeRow { mem, mem_row: q, tgt, pos: b.tokens.len() - 1 });
                        row_meta.push((q, bi, d));
                    }
                }
            }
            if rows.is_empty() {
                break;
            }
            let out = model.decode(&rows, win)?;
            stats.model_calls += 1;
            stats.rows_logical += rows.len() as u64;
            stats.rows_padded += out.padded_rows as u64;

            // Per (query, beam): pick the draft with most accepted tokens.
            use std::collections::HashMap;
            // (q, bi) -> (accepted, row index)
            let mut best: HashMap<(usize, usize), (usize, usize)> = HashMap::new();
            for (r, (q, bi, draft)) in row_meta.iter().enumerate() {
                let b = &beams[*q][*bi];
                let p0 = b.tokens.len() - 1;
                let mut acc = 0;
                for (j, &dt) in draft.iter().enumerate() {
                    let Some(off) = out.offset_of(r, p0 + j) else { break };
                    let greedy = argmax(out.logits(r, off, 0)) as i32;
                    if greedy == dt && dt != EOS {
                        acc += 1;
                    } else {
                        break;
                    }
                }
                let e = best.entry((*q, *bi)).or_insert((acc, r));
                if acc > e.0 {
                    *e = (acc, r);
                }
            }

            // Harvest candidates.
            let mut pools: Vec<CandidatePool> =
                (0..srcs.len()).map(|_| CandidatePool::new(k)).collect();
            for (q, qbeams) in beams.iter().enumerate() {
                for b in qbeams {
                    if b.finished {
                        pools[q].push(b.clone());
                    }
                }
            }
            for (&(q, bi), &(acc, r)) in best.iter() {
                let b = &beams[q][bi];
                let p0 = b.tokens.len() - 1;
                let draft = &row_meta[r].2;
                stats.drafts_offered += draft.len() as u64;
                stats.drafts_accepted += acc as u64;
                // Backbone-and-divergences harvesting (see msbs.rs for the
                // rationale): top-K continuations at the end of the
                // accepted backbone, top-K divergent branches elsewhere.
                let ext_cap = acc.min(draft.len());
                let mut cum = b.logp;
                for j in 0..=ext_cap {
                    let Some(off) = out.offset_of(r, p0 + j) else { break };
                    let lsm = log_softmax(out.logits(r, off, 0));
                    let prefix_len = b.tokens.len() + j;
                    if prefix_len >= max_len {
                        break;
                    }
                    let backbone_end = j == ext_cap;
                    for &tok in crate::model::top_k(&lsm, k).iter() {
                        if !backbone_end && tok as i32 == draft[j] {
                            continue;
                        }
                        let mut t = b.tokens.clone();
                        t.extend_from_slice(&draft[..j]);
                        t.push(tok as i32);
                        let finished = tok as i32 == EOS || t.len() >= max_len;
                        pools[q].push(Beam { tokens: t, logp: cum + lsm[tok], finished });
                    }
                    if j < draft.len() {
                        cum += lsm[draft[j] as usize];
                    }
                }
            }
            for (q, pool) in pools.into_iter().enumerate() {
                if done[q] {
                    continue;
                }
                let next = pool.take();
                if !next.is_empty() {
                    beams[q] = next;
                }
                done[q] = beams[q].iter().all(|b| b.finished);
            }
        }
        model.release(mem);
        stats.wall_secs += t0.elapsed().as_secs_f64();
        Ok(beams.into_iter().map(finalize).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};
    use crate::tokenizer::BOS;

    fn src(tokens: &[i32]) -> Vec<i32> {
        let mut v = vec![BOS];
        v.extend_from_slice(tokens);
        v.push(EOS);
        v
    }

    #[test]
    fn top1_matches_beam_search() {
        let model = MockModel::new(MockConfig::default());
        let s = vec![src(&[5, 6, 7, 8, 9, 10])];
        let mut s1 = DecodeStats::default();
        let bs = BeamSearch::vanilla().generate(&model, &s, 3, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        let hs = Hsbs::new(4, 4).generate(&model, &s, 3, &mut s2).unwrap();
        assert_eq!(bs[0].hyps[0].tokens, hs[0].hyps[0].tokens);
        assert!((bs[0].hyps[0].logp - hs[0].hyps[0].logp).abs() < 1e-9);
    }

    #[test]
    fn fewer_model_calls_than_beam_search() {
        // The mock's copy task means query fragments are perfect drafts.
        // Like MSBS, the speculative win needs paper-scale K (nested
        // beams of different lengths carry the progress).
        let model = MockModel::new(MockConfig::default());
        let body: Vec<i32> = (5..23).collect();
        let s = vec![src(&body)];
        let mut s1 = DecodeStats::default();
        BeamSearch::vanilla().generate(&model, &s, 10, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        Hsbs::new(4, 8).generate(&model, &s, 10, &mut s2).unwrap();
        assert!(
            s2.model_calls < s1.model_calls,
            "hsbs {} !< bs {}",
            s2.model_calls,
            s1.model_calls
        );
        assert!(s2.acceptance_rate() > 0.5, "acceptance {}", s2.acceptance_rate());
    }

    #[test]
    fn drafts_prefer_matching_positions() {
        let h = Hsbs::new(3, 3);
        // last token 7 appears at index 2; smart draft = src[3..6]
        let drafts = h.make_drafts(&[5, 6, 7, 8, 9, 10], 7, 100);
        assert_eq!(drafts[0], vec![8, 9, 10]);
        assert_eq!(drafts.len(), 3);
    }

    #[test]
    fn paper_schedule() {
        assert_eq!((Hsbs::for_batch_size(1).n_drafts, Hsbs::for_batch_size(1).draft_len), (10, 10));
        assert_eq!((Hsbs::for_batch_size(4).n_drafts, Hsbs::for_batch_size(4).draft_len), (3, 10));
        assert_eq!((Hsbs::for_batch_size(16).n_drafts, Hsbs::for_batch_size(16).draft_len), (1, 20));
    }

    #[test]
    fn all_hypotheses_finish_on_easy_input(){
        let model = MockModel::new(MockConfig::default());
        let mut st = DecodeStats::default();
        let out = Hsbs::new(2, 5)
            .generate(&model, &[src(&[5, 6, 7, 8])], 3, &mut st)
            .unwrap();
        assert_eq!(out[0].hyps.len(), 3);
        assert!(out[0].hyps[0].finished());
    }
}
