//! HSBS: speculative beam search with heuristic drafting.
//!
//! Drafts are fragments of the *query* SMILES (the SBS paper's insight:
//! large parts of the product string reappear verbatim in the
//! reactants). The "smart" variant extracts fragments starting right
//! after positions whose token matches the beam's last generated token;
//! remaining draft slots are filled with evenly spaced windows.
//!
//! Per step, every live beam submits `n_drafts` rows (prefix ++ draft).
//! Verification is greedy-consistent: draft tokens are accepted while
//! they equal the main head's argmax. Candidates are harvested at every
//! accepted length from the best draft, ranked by cumulative
//! log-probability, and the top K become the next beams. This trades a
//! larger effective batch (`O(B*K*n_drafts)`) for fewer sequential model
//! calls — the scalability ceiling the paper's Medusa variant removes.
//!
//! Hot-loop layout: drafts are `(start, end)` windows into the query
//! body (never copied), beams are [`TokenArena`] nodes, and the
//! best-draft-per-beam selection is a single deterministic scan over
//! the row metadata (rows for one beam are contiguous by construction).
//!
//! The algorithm lives in [`HsbsTask`], a resumable [`DecodeTask`]: one
//! `next_rows`/`absorb` round trip per draft-and-verify step (HSBS
//! verifies in the same call that scores, so one phase per cycle).

use super::arena::{CompactScratch, TokenArena};
use super::{
    adopt_beams, chain_links, compact_beams, delta_spec, finalize, release_beam_states,
    release_state, Beam, CandidatePool, DecodeStats, DecodeTask, Decoder, ForkBatch, GenOutput,
    RowBuf, TaskState, COMPACT_MIN,
};
use crate::model::scratch::ScoringScratch;
use crate::model::{argmax, DecodeOut, MemView, StateId, StateParent, StepModel};
use crate::tokenizer::EOS;
use anyhow::Result;

/// Heuristic-drafting speculative beam search.
#[derive(Clone, Debug)]
pub struct Hsbs {
    pub n_drafts: usize,
    pub draft_len: usize,
}

impl Hsbs {
    pub fn new(n_drafts: usize, draft_len: usize) -> Self {
        Self { n_drafts: n_drafts.max(1), draft_len: draft_len.max(1) }
    }

    /// The paper's per-batch-size draft schedule (Table 1 caption):
    /// B=1 -> 10x10, B<=4 -> 3x10, else 1x20.
    pub fn for_batch_size(b: usize) -> Self {
        if b <= 1 {
            Self::new(10, 10)
        } else if b <= 4 {
            Self::new(3, 10)
        } else {
            Self::new(1, 20)
        }
    }

    /// Extract drafts from the source for a beam whose last token is
    /// `last`: up to `n_drafts` non-empty `(start, end)` windows into
    /// `src_body`, written into `out` (cleared first; no token copies).
    fn make_drafts_into(
        &self,
        src_body: &[i32],
        last: i32,
        budget: usize,
        out: &mut Vec<(usize, usize)>,
    ) {
        out.clear();
        if budget == 0 || src_body.is_empty() {
            return;
        }
        let dlen = self.draft_len.min(budget);
        let contains = |out: &[(usize, usize)], w: (usize, usize)| {
            out.iter().any(|&(s, e)| src_body[s..e] == src_body[w.0..w.1])
        };
        // smart: windows following a token equal to `last`
        for (i, &t) in src_body.iter().enumerate() {
            if out.len() >= self.n_drafts {
                break;
            }
            if t == last && i + 1 < src_body.len() {
                let w = (i + 1, (i + 1 + dlen).min(src_body.len()));
                if w.1 > w.0 && !contains(out, w) {
                    out.push(w);
                }
            }
        }
        // fill: evenly spaced windows
        let stride = (src_body.len() / self.n_drafts.max(1)).max(1);
        let mut start = 0;
        while out.len() < self.n_drafts && start < src_body.len() {
            let w = (start, (start + dlen).min(src_body.len()));
            if w.1 > w.0 && !contains(out, w) {
                out.push(w);
            }
            start += stride;
        }
    }
}

impl Decoder for Hsbs {
    fn name(&self) -> &'static str {
        "hsbs"
    }

    fn start_task_on(
        &self,
        model: &dyn StepModel,
        views: Vec<MemView>,
        srcs: &[Vec<i32>],
        k: usize,
    ) -> Result<Box<dyn DecodeTask>> {
        debug_assert_eq!(views.len(), srcs.len(), "one memory view per query");
        // Source bodies (without BOS/EOS) for drafting.
        let bodies: Vec<Vec<i32>> = srcs
            .iter()
            .map(|s| {
                let inner = &s[1..];
                match inner.split_last() {
                    Some((&last, rest)) if last == EOS => rest.to_vec(),
                    _ => inner.to_vec(),
                }
            })
            .collect();
        let mut arena = TokenArena::with_capacity(srcs.len() * k * 16);
        let root = Beam::root(&mut arena);
        Ok(Box::new(HsbsTask {
            cfg: self.clone(),
            k,
            max_len: model.max_tgt(),
            inc: model.supports_incremental(),
            views,
            bodies,
            arena,
            beams: srcs.iter().map(|_| vec![root]).collect(),
            done: vec![false; srcs.len()],
            scratch: ScoringScratch::new(),
            row_meta: Vec::new(),
            windows: Vec::new(),
            best: Vec::new(),
            pools: (0..srcs.len()).map(|_| CandidatePool::new(k)).collect(),
            next: Vec::with_capacity(k),
            stats: DecodeStats { encode_calls: 1, ..Default::default() },
            compact: CompactScratch::new(),
            compact_at: COMPACT_MIN,
            cycle_states: Vec::new(),
            fork_batch: ForkBatch::new(),
            chain_slots: Vec::new(),
        }))
    }
}

/// Resumable HSBS state: one `next_rows`/`absorb` round trip per
/// speculative step.
pub struct HsbsTask {
    cfg: Hsbs,
    k: usize,
    max_len: usize,
    /// Delta rows over cached decoder state when the model supports it.
    inc: bool,
    /// One ref-counted encoder-memory view per query (possibly rows of
    /// a batch shared with other tasks).
    views: Vec<MemView>,
    /// Source bodies (without BOS/EOS), owned by the task for drafting.
    bodies: Vec<Vec<i32>>,
    arena: TokenArena,
    beams: Vec<Vec<Beam>>,
    done: Vec<bool>,
    scratch: ScoringScratch,
    /// (query, beam, draft window into bodies[query]) per row.
    row_meta: Vec<(usize, usize, usize, usize)>,
    windows: Vec<(usize, usize)>,
    /// (query, beam, accepted, row) — best draft per beam.
    best: Vec<(usize, usize, usize, usize)>,
    pools: Vec<CandidatePool>,
    next: Vec<Beam>,
    stats: DecodeStats,
    compact: CompactScratch,
    compact_at: usize,
    /// Claims from this cycle's backbone commits, released after
    /// survivor adoption (losing drafts are never committed — rollback).
    cycle_states: Vec<StateId>,
    /// The cycle's fork commits, batched into one model call.
    fork_batch: ForkBatch,
    /// Per-`best`-entry root slot in the batch; the entry's chain
    /// occupies slots `root..=root+links` contiguously.
    chain_slots: Vec<usize>,
}

impl DecodeTask for HsbsTask {
    fn next_rows(&mut self, rows: &mut RowBuf) -> TaskState {
        if self.done.iter().all(|&d| d) {
            return TaskState::Done;
        }
        // Build (beam, draft) rows for all live beams.
        self.row_meta.clear();
        let before = rows.len();
        for (q, qbeams) in self.beams.iter().enumerate() {
            if self.done[q] {
                continue;
            }
            for (bi, b) in qbeams.iter().enumerate() {
                if b.finished {
                    continue;
                }
                let budget = self.max_len.saturating_sub(self.arena.len(b.node));
                let last = self.arena.last_tok(b.node);
                self.cfg.make_drafts_into(&self.bodies[q], last, budget, &mut self.windows);
                if self.windows.is_empty() {
                    self.windows.push((0, 0)); // plain one-token step
                }
                for &(s, e) in &self.windows {
                    let v = &self.views[q];
                    let (state, from) = delta_spec(&self.arena, b, self.inc);
                    rows.push_row_delta(
                        &self.arena,
                        v.mem(),
                        v.row(),
                        state,
                        b.node,
                        from,
                        &self.bodies[q][s..e],
                    );
                    self.row_meta.push((q, bi, s, e));
                }
            }
        }
        if rows.len() == before {
            TaskState::Done
        } else {
            TaskState::Need { win: self.cfg.draft_len + 1 }
        }
    }

    fn absorb(&mut self, model: &dyn StepModel, out: &DecodeOut, range: std::ops::Range<usize>) {
        debug_assert_eq!(range.len(), self.row_meta.len());
        // Per (query, beam): pick the draft with most accepted
        // tokens. Rows of one beam are contiguous, so one scan with
        // a running entry suffices (deterministic, beam order).
        self.best.clear();
        for (r, &(q, bi, s, e)) in self.row_meta.iter().enumerate() {
            let b = self.beams[q][bi];
            let p0 = self.arena.len(b.node) - 1;
            let draft = &self.bodies[q][s..e];
            let gr = range.start + r;
            let mut acc = 0;
            for (j, &dt) in draft.iter().enumerate() {
                let Some(off) = out.offset_of(gr, p0 + j) else { break };
                let greedy = argmax(out.logits(gr, off, 0)) as i32;
                if greedy == dt && dt != EOS {
                    acc += 1;
                } else {
                    break;
                }
            }
            let same_beam = matches!(self.best.last(), Some(e) if e.0 == q && e.1 == bi);
            if same_beam {
                let entry = self.best.last_mut().expect("just matched");
                if acc > entry.2 {
                    entry.2 = acc;
                    entry.3 = r;
                }
            } else {
                self.best.push((q, bi, acc, r));
            }
        }

        // Harvest candidates.
        for pool in self.pools.iter_mut() {
            pool.reset();
        }
        for (q, qbeams) in self.beams.iter().enumerate() {
            for b in qbeams {
                if b.finished {
                    self.pools[q].push(*b);
                }
            }
        }
        self.cycle_states.clear();
        // Pass 1 — plan the backbone state chains: one root fork per
        // winning row (its call just processed the beam's last token)
        // plus one link per accepted backbone token, expressed as
        // intra-batch `Slot` parents so the whole cycle commits in ONE
        // model call. Losing drafts never commit — free rollback.
        self.fork_batch.clear();
        self.chain_slots.clear();
        if self.inc {
            for &(q, bi, acc, r) in self.best.iter() {
                let b = self.beams[q][bi];
                let p0 = self.arena.len(b.node) - 1;
                let (ds, de) = (self.row_meta[r].2, self.row_meta[r].3);
                let draft = &self.bodies[q][ds..de];
                let ext_cap = acc.min(draft.len());
                let gr = range.start + r;
                let root = self.fork_batch.push(
                    &self.views[q],
                    StateParent::Id(b.state),
                    self.arena.last_tok(b.node),
                );
                self.chain_slots.push(root);
                // Mirror the harvest loop's break order: the fork at
                // iteration j happens before that iteration's window /
                // max-length checks.
                let links = chain_links(out, gr, p0, self.max_len, ext_cap);
                let mut prev = root;
                for j in 1..=links {
                    prev = self.fork_batch.push(
                        &self.views[q],
                        StateParent::Slot(prev),
                        draft[j - 1],
                    );
                }
            }
        }
        self.fork_batch.flush(model, &mut self.inc, &mut self.cycle_states);

        // Pass 2 — harvest. Backbone-and-divergences (see msbs.rs for
        // the rationale): top-K continuations at the end of the
        // accepted backbone, top-K divergent branches elsewhere.
        for (i, &(q, bi, acc, r)) in self.best.iter().enumerate() {
            let b = self.beams[q][bi];
            let blen = self.arena.len(b.node);
            let p0 = blen - 1;
            let gr = range.start + r;
            let (ds, de) = (self.row_meta[r].2, self.row_meta[r].3);
            let draft = &self.bodies[q][ds..de];
            self.stats.drafts_offered += draft.len() as u64;
            self.stats.drafts_accepted += acc as u64;
            let ext_cap = acc.min(draft.len());
            let mut cum = b.logp;
            let mut backbone = b.node;
            let root_slot = self.chain_slots.get(i).copied().unwrap_or(usize::MAX);
            let mut anchor = if root_slot == usize::MAX {
                StateId::NONE
            } else {
                self.fork_batch.id(root_slot)
            };
            for j in 0..=ext_cap {
                if j > 0 {
                    backbone = self.arena.push(backbone, draft[j - 1]);
                    anchor = if root_slot == usize::MAX {
                        StateId::NONE
                    } else {
                        self.fork_batch.id(root_slot + j)
                    };
                }
                let Some(off) = out.offset_of(gr, p0 + j) else { break };
                let prefix_len = blen + j;
                if prefix_len >= self.max_len {
                    break;
                }
                let backbone_end = j == ext_cap;
                self.scratch.top_k_log_softmax(out.logits(gr, off, 0), self.k);
                for &tok in &self.scratch.topk {
                    if !backbone_end && tok as i32 == draft[j] {
                        continue;
                    }
                    let node = self.arena.push(backbone, tok as i32);
                    let finished = tok as i32 == EOS || self.arena.len(node) >= self.max_len;
                    self.pools[q].push(Beam {
                        node,
                        logp: cum + self.scratch.lsm[tok],
                        finished,
                        state: anchor,
                    });
                }
                if j < draft.len() {
                    cum += self.scratch.lsm[draft[j] as usize];
                }
            }
        }
        for (q, pool) in self.pools.iter_mut().enumerate() {
            if self.done[q] {
                continue;
            }
            pool.take_into(&self.arena, &mut self.next);
            if !self.next.is_empty() {
                adopt_beams(model, &mut self.beams[q], &mut self.next);
            }
            self.done[q] = self.beams[q].iter().all(|b| b.finished);
        }
        for s in self.cycle_states.drain(..) {
            release_state(model, s);
        }
        compact_beams(&mut self.arena, &mut self.compact, &mut self.beams, &mut self.compact_at);
    }

    fn stats_mut(&mut self) -> &mut DecodeStats {
        &mut self.stats
    }

    fn arena_nodes(&self) -> usize {
        self.arena.node_count()
    }

    fn finish(self: Box<Self>, model: &dyn StepModel) -> (Vec<GenOutput>, DecodeStats) {
        let this = *self;
        release_beam_states(model, &this.beams);
        crate::model::release_views(model, this.views);
        let outs = this.beams.iter().map(|qb| finalize(&this.arena, qb)).collect();
        (outs, this.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};
    use crate::tokenizer::BOS;

    fn src(tokens: &[i32]) -> Vec<i32> {
        let mut v = vec![BOS];
        v.extend_from_slice(tokens);
        v.push(EOS);
        v
    }

    fn drafts_of(h: &Hsbs, body: &[i32], last: i32, budget: usize) -> Vec<Vec<i32>> {
        let mut windows = Vec::new();
        h.make_drafts_into(body, last, budget, &mut windows);
        windows.iter().map(|&(s, e)| body[s..e].to_vec()).collect()
    }

    #[test]
    fn top1_matches_beam_search() {
        let model = MockModel::new(MockConfig::default());
        let s = vec![src(&[5, 6, 7, 8, 9, 10])];
        let mut s1 = DecodeStats::default();
        let bs = BeamSearch::vanilla().generate(&model, &s, 3, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        let hs = Hsbs::new(4, 4).generate(&model, &s, 3, &mut s2).unwrap();
        assert_eq!(bs[0].hyps[0].tokens, hs[0].hyps[0].tokens);
        assert!((bs[0].hyps[0].logp - hs[0].hyps[0].logp).abs() < 1e-9);
    }

    #[test]
    fn fewer_model_calls_than_beam_search() {
        // The mock's copy task means query fragments are perfect drafts.
        // Like MSBS, the speculative win needs paper-scale K (nested
        // beams of different lengths carry the progress).
        let model = MockModel::new(MockConfig::default());
        let body: Vec<i32> = (5..23).collect();
        let s = vec![src(&body)];
        let mut s1 = DecodeStats::default();
        BeamSearch::vanilla().generate(&model, &s, 10, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        Hsbs::new(4, 8).generate(&model, &s, 10, &mut s2).unwrap();
        assert!(
            s2.model_calls < s1.model_calls,
            "hsbs {} !< bs {}",
            s2.model_calls,
            s1.model_calls
        );
        assert!(s2.acceptance_rate() > 0.5, "acceptance {}", s2.acceptance_rate());
    }

    #[test]
    fn drafts_prefer_matching_positions() {
        let h = Hsbs::new(3, 3);
        // last token 7 appears at index 2; smart draft = src[3..6]
        let drafts = drafts_of(&h, &[5, 6, 7, 8, 9, 10], 7, 100);
        assert_eq!(drafts[0], vec![8, 9, 10]);
        assert_eq!(drafts.len(), 3);
    }

    #[test]
    fn paper_schedule() {
        let sched = |b: usize| {
            let h = Hsbs::for_batch_size(b);
            (h.n_drafts, h.draft_len)
        };
        assert_eq!(sched(1), (10, 10));
        assert_eq!(sched(4), (3, 10));
        assert_eq!(sched(16), (1, 20));
    }

    #[test]
    fn all_hypotheses_finish_on_easy_input() {
        let model = MockModel::new(MockConfig::default());
        let mut st = DecodeStats::default();
        let out = Hsbs::new(2, 5)
            .generate(&model, &[src(&[5, 6, 7, 8])], 3, &mut st)
            .unwrap();
        assert_eq!(out[0].hyps.len(), 3);
        assert!(out[0].hyps[0].finished());
    }
}
