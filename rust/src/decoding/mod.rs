//! Single-step decoding engines.
//!
//! Four inference strategies for the SMILES-to-SMILES transformer, all
//! implementing [`Decoder`]:
//!
//! * [`beam::BeamSearch`] — vanilla beam search (finished beams keep
//!   occupying model-call rows; the paper's "beam search" baseline) and
//!   the "optimized" variant (finished beams leave the batch);
//! * [`hsbs::Hsbs`] — speculative beam search with heuristic drafting
//!   (query-fragment drafts, the SBS paper's "smart" variant);
//! * [`msbs::Msbs`] — speculative beam search with Medusa-head drafting:
//!   the paper's headline method. Two model calls per cycle (draft +
//!   verify with top-p nucleus acceptance), top-K candidate harvesting
//!   at every accepted prefix length.
//!
//! Engines operate on *groups* of queries (one encode per group, shared
//! decode calls) so the batch-size sweeps of Table 1 and the beam-width
//! batching of Table 4 fall out naturally.

pub mod beam;
pub mod hsbs;
pub mod msbs;

use crate::model::StepModel;
use anyhow::Result;

/// One generated hypothesis: tokens without BOS; ends with EOS iff the
/// model finished it within the length budget.
#[derive(Clone, Debug, PartialEq)]
pub struct Hypothesis {
    pub tokens: Vec<i32>,
    pub logp: f64,
}

impl Hypothesis {
    pub fn finished(&self) -> bool {
        self.tokens.last() == Some(&crate::tokenizer::EOS)
    }

    /// Tokens without the trailing EOS.
    pub fn body(&self) -> &[i32] {
        match self.tokens.split_last() {
            Some((&last, rest)) if last == crate::tokenizer::EOS => rest,
            _ => &self.tokens,
        }
    }
}

/// K hypotheses for one query, sorted by descending log-probability.
#[derive(Clone, Debug, Default)]
pub struct GenOutput {
    pub hyps: Vec<Hypothesis>,
}

/// Accounting for Table 1 (wall time is tracked by the caller's clock
/// around `generate`, and also accumulated here for convenience).
#[derive(Clone, Debug, Default)]
pub struct DecodeStats {
    /// Decoder forward passes (Table 1B).
    pub model_calls: u64,
    pub encode_calls: u64,
    /// Sum over calls of the logical row count (Table 1C numerator).
    pub rows_logical: u64,
    /// Sum over calls of the padded (bucketed) row count.
    pub rows_padded: u64,
    /// Draft tokens offered by the chosen draft per verification.
    pub drafts_offered: u64,
    /// Draft tokens accepted (Table 1D numerator).
    pub drafts_accepted: u64,
    pub wall_secs: f64,
}

impl DecodeStats {
    pub fn avg_effective_batch(&self) -> f64 {
        if self.model_calls == 0 {
            0.0
        } else {
            self.rows_logical as f64 / self.model_calls as f64
        }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.drafts_offered == 0 {
            0.0
        } else {
            self.drafts_accepted as f64 / self.drafts_offered as f64
        }
    }

    pub fn merge(&mut self, o: &DecodeStats) {
        self.model_calls += o.model_calls;
        self.encode_calls += o.encode_calls;
        self.rows_logical += o.rows_logical;
        self.rows_padded += o.rows_padded;
        self.drafts_offered += o.drafts_offered;
        self.drafts_accepted += o.drafts_accepted;
        self.wall_secs += o.wall_secs;
    }
}

/// A decoding engine: generate K candidate target sequences for each of
/// a group of query token sequences.
pub trait Decoder: Send + Sync {
    fn name(&self) -> &'static str;
    /// `srcs` are BOS/EOS-wrapped query token rows (one group = one
    /// encode + shared decode batches).
    fn generate(
        &self,
        model: &dyn StepModel,
        srcs: &[Vec<i32>],
        k: usize,
        stats: &mut DecodeStats,
    ) -> Result<Vec<GenOutput>>;
}

/// An in-flight beam (BOS-led token prefix).
#[derive(Clone, Debug)]
pub(crate) struct Beam {
    pub tokens: Vec<i32>,
    pub logp: f64,
    pub finished: bool,
}

impl Beam {
    pub fn root() -> Beam {
        Beam { tokens: vec![crate::tokenizer::BOS], logp: 0.0, finished: false }
    }

    pub fn into_hypothesis(self) -> Hypothesis {
        Hypothesis { tokens: self.tokens[1..].to_vec(), logp: self.logp }
    }
}

/// Candidate pool helper: keeps the best `k` unique token sequences.
pub(crate) struct CandidatePool {
    k: usize,
    items: Vec<Beam>,
}

impl CandidatePool {
    pub fn new(k: usize) -> Self {
        Self { k, items: Vec::with_capacity(k * 4) }
    }

    pub fn push(&mut self, b: Beam) {
        self.items.push(b);
    }

    /// Top-k by logp, deduplicated by token sequence (keep best score).
    pub fn take(mut self) -> Vec<Beam> {
        self.items.sort_by(|a, b| {
            b.logp
                .partial_cmp(&a.logp)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut seen: std::collections::HashSet<Vec<i32>> = std::collections::HashSet::new();
        let mut out: Vec<Beam> = Vec::with_capacity(self.k);
        for b in self.items.drain(..) {
            if out.len() >= self.k {
                break;
            }
            if seen.insert(b.tokens.clone()) {
                out.push(b);
            }
        }
        out
    }
}

/// Build a decoder by name: `bs` / `beam-search`, `bs-opt`, `hsbs`,
/// `msbs`. `batch_hint` sizes HSBS's draft schedule (Table 1 caption).
pub fn make_decoder(name: &str, batch_hint: usize) -> anyhow::Result<Box<dyn Decoder + Send>> {
    Ok(match name {
        "bs" | "beam" | "beam-search" => Box::new(beam::BeamSearch::vanilla()),
        "bs-opt" | "beam-search-optimized" => Box::new(beam::BeamSearch::optimized()),
        "hsbs" => Box::new(hsbs::Hsbs::for_batch_size(batch_hint)),
        "msbs" => Box::new(msbs::Msbs::default()),
        other => anyhow::bail!("unknown decoder {other:?} (bs|bs-opt|hsbs|msbs)"),
    })
}

/// Sort hypotheses by descending logp into a [`GenOutput`].
pub(crate) fn finalize(beams: Vec<Beam>) -> GenOutput {
    let mut hyps: Vec<Hypothesis> = beams.into_iter().map(Beam::into_hypothesis).collect();
    hyps.sort_by(|a, b| b.logp.partial_cmp(&a.logp).unwrap_or(std::cmp::Ordering::Equal));
    GenOutput { hyps }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_pool_dedups_and_sorts() {
        let mut pool = CandidatePool::new(2);
        pool.push(Beam { tokens: vec![1, 5], logp: -1.0, finished: false });
        pool.push(Beam { tokens: vec![1, 5], logp: -0.5, finished: false });
        pool.push(Beam { tokens: vec![1, 6], logp: -2.0, finished: false });
        pool.push(Beam { tokens: vec![1, 7], logp: -3.0, finished: false });
        let top = pool.take();
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].tokens, vec![1, 5]);
        assert_eq!(top[0].logp, -0.5);
        assert_eq!(top[1].tokens, vec![1, 6]);
    }

    #[test]
    fn hypothesis_body_strips_eos() {
        let h = Hypothesis { tokens: vec![5, 6, crate::tokenizer::EOS], logp: 0.0 };
        assert!(h.finished());
        assert_eq!(h.body(), &[5, 6]);
        let h2 = Hypothesis { tokens: vec![5, 6], logp: 0.0 };
        assert!(!h2.finished());
        assert_eq!(h2.body(), &[5, 6]);
    }

    #[test]
    fn stats_rates() {
        let s = DecodeStats {
            model_calls: 4,
            rows_logical: 40,
            drafts_offered: 10,
            drafts_accepted: 9,
            ..Default::default()
        };
        assert_eq!(s.avg_effective_batch(), 10.0);
        assert_eq!(s.acceptance_rate(), 0.9);
    }
}
