//! Single-step decoding engines.
//!
//! Four inference strategies for the SMILES-to-SMILES transformer, all
//! implementing [`Decoder`]:
//!
//! * [`beam::BeamSearch`] — vanilla beam search (finished beams keep
//!   occupying model-call rows; the paper's "beam search" baseline) and
//!   the "optimized" variant (finished beams leave the batch);
//! * [`hsbs::Hsbs`] — speculative beam search with heuristic drafting
//!   (query-fragment drafts, the SBS paper's "smart" variant);
//! * [`msbs::Msbs`] — speculative beam search with Medusa-head drafting:
//!   the paper's headline method. Two model calls per cycle (draft +
//!   verify with top-p nucleus acceptance), top-K candidate harvesting
//!   at every accepted prefix length.
//!
//! Engines operate on *groups* of queries (one encode per group, shared
//! decode calls) so the batch-size sweeps of Table 1 and the beam-width
//! batching of Table 4 fall out naturally.
//!
//! ## Resumable tasks and cycle-level batching
//!
//! Every engine is written as a **resumable state machine** behind the
//! [`DecodeTask`] trait rather than a closed `generate` loop. One
//! [`DecodeTask::next_rows`] / [`DecodeTask::absorb`] round trip equals
//! one of the engine's decode cycles (MSBS's draft and verify calls are
//! two explicit phases of its task), which makes two drivers possible
//! over the *same* algorithm code:
//!
//! * [`Decoder::generate`] — the classic closed loop, now a thin default
//!   driver ([`run_task_to_done`]) over one task: build rows, run one
//!   [`StepModel::decode_into`], absorb, repeat. Existing callers,
//!   benches and the table harnesses are untouched.
//! * [`scheduler::DecodeScheduler`] — cycle-level continuous batching:
//!   many in-flight tasks' pending rows are concatenated into ONE fused
//!   model call per tick (per-row [`MemHandle`]s keep encoder memory
//!   per task), the logits windows are demultiplexed back, and a new
//!   expansion request joins the very next device call instead of
//!   queueing behind a whole multi-cycle `generate`. This is the
//!   serving-side lever behind the paper's throughput-under-latency
//!   claims: effective batch per call stays high even as individual
//!   requests' beams finish (the Table 1C decay).
//!
//! Task contract: `next_rows` *rebuilds* the current phase's rows and
//! must be idempotent (the scheduler may bounce a task to the next tick
//! when the fused-row budget is exhausted); all state advances happen in
//! `absorb`, which receives the fused [`crate::model::DecodeOut`] plus
//! the range its own rows occupy in it. Interleaving is
//! result-invariant: `tests/parity_decoding.rs` pins scheduler-fused
//! decoding bit-identical to solo `generate` for all four engines.
//!
//! ## Shared-encode admission
//!
//! Encoder memory is held through ref-counted row views
//! ([`crate::model::MemView`]): [`Decoder::start_task_on`] builds a
//! task over *pre-encoded* rows, so an admission layer (the
//! coordinator's hub) can encode every co-arriving molecule in ONE
//! [`StepModel::encode`] call and hand each molecule its own task over
//! its row — encoder cost becomes O(submission rounds), not O(misses).
//! The batch memory is freed on the device exactly when the last
//! member task finishes or is cancelled, so speculative cancellation
//! never strands a sibling's memory
//! (`tests/parity_encode_fusion.rs` pins both the bit-parity and the
//! ref-count rule).
//!
//! ## Incremental decode protocol
//!
//! When the model caches decoder state
//! ([`StepModel::supports_incremental`]), every engine sends **delta
//! rows**: a [`crate::model::StateId`] anchor covering the beam's
//! prefix plus only the new tokens ([`RowBuf::push_row_delta`]), so
//! decode cost per cycle is proportional to fresh positions, not
//! prefix length. Beam reordering is explicit state forking — each
//! survivor adopts (claims) the state committed for its parent's row
//! ([`adopt_beams`]); MSBS's draft and verify phases share the
//! accepted-prefix state, so a verify cycle processes only `draft_len`
//! new positions; rejected draft positions are never committed
//! (rollback is free). State lifetime follows the `MemView` ownership
//! discipline: a task's whole chain is released on retirement *and* on
//! cancellation, never stranding a sibling fork.
//! `DecodeStats::decode_tokens` counts positions actually processed,
//! and `tests/parity_decoding.rs` pins the incremental path
//! bit-identical (tokens, logp, all other stats) to the full-prefix
//! path for all four engines, solo and scheduler-fused. Models without
//! cached state keep receiving classic full-prefix rows.
//!
//! ## Zero-allocation decoding core
//!
//! All engines share primitives that keep the host-side hot loop free of
//! steady-state heap traffic (model calls dominate wall time in
//! production; the paper's several-second planning budget is why the
//! host side must not add to them):
//!
//! * [`arena::TokenArena`] — beam prefixes as parent-pointer trie
//!   nodes: extending a beam is an O(1) node push, not an O(len)
//!   `Vec<i32>` clone; sequences materialize only for model calls and
//!   [`finalize`]; per-cycle compaction keeps the node table bounded on
//!   long sequences / huge K;
//! * [`crate::model::scratch::ScoringScratch`] — reusable log-softmax /
//!   top-k buffers plus a fused nucleus-mass test over raw logits;
//! * [`CandidatePool`] — top-k by partial selection over beam indices,
//!   deduplicated by arena chain-hash instead of cloned token vectors;
//! * [`RowBuf`] + [`StepModel::decode_into`] — decode-call inputs *and*
//!   outputs recycle their buffers, so a steady-state cycle (or fused
//!   scheduler tick) performs no heap allocation.
//!
//! Semantics (hypotheses, tie order, log-probabilities, model-call
//! accounting) are preserved exactly; `tests/parity_decoding.rs` pins
//! them against reference implementations of the seed algorithms.

pub mod arena;
pub mod beam;
pub mod hsbs;
pub mod msbs;
pub mod scheduler;

use crate::model::{
    encode_shared, DecodeOut, DecodeRow, MemHandle, MemView, StateForkReq, StateId, StateParent,
    StepModel,
};
use anyhow::Result;
use arena::{NodeId, TokenArena};

/// One generated hypothesis: tokens without BOS; ends with EOS iff the
/// model finished it within the length budget.
#[derive(Clone, Debug, PartialEq)]
pub struct Hypothesis {
    pub tokens: Vec<i32>,
    pub logp: f64,
}

impl Hypothesis {
    pub fn finished(&self) -> bool {
        self.tokens.last() == Some(&crate::tokenizer::EOS)
    }

    /// Tokens without the trailing EOS.
    pub fn body(&self) -> &[i32] {
        match self.tokens.split_last() {
            Some((&last, rest)) if last == crate::tokenizer::EOS => rest,
            _ => &self.tokens,
        }
    }
}

/// K hypotheses for one query, sorted by descending log-probability.
#[derive(Clone, Debug, Default)]
pub struct GenOutput {
    pub hyps: Vec<Hypothesis>,
}

/// Accounting for Table 1 (wall time is tracked by the caller's clock
/// around `generate`, and also accumulated here for convenience).
#[derive(Clone, Debug, Default)]
pub struct DecodeStats {
    /// Decoder forward passes (Table 1B).
    pub model_calls: u64,
    pub encode_calls: u64,
    /// Sum over calls of the logical row count (Table 1C numerator).
    pub rows_logical: u64,
    /// Sum over calls of the padded (bucketed) row count.
    pub rows_padded: u64,
    /// Decoder positions actually processed: the sum of every row's
    /// delta length. On the full-prefix path this grows O(L²) per
    /// sequence (each cycle resends the whole prefix); with incremental
    /// state it is a small constant per generated token — the win the
    /// incremental decode protocol exists to deliver.
    pub decode_tokens: u64,
    /// Draft tokens offered by the chosen draft per verification.
    pub drafts_offered: u64,
    /// Draft tokens accepted (Table 1D numerator).
    pub drafts_accepted: u64,
    pub wall_secs: f64,
}

impl DecodeStats {
    pub fn avg_effective_batch(&self) -> f64 {
        if self.model_calls == 0 {
            0.0
        } else {
            self.rows_logical as f64 / self.model_calls as f64
        }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.drafts_offered == 0 {
            0.0
        } else {
            self.drafts_accepted as f64 / self.drafts_offered as f64
        }
    }

    pub fn merge(&mut self, o: &DecodeStats) {
        self.model_calls += o.model_calls;
        self.encode_calls += o.encode_calls;
        self.rows_logical += o.rows_logical;
        self.rows_padded += o.rows_padded;
        self.decode_tokens += o.decode_tokens;
        self.drafts_offered += o.drafts_offered;
        self.drafts_accepted += o.drafts_accepted;
        self.wall_secs += o.wall_secs;
    }
}

/// What a resumable decode task wants next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Rows were appended; the task needs one model call with a logits
    /// window of at least `win` positions, then an [`DecodeTask::absorb`].
    Need { win: usize },
    /// All queries finished (or nothing left to decode): outputs are
    /// ready via [`DecodeTask::finish`].
    Done,
}

/// A resumable decoding engine instance: one group of queries advancing
/// one decode cycle per `next_rows`/`absorb` round trip.
///
/// Contract:
/// * `next_rows` **rebuilds** the current phase's rows from task state
///   and appends them to `rows` (which may already hold other tasks'
///   rows). It must be idempotent — calling it again without an
///   intervening `absorb` appends an identical row set, so a scheduler
///   can truncate a task's rows back off the buffer and retry it next
///   tick when the fused-row budget is exhausted.
/// * `absorb` consumes the logits of this task's rows (`range` indexes
///   the rows of the call `out` answers; the window may be *wider* than
///   requested — logits are read by absolute position) and advances the
///   state machine by one phase.
/// * The driver — solo [`run_task_to_done`] or the fused
///   [`scheduler::DecodeScheduler`] — adds model-call-level accounting
///   (`model_calls`, `rows_logical`, `rows_padded`) through `stats_mut`;
///   the task itself accounts encode calls and draft acceptance.
pub trait DecodeTask: Send {
    /// Append pending rows for the current phase; see the trait docs.
    fn next_rows(&mut self, rows: &mut RowBuf) -> TaskState;
    /// Consume this task's logits window and advance one phase. The
    /// model is passed so incremental tasks can commit the decoder
    /// states this call just processed (and fork/release beam anchors).
    fn absorb(&mut self, model: &dyn StepModel, out: &DecodeOut, range: std::ops::Range<usize>);
    /// Per-task accounting (the paper's Table 1 counters).
    fn stats_mut(&mut self) -> &mut DecodeStats;
    /// Current token-arena node count (compaction diagnostics).
    fn arena_nodes(&self) -> usize;
    /// Release device memory and return per-query outputs plus the
    /// accumulated stats. Callable in any state (partial outputs are
    /// whatever the beams hold).
    fn finish(self: Box<Self>, model: &dyn StepModel) -> (Vec<GenOutput>, DecodeStats);
}

/// Drive a single task to completion against `model`: the closed-loop
/// `generate` shape, with the decode output buffer recycled across
/// cycles via [`StepModel::decode_into`].
pub fn run_task_to_done(model: &dyn StepModel, task: &mut dyn DecodeTask) -> Result<()> {
    let mut rows = RowBuf::new();
    let mut out = DecodeOut::default();
    loop {
        rows.begin();
        match task.next_rows(&mut rows) {
            TaskState::Done => return Ok(()),
            TaskState::Need { win } => {
                model.decode_into(&rows.rows, win, &mut out)?;
                let (n, padded) = (rows.len() as u64, out.padded_rows as u64);
                let toks: u64 = rows.rows.iter().map(|r| r.delta.len() as u64).sum();
                let st = task.stats_mut();
                st.model_calls += 1;
                st.rows_logical += n;
                st.rows_padded += padded;
                st.decode_tokens += toks;
                task.absorb(model, &out, 0..rows.len());
            }
        }
    }
}

/// A decoding engine: generate K candidate target sequences for each of
/// a group of query token sequences.
pub trait Decoder: Send + Sync {
    fn name(&self) -> &'static str;
    /// Start a resumable task over one group: encodes `srcs` in one
    /// [`encode_shared`] call (the task owns the resulting views until
    /// `finish`) and returns the engine's state machine positioned
    /// before its first decode cycle.
    fn start_task(
        &self,
        model: &dyn StepModel,
        srcs: &[Vec<i32>],
        k: usize,
    ) -> Result<Box<dyn DecodeTask>> {
        let views = encode_shared(model, srcs)?;
        self.start_task_on(model, views, srcs, k)
    }
    /// Start a resumable task over **pre-encoded** memory: `views[q]` is
    /// query `q`'s row of a (possibly shared) encoder batch, and
    /// `srcs[q]` its token row (still needed for drafting and shape
    /// checks). This is the fused-encode admission entry point —
    /// co-arriving molecules share ONE encoder call and each gets its
    /// own task over its row view.
    ///
    /// Ownership: the task takes the views and releases them in
    /// `finish` (normal retirement *and* cancellation); on error this
    /// method releases them before returning, so callers never clean
    /// up. Per-task [`DecodeStats::encode_calls`] stays at the
    /// solo-equivalent 1 (like `pad_rows` padding, a task is charged
    /// what it would have cost alone); *physical* encoder calls are the
    /// admission layer's counter.
    fn start_task_on(
        &self,
        model: &dyn StepModel,
        views: Vec<MemView>,
        srcs: &[Vec<i32>],
        k: usize,
    ) -> Result<Box<dyn DecodeTask>>;
    /// `srcs` are BOS/EOS-wrapped query token rows (one group = one
    /// encode + shared decode batches). Default: drive one task to
    /// completion (solo closed loop).
    fn generate(
        &self,
        model: &dyn StepModel,
        srcs: &[Vec<i32>],
        k: usize,
        stats: &mut DecodeStats,
    ) -> Result<Vec<GenOutput>> {
        let t0 = std::time::Instant::now();
        let mut task = self.start_task(model, srcs, k)?;
        if let Err(e) = run_task_to_done(model, task.as_mut()) {
            let _ = task.finish(model); // release encoder memory
            return Err(e);
        }
        let (outs, tstats) = task.finish(model);
        stats.merge(&tstats);
        stats.wall_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }
}

/// An in-flight beam: a prefix node in the token arena plus its score
/// and (under the incremental protocol) the cached decoder state
/// covering all of its tokens but the last — the **anchor** the beam's
/// next delta row continues from. 32 bytes, `Copy` — extending or
/// carrying a beam never touches the heap.
///
/// Claim discipline: every beam held in a task's `beams` owns exactly
/// one claim on its anchor ([`adopt_beams`] retains for survivors
/// before releasing the beams they replace; `finish`/cancel releases
/// the lot). Candidates inside a cycle carry anchors without claims —
/// the cycle's commit claims keep them alive until adoption.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Beam {
    pub node: NodeId,
    pub logp: f64,
    pub finished: bool,
    /// Cached state covering `tokens[0..len-1]` (`NONE` on the
    /// full-prefix path and for root beams).
    pub state: StateId,
}

impl Beam {
    /// A fresh BOS-only beam rooted in `arena`.
    pub fn root(arena: &mut TokenArena) -> Beam {
        Beam {
            node: arena.root(crate::tokenizer::BOS),
            logp: 0.0,
            finished: false,
            state: StateId::NONE,
        }
    }
}

/// Release a beam-state claim (`NONE`-safe).
#[inline]
pub(crate) fn release_state(model: &dyn StepModel, s: StateId) {
    if !s.is_none() {
        model.state_release(s);
    }
}

/// Swap a query's beams for the pool's selection under the state-claim
/// discipline: survivors take their claims *before* the beams they
/// replace drop theirs, so an anchor shared by both sides never dips to
/// zero claims mid-swap. NONE anchors are skipped, so this is free on
/// the full-prefix path — and stays correct if a task degrades to it
/// mid-flight while earlier beams still hold real claims.
pub(crate) fn adopt_beams(model: &dyn StepModel, beams: &mut Vec<Beam>, next: &mut Vec<Beam>) {
    for b in next.iter() {
        if !b.state.is_none() {
            model.state_retain(b.state);
        }
    }
    for b in beams.iter() {
        release_state(model, b.state);
    }
    std::mem::swap(beams, next);
}

/// Release every beam's anchor claim (task retirement / cancellation).
/// NONE-safe and unconditional for the same degradation reason as
/// [`adopt_beams`].
pub(crate) fn release_beam_states(model: &dyn StepModel, beams: &[Vec<Beam>]) {
    for qb in beams {
        for b in qb {
            release_state(model, b.state);
        }
    }
}

/// The `(state, from)` pair for a beam's next delta row: under the
/// incremental protocol the anchor covers all but the last token (the
/// delta is exactly one fresh position plus any extension); on the
/// full-prefix path the row carries everything from position 0.
#[inline]
pub(crate) fn delta_spec(arena: &TokenArena, b: &Beam, inc: bool) -> (StateId, usize) {
    if inc {
        (b.state, arena.len(b.node) - 1)
    } else {
        (StateId::NONE, 0)
    }
}

/// A decode cycle's state forks, collected first and committed in ONE
/// [`StepModel::state_commit_batch`] call. Chained forks reference the
/// preceding link's batch slot ([`StateParent::Slot`]), so a whole
/// cycle's commits cost one executor round trip on
/// [`crate::runtime::server::SharedModel`] instead of one per committed
/// row — the protocol overhead that used to dominate incremental decode
/// behind the executor channel.
///
/// Failure semantics are the old per-call forking's, exactly: the batch
/// stops at the first failed commit, the task **degrades to
/// full-prefix rows** for the rest of its life (`inc` flips off; the
/// failed slot and every later one read back as `NONE`), and each
/// committed id is recorded in the caller's claim vector so it drains
/// through the usual adopt/cycle/finish releases. A commit failure
/// therefore still never takes down a scheduler tick, and results are
/// unaffected — full rows are the bit-identical fallback path.
pub(crate) struct ForkBatch {
    reqs: Vec<StateForkReq>,
    ids: Vec<StateId>,
}

impl ForkBatch {
    pub fn new() -> Self {
        Self { reqs: Vec::new(), ids: Vec::new() }
    }

    /// Queue a fork of `parent ++ [tok]` on `view`'s encoder row;
    /// returns the entry's slot (usable as a later entry's parent and
    /// as the [`ForkBatch::id`] lookup key after the flush).
    pub fn push(&mut self, view: &MemView, parent: StateParent, tok: i32) -> usize {
        self.reqs.push(StateForkReq { mem: view.mem(), mem_row: view.row(), parent, tok });
        self.reqs.len() - 1
    }

    /// Clear queued entries and resolved ids for the next cycle
    /// (buffers keep their capacity).
    pub fn clear(&mut self) {
        self.reqs.clear();
        self.ids.clear();
    }

    /// Commit every queued fork in one model call. Committed ids are
    /// pushed into `claims` in queue order — identical id assignment to
    /// committing one at a time — and become readable via
    /// [`ForkBatch::id`]. The first failure flips `inc` off (degrade to
    /// full-prefix rows); with `inc` already off nothing is committed
    /// and every slot reads `NONE`.
    pub fn flush(&mut self, model: &dyn StepModel, inc: &mut bool, claims: &mut Vec<StateId>) {
        self.ids.clear();
        if !*inc || self.reqs.is_empty() {
            return;
        }
        for res in model.state_commit_batch(&self.reqs) {
            match res {
                Ok(s) => {
                    claims.push(s);
                    self.ids.push(s);
                }
                Err(_) => {
                    *inc = false;
                    self.ids.push(StateId::NONE);
                }
            }
        }
    }

    /// The committed id for `slot` (`NONE` when that commit failed, was
    /// never reached, or the batch was skipped entirely).
    pub fn id(&self, slot: usize) -> StateId {
        self.ids.get(slot).copied().unwrap_or(StateId::NONE)
    }
}

/// How many backbone forks the speculative harvest loop will perform
/// for one row: a pure mirror of its control flow (a fork at the top of
/// every iteration `j >= 1`, the window/length break checks after it),
/// so the chain can be queued on a [`ForkBatch`] and committed *before*
/// the loop runs. `p0` is the row's window start (`prefix len - 1`).
pub(crate) fn chain_links(
    out: &DecodeOut,
    row: usize,
    p0: usize,
    max_len: usize,
    ext_cap: usize,
) -> usize {
    let mut links = 0;
    for j in 0..=ext_cap {
        if j > 0 {
            links += 1;
        }
        if out.offset_of(row, p0 + j).is_none() || p0 + 1 + j >= max_len {
            break;
        }
    }
    links
}

/// Reusable decode-call row storage: `DecodeRow::delta` buffers are
/// recycled between cycles, so steady-state row building allocates
/// nothing. Tasks append rows here; the solo driver and the fused
/// scheduler both own one `RowBuf` for the lifetime of their loop.
pub struct RowBuf {
    pub rows: Vec<DecodeRow>,
    spare: Vec<Vec<i32>>,
}

impl RowBuf {
    pub fn new() -> Self {
        Self { rows: Vec::new(), spare: Vec::new() }
    }

    /// Start a new decode call: reclaim all previous rows' buffers.
    pub fn begin(&mut self) {
        for r in self.rows.drain(..) {
            self.spare.push(r.delta);
        }
    }

    /// Append a full-prefix row for `node`'s sequence extended by
    /// `ext`, windowed at the node's last position (the seed's
    /// `prefix ++ draft` shape; no cached state).
    pub fn push_row(
        &mut self,
        arena: &TokenArena,
        mem: MemHandle,
        mem_row: usize,
        node: NodeId,
        ext: &[i32],
    ) {
        self.push_row_delta(arena, mem, mem_row, StateId::NONE, node, 0, ext);
    }

    /// Append a delta row: `state` names cached decoder state covering
    /// `node`'s first `from` tokens; the row carries only tokens
    /// `[from..len)` plus `ext`. The window start stays the node's last
    /// position, identical to the full-prefix row for the same node —
    /// which is what makes the two paths bit-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn push_row_delta(
        &mut self,
        arena: &TokenArena,
        mem: MemHandle,
        mem_row: usize,
        state: StateId,
        node: NodeId,
        from: usize,
        ext: &[i32],
    ) {
        let mut delta = self.spare.pop().unwrap_or_default();
        arena.materialize_suffix_into(node, from, &mut delta);
        delta.extend_from_slice(ext);
        self.rows.push(DecodeRow { mem, mem_row, state, delta, pos: arena.len(node) - 1 });
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Drop rows back to `n`, reclaiming their buffers (the scheduler
    /// uses this to bounce a task whose rows overflow the tick budget).
    pub fn truncate_to(&mut self, n: usize) {
        while self.rows.len() > n {
            let r = self.rows.pop().expect("len checked");
            self.spare.push(r.delta);
        }
    }
}

impl Default for RowBuf {
    fn default() -> Self {
        Self::new()
    }
}

/// Candidate pool helper: keeps the best `k` unique token sequences.
///
/// `push` records a `Copy` beam; `take_into` ranks by log-probability
/// (partial selection — the tail beyond the worst position a unique
/// top-k member can occupy is never sorted) and deduplicates by arena
/// chain-hash with exact collision resolution, all in reusable buffers.
pub(crate) struct CandidatePool {
    k: usize,
    items: Vec<Beam>,
    idx: Vec<u32>,
    seen: std::collections::HashMap<u64, NodeId>,
}

impl CandidatePool {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            items: Vec::with_capacity(k * 4),
            idx: Vec::new(),
            seen: std::collections::HashMap::new(),
        }
    }

    /// Clear for the next cycle (buffers keep their capacity).
    pub fn reset(&mut self) {
        self.items.clear();
    }

    pub fn push(&mut self, b: Beam) {
        self.items.push(b);
    }

    /// Top-k by logp into `out`, deduplicated by token sequence (first
    /// occurrence in rank order wins, i.e. the best score). Rank order
    /// matches the seed's stable sort: logp descending, insertion order
    /// ascending on ties.
    pub fn take_into(&mut self, arena: &TokenArena, out: &mut Vec<Beam>) {
        out.clear();
        let items = &self.items;
        self.idx.clear();
        self.idx.extend(0..items.len() as u32);
        let cmp = |a: &u32, b: &u32| {
            items[*b as usize]
                .logp
                .partial_cmp(&items[*a as usize].logp)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.cmp(b))
        };
        // Each distinct sequence occurs at most (k live parents + 1
        // finished carryover) times in a cycle's pool, so the k best
        // unique sequences all rank within the first k*(k+1) entries;
        // everything past that partition point is never even sorted.
        let cap = self.k * (self.k + 1);
        if self.idx.len() > cap {
            self.idx.select_nth_unstable_by(cap, cmp);
            self.idx.truncate(cap);
        }
        self.idx.sort_unstable_by(cmp);
        self.seen.clear();
        for &i in &self.idx {
            if out.len() >= self.k {
                break;
            }
            let b = items[i as usize];
            let mut key = arena.seq_hash(b.node);
            loop {
                use std::collections::hash_map::Entry;
                match self.seen.entry(key) {
                    Entry::Vacant(v) => {
                        v.insert(b.node);
                        out.push(b);
                        break;
                    }
                    Entry::Occupied(o) => {
                        if arena.seq_eq(*o.get(), b.node) {
                            break; // true duplicate sequence: skip
                        }
                        // 64-bit hash collision between distinct
                        // sequences: probe to a fresh slot.
                        key = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
                    }
                }
            }
        }
    }
}

/// Compaction trigger floor: below this many arena nodes the bookkeeping
/// is not worth one live-chain copy.
pub(crate) const COMPACT_MIN: usize = 1024;

/// End-of-cycle arena compaction shared by all engines: once the node
/// table crosses the task's moving threshold, copy the chains reachable
/// from the current beams (the only node ids still live between cycles)
/// and drop every discarded candidate. The threshold re-arms at 4x the
/// live size, so compaction cost is amortized geometric and the arena
/// stays within a constant factor of the live beam set.
pub(crate) fn compact_beams(
    arena: &mut TokenArena,
    scratch: &mut arena::CompactScratch,
    beams: &mut [Vec<Beam>],
    compact_at: &mut usize,
) {
    if arena.node_count() < *compact_at {
        return;
    }
    arena.compact_begin(scratch);
    for qbeams in beams.iter() {
        for b in qbeams {
            arena.compact_mark(scratch, b.node);
        }
    }
    arena.compact_finish(scratch);
    for qbeams in beams.iter_mut() {
        for b in qbeams {
            b.node = scratch.remapped(b.node);
        }
    }
    *compact_at = (arena.node_count() * 4).max(COMPACT_MIN);
}

/// Build a decoder by name: `bs` / `beam-search`, `bs-opt`, `hsbs`,
/// `msbs`. `batch_hint` sizes HSBS's draft schedule (Table 1 caption).
pub fn make_decoder(name: &str, batch_hint: usize) -> anyhow::Result<Box<dyn Decoder + Send>> {
    Ok(match name {
        "bs" | "beam" | "beam-search" => Box::new(beam::BeamSearch::vanilla()),
        "bs-opt" | "beam-search-optimized" => Box::new(beam::BeamSearch::optimized()),
        "hsbs" => Box::new(hsbs::Hsbs::for_batch_size(batch_hint)),
        "msbs" => Box::new(msbs::Msbs::default()),
        other => anyhow::bail!("unknown decoder {other:?} (bs|bs-opt|hsbs|msbs)"),
    })
}

/// Materialize beams and sort hypotheses by descending logp into a
/// [`GenOutput`] (the only point where beam token sequences are copied
/// out of the arena).
pub(crate) fn finalize(arena: &TokenArena, beams: &[Beam]) -> GenOutput {
    let mut hyps: Vec<Hypothesis> = beams
        .iter()
        .map(|b| {
            let mut tokens = arena.tokens(b.node);
            tokens.remove(0); // strip BOS
            Hypothesis { tokens, logp: b.logp }
        })
        .collect();
    hyps.sort_by(|a, b| b.logp.partial_cmp(&a.logp).unwrap_or(std::cmp::Ordering::Equal));
    GenOutput { hyps }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn beam(arena: &mut TokenArena, toks: &[i32], logp: f64) -> Beam {
        let mut node = arena.root(toks[0]);
        for &t in &toks[1..] {
            node = arena.push(node, t);
        }
        Beam { node, logp, finished: false, state: StateId::NONE }
    }

    #[test]
    fn candidate_pool_dedups_and_sorts() {
        let mut arena = TokenArena::new();
        let mut pool = CandidatePool::new(2);
        let dup_a = beam(&mut arena, &[1, 5], -1.0);
        let dup_b = beam(&mut arena, &[1, 5], -0.5); // same sequence, distinct node
        pool.push(dup_a);
        pool.push(dup_b);
        pool.push(beam(&mut arena, &[1, 6], -2.0));
        pool.push(beam(&mut arena, &[1, 7], -3.0));
        let mut top = Vec::new();
        pool.take_into(&arena, &mut top);
        assert_eq!(top.len(), 2);
        assert_eq!(arena.tokens(top[0].node), vec![1, 5]);
        assert_eq!(top[0].logp, -0.5);
        assert_eq!(arena.tokens(top[1].node), vec![1, 6]);
    }

    #[test]
    fn candidate_pool_insertion_order_breaks_ties() {
        let mut arena = TokenArena::new();
        let mut pool = CandidatePool::new(1);
        pool.push(beam(&mut arena, &[1, 8], -1.0));
        pool.push(beam(&mut arena, &[1, 9], -1.0));
        let mut top = Vec::new();
        pool.take_into(&arena, &mut top);
        assert_eq!(arena.tokens(top[0].node), vec![1, 8], "first pushed wins ties");
    }

    #[test]
    fn candidate_pool_reset_reuses_buffers() {
        let mut arena = TokenArena::new();
        let mut pool = CandidatePool::new(2);
        let mut top = Vec::new();
        for round in 0..3 {
            pool.reset();
            pool.push(beam(&mut arena, &[1, 5 + round], -1.0));
            pool.take_into(&arena, &mut top);
            assert_eq!(top.len(), 1);
            assert_eq!(arena.tokens(top[0].node), vec![1, 5 + round]);
        }
    }

    #[test]
    fn row_buf_recycles_delta_buffers() {
        let mut arena = TokenArena::new();
        let b = beam(&mut arena, &[1, 5, 6], 0.0);
        let mut rb = RowBuf::new();
        rb.begin();
        rb.push_row(&arena, MemHandle(1), 0, b.node, &[7, 8]);
        assert_eq!(rb.len(), 1);
        assert_eq!(rb.rows[0].delta, vec![1, 5, 6, 7, 8]);
        assert_eq!(rb.rows[0].pos, 2);
        let ptr = rb.rows[0].delta.as_ptr();
        rb.begin();
        assert!(rb.is_empty());
        rb.push_row(&arena, MemHandle(1), 0, b.node, &[]);
        assert_eq!(rb.rows[0].delta, vec![1, 5, 6]);
        assert_eq!(ptr, rb.rows[0].delta.as_ptr(), "delta buffer must be recycled");
    }

    #[test]
    fn push_row_delta_carries_suffix_and_state() {
        let mut arena = TokenArena::new();
        let b = beam(&mut arena, &[1, 5, 6], 0.0);
        let mut rb = RowBuf::new();
        rb.begin();
        // Anchor covers [1, 5]; the delta is the last token plus a draft.
        rb.push_row_delta(&arena, MemHandle(1), 0, StateId(9), b.node, 2, &[7, 8]);
        assert_eq!(rb.rows[0].state, StateId(9));
        assert_eq!(rb.rows[0].delta, vec![6, 7, 8]);
        assert_eq!(rb.rows[0].pos, 2, "window start stays the node's last position");
        // from == len: the delta is just the extension (MSBS verify shape).
        rb.begin();
        rb.push_row_delta(&arena, MemHandle(1), 0, StateId(9), b.node, 3, &[7, 8]);
        assert_eq!(rb.rows[0].delta, vec![7, 8]);
        assert_eq!(rb.rows[0].pos, 2);
    }

    #[test]
    fn finalize_sorts_and_strips_bos() {
        let mut arena = TokenArena::new();
        let a = beam(&mut arena, &[1, 5, 2], -2.0);
        let b = beam(&mut arena, &[1, 6, 2], -1.0);
        let out = finalize(&arena, &[a, b]);
        assert_eq!(out.hyps[0].tokens, vec![6, 2]);
        assert_eq!(out.hyps[1].tokens, vec![5, 2]);
        assert!(out.hyps[0].logp >= out.hyps[1].logp);
    }

    #[test]
    fn hypothesis_body_strips_eos() {
        let h = Hypothesis { tokens: vec![5, 6, crate::tokenizer::EOS], logp: 0.0 };
        assert!(h.finished());
        assert_eq!(h.body(), &[5, 6]);
        let h2 = Hypothesis { tokens: vec![5, 6], logp: 0.0 };
        assert!(!h2.finished());
        assert_eq!(h2.body(), &[5, 6]);
    }

    #[test]
    fn stats_rates() {
        let s = DecodeStats {
            model_calls: 4,
            rows_logical: 40,
            drafts_offered: 10,
            drafts_accepted: 9,
            ..Default::default()
        };
        assert_eq!(s.avg_effective_batch(), 10.0);
        assert_eq!(s.acceptance_rate(), 0.9);
    }
}
