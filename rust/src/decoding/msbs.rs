//! MSBS: speculative beam search with Medusa-head drafting — the
//! paper's headline contribution.
//!
//! Each cycle costs two model calls for the whole group:
//!
//! 1. **Draft call** (window 1): read all `M+1` heads at each live
//!    beam's last position; greedy-pick head 0..M to form a draft of
//!    `M` tokens per beam (one draft per beam — effective batch stays
//!    `O(B*K)`, which is what makes MSBS scale where HSBS cannot).
//! 2. **Verify call** (window `M+1`): decode `prefix ++ draft`; accept
//!    draft tokens by the top-p (nucleus, default 99.75%) rank test —
//!    a token is accepted while the probability mass of strictly more
//!    probable tokens is below the nucleus (the argmax is therefore
//!    always acceptable). Then harvest top-K continuations at *every*
//!    accepted prefix length, rank all candidates by cumulative
//!    log-probability and keep the top K as the next beams.
//!
//! Guarantees >= 1 generated token per cycle and <= M+1; finished beams
//! are put aside (as in optimized beam search).
//!
//! Hot-loop layout: beams are [`TokenArena`] nodes, drafts live in one
//! flat per-cycle buffer indexed by spans, the nucleus test runs fused
//! over raw logits ([`nucleus_mass_before`]), and candidate pools
//! deduplicate by arena chain-hash — no steady-state allocation.

use super::arena::TokenArena;
use super::{finalize, Beam, CandidatePool, DecodeStats, Decoder, GenOutput, RowBuf};
use crate::model::scratch::{nucleus_mass_before, ScoringScratch};
use crate::model::{argmax, StepModel};
use crate::tokenizer::EOS;
use anyhow::Result;

/// Medusa speculative beam search.
#[derive(Clone, Debug)]
pub struct Msbs {
    /// Nucleus parameter for draft verification (paper: 0.9975).
    pub nucleus: f64,
    /// Cap on draft length (defaults to the model's Medusa head count).
    pub max_draft: Option<usize>,
}

impl Default for Msbs {
    fn default() -> Self {
        Self { nucleus: 0.9975, max_draft: None }
    }
}

impl Msbs {
    pub fn new(nucleus: f64) -> Self {
        Self { nucleus, max_draft: None }
    }

    /// Is `tok` inside the top-p nucleus of `probs` (or the argmax)?
    /// Reference form over materialized probabilities, kept only to
    /// cross-check the fused [`nucleus_mass_before`] the hot loop uses.
    #[cfg(test)]
    fn in_nucleus(&self, probs: &[f64], tok: usize) -> bool {
        let p_tok = probs[tok];
        // mass of strictly-more-probable tokens (ties resolved in favor
        // of acceptance); argmax has mass_before == 0.
        let mass_before: f64 = probs.iter().filter(|&&p| p > p_tok).sum();
        mass_before < self.nucleus
    }
}

/// Per-cycle trace record (for the Fig. 1/2 example driver).
#[derive(Clone, Debug)]
pub struct CycleTrace {
    pub cycle: usize,
    pub drafts: Vec<Vec<i32>>,
    pub accepted: Vec<usize>,
    pub beams: Vec<(Vec<i32>, f64)>,
}

impl Decoder for Msbs {
    fn name(&self) -> &'static str {
        "msbs"
    }

    fn generate(
        &self,
        model: &dyn StepModel,
        srcs: &[Vec<i32>],
        k: usize,
        stats: &mut DecodeStats,
    ) -> Result<Vec<GenOutput>> {
        self.generate_traced(model, srcs, k, stats, &mut None)
    }
}

impl Msbs {
    /// `generate` with an optional per-cycle trace (first query only),
    /// used by `examples/msbs_trace.rs` to reproduce Fig. 1/2.
    pub fn generate_traced(
        &self,
        model: &dyn StepModel,
        srcs: &[Vec<i32>],
        k: usize,
        stats: &mut DecodeStats,
        trace: &mut Option<Vec<CycleTrace>>,
    ) -> Result<Vec<GenOutput>> {
        let t0 = std::time::Instant::now();
        let mem = model.encode(srcs)?;
        stats.encode_calls += 1;
        let max_len = model.max_tgt();
        let m = if let Some(cap) = self.max_draft {
            cap.min(model.medusa_heads())
        } else {
            model.medusa_heads()
        };
        anyhow::ensure!(m > 0, "MSBS requires a model with Medusa heads");

        let mut arena = TokenArena::with_capacity(srcs.len() * k * 16);
        let root = Beam::root(&mut arena);
        let mut beams: Vec<Vec<Beam>> = srcs.iter().map(|_| vec![root]).collect();
        let mut done: Vec<bool> = vec![false; srcs.len()];
        let mut cycle = 0usize;

        let mut scratch = ScoringScratch::new();
        let mut rowbuf = RowBuf::new();
        let mut vrowbuf = RowBuf::new();
        let mut row_of: Vec<(usize, usize)> = Vec::new();
        // Per-cycle drafts: one flat token buffer + a (start, end) span
        // per row, reused across cycles.
        let mut draft_flat: Vec<i32> = Vec::new();
        let mut draft_span: Vec<(usize, usize)> = Vec::new();
        let mut accepted_log: Vec<usize> = Vec::new();
        let mut pools: Vec<CandidatePool> =
            (0..srcs.len()).map(|_| CandidatePool::new(k)).collect();
        let mut next: Vec<Beam> = Vec::with_capacity(k);

        while !done.iter().all(|&d| d) {
            cycle += 1;
            // ---- call 1: draft ----
            rowbuf.begin();
            row_of.clear();
            for (q, qbeams) in beams.iter().enumerate() {
                if done[q] {
                    continue;
                }
                for (bi, b) in qbeams.iter().enumerate() {
                    if !b.finished {
                        rowbuf.push_row(&arena, mem, q, b.node, &[]);
                        row_of.push((q, bi));
                    }
                }
            }
            if rowbuf.is_empty() {
                break;
            }
            let dout = model.decode(&rowbuf.rows, 1)?;
            stats.model_calls += 1;
            stats.rows_logical += rowbuf.len() as u64;
            stats.rows_padded += dout.padded_rows as u64;

            // Greedy draft per beam: token j from head j (head 0 = main).
            draft_flat.clear();
            draft_span.clear();
            for (r, &(q, bi)) in row_of.iter().enumerate() {
                let b = beams[q][bi];
                let blen = arena.len(b.node);
                let off = dout
                    .offset_of(r, blen - 1)
                    .expect("draft window covers last position");
                let budget = max_len.saturating_sub(blen + 1).min(m);
                let start = draft_flat.len();
                for h in 0..budget {
                    draft_flat.push(argmax(dout.logits(r, off, h)) as i32);
                }
                draft_span.push((start, draft_flat.len()));
            }

            // ---- call 2: verify ----
            let win = m + 1;
            vrowbuf.begin();
            for (r, &(q, bi)) in row_of.iter().enumerate() {
                let b = beams[q][bi];
                let (s, e) = draft_span[r];
                vrowbuf.push_row(&arena, mem, q, b.node, &draft_flat[s..e]);
            }
            let vout = model.decode(&vrowbuf.rows, win)?;
            stats.model_calls += 1;
            stats.rows_logical += vrowbuf.len() as u64;
            stats.rows_padded += vout.padded_rows as u64;

            // ---- acceptance + harvesting ----
            for pool in pools.iter_mut() {
                pool.reset();
            }
            for (q, qbeams) in beams.iter().enumerate() {
                for b in qbeams {
                    if b.finished {
                        pools[q].push(*b);
                    }
                }
            }
            accepted_log.clear();
            for (r, &(q, bi)) in row_of.iter().enumerate() {
                let b = beams[q][bi];
                let blen = arena.len(b.node);
                let p0 = blen - 1;
                let (ds, de) = draft_span[r];
                let draft = &draft_flat[ds..de];
                // accept a prefix of the draft via the nucleus test; an
                // accepted EOS terminates the draft (nothing after it can
                // be meaningful).
                let mut acc = 0usize;
                let mut eos_idx: Option<usize> = None;
                for (j, &dt) in draft.iter().enumerate() {
                    let Some(off) = vout.offset_of(r, p0 + j) else { break };
                    if nucleus_mass_before(vout.logits(r, off, 0), dt as usize) >= self.nucleus {
                        break;
                    }
                    acc += 1;
                    if dt == EOS {
                        eos_idx = Some(j);
                        break;
                    }
                }
                stats.drafts_offered += draft.len() as u64;
                stats.drafts_accepted += acc as u64;
                accepted_log.push(acc);

                // Harvest candidates. The accepted tokens form a committed
                // *backbone*: at its end we take the top-K continuations;
                // at every earlier accepted position we take the top-K
                // *divergent* branches (excluding the draft token itself —
                // it already lives inside the backbone, and re-adding it
                // would flood the pool with nested prefixes). Cumulative
                // log-probability ranks the pool, so a weakly-accepted
                // backbone can lose to a short divergence — the paper's
                // "both shorter and longer sequences may be the most
                // probable".
                let ext_cap = eos_idx.unwrap_or(acc);
                let mut cum = b.logp;
                let mut backbone = b.node;
                for j in 0..=ext_cap {
                    if j > 0 {
                        backbone = arena.push(backbone, draft[j - 1]);
                    }
                    let Some(off) = vout.offset_of(r, p0 + j) else { break };
                    let prefix_len = blen + j;
                    if prefix_len >= max_len {
                        break;
                    }
                    let backbone_end = j == ext_cap;
                    scratch.top_k_log_softmax(vout.logits(r, off, 0), k);
                    for &tok in &scratch.topk {
                        if !backbone_end && tok as i32 == draft[j] {
                            continue; // divergences only before the backbone end
                        }
                        let node = arena.push(backbone, tok as i32);
                        let finished = tok as i32 == EOS || arena.len(node) >= max_len;
                        pools[q].push(Beam {
                            node,
                            logp: cum + scratch.lsm[tok],
                            finished,
                        });
                    }
                    if j < draft.len() {
                        cum += scratch.lsm[draft[j] as usize];
                    }
                }
            }
            for (q, pool) in pools.iter_mut().enumerate() {
                if done[q] {
                    continue;
                }
                pool.take_into(&arena, &mut next);
                if !next.is_empty() {
                    std::mem::swap(&mut beams[q], &mut next);
                }
                done[q] = beams[q].iter().all(|b| b.finished);
            }
            if let Some(tr) = trace.as_mut() {
                tr.push(CycleTrace {
                    cycle,
                    drafts: draft_span
                        .iter()
                        .map(|&(s, e)| draft_flat[s..e].to_vec())
                        .collect(),
                    accepted: accepted_log.clone(),
                    beams: beams[0]
                        .iter()
                        .map(|b| (arena.tokens(b.node), b.logp))
                        .collect(),
                });
            }
        }
        model.release(mem);
        stats.wall_secs += t0.elapsed().as_secs_f64();
        Ok(beams.iter().map(|qb| finalize(&arena, qb)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};
    use crate::tokenizer::BOS;

    fn src(tokens: &[i32]) -> Vec<i32> {
        let mut v = vec![BOS];
        v.extend_from_slice(tokens);
        v.push(EOS);
        v
    }

    #[test]
    fn top1_matches_beam_search() {
        let model = MockModel::new(MockConfig::default());
        let s = vec![src(&[5, 6, 7, 8, 9, 10, 11])];
        let mut s1 = DecodeStats::default();
        let bs = BeamSearch::vanilla().generate(&model, &s, 3, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        let ms = Msbs::default().generate(&model, &s, 3, &mut s2).unwrap();
        assert_eq!(bs[0].hyps[0].tokens, ms[0].hyps[0].tokens);
        assert!((bs[0].hyps[0].logp - ms[0].hyps[0].logp).abs() < 1e-9);
    }

    #[test]
    fn far_fewer_model_calls_than_beam_search() {
        // SBS progress relies on nested beams of different lengths: the
        // longest beam advances by up to M+1 tokens per cycle, so the
        // effect needs paper-scale K (the paper uses K=10).
        let model = MockModel::new(MockConfig::default());
        let body: Vec<i32> = (5..23).collect();
        let s = vec![src(&body)];
        let mut s1 = DecodeStats::default();
        BeamSearch::vanilla().generate(&model, &s, 10, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        Msbs::default().generate(&model, &s, 10, &mut s2).unwrap();
        assert!(
            (s2.model_calls as f64) < 0.7 * s1.model_calls as f64,
            "msbs {} vs bs {}",
            s2.model_calls,
            s1.model_calls
        );
    }

    #[test]
    fn acceptance_rate_tracks_head_accuracy() {
        // perfect heads -> high acceptance (tail cycles still truncate
        // at EOS, so it does not reach exactly 1)
        let perfect = MockModel::new(MockConfig {
            head_base_acc: 100,
            head_acc_decay: 0,
            ..Default::default()
        });
        let body: Vec<i32> = (5..21).collect();
        let s = vec![src(&body)];
        let mut st = DecodeStats::default();
        Msbs::default().generate(&perfect, &s, 10, &mut st).unwrap();
        assert!(st.acceptance_rate() > 0.7, "{}", st.acceptance_rate());

        // poor heads -> lower acceptance, but still the correct output
        let poor = MockModel::new(MockConfig {
            head_base_acc: 30,
            head_acc_decay: 0,
            ..Default::default()
        });
        let mut st2 = DecodeStats::default();
        let out = Msbs::default().generate(&poor, &s, 10, &mut st2).unwrap();
        assert!(st2.acceptance_rate() < st.acceptance_rate());
        assert_eq!(out[0].hyps[0].body(), &body[..]);
    }

    #[test]
    fn nucleus_cut_rejects_unlikely_tokens() {
        let m = Msbs::new(0.9);
        // probs: argmax 0.85, second 0.1, third 0.05
        let probs = vec![0.85, 0.1, 0.05];
        assert!(m.in_nucleus(&probs, 0)); // argmax always
        assert!(m.in_nucleus(&probs, 1)); // 0.85 < 0.9
        assert!(!m.in_nucleus(&probs, 2)); // 0.95 !< 0.9
    }

    #[test]
    fn fused_nucleus_test_agrees_with_reference() {
        use crate::model::softmax;
        let m = Msbs::new(0.9975);
        let logits: Vec<f32> = vec![8.0, 4.0, -4.0, -4.0, -4.0, 2.0];
        let probs = softmax(&logits);
        for tok in 0..logits.len() {
            let fused = nucleus_mass_before(&logits, tok) < m.nucleus;
            assert_eq!(fused, m.in_nucleus(&probs, tok), "tok={tok}");
        }
    }

    #[test]
    fn two_calls_per_cycle() {
        let model = MockModel::new(MockConfig::default());
        let s = vec![src(&[5, 6, 7, 8])];
        let mut st = DecodeStats::default();
        let mut trace = Some(Vec::new());
        Msbs::default()
            .generate_traced(&model, &s, 2, &mut st, &mut trace)
            .unwrap();
        let cycles = trace.unwrap().len() as u64;
        assert_eq!(st.model_calls, 2 * cycles);
    }

    #[test]
    fn batch_group_processes_all_queries() {
        let model = MockModel::new(MockConfig::default());
        let srcs = vec![src(&[5, 6, 7]), src(&[8, 9, 10, 11]), src(&[12, 13])];
        let mut st = DecodeStats::default();
        let out = Msbs::default().generate(&model, &srcs, 4, &mut st).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].hyps[0].body(), &[5, 6, 7]);
        assert_eq!(out[1].hyps[0].body(), &[8, 9, 10, 11]);
        assert_eq!(out[2].hyps[0].body(), &[12, 13]);
    }
}
