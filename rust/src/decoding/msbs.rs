//! MSBS: speculative beam search with Medusa-head drafting — the
//! paper's headline contribution.
//!
//! Each cycle costs two model calls for the whole group:
//!
//! 1. **Draft call** (window 1): read all `M+1` heads at each live
//!    beam's last position; greedy-pick head 0..M to form a draft of
//!    `M` tokens per beam (one draft per beam — effective batch stays
//!    `O(B*K)`, which is what makes MSBS scale where HSBS cannot).
//! 2. **Verify call** (window `M+1`): decode `prefix ++ draft`; accept
//!    draft tokens by the top-p (nucleus, default 99.75%) rank test —
//!    a token is accepted while the probability mass of strictly more
//!    probable tokens is below the nucleus (the argmax is therefore
//!    always acceptable). Then harvest top-K continuations at *every*
//!    accepted prefix length, rank all candidates by cumulative
//!    log-probability and keep the top K as the next beams.
//!
//! Guarantees >= 1 generated token per cycle and <= M+1; finished beams
//! are put aside (as in optimized beam search).
//!
//! Hot-loop layout: beams are [`TokenArena`] nodes, drafts live in one
//! flat per-cycle buffer indexed by spans, the nucleus test runs fused
//! over raw logits ([`nucleus_mass_before`]), and candidate pools
//! deduplicate by arena chain-hash — no steady-state allocation.
//!
//! The algorithm lives in [`MsbsTask`], a resumable [`DecodeTask`] with
//! an explicit two-phase cycle: the draft call and the verify call are
//! separate `next_rows`/`absorb` round trips, so a fused scheduler can
//! interleave other tasks' rows into either phase's device call.

use super::arena::{CompactScratch, TokenArena};
use super::{
    adopt_beams, chain_links, compact_beams, delta_spec, finalize, release_beam_states,
    release_state, Beam, CandidatePool, DecodeStats, DecodeTask, Decoder, ForkBatch, GenOutput,
    RowBuf, TaskState, COMPACT_MIN,
};
use crate::model::scratch::{nucleus_mass_before, ScoringScratch};
use crate::model::{
    argmax, encode_shared, release_views, DecodeOut, MemView, StateId, StateParent, StepModel,
};
use crate::tokenizer::EOS;
use anyhow::Result;

/// Medusa speculative beam search.
#[derive(Clone, Debug)]
pub struct Msbs {
    /// Nucleus parameter for draft verification (paper: 0.9975).
    pub nucleus: f64,
    /// Cap on draft length (defaults to the model's Medusa head count).
    pub max_draft: Option<usize>,
}

impl Default for Msbs {
    fn default() -> Self {
        Self { nucleus: 0.9975, max_draft: None }
    }
}

impl Msbs {
    pub fn new(nucleus: f64) -> Self {
        Self { nucleus, max_draft: None }
    }

    /// Is `tok` inside the top-p nucleus of `probs` (or the argmax)?
    /// Reference form over materialized probabilities, kept only to
    /// cross-check the fused [`nucleus_mass_before`] the hot loop uses.
    #[cfg(test)]
    fn in_nucleus(&self, probs: &[f64], tok: usize) -> bool {
        let p_tok = probs[tok];
        // mass of strictly-more-probable tokens (ties resolved in favor
        // of acceptance); argmax has mass_before == 0.
        let mass_before: f64 = probs.iter().filter(|&&p| p > p_tok).sum();
        mass_before < self.nucleus
    }
}

/// Per-cycle trace record (for the Fig. 1/2 example driver).
#[derive(Clone, Debug)]
pub struct CycleTrace {
    pub cycle: usize,
    pub drafts: Vec<Vec<i32>>,
    pub accepted: Vec<usize>,
    pub beams: Vec<(Vec<i32>, f64)>,
}

impl Decoder for Msbs {
    fn name(&self) -> &'static str {
        "msbs"
    }

    fn start_task_on(
        &self,
        model: &dyn StepModel,
        views: Vec<MemView>,
        srcs: &[Vec<i32>],
        k: usize,
    ) -> Result<Box<dyn DecodeTask>> {
        Ok(Box::new(self.task_on(model, views, srcs, k)?))
    }
}

/// Which device call an [`MsbsTask`] runs next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum MsbsPhase {
    Draft,
    Verify,
}

impl Msbs {
    /// Build the concrete task over pre-encoded views (the trait object
    /// path goes through [`Decoder::start_task_on`];
    /// [`Msbs::generate_traced`] needs the concrete type to thread the
    /// trace through). Releases the views on error.
    fn task_on(
        &self,
        model: &dyn StepModel,
        views: Vec<MemView>,
        srcs: &[Vec<i32>],
        k: usize,
    ) -> Result<MsbsTask> {
        debug_assert_eq!(views.len(), srcs.len(), "one memory view per query");
        let m = if let Some(cap) = self.max_draft {
            cap.min(model.medusa_heads())
        } else {
            model.medusa_heads()
        };
        if m == 0 {
            release_views(model, views);
            anyhow::bail!("MSBS requires a model with Medusa heads");
        }
        let mut arena = TokenArena::with_capacity(srcs.len() * k * 16);
        let root = Beam::root(&mut arena);
        Ok(MsbsTask {
            nucleus: self.nucleus,
            k,
            m,
            max_len: model.max_tgt(),
            inc: model.supports_incremental(),
            views,
            arena,
            beams: srcs.iter().map(|_| vec![root]).collect(),
            done: vec![false; srcs.len()],
            phase: MsbsPhase::Draft,
            cycle: 0,
            scratch: ScoringScratch::new(),
            row_of: Vec::new(),
            draft_flat: Vec::new(),
            draft_span: Vec::new(),
            accepted_log: Vec::new(),
            pools: (0..srcs.len()).map(|_| CandidatePool::new(k)).collect(),
            next: Vec::with_capacity(k),
            trace: None,
            stats: DecodeStats { encode_calls: 1, ..Default::default() },
            compact: CompactScratch::new(),
            compact_at: COMPACT_MIN,
            row_states: Vec::new(),
            cycle_states: Vec::new(),
            fork_batch: ForkBatch::new(),
            verify_plan: Vec::new(),
        })
    }

    /// `generate` with an optional per-cycle trace (first query only),
    /// used by `examples/msbs_trace.rs` to reproduce Fig. 1/2.
    pub fn generate_traced(
        &self,
        model: &dyn StepModel,
        srcs: &[Vec<i32>],
        k: usize,
        stats: &mut DecodeStats,
        trace: &mut Option<Vec<CycleTrace>>,
    ) -> Result<Vec<GenOutput>> {
        let t0 = std::time::Instant::now();
        let views = encode_shared(model, srcs)?;
        let mut task = self.task_on(model, views, srcs, k)?;
        task.trace = trace.take();
        if let Err(e) = super::run_task_to_done(model, &mut task) {
            *trace = task.trace.take(); // completed cycles survive the error
            let _ = Box::new(task).finish(model); // release encoder memory
            return Err(e);
        }
        *trace = task.trace.take();
        let (outs, tstats) = Box::new(task).finish(model);
        stats.merge(&tstats);
        stats.wall_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }
}

/// Resumable MSBS state: each decode cycle is two explicit phases
/// (draft, then verify), one `next_rows`/`absorb` round trip each.
pub struct MsbsTask {
    nucleus: f64,
    k: usize,
    /// Draft length (Medusa heads, possibly capped).
    m: usize,
    max_len: usize,
    /// Delta rows over cached decoder state when the model supports it.
    inc: bool,
    /// One ref-counted encoder-memory view per query (possibly rows of
    /// a batch shared with other tasks).
    views: Vec<MemView>,
    arena: TokenArena,
    beams: Vec<Vec<Beam>>,
    done: Vec<bool>,
    phase: MsbsPhase,
    cycle: usize,
    scratch: ScoringScratch,
    row_of: Vec<(usize, usize)>,
    /// Per-cycle drafts: one flat token buffer + a (start, end) span
    /// per row, reused across cycles.
    draft_flat: Vec<i32>,
    draft_span: Vec<(usize, usize)>,
    accepted_log: Vec<usize>,
    pools: Vec<CandidatePool>,
    next: Vec<Beam>,
    trace: Option<Vec<CycleTrace>>,
    stats: DecodeStats,
    compact: CompactScratch,
    compact_at: usize,
    /// Per-row full-prefix states committed by the draft phase and
    /// **shared with the verify phase**: the verify row continues from
    /// the accepted-prefix state, so it carries only `draft_len` new
    /// positions. Claims are held across the phase boundary and
    /// released at the end of `absorb_verify` (or in `finish`, so a
    /// cancellation between the phases leaks nothing).
    row_states: Vec<StateId>,
    /// Claims from the verify phase's backbone commits, released after
    /// survivor adoption (rejected draft positions are never committed).
    cycle_states: Vec<StateId>,
    /// The cycle's fork commits, batched into one model call.
    fork_batch: ForkBatch,
    /// Per-row `(ext_cap, slot_start)` from the verify plan pass;
    /// `slot_start == usize::MAX` means the row queued no chain forks.
    verify_plan: Vec<(usize, usize)>,
}

impl MsbsTask {
    /// Absorb the draft call: greedy draft per beam, token j from head j
    /// (head 0 = main). Incrementally, the draft call processed each
    /// beam's last position, so the full prefix is committed here and
    /// handed to the verify phase — prefix-shared verification.
    fn absorb_draft(
        &mut self,
        model: &dyn StepModel,
        dout: &DecodeOut,
        range: std::ops::Range<usize>,
    ) {
        self.cycle += 1;
        self.draft_flat.clear();
        self.draft_span.clear();
        debug_assert!(self.row_states.is_empty(), "verify must have drained row states");
        self.row_states.clear();
        self.fork_batch.clear();
        for (r, &(q, bi)) in self.row_of.iter().enumerate() {
            let b = self.beams[q][bi];
            let blen = self.arena.len(b.node);
            let gr = range.start + r;
            let off = dout
                .offset_of(gr, blen - 1)
                .expect("draft window covers last position");
            let budget = self.max_len.saturating_sub(blen + 1).min(self.m);
            let start = self.draft_flat.len();
            for h in 0..budget {
                self.draft_flat.push(argmax(dout.logits(gr, off, h)) as i32);
            }
            self.draft_span.push((start, self.draft_flat.len()));
            if self.inc {
                self.fork_batch.push(
                    &self.views[q],
                    StateParent::Id(b.state),
                    self.arena.last_tok(b.node),
                );
            }
        }
        // One batched commit for the whole cycle. The batch stops at
        // the first failure, so the Ok ids land as a *prefix* of the
        // rows in row order — the verify builder indexes row_states
        // per row and a missing tail slot reads as NONE (full-prefix
        // fallback), keeping the alignment the sequential path had.
        self.fork_batch.flush(model, &mut self.inc, &mut self.row_states);
        self.phase = MsbsPhase::Verify;
    }

    /// Absorb the verify call: nucleus acceptance + candidate harvest.
    fn absorb_verify(
        &mut self,
        model: &dyn StepModel,
        vout: &DecodeOut,
        range: std::ops::Range<usize>,
    ) {
        for pool in self.pools.iter_mut() {
            pool.reset();
        }
        for (q, qbeams) in self.beams.iter().enumerate() {
            for b in qbeams {
                if b.finished {
                    self.pools[q].push(*b);
                }
            }
        }
        self.accepted_log.clear();
        // Pass 1 — accept drafts and *plan* the backbone state chains.
        // Each accepted backbone walks `prefix ++ draft[..links]`; the
        // chain forks one token at a time off the draft phase's
        // full-prefix state, expressed as intra-batch `Slot` parents so
        // the whole cycle commits in ONE model call. Positions past the
        // accepted backbone are never committed, so a rejected draft
        // rolls back for free.
        self.fork_batch.clear();
        self.verify_plan.clear();
        for (r, &(q, bi)) in self.row_of.iter().enumerate() {
            let b = self.beams[q][bi];
            let blen = self.arena.len(b.node);
            let p0 = blen - 1;
            let gr = range.start + r;
            let (ds, de) = self.draft_span[r];
            let draft = &self.draft_flat[ds..de];
            // accept a prefix of the draft via the nucleus test; an
            // accepted EOS terminates the draft (nothing after it can
            // be meaningful).
            let mut acc = 0usize;
            let mut eos_idx: Option<usize> = None;
            for (j, &dt) in draft.iter().enumerate() {
                let Some(off) = vout.offset_of(gr, p0 + j) else { break };
                if nucleus_mass_before(vout.logits(gr, off, 0), dt as usize) >= self.nucleus {
                    break;
                }
                acc += 1;
                if dt == EOS {
                    eos_idx = Some(j);
                    break;
                }
            }
            self.stats.drafts_offered += draft.len() as u64;
            self.stats.drafts_accepted += acc as u64;
            self.accepted_log.push(acc);

            let ext_cap = eos_idx.unwrap_or(acc);
            let start_anchor = self.row_states.get(r).copied().unwrap_or(StateId::NONE);
            let mut slot_start = usize::MAX;
            if self.inc && !start_anchor.is_none() {
                // Mirror the harvest loop's break order: a fork at
                // iteration j happens before that iteration's window /
                // max-length checks, so the chain length is the number
                // of iterations the harvest *enters* past j=0.
                let links = chain_links(vout, gr, p0, self.max_len, ext_cap);
                let mut prev: Option<usize> = None;
                for j in 1..=links {
                    let parent = match prev {
                        None => StateParent::Id(start_anchor),
                        Some(s) => StateParent::Slot(s),
                    };
                    let s = self.fork_batch.push(&self.views[q], parent, draft[j - 1]);
                    if j == 1 {
                        slot_start = s;
                    }
                    prev = Some(s);
                }
            }
            self.verify_plan.push((ext_cap, slot_start));
        }
        self.fork_batch.flush(model, &mut self.inc, &mut self.cycle_states);

        // Pass 2 — harvest candidates. The accepted tokens form a
        // committed *backbone*: at its end we take the top-K
        // continuations; at every earlier accepted position we take
        // the top-K *divergent* branches (excluding the draft token
        // itself — it already lives inside the backbone, and re-adding
        // it would flood the pool with nested prefixes). Cumulative
        // log-probability ranks the pool, so a weakly-accepted
        // backbone can lose to a short divergence — the paper's "both
        // shorter and longer sequences may be the most probable".
        for (r, &(q, bi)) in self.row_of.iter().enumerate() {
            let b = self.beams[q][bi];
            let blen = self.arena.len(b.node);
            let p0 = blen - 1;
            let gr = range.start + r;
            let (ds, de) = self.draft_span[r];
            let draft = &self.draft_flat[ds..de];
            let (ext_cap, slot_start) = self.verify_plan[r];
            let mut cum = b.logp;
            let mut backbone = b.node;
            let mut anchor = self.row_states.get(r).copied().unwrap_or(StateId::NONE);
            for j in 0..=ext_cap {
                if j > 0 {
                    backbone = self.arena.push(backbone, draft[j - 1]);
                    if !anchor.is_none() {
                        anchor = if slot_start == usize::MAX {
                            StateId::NONE
                        } else {
                            self.fork_batch.id(slot_start + j - 1)
                        };
                    }
                }
                let Some(off) = vout.offset_of(gr, p0 + j) else { break };
                let prefix_len = blen + j;
                if prefix_len >= self.max_len {
                    break;
                }
                let backbone_end = j == ext_cap;
                self.scratch.top_k_log_softmax(vout.logits(gr, off, 0), self.k);
                for &tok in &self.scratch.topk {
                    if !backbone_end && tok as i32 == draft[j] {
                        continue; // divergences only before the backbone end
                    }
                    let node = self.arena.push(backbone, tok as i32);
                    let finished = tok as i32 == EOS || self.arena.len(node) >= self.max_len;
                    self.pools[q].push(Beam {
                        node,
                        logp: cum + self.scratch.lsm[tok],
                        finished,
                        state: anchor,
                    });
                }
                if j < draft.len() {
                    cum += self.scratch.lsm[draft[j] as usize];
                }
            }
        }
        for (q, pool) in self.pools.iter_mut().enumerate() {
            if self.done[q] {
                continue;
            }
            pool.take_into(&self.arena, &mut self.next);
            if !self.next.is_empty() {
                adopt_beams(model, &mut self.beams[q], &mut self.next);
            }
            self.done[q] = self.beams[q].iter().all(|b| b.finished);
        }
        for s in self.cycle_states.drain(..) {
            release_state(model, s);
        }
        for s in self.row_states.drain(..) {
            release_state(model, s);
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.push(CycleTrace {
                cycle: self.cycle,
                drafts: self
                    .draft_span
                    .iter()
                    .map(|&(s, e)| self.draft_flat[s..e].to_vec())
                    .collect(),
                accepted: self.accepted_log.clone(),
                beams: self.beams[0]
                    .iter()
                    .map(|b| (self.arena.tokens(b.node), b.logp))
                    .collect(),
            });
        }
        compact_beams(&mut self.arena, &mut self.compact, &mut self.beams, &mut self.compact_at);
        self.phase = MsbsPhase::Draft;
    }
}

impl DecodeTask for MsbsTask {
    fn next_rows(&mut self, rows: &mut RowBuf) -> TaskState {
        match self.phase {
            MsbsPhase::Draft => {
                if self.done.iter().all(|&d| d) {
                    return TaskState::Done;
                }
                self.row_of.clear();
                let before = rows.len();
                for (q, qbeams) in self.beams.iter().enumerate() {
                    if self.done[q] {
                        continue;
                    }
                    for (bi, b) in qbeams.iter().enumerate() {
                        if !b.finished {
                            let v = &self.views[q];
                            let (state, from) = delta_spec(&self.arena, b, self.inc);
                            rows.push_row_delta(
                                &self.arena,
                                v.mem(),
                                v.row(),
                                state,
                                b.node,
                                from,
                                &[],
                            );
                            self.row_of.push((q, bi));
                        }
                    }
                }
                if rows.len() == before {
                    TaskState::Done
                } else {
                    TaskState::Need { win: 1 }
                }
            }
            MsbsPhase::Verify => {
                // Never empty: the draft phase only transitions here
                // with at least one live row. Incrementally, the verify
                // row continues from the draft phase's full-prefix
                // state, so its delta is ONLY the draft — a verify
                // cycle processes `draft_len` new positions, not the
                // whole prefix (prefix-shared Medusa verification).
                for (r, &(q, bi)) in self.row_of.iter().enumerate() {
                    let b = self.beams[q][bi];
                    let (s, e) = self.draft_span[r];
                    let v = &self.views[q];
                    // Prefix-shared verification: continue from the
                    // draft phase's full-prefix state so the delta is
                    // ONLY the draft (a NONE slot — degraded task —
                    // falls back to the full row).
                    let state = self.row_states.get(r).copied().unwrap_or(StateId::NONE);
                    let from = if state.is_none() { 0 } else { self.arena.len(b.node) };
                    rows.push_row_delta(
                        &self.arena,
                        v.mem(),
                        v.row(),
                        state,
                        b.node,
                        from,
                        &self.draft_flat[s..e],
                    );
                }
                TaskState::Need { win: self.m + 1 }
            }
        }
    }

    fn absorb(&mut self, model: &dyn StepModel, out: &DecodeOut, range: std::ops::Range<usize>) {
        debug_assert_eq!(range.len(), self.row_of.len());
        match self.phase {
            MsbsPhase::Draft => self.absorb_draft(model, out, range),
            MsbsPhase::Verify => self.absorb_verify(model, out, range),
        }
    }

    fn stats_mut(&mut self) -> &mut DecodeStats {
        &mut self.stats
    }

    fn arena_nodes(&self) -> usize {
        self.arena.node_count()
    }

    fn finish(self: Box<Self>, model: &dyn StepModel) -> (Vec<GenOutput>, DecodeStats) {
        let this = *self;
        // A cancellation between the draft and verify phases leaves the
        // per-row prefix states claimed — release them with the beams'.
        for s in this.row_states {
            release_state(model, s);
        }
        release_beam_states(model, &this.beams);
        release_views(model, this.views);
        let outs = this.beams.iter().map(|qb| finalize(&this.arena, qb)).collect();
        (outs, this.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::beam::BeamSearch;
    use crate::model::mock::{MockConfig, MockModel};
    use crate::tokenizer::BOS;

    fn src(tokens: &[i32]) -> Vec<i32> {
        let mut v = vec![BOS];
        v.extend_from_slice(tokens);
        v.push(EOS);
        v
    }

    #[test]
    fn top1_matches_beam_search() {
        let model = MockModel::new(MockConfig::default());
        let s = vec![src(&[5, 6, 7, 8, 9, 10, 11])];
        let mut s1 = DecodeStats::default();
        let bs = BeamSearch::vanilla().generate(&model, &s, 3, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        let ms = Msbs::default().generate(&model, &s, 3, &mut s2).unwrap();
        assert_eq!(bs[0].hyps[0].tokens, ms[0].hyps[0].tokens);
        assert!((bs[0].hyps[0].logp - ms[0].hyps[0].logp).abs() < 1e-9);
    }

    #[test]
    fn far_fewer_model_calls_than_beam_search() {
        // SBS progress relies on nested beams of different lengths: the
        // longest beam advances by up to M+1 tokens per cycle, so the
        // effect needs paper-scale K (the paper uses K=10).
        let model = MockModel::new(MockConfig::default());
        let body: Vec<i32> = (5..23).collect();
        let s = vec![src(&body)];
        let mut s1 = DecodeStats::default();
        BeamSearch::vanilla().generate(&model, &s, 10, &mut s1).unwrap();
        let mut s2 = DecodeStats::default();
        Msbs::default().generate(&model, &s, 10, &mut s2).unwrap();
        assert!(
            (s2.model_calls as f64) < 0.7 * s1.model_calls as f64,
            "msbs {} vs bs {}",
            s2.model_calls,
            s1.model_calls
        );
    }

    #[test]
    fn acceptance_rate_tracks_head_accuracy() {
        // perfect heads -> high acceptance (tail cycles still truncate
        // at EOS, so it does not reach exactly 1)
        let perfect = MockModel::new(MockConfig {
            head_base_acc: 100,
            head_acc_decay: 0,
            ..Default::default()
        });
        let body: Vec<i32> = (5..21).collect();
        let s = vec![src(&body)];
        let mut st = DecodeStats::default();
        Msbs::default().generate(&perfect, &s, 10, &mut st).unwrap();
        assert!(st.acceptance_rate() > 0.7, "{}", st.acceptance_rate());

        // poor heads -> lower acceptance, but still the correct output
        let poor = MockModel::new(MockConfig {
            head_base_acc: 30,
            head_acc_decay: 0,
            ..Default::default()
        });
        let mut st2 = DecodeStats::default();
        let out = Msbs::default().generate(&poor, &s, 10, &mut st2).unwrap();
        assert!(st2.acceptance_rate() < st.acceptance_rate());
        assert_eq!(out[0].hyps[0].body(), &body[..]);
    }

    #[test]
    fn nucleus_cut_rejects_unlikely_tokens() {
        let m = Msbs::new(0.9);
        // probs: argmax 0.85, second 0.1, third 0.05
        let probs = vec![0.85, 0.1, 0.05];
        assert!(m.in_nucleus(&probs, 0)); // argmax always
        assert!(m.in_nucleus(&probs, 1)); // 0.85 < 0.9
        assert!(!m.in_nucleus(&probs, 2)); // 0.95 !< 0.9
    }

    #[test]
    fn fused_nucleus_test_agrees_with_reference() {
        use crate::model::softmax;
        let m = Msbs::new(0.9975);
        let logits: Vec<f32> = vec![8.0, 4.0, -4.0, -4.0, -4.0, 2.0];
        let probs = softmax(&logits);
        for tok in 0..logits.len() {
            let fused = nucleus_mass_before(&logits, tok) < m.nucleus;
            assert_eq!(fused, m.in_nucleus(&probs, tok), "tok={tok}");
        }
    }

    #[test]
    fn two_calls_per_cycle() {
        let model = MockModel::new(MockConfig::default());
        let s = vec![src(&[5, 6, 7, 8])];
        let mut st = DecodeStats::default();
        let mut trace = Some(Vec::new());
        Msbs::default()
            .generate_traced(&model, &s, 2, &mut st, &mut trace)
            .unwrap();
        let cycles = trace.unwrap().len() as u64;
        assert_eq!(st.model_calls, 2 * cycles);
    }

    #[test]
    fn batch_group_processes_all_queries() {
        let model = MockModel::new(MockConfig::default());
        let srcs = vec![src(&[5, 6, 7]), src(&[8, 9, 10, 11]), src(&[12, 13])];
        let mut st = DecodeStats::default();
        let out = Msbs::default().generate(&model, &srcs, 4, &mut st).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].hyps[0].body(), &[5, 6, 7]);
        assert_eq!(out[1].hyps[0].body(), &[8, 9, 10, 11]);
        assert_eq!(out[2].hyps[0].body(), &[12, 13]);
    }
}
