//! Cycle-level continuous batching: one fused device call per tick over
//! the pending rows of many in-flight [`DecodeTask`]s.
//!
//! The request-granularity hub ran one `generate` *to completion* per
//! batch, so every concurrent planning session stalled behind the
//! slowest group, late requests waited out whole multi-cycle decodes,
//! and the device saw shrinking batches as beams finished — exactly the
//! Table 1C effective-batch decay the paper measures. The scheduler is
//! the same shift continuous batching brought to LLM serving, applied at
//! the decode-*cycle* level:
//!
//! * [`DecodeScheduler::submit`] parks a resumable task (its encoder
//!   memory already lives behind a per-row [`crate::model::MemHandle`],
//!   so rows from different tasks mix freely in one call);
//! * [`DecodeScheduler::tick`] polls tasks **oldest-first**, concatenates
//!   their pending rows into ONE [`StepModel::decode_into`] call (window
//!   = the widest any staged task asked for; logits are addressed by
//!   absolute position, so a wider window is harmless), demultiplexes
//!   the output windows back via [`DecodeTask::absorb`], and retires
//!   finished tasks;
//! * a `max_rows` budget bounds the fused call. Fairness is strict
//!   oldest-first with head-of-line blocking: a task whose rows don't
//!   fit waits for the next tick and nothing younger jumps the queue
//!   (no starvation; the oldest staged task is always admitted even if
//!   it alone exceeds the budget). Deferral never changes results —
//!   `next_rows` is idempotent and logits are position-pure — it only
//!   trades latency, which `tests/parity_decoding.rs` pins.
//!
//! Per-task accounting stays solo-equivalent: each staged task is
//! charged one `model_call`, its own logical rows, and the padding the
//! device *would* have applied to its rows alone
//! ([`StepModel::pad_rows`]) — so a task's `DecodeStats` are identical
//! whether it ran fused or via `Decoder::generate`. The scheduler's own
//! [`FusedStats`] track the actual fused calls for throughput
//! accounting.
//!
//! Steady-state ticks allocate nothing: rows, the fused output buffer,
//! and the staging table are all recycled; tasks reuse their arenas,
//! pools and scratch (see the benches' counting-allocator check).

use super::{DecodeStats, DecodeTask, GenOutput, RowBuf, TaskState};
use crate::model::{DecodeOut, StepModel};
use anyhow::Result;

/// Identifies a submitted task until it is returned via [`Finished`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskId(pub u64);

/// Scheduler tuning knobs.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Fused-call row budget per tick. The oldest staged task may exceed
    /// it alone; younger tasks then wait for the next tick.
    pub max_rows: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { max_rows: 256 }
    }
}

/// Whole-scheduler accounting across fused calls.
#[derive(Clone, Debug, Default)]
pub struct FusedStats {
    /// Fused device calls issued.
    pub fused_calls: u64,
    /// Sum of logical rows over fused calls.
    pub rows_logical: u64,
    /// Sum of device-padded rows over fused calls.
    pub rows_padded: u64,
    /// Tasks submitted / retired.
    pub tasks_submitted: u64,
    pub tasks_finished: u64,
    /// Tasks abandoned via [`DecodeScheduler::cancel`] (speculative
    /// expansions whose waiters went away).
    pub tasks_cancelled: u64,
}

impl FusedStats {
    /// Average logical rows per fused call (the serving-side Table 1C).
    pub fn avg_effective_batch(&self) -> f64 {
        if self.fused_calls == 0 {
            0.0
        } else {
            self.rows_logical as f64 / self.fused_calls as f64
        }
    }
}

/// A retired task: its per-query outputs and solo-equivalent stats.
pub struct Finished {
    pub id: TaskId,
    pub outputs: Vec<GenOutput>,
    pub stats: DecodeStats,
}

struct InFlight {
    id: TaskId,
    task: Box<dyn DecodeTask>,
    done: bool,
}

/// Owns many in-flight decode tasks and drives them with fused calls.
pub struct DecodeScheduler {
    cfg: SchedulerConfig,
    /// Submission order == service order (oldest first).
    tasks: Vec<InFlight>,
    rows: RowBuf,
    out: DecodeOut,
    /// (task index, row start, row end) staged this tick.
    staged: Vec<(usize, usize, usize)>,
    /// Tasks dropped by the last errored tick (see
    /// [`DecodeScheduler::drain_failed`]).
    failed: Vec<TaskId>,
    next_id: u64,
    /// Id increment per submit (see [`DecodeScheduler::with_ids`]).
    id_stride: u64,
    pub stats: FusedStats,
}

impl DecodeScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Self::with_ids(cfg, 1, 1)
    }

    /// A scheduler whose [`TaskId`]s walk `base, base+stride, ...` —
    /// several schedulers (one per model replica) can then share one
    /// id space without a coordination lock: give scheduler `r` of `N`
    /// `base = r + 1, stride = N` and their ids interleave disjointly.
    pub fn with_ids(mut cfg: SchedulerConfig, base: u64, stride: u64) -> Self {
        cfg.max_rows = cfg.max_rows.max(1);
        Self {
            cfg,
            tasks: Vec::new(),
            rows: RowBuf::new(),
            out: DecodeOut::default(),
            staged: Vec::new(),
            failed: Vec::new(),
            next_id: base.max(1),
            id_stride: stride.max(1),
            stats: FusedStats::default(),
        }
    }

    /// Park a task; it joins the very next tick's fused call.
    pub fn submit(&mut self, task: Box<dyn DecodeTask>) -> TaskId {
        let id = TaskId(self.next_id);
        self.next_id += self.id_stride;
        self.stats.tasks_submitted += 1;
        self.tasks.push(InFlight { id, task, done: false });
        id
    }

    pub fn in_flight(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_idle(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Total arena nodes across in-flight tasks (memory diagnostics).
    pub fn arena_nodes(&self) -> usize {
        self.tasks.iter().map(|t| t.task.arena_nodes()).sum()
    }

    /// Abandon one in-flight task: its rows leave the very next fused
    /// call and its encoder memory is released. Partial outputs are
    /// discarded — the task never appears in `finished`. Returns whether
    /// the id was in flight (a task that already retired is a no-op).
    pub fn cancel(&mut self, model: &dyn StepModel, id: TaskId) -> bool {
        if let Some(pos) = self.tasks.iter().position(|t| t.id == id) {
            let slot = self.tasks.remove(pos);
            let _ = slot.task.finish(model);
            self.stats.tasks_cancelled += 1;
            true
        } else {
            false
        }
    }

    /// Task ids dropped by the last errored [`DecodeScheduler::tick`]:
    /// exactly the tasks whose rows were in the failed fused call.
    /// Unstaged tasks keep flying — callers fail only these waiters.
    pub fn drain_failed(&mut self) -> Vec<TaskId> {
        std::mem::take(&mut self.failed)
    }

    /// Run one fused decode over as many tasks' pending rows as the
    /// budget admits (oldest-first), absorb the results, and append
    /// retired tasks to `finished`. Returns the number of logical rows
    /// fused this tick (0 when the scheduler only retired tasks or is
    /// idle).
    pub fn tick(&mut self, model: &dyn StepModel, finished: &mut Vec<Finished>) -> Result<usize> {
        self.rows.begin();
        self.staged.clear();
        let mut win = 1usize;
        let mut done_any = false;
        for (i, slot) in self.tasks.iter_mut().enumerate() {
            if self.rows.len() >= self.cfg.max_rows {
                break; // budget exhausted; younger tasks wait (oldest-first)
            }
            let start = self.rows.len();
            match slot.task.next_rows(&mut self.rows) {
                TaskState::Done => {
                    slot.done = true;
                    done_any = true;
                }
                TaskState::Need { win: w } => {
                    let end = self.rows.len();
                    if end > self.cfg.max_rows && !self.staged.is_empty() {
                        // Doesn't fit: put its rows back and stop — no
                        // younger task may jump the queue past it.
                        self.rows.truncate_to(start);
                        break;
                    }
                    win = win.max(w);
                    self.staged.push((i, start, end));
                }
            }
        }

        let fused_rows = self.rows.len();
        if !self.staged.is_empty() {
            if let Err(e) = model.decode_into(&self.rows.rows, win, &mut self.out) {
                // The fused call failed: exactly the *staged* tasks were
                // in it. Drop them (releasing encoder memory), record
                // their ids for the caller, and leave every unstaged
                // task intact — a tick error must not fail tasks that
                // never touched the errored call.
                for &(i, _, _) in self.staged.iter().rev() {
                    let slot = self.tasks.remove(i);
                    self.failed.push(slot.id);
                    let _ = slot.task.finish(model);
                }
                self.staged.clear();
                return Err(e);
            }
            self.stats.fused_calls += 1;
            self.stats.rows_logical += fused_rows as u64;
            self.stats.rows_padded += self.out.padded_rows as u64;
            for &(i, start, end) in &self.staged {
                // Positions actually processed for this task's rows: the
                // delta lengths, the same number solo `generate` charges.
                let toks: u64 =
                    self.rows.rows[start..end].iter().map(|r| r.delta.len() as u64).sum();
                let slot = &mut self.tasks[i];
                let st = slot.task.stats_mut();
                st.model_calls += 1;
                st.rows_logical += (end - start) as u64;
                st.rows_padded += model.pad_rows(end - start) as u64;
                st.decode_tokens += toks;
                slot.task.absorb(model, &self.out, start..end);
            }
        }

        if done_any {
            let mut kept = Vec::with_capacity(self.tasks.len());
            for slot in std::mem::take(&mut self.tasks) {
                if slot.done {
                    let (outputs, stats) = slot.task.finish(model);
                    self.stats.tasks_finished += 1;
                    finished.push(Finished { id: slot.id, outputs, stats });
                } else {
                    kept.push(slot);
                }
            }
            self.tasks = kept;
        }
        Ok(fused_rows)
    }

    /// Tick until every in-flight task has retired.
    pub fn run_to_idle(
        &mut self,
        model: &dyn StepModel,
        finished: &mut Vec<Finished>,
    ) -> Result<()> {
        while !self.is_idle() {
            self.tick(model, finished)?;
        }
        Ok(())
    }

    /// Drop every in-flight task, releasing its device memory; partial
    /// outputs are discarded. A fused-call *error* no longer needs this
    /// (the failed tick already drops exactly its staged tasks — see
    /// [`DecodeScheduler::drain_failed`]); this is the full-reset path.
    pub fn abort(&mut self, model: &dyn StepModel) {
        for slot in std::mem::take(&mut self.tasks) {
            let _ = slot.task.finish(model);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::{beam::BeamSearch, msbs::Msbs, DecodeStats, Decoder};
    use crate::model::mock::{MockConfig, MockModel};
    use crate::tokenizer::{BOS, EOS};

    fn src(tokens: &[i32]) -> Vec<i32> {
        let mut v = vec![BOS];
        v.extend_from_slice(tokens);
        v.push(EOS);
        v
    }

    fn groups() -> Vec<Vec<Vec<i32>>> {
        vec![
            vec![src(&[5, 6, 7, 8]), src(&[9, 10, 11])],
            vec![src(&[12, 13, 14, 15, 16])],
            vec![src(&[6, 8, 10])],
        ]
    }

    #[test]
    fn fused_ticks_match_solo_generate() {
        let dec = BeamSearch::optimized();
        // Solo reference on its own model, sequential (same encode-id
        // order as the scheduler run below).
        let solo_model = MockModel::new(MockConfig::default());
        let mut solo = Vec::new();
        for g in groups() {
            let mut st = DecodeStats::default();
            let out = dec.generate(&solo_model, &g, 3, &mut st).unwrap();
            solo.push((out, st));
        }
        // Fused: all three tasks share every tick.
        let model = MockModel::new(MockConfig::default());
        let mut sched = DecodeScheduler::new(SchedulerConfig::default());
        let mut ids = Vec::new();
        for g in groups() {
            ids.push(sched.submit(dec.start_task(&model, &g, 3).unwrap()));
        }
        let mut finished = Vec::new();
        sched.run_to_idle(&model, &mut finished).unwrap();
        assert_eq!(finished.len(), 3);
        for (i, id) in ids.iter().enumerate() {
            let f = finished.iter().find(|f| f.id == *id).unwrap();
            let (want_out, want_st) = &solo[i];
            assert_eq!(f.outputs.len(), want_out.len());
            for (a, b) in f.outputs.iter().zip(want_out.iter()) {
                for (x, y) in a.hyps.iter().zip(b.hyps.iter()) {
                    assert_eq!(x.tokens, y.tokens);
                    assert!((x.logp - y.logp).abs() < 1e-9);
                }
            }
            assert_eq!(f.stats.model_calls, want_st.model_calls);
            assert_eq!(f.stats.rows_logical, want_st.rows_logical);
            assert_eq!(f.stats.rows_padded, want_st.rows_padded);
        }
        // Fusion actually fused: fewer device calls than the solo total.
        let solo_calls: u64 = solo.iter().map(|(_, st)| st.model_calls).sum();
        assert!(
            sched.stats.fused_calls < solo_calls,
            "fused {} !< solo {}",
            sched.stats.fused_calls,
            solo_calls
        );
        assert_eq!(sched.stats.tasks_finished, 3);
    }

    #[test]
    fn budget_defers_youngest_without_changing_results() {
        let dec = Msbs::default();
        let solo_model = MockModel::new(MockConfig::default());
        let mut solo = Vec::new();
        for g in groups() {
            let mut st = DecodeStats::default();
            let out = dec.generate(&solo_model, &g, 4, &mut st).unwrap();
            solo.push((out, st));
        }
        let model = MockModel::new(MockConfig::default());
        // Tiny budget: most ticks carry a single task's rows.
        let mut sched = DecodeScheduler::new(SchedulerConfig { max_rows: 4 });
        let mut ids = Vec::new();
        for g in groups() {
            ids.push(sched.submit(dec.start_task(&model, &g, 4).unwrap()));
        }
        let mut finished = Vec::new();
        sched.run_to_idle(&model, &mut finished).unwrap();
        for (i, id) in ids.iter().enumerate() {
            let f = finished.iter().find(|f| f.id == *id).unwrap();
            let (want_out, want_st) = &solo[i];
            for (a, b) in f.outputs.iter().zip(want_out.iter()) {
                assert_eq!(a.hyps[0].tokens, b.hyps[0].tokens);
            }
            assert_eq!(f.stats.model_calls, want_st.model_calls, "task {i}");
            assert_eq!(f.stats.rows_logical, want_st.rows_logical, "task {i}");
        }
    }

    #[test]
    fn abort_releases_encoder_memory() {
        let dec = BeamSearch::vanilla();
        let model = MockModel::new(MockConfig::default());
        let mut sched = DecodeScheduler::new(SchedulerConfig::default());
        sched.submit(dec.start_task(&model, &groups()[0], 2).unwrap());
        let mut finished = Vec::new();
        sched.tick(&model, &mut finished).unwrap();
        sched.abort(&model);
        assert!(sched.is_idle());
        // A fresh task still works and ids keep advancing.
        let id = sched.submit(dec.start_task(&model, &groups()[1], 2).unwrap());
        assert!(id.0 >= 2);
        sched.run_to_idle(&model, &mut finished).unwrap();
        assert_eq!(finished.len(), 1);
    }

    #[test]
    fn cancel_releases_memory_and_skips_output() {
        let dec = BeamSearch::optimized();
        let model = MockModel::new(MockConfig::default());
        let mut sched = DecodeScheduler::new(SchedulerConfig::default());
        let keep = sched.submit(dec.start_task(&model, &groups()[0], 2).unwrap());
        let drop_id = sched.submit(dec.start_task(&model, &groups()[1], 2).unwrap());
        let handles_full = model.live_handles();
        let mut finished = Vec::new();
        sched.tick(&model, &mut finished).unwrap();
        assert!(sched.cancel(&model, drop_id), "in-flight task must cancel");
        assert!(
            model.live_handles() < handles_full,
            "cancel must release the task's encoder memory"
        );
        assert_eq!(sched.stats.tasks_cancelled, 1);
        assert!(!sched.cancel(&model, drop_id), "second cancel is a no-op");
        sched.run_to_idle(&model, &mut finished).unwrap();
        assert_eq!(finished.len(), 1, "cancelled task must not retire");
        assert_eq!(finished[0].id, keep);
        assert_eq!(model.live_handles(), 0, "all encoder memory released");
    }

    /// Fails the N-th decode call, then delegates.
    struct FailNth {
        inner: MockModel,
        calls: std::sync::atomic::AtomicUsize,
        fail_at: usize,
    }

    impl crate::model::StepModel for FailNth {
        fn vocab(&self) -> usize {
            self.inner.vocab()
        }
        fn medusa_heads(&self) -> usize {
            self.inner.medusa_heads()
        }
        fn max_src(&self) -> usize {
            self.inner.max_src()
        }
        fn max_tgt(&self) -> usize {
            self.inner.max_tgt()
        }
        fn encode(&self, src: &[Vec<i32>]) -> Result<crate::model::MemHandle> {
            self.inner.encode(src)
        }
        fn decode(&self, rows: &[crate::model::DecodeRow], win: usize) -> Result<DecodeOut> {
            let mut out = DecodeOut::default();
            self.decode_into(rows, win, &mut out)?;
            Ok(out)
        }
        fn decode_into(
            &self,
            rows: &[crate::model::DecodeRow],
            win: usize,
            out: &mut DecodeOut,
        ) -> Result<()> {
            let n = self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if n + 1 == self.fail_at {
                anyhow::bail!("injected device fault");
            }
            self.inner.decode_into(rows, win, out)
        }
        fn release(&self, mem: crate::model::MemHandle) {
            self.inner.release(mem)
        }
    }

    #[test]
    fn tick_error_fails_only_staged_tasks() {
        let dec = BeamSearch::optimized();
        let model = FailNth {
            inner: MockModel::new(MockConfig::default()),
            calls: std::sync::atomic::AtomicUsize::new(0),
            fail_at: 1,
        };
        // Tiny budget: only the oldest task's rows fit the first tick,
        // which is the one that errors.
        let mut sched = DecodeScheduler::new(SchedulerConfig { max_rows: 1 });
        let a = sched.submit(dec.start_task(&model, &groups()[0], 2).unwrap());
        let b = sched.submit(dec.start_task(&model, &groups()[2], 2).unwrap());
        let mut finished = Vec::new();
        let err = sched.tick(&model, &mut finished);
        assert!(err.is_err());
        assert_eq!(sched.drain_failed(), vec![a], "only the staged task fails");
        assert!(sched.drain_failed().is_empty(), "drain is one-shot");
        assert_eq!(sched.in_flight(), 1, "unstaged task keeps flying");
        sched.run_to_idle(&model, &mut finished).unwrap();
        assert_eq!(finished.len(), 1);
        assert_eq!(finished[0].id, b);
        assert_eq!(model.inner.live_handles(), 0, "failed task released its memory");
    }

    #[test]
    fn strided_ids_stay_disjoint_across_schedulers() {
        let dec = BeamSearch::vanilla();
        let model = MockModel::new(MockConfig::default());
        // Two schedulers sharing one id space: r+1 base, stride 2.
        let mut a = DecodeScheduler::with_ids(SchedulerConfig::default(), 1, 2);
        let mut b = DecodeScheduler::with_ids(SchedulerConfig::default(), 2, 2);
        let mut ids = Vec::new();
        for _ in 0..3 {
            ids.push(a.submit(dec.start_task(&model, &groups()[0], 2).unwrap()));
            ids.push(b.submit(dec.start_task(&model, &groups()[1], 2).unwrap()));
        }
        assert_eq!(
            ids.iter().map(|t| t.0).collect::<Vec<_>>(),
            vec![1, 2, 3, 4, 5, 6],
            "ids interleave without collision"
        );
        a.abort(&model);
        b.abort(&model);
        assert_eq!(model.live_handles(), 0);
    }

    #[test]
    fn idle_tick_is_a_noop() {
        let model = MockModel::new(MockConfig::default());
        let mut sched = DecodeScheduler::new(SchedulerConfig::default());
        let mut finished = Vec::new();
        assert_eq!(sched.tick(&model, &mut finished).unwrap(), 0);
        assert!(finished.is_empty());
        assert_eq!(sched.stats.fused_calls, 0);
    }
}
