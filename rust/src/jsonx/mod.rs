//! Minimal JSON implementation (the offline build has no `serde`).
//!
//! Supports the full JSON data model with a hand-rolled recursive-descent
//! parser and a compact serializer. Used for `artifacts/vocab.json`,
//! `manifest.json`, and the line-delimited JSON protocol of the serving
//! coordinator.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj()?.get(key)
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = P { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

/// Compact serialization (`.to_string()` comes from the blanket
/// `ToString` impl).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.b.get(self.i) {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut v = Vec::new();
                self.ws();
                if self.b.get(self.i) == Some(&b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    v.push(self.value()?);
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => return Err(format!("expected , or ] at {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.b.get(self.i) == Some(&b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    if self.b.get(self.i) != Some(&b':') {
                        return Err(format!("expected : at {}", self.i));
                    }
                    self.i += 1;
                    let v = self.value()?;
                    m.insert(k, v);
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("expected , or }} at {}", self.i)),
                    }
                }
            }
            Some(_) => self.number(),
            None => Err("unexpected EOF".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if self.b.get(self.i) != Some(&b'"') {
            return Err(format!("expected string at {}", self.i));
        }
        self.i += 1;
        let mut s = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(&c) => {
                    // copy a UTF-8 run
                    let start = self.i;
                    let len = utf8_len(c);
                    let chunk = self.b.get(start..start + len).ok_or("bad utf8")?;
                    s.push_str(std::str::from_utf8(chunk).map_err(|_| "bad utf8")?);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad num")?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_values() {
        for s in [
            "null",
            "true",
            "42",
            "-3.5",
            "\"hi\"",
            "[1,2,3]",
            "{\"a\":1,\"b\":[true,null]}",
            "{\"nested\":{\"x\":\"y\"}}",
        ] {
            let v = Json::parse(s).unwrap();
            let out = v.to_string();
            assert_eq!(Json::parse(&out).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
        let u = Json::parse("\"\\u0041\"").unwrap();
        assert_eq!(u.as_str().unwrap(), "A");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn accessors() {
        let v = Json::parse("{\"k\":[1,\"two\",false]}").unwrap();
        let arr = v.get("k").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize().unwrap(), 1);
        assert_eq!(arr[1].as_str().unwrap(), "two");
        assert_eq!(arr[2].as_bool().unwrap(), false);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn whole_numbers_serialize_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}
