//! # retroserve
//!
//! A production-shaped reproduction of *"Fast and scalable retrosynthetic
//! planning with a transformer neural network and speculative beam search"*
//! (Andronov et al., 2025).
//!
//! The system is a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the serving coordinator: single-step decoding
//!   engines (beam search, optimized beam search, HSBS, MSBS), multi-step
//!   planners (Retro\*, DFS), stock management, a request router with a
//!   dynamic cross-tree batcher, metrics and a CLI. Python is never on the
//!   request path.
//! * **L2** — a JAX encoder-decoder transformer with Medusa heads
//!   (`python/compile/model.py`), trained at build time and AOT-lowered to
//!   HLO text artifacts per batch bucket.
//! * **L1** — Pallas kernels for the Medusa-head fan-out and fused
//!   attention (`python/compile/kernels/`), verified against a pure-jnp
//!   oracle.
//!
//! The [`runtime`] module loads the HLO artifacts through the PJRT C API
//! (`xla` crate) and exposes them behind the [`model::StepModel`] trait;
//! [`model::mock`] provides a deterministic in-process model so the whole
//! L3 stack is testable without artifacts.
//!
//! See `DESIGN.md` for the system inventory and the experiment index.

pub mod chem;
pub mod config;
pub mod coordinator;
pub mod decoding;
pub mod jsonx;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod search;
pub mod store;
pub mod synthchem;
pub mod tokenizer;
pub mod util;
pub mod benchkit;
