//! `retroserve` CLI — the leader entrypoint.
//!
//! ```text
//! retroserve serve   [--config FILE] [--listen ADDR] [--decoder NAME]
//!                    [--max-sessions N] [--max-queue N] [--drain-ms N] ...
//! retroserve plan    --smiles S [--algo retrostar|dfs] [--decoder NAME]
//!                    [--deadline-ms N] [--beam-width N] [--artifacts DIR]
//!                    [--connect ADDR]
//! retroserve screen  --targets FILE [--out FILE] [--concurrency N]
//!                    [--job-deadline-ms N] [--job-max-decode-tokens N]
//!                    [--deadline-ms N] [--decoder NAME] [--artifacts DIR]
//!                    [--connect ADDR]
//! retroserve expand  --smiles S [--decoder NAME] [--k N] [--artifacts DIR]
//! retroserve routes  --smiles S (--cache-path FILE | --connect ADDR)
//! retroserve info    [--artifacts DIR]
//! ```
//!
//! `--cache-path FILE` (or `cache.path` in the config) enables the
//! persistent expansion/route store: a crash-safe append-only log under
//! the in-memory cache, so a restarted process warm-starts from
//! yesterday's decodes. `screen --warm` additionally skips targets
//! whose solved route is already persisted.
//!
//! With `--connect ADDR`, `plan` and `screen` skip loading artifacts and
//! act as protocol clients against a running `retroserve serve`, retrying
//! through transient faults and `overloaded` sheds (honouring the
//! server's `retry_after_ms` hint) and surfacing `draining` / `degraded`
//! status on stderr.
//!
//! `screen` reads one SMILES per line (blank lines and `#` comments
//! skipped), plans the whole list as one batch-class job over a shared
//! hub, and writes one JSON line per target (completion order) plus a
//! final summary line — JSONL, same shapes as the server's `screen` op.
//!
//! All subcommands load the AOT artifacts (HLO text + params.npz) through
//! the PJRT runtime; Python is never invoked.

use anyhow::{bail, Context, Result};
use retroserve::config::{Config, ServeConfig};
use retroserve::coordinator::batcher::{BatcherConfig, ExpansionHub};
use retroserve::coordinator::protocol;
use retroserve::coordinator::server::{Client, ScreenDefaults, Server, ServerCtx};
use retroserve::coordinator::{BatchedPolicy, OverloadConfig, OverloadController};
use retroserve::decoding::make_decoder;
use retroserve::jsonx::Json;
use retroserve::metrics::Metrics;
use retroserve::model::{PooledModel, ReplicaPool};
use retroserve::runtime::server::{SharedModel, SupervisorConfig};
use retroserve::runtime::PjrtModel;
use retroserve::search::{
    dfs::Dfs, retrostar::RetroStar, Planner, ScreenConfig, ScreeningJob, Stock,
};
use retroserve::store::{ExpansionStore, StoreConfig};
use retroserve::tokenizer::Vocab;
use std::io::Write;
use std::sync::Arc;

struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut flags = std::collections::HashMap::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = it.next().unwrap_or_else(|| "true".to_string());
            flags.insert(name.to_string(), val);
        } else {
            bail!("unexpected argument {a:?}");
        }
    }
    Ok(Args { cmd, flags })
}

/// Persistent-store knobs carried from `cache.*` config keys or
/// `--cache-*` flags into [`build_hub`]. An empty `path` means
/// memory-only (no store).
struct CacheOpts {
    path: String,
    flush_ms: u64,
    compact_ratio: f64,
    /// Expansions-per-step the tier decodes at — part of the store
    /// fingerprint, so a store written at one k is never served at
    /// another configuration.
    k: usize,
}

impl CacheOpts {
    /// `--cache-path` / `--cache-flush-ms` / `--cache-compact-ratio`
    /// for the offline subcommands (serve reads the config keys).
    fn from_flags(args: &Args, k: usize) -> Result<CacheOpts> {
        Ok(CacheOpts {
            path: args.flags.get("cache-path").cloned().unwrap_or_default(),
            flush_ms: args
                .flags
                .get("cache-flush-ms")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(200u64)
                .max(1),
            compact_ratio: args
                .flags
                .get("cache-compact-ratio")
                .map(|s| s.parse::<f64>())
                .transpose()?
                .unwrap_or(0.5)
                .clamp(0.0, 1.0),
            k,
        })
    }
}

fn build_hub(
    artifacts: &str,
    decoder: &str,
    batch_hint: usize,
    replicas: usize,
    batcher: BatcherConfig,
    supervise: SupervisorConfig,
    cache: CacheOpts,
    metrics: Arc<Metrics>,
) -> Result<(Arc<ExpansionHub>, Arc<Stock>, Vocab, Option<Arc<ExpansionStore>>)> {
    let vocab = Vocab::load(&std::path::Path::new(artifacts).join("vocab.json"))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let stock = Arc::new(
        Stock::load(std::path::Path::new(artifacts).join("stock.txt"))
            .context("loading stock.txt")?,
    );
    // One supervised executor per replica, each with its own re-callable
    // factory: a model panic fails only the in-flight call, then that
    // replica's executor rebuilds from the artifacts on disk.
    let mut models: Vec<PooledModel> = Vec::with_capacity(replicas.max(1));
    for _ in 0..replicas.max(1) {
        let art = artifacts.to_string();
        models.push(Arc::new(SharedModel::spawn_supervised(
            move || PjrtModel::load(&art),
            supervise.clone(),
        )?));
    }
    // The persistent L2 tier under the expansion cache. Open failures
    // downgrade to memory-only serving with a warning — the store is a
    // performance tier, never load-bearing for boot.
    let store = if cache.path.is_empty() {
        None
    } else {
        let fingerprint = format!("{}|{decoder}|k{}", models[0].fingerprint(), cache.k);
        let cfg = StoreConfig {
            path: cache.path.clone().into(),
            fingerprint,
            flush_ms: cache.flush_ms,
            compact_ratio: cache.compact_ratio,
        };
        match ExpansionStore::open(cfg, metrics.clone()) {
            Ok(s) => {
                eprintln!(
                    "retroserve: cache store {} open ({} expansion(s) warm)",
                    cache.path,
                    s.expansions_len()
                );
                Some(Arc::new(s))
            }
            Err(e) => {
                eprintln!(
                    "retroserve: cache store {} unavailable ({e:#}); running memory-only",
                    cache.path
                );
                None
            }
        }
    };
    let pool = ReplicaPool::from_models(models);
    let dec = make_decoder(decoder, batch_hint)?;
    let hub = ExpansionHub::start_pool_with_store(
        pool,
        dec,
        vocab.clone(),
        batcher,
        metrics,
        store.clone(),
    );
    Ok((hub, stock, vocab, store))
}

fn main() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "serve" => cmd_serve(&args),
        "plan" => cmd_plan(&args),
        "screen" => cmd_screen(&args),
        "expand" => cmd_expand(&args),
        "routes" => cmd_routes(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!(
                "retroserve — transformer retrosynthesis serving with speculative beam \
                 search\n\
                 \n\
                 usage:\n\
                 retroserve serve  [--config FILE] [--listen ADDR] \
                 [--decoder bs|bs-opt|hsbs|msbs]\n\
                 [--shards N] [--replicas N] [--steal true|false]\n\
                 [--max-sessions N] [--max-queue N] [--drain-ms N] \
                 [--retry-after-ms N]\n\
                 [--degrade-high X] [--degrade-low X] [--degraded-beam N] \
                 [--degraded-deadline-ms N]\n\
                 [--cache-path FILE] [--cache-flush-ms N] [--cache-compact-ratio X]\n\
                 retroserve plan   --smiles S [--algo retrostar|dfs] [--decoder NAME] \
                 [--deadline-ms N]\n\
                 [--beam-width N] [--artifacts DIR] [--k N] [--max-depth N]\n\
                 [--max-expansions N] [--max-decode-tokens N] [--cache-path FILE] \
                 [--connect ADDR]\n\
                 retroserve screen --targets FILE [--out FILE] [--concurrency N]\n\
                 [--job-deadline-ms N] [--job-max-decode-tokens N] [--deadline-ms N]\n\
                 [--decoder NAME] [--shards N] [--replicas N] [--artifacts DIR]\n\
                 [--cache-path FILE] [--warm] [--connect ADDR]\n\
                 retroserve expand --smiles S [--decoder NAME] [--k N] [--artifacts DIR] \
                 [--cache-path FILE]\n\
                 retroserve routes --smiles S (--cache-path FILE | --connect ADDR)\n\
                 retroserve info   [--artifacts DIR]"
            );
            Ok(())
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.flags.get("config") {
        Some(path) => Config::load(path)?,
        None => Config::new(),
    };
    for (k, v) in &args.flags {
        match k.as_str() {
            "listen" => cfg.apply_override("server.listen", v)?,
            "artifacts" => cfg.apply_override("server.artifacts", v)?,
            "decoder" => cfg.apply_override("planner.decoder", v)?,
            "beam-width" => cfg.apply_override("planner.beam_width", v)?,
            "spec-depth" => cfg.apply_override("planner.spec_depth", v)?,
            "max-expansions" => cfg.apply_override("planner.max_expansions", v)?,
            "max-decode-tokens" => cfg.apply_override("planner.max_decode_tokens", v)?,
            "model-retries" => cfg.apply_override("model.retries", v)?,
            "model-backoff-us" => cfg.apply_override("model.backoff_us", v)?,
            "replicas" => cfg.apply_override("model.replicas", v)?,
            "shards" => cfg.apply_override("batcher.shards", v)?,
            "steal" => cfg.apply_override("batcher.steal", v)?,
            "screen-concurrency" => cfg.apply_override("planner.screen_concurrency", v)?,
            "screen-job-deadline-ms" => {
                cfg.apply_override("planner.screen_job_deadline_ms", v)?
            }
            "screen-job-decode-tokens" => {
                cfg.apply_override("planner.screen_job_decode_tokens", v)?
            }
            "max-sessions" => cfg.apply_override("server.max_sessions", v)?,
            "max-queue" => cfg.apply_override("server.max_queue", v)?,
            "drain-ms" => cfg.apply_override("server.drain_ms", v)?,
            "retry-after-ms" => cfg.apply_override("server.retry_after_ms", v)?,
            "degrade-high" => cfg.apply_override("server.degrade_high", v)?,
            "degrade-low" => cfg.apply_override("server.degrade_low", v)?,
            "degraded-beam" => cfg.apply_override("planner.degraded_beam", v)?,
            "degraded-deadline-ms" => {
                cfg.apply_override("planner.degraded_deadline_ms", v)?
            }
            "cache-path" => cfg.apply_override("cache.path", v)?,
            "cache-flush-ms" => cfg.apply_override("cache.flush_ms", v)?,
            "cache-compact-ratio" => cfg.apply_override("cache.compact_ratio", v)?,
            "config" => {}
            other => cfg.apply_override(other, v)?,
        }
    }
    let sc = ServeConfig::from_config(&cfg);
    let metrics = Arc::new(Metrics::new());
    let (hub, stock, _vocab, store) = build_hub(
        &sc.artifacts,
        &sc.decoder,
        sc.batch_max,
        sc.replicas,
        BatcherConfig {
            max_batch: sc.batch_max,
            max_wait: std::time::Duration::from_micros(sc.batch_wait_us),
            coalesce: std::time::Duration::from_micros(sc.batch_coalesce_us),
            max_rows: sc.batch_rows,
            cache_cap: sc.cache_cap,
            shards: sc.shards,
            steal: sc.steal,
        },
        SupervisorConfig {
            retries: sc.model_retries,
            backoff_us: sc.model_backoff_us,
            max_restarts: 3,
            metrics: Some(metrics.clone()),
        },
        CacheOpts {
            path: sc.cache_path.clone(),
            flush_ms: sc.cache_flush_ms,
            compact_ratio: sc.cache_compact_ratio,
            k: sc.expansions_per_step,
        },
        metrics.clone(),
    )?;
    eprintln!(
        "retroserve: serving on {} (decoder={}, algo={}, stock={})",
        sc.listen,
        sc.decoder,
        sc.algo,
        stock.len()
    );
    let server = Server::start(
        &sc.listen,
        ServerCtx {
            hub,
            stock,
            metrics,
            default_limits: sc.limits(),
            default_algo: sc.algo.clone(),
            default_beam_width: sc.beam_width,
            default_spec_depth: sc.spec_depth,
            default_spec_adaptive: sc.spec_adaptive,
            default_spec_max: sc.spec_depth_max,
            screen: ScreenDefaults {
                concurrency: sc.screen_concurrency,
                job_deadline_ms: sc.screen_job_deadline_ms,
                job_decode_tokens: sc.screen_job_decode_tokens,
            },
            overload: Arc::new(OverloadController::new(OverloadConfig {
                max_sessions: sc.max_sessions,
                max_queue: sc.max_queue,
                drain_ms: sc.drain_ms,
                retry_after_ms: sc.retry_after_ms,
                degrade_high: sc.degrade_high,
                degrade_low: sc.degrade_low,
                degraded_beam: sc.degraded_beam,
                degraded_deadline_ms: sc.degraded_deadline_ms,
            })),
            store,
        },
    )?;
    eprintln!("retroserve: ready on {}", server.addr());
    // Serve until killed, or until a `drain` protocol op flips the
    // server into draining — then run the drain-clean shutdown (join
    // the accept loop, wait out in-flight solves, close connections)
    // and exit so process managers observe a real termination.
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        if server.draining() {
            eprintln!("retroserve: drain requested; shutting down clean");
            server.shutdown();
            return Ok(());
        }
    }
}

/// Turn a structured refusal (`ok:false`) into a descriptive error,
/// surfacing the shed / draining codes and the server's retry hint.
fn refusal_error(r: &retroserve::jsonx::Json) -> anyhow::Error {
    let code = r.get("code").and_then(|x| x.as_str()).unwrap_or("error");
    let msg = r.get("error").and_then(|x| x.as_str()).unwrap_or("request failed");
    match code {
        "overloaded" => {
            let hint = r.get("retry_after_ms").and_then(|x| x.as_usize()).unwrap_or(0);
            anyhow::anyhow!("server shed the request: {msg} (retry after {hint} ms)")
        }
        "draining" => anyhow::anyhow!("server is draining: {msg}"),
        _ => anyhow::anyhow!("request failed ({code}): {msg}"),
    }
}

/// `plan --connect ADDR`: speak the wire protocol to a running
/// `retroserve serve` instead of loading artifacts locally. Transient
/// faults and `overloaded` sheds are retried with jittered backoff
/// (honouring `retry_after_ms`); `draining` refusals and degraded-mode
/// answers are surfaced instead of silently absorbed.
fn plan_remote(addr: &str, smiles: &str, args: &Args) -> Result<()> {
    let addr: std::net::SocketAddr =
        addr.parse().with_context(|| format!("bad --connect address {addr:?}"))?;
    let mut fields = vec![("op", Json::str("plan")), ("smiles", Json::str(smiles))];
    if let Some(a) = args.flags.get("algo") {
        fields.push(("algo", Json::str(a.clone())));
    }
    for (flag, key) in [
        ("deadline-ms", "deadline_ms"),
        ("beam-width", "beam_width"),
        ("max-depth", "max_depth"),
        ("max-expansions", "max_expansions"),
        ("max-decode-tokens", "max_decode_tokens"),
        ("k", "k"),
    ] {
        if let Some(v) = args.flags.get(flag) {
            fields.push((key, Json::num(v.parse::<f64>()?)));
        }
    }
    if let Some(sd) = args.flags.get("spec-depth") {
        if sd == "auto" {
            fields.push(("spec_depth", Json::str("auto")));
        } else {
            fields.push(("spec_depth", Json::num(sd.parse::<f64>()?)));
        }
    }
    let mut client = Client::connect_retry(addr, 5)?;
    let r = client.call_retry(Json::obj(fields), 5)?;
    if r.get("ok").and_then(|x| x.as_bool()) != Some(true) {
        return Err(refusal_error(&r));
    }
    if r.get("degraded").and_then(|x| x.as_bool()) == Some(true) {
        eprintln!("plan: answered in DEGRADED mode (server under load; reduced effort)");
    }
    eprintln!(
        "plan: solved={} stop={} iterations={} expansions={} wall={}ms",
        r.get("solved").and_then(|x| x.as_bool()).unwrap_or(false),
        r.get("stop_reason").and_then(|x| x.as_str()).unwrap_or("?"),
        r.get("iterations").and_then(|x| x.as_usize()).unwrap_or(0),
        r.get("expansions").and_then(|x| x.as_usize()).unwrap_or(0),
        r.get("wall_ms").and_then(|x| x.as_f64()).unwrap_or(0.0),
    );
    println!("{r}");
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let smiles = args.flags.get("smiles").context("--smiles required")?;
    if let Some(addr) = args.flags.get("connect") {
        return plan_remote(addr, smiles, args);
    }
    let artifacts = args.flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let decoder = args.flags.get("decoder").map(String::as_str).unwrap_or("msbs");
    let algo = args.flags.get("algo").map(String::as_str).unwrap_or("retrostar");
    let bw: usize = args.flags.get("beam-width").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let mut limits = retroserve::search::SearchLimits::default();
    let metrics = Arc::new(Metrics::new());
    let k_step: usize = args
        .flags
        .get("k")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(limits.expansions_per_step);
    let (hub, stock, _, store) = build_hub(
        artifacts,
        decoder,
        bw.max(1),
        1,
        BatcherConfig::default(),
        SupervisorConfig::default(),
        CacheOpts::from_flags(args, k_step)?,
        metrics,
    )?;
    if let Some(ms) = args.flags.get("deadline-ms") {
        limits.deadline = std::time::Duration::from_millis(ms.parse()?);
    }
    if let Some(d) = args.flags.get("max-depth") {
        limits.max_depth = d.parse()?;
    }
    if let Some(k) = args.flags.get("k") {
        limits.expansions_per_step = k.parse()?;
    }
    if let Some(n) = args.flags.get("max-expansions") {
        limits.max_expansions = n.parse()?;
    }
    if let Some(n) = args.flags.get("max-decode-tokens") {
        limits.max_decode_tokens = n.parse()?;
    }
    // --spec-depth N pins the in-flight depth; --spec-depth auto adapts
    // it to the observed apply-rate (bounded by --spec-max, default 8).
    let sd_raw = args.flags.get("spec-depth").map(String::as_str).unwrap_or("1");
    let (sd, sd_auto) = if sd_raw == "auto" {
        let max: usize =
            args.flags.get("spec-max").map(|s| s.parse()).transpose()?.unwrap_or(8);
        (max.max(1), true)
    } else {
        (sd_raw.parse::<usize>()?.max(1), false)
    };
    let policy = BatchedPolicy::new(hub);
    let r = match algo {
        "dfs" => Dfs.solve(smiles, &policy, &stock, &limits)?,
        "retrostar" | "retro*" => {
            let rs = if sd_auto {
                RetroStar::new(bw).with_adaptive_spec_depth(sd)
            } else {
                RetroStar::new(bw).with_spec_depth(sd)
            };
            rs.solve_pipelined(smiles, &policy, &stock, &limits)?
        }
        other => bail!("unknown algo {other}"),
    };
    if let (Some(store), Some(route)) = (&store, &r.route) {
        if r.solved {
            // The store's graceful drop at the end of this function
            // flushes and fsyncs the record.
            store.put_route(smiles, route);
        }
    }
    println!(
        "solved={} stop={} iterations={} expansions={} wall={:.2}s model_calls={} \
         acceptance={:.1}%",
        r.solved,
        r.stop_reason,
        r.iterations,
        r.expansions,
        r.wall_secs,
        r.decode_stats.model_calls,
        r.decode_stats.acceptance_rate() * 100.0
    );
    if let Some(err) = &r.error {
        println!("plan error: {err}");
    }
    if r.spec.groups_submitted > 0 && sd > 1 {
        println!(
            "speculation: submitted={} applied={} cancelled={} hits={} max_in_flight={} \
             depth_trajectory={:?}",
            r.spec.groups_submitted,
            r.spec.groups_applied,
            r.spec.groups_cancelled,
            r.spec.spec_hits,
            r.spec.max_in_flight,
            r.spec.depth_trajectory
        );
    }
    if let Some(route) = &r.route {
        println!("route (depth {}):\n{}", route.depth(), route.render());
    } else if let Some(partial) = &r.partial_route {
        println!("partial route (anytime, depth {}):\n{}", partial.depth(), partial.render());
    }
    Ok(())
}

/// `screen --connect ADDR`: run the whole target list as one
/// batch-class `screen` op against a running server, streaming each
/// per-target line to `--out` (or stdout) as it arrives. Batch-class
/// traffic sheds first under overload, so the terminal line may be a
/// structured refusal — surfaced with the retry hint, never a hang.
fn screen_remote(addr: &str, targets: &[String], args: &Args) -> Result<()> {
    let addr: std::net::SocketAddr =
        addr.parse().with_context(|| format!("bad --connect address {addr:?}"))?;
    let mut fields = vec![
        ("op", Json::str("screen")),
        ("targets", Json::Arr(targets.iter().map(|t| Json::str(t.clone())).collect())),
    ];
    for (flag, key) in [
        ("concurrency", "concurrency"),
        ("job-deadline-ms", "job_deadline_ms"),
        ("job-max-decode-tokens", "job_max_decode_tokens"),
        ("deadline-ms", "deadline_ms"),
        ("beam-width", "beam_width"),
        ("max-expansions", "max_expansions"),
        ("max-decode-tokens", "max_decode_tokens"),
    ] {
        if let Some(v) = args.flags.get(flag) {
            fields.push((key, Json::num(v.parse::<f64>()?)));
        }
    }
    if args.flags.contains_key("warm") {
        fields.push(("warm", Json::Bool(true)));
    }
    let mut client = Client::connect_retry(addr, 5)?;
    // The stream is one job; a mid-stream retry would re-run it, so
    // only the connection is retried — refusals surface structurally.
    let lines = client.call_stream(Json::obj(fields))?;
    // Keep a raw handle next to the BufWriter so the tail of the JSONL
    // stream can be fsynced once the job is done — a drained or killed
    // process must not lose results the writer already buffered.
    let mut sync_handle: Option<std::fs::File> = None;
    let mut out: Box<dyn Write> = match args.flags.get("out") {
        Some(p) => {
            let f = std::fs::File::create(p).with_context(|| format!("creating {p}"))?;
            sync_handle = Some(f.try_clone().with_context(|| format!("cloning handle for {p}"))?);
            Box::new(std::io::BufWriter::new(f))
        }
        None => Box::new(std::io::stdout()),
    };
    for j in &lines {
        writeln!(out, "{j}")?;
    }
    out.flush()?;
    if let Some(f) = &sync_handle {
        f.sync_all().context("fsyncing --out file")?;
    }
    let last = lines.last().context("empty response stream")?;
    if last.get("ok").and_then(|x| x.as_bool()) == Some(false) {
        return Err(refusal_error(last));
    }
    if last.get("degraded").and_then(|x| x.as_bool()) == Some(true) {
        eprintln!("screen: ran in DEGRADED mode (server under load; reduced effort)");
    }
    eprintln!(
        "screen: {}/{} solved in {:.2}s (remote)",
        last.get("solved").and_then(|x| x.as_usize()).unwrap_or(0),
        last.get("targets").and_then(|x| x.as_usize()).unwrap_or(0),
        last.get("wall_ms").and_then(|x| x.as_f64()).unwrap_or(0.0) / 1e3,
    );
    Ok(())
}

fn cmd_screen(args: &Args) -> Result<()> {
    let path = args.flags.get("targets").context("--targets FILE required")?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading targets file {path}"))?;
    let targets: Vec<String> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect();
    if targets.is_empty() {
        bail!("no targets in {path} (one SMILES per line)");
    }
    if let Some(addr) = args.flags.get("connect") {
        return screen_remote(addr, &targets, args);
    }
    let artifacts = args.flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let decoder = args.flags.get("decoder").map(String::as_str).unwrap_or("msbs");
    let bw: usize = args.flags.get("beam-width").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let shards: usize = args.flags.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let replicas: usize =
        args.flags.get("replicas").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let concurrency: usize =
        args.flags.get("concurrency").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let job_deadline_ms: u64 =
        args.flags.get("job-deadline-ms").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let job_decode_tokens: u64 =
        args.flags.get("job-max-decode-tokens").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let metrics = Arc::new(Metrics::new());
    let mut limits = retroserve::search::SearchLimits::default();
    let k_step: usize = args
        .flags
        .get("k")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(limits.expansions_per_step);
    let (hub, stock, _, store) = build_hub(
        artifacts,
        decoder,
        bw.max(1),
        replicas.max(1),
        BatcherConfig { shards: shards.max(1), ..Default::default() },
        SupervisorConfig::default(),
        CacheOpts::from_flags(args, k_step)?,
        metrics.clone(),
    )?;
    if let Some(ms) = args.flags.get("deadline-ms") {
        limits.deadline = std::time::Duration::from_millis(ms.parse()?);
    }
    if let Some(d) = args.flags.get("max-depth") {
        limits.max_depth = d.parse()?;
    }
    if let Some(k) = args.flags.get("k") {
        limits.expansions_per_step = k.parse()?;
    }
    if let Some(n) = args.flags.get("max-expansions") {
        limits.max_expansions = n.parse()?;
    }
    if let Some(n) = args.flags.get("max-decode-tokens") {
        limits.max_decode_tokens = n.parse()?;
    }
    let sd_raw = args.flags.get("spec-depth").map(String::as_str).unwrap_or("1");
    let (sd, sd_auto) = if sd_raw == "auto" {
        let max: usize =
            args.flags.get("spec-max").map(|s| s.parse()).transpose()?.unwrap_or(8);
        (max.max(1), true)
    } else {
        (sd_raw.parse::<usize>()?.max(1), false)
    };
    let cfg = ScreenConfig {
        concurrency: concurrency.max(1),
        job_deadline: (job_deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(job_deadline_ms)),
        job_decode_tokens,
        beam_width: bw.max(1),
        spec_depth: sd,
        spec_adaptive: sd_auto,
        limits,
    };
    // JSONL out: one line per target in completion order, then the
    // summary line (same shapes as the server's `screen` op). The raw
    // handle alongside the BufWriter lets the finished job fsync its
    // tail — a drain must never lose buffered results.
    let mut sync_handle: Option<std::fs::File> = None;
    let mut out: Box<dyn Write> = match args.flags.get("out") {
        Some(p) => {
            let f = std::fs::File::create(p).with_context(|| format!("creating {p}"))?;
            sync_handle = Some(f.try_clone().with_context(|| format!("cloning handle for {p}"))?);
            Box::new(std::io::BufWriter::new(f))
        }
        None => Box::new(std::io::stdout()),
    };
    let mut on_result = |tr: retroserve::search::TargetResult| {
        let j = protocol::screen_target_response(0, tr.index, &tr.smiles, &tr.result);
        let _ = writeln!(out, "{j}");
    };
    let mut job = ScreeningJob::new(cfg);
    if let Some(store) = &store {
        job = job
            .with_store(store.clone())
            .warm_start(args.flags.contains_key("warm"));
    }
    let summary = job.run(&hub, &stock, &targets, &metrics, &mut on_result)?;
    writeln!(out, "{}", protocol::screen_summary_response(0, &summary))?;
    out.flush()?;
    if let Some(f) = &sync_handle {
        f.sync_all().context("fsyncing --out file")?;
    }
    if summary.skipped_warm > 0 {
        eprintln!("screen: {} target(s) answered warm from the route store", summary.skipped_warm);
    }
    eprintln!(
        "screen: {}/{} solved in {:.2}s (deadline {}, budget {}, exhausted {}, error {}) — \
         {:.1} solved/s, {:.0} tokens/solved, cache hit {:.0}%, dedup join {:.0}%",
        summary.solved,
        summary.targets,
        summary.wall_secs,
        summary.stop_deadline,
        summary.stop_budget,
        summary.stop_exhausted,
        summary.stop_error,
        summary.solved as f64 / summary.wall_secs.max(1e-9),
        summary.tokens_per_solved,
        summary.cache_hit_rate * 100.0,
        summary.dedup_join_rate * 100.0
    );
    Ok(())
}

fn cmd_expand(args: &Args) -> Result<()> {
    let smiles = args.flags.get("smiles").context("--smiles required")?;
    let artifacts = args.flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let decoder = args.flags.get("decoder").map(String::as_str).unwrap_or("msbs");
    let k: usize = args.flags.get("k").map(|s| s.parse()).transpose()?.unwrap_or(10);
    let metrics = Arc::new(Metrics::new());
    let (hub, _, _, _store) = build_hub(
        artifacts,
        decoder,
        1,
        1,
        BatcherConfig::default(),
        SupervisorConfig::default(),
        CacheOpts::from_flags(args, k)?,
        metrics,
    )?;
    let canonical = retroserve::chem::canonicalize(smiles)
        .map_err(|e| anyhow::anyhow!("bad smiles: {e}"))?;
    let t0 = std::time::Instant::now();
    let proposals = hub.expand(&canonical, k)?;
    let stats = hub.stats();
    println!(
        "{} proposals in {:.0} ms (model calls {}, acceptance {:.1}%)",
        proposals.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        stats.model_calls,
        stats.acceptance_rate() * 100.0
    );
    for (i, p) in proposals.iter().enumerate() {
        println!("{:2}. logp {:7.3}  {}", i + 1, p.logp, p.reactants.join(" . "));
    }
    Ok(())
}

/// `retroserve routes --smiles S`: the persisted k-best routes for a
/// target, either from a running server (`--connect`, the `routes`
/// protocol op) or straight from a store log on disk (`--cache-path`,
/// a read-only scan — no model required and no file mutation).
fn cmd_routes(args: &Args) -> Result<()> {
    let smiles = args.flags.get("smiles").context("--smiles required")?;
    if let Some(addr) = args.flags.get("connect") {
        let addr: std::net::SocketAddr =
            addr.parse().with_context(|| format!("bad --connect address {addr:?}"))?;
        let mut client = Client::connect_retry(addr, 5)?;
        let r = client.call_retry(
            Json::obj(vec![("op", Json::str("routes")), ("smiles", Json::str(smiles.clone()))]),
            5,
        )?;
        if r.get("ok").and_then(|x| x.as_bool()) != Some(true) {
            return Err(refusal_error(&r));
        }
        let n = r.get("routes").and_then(|x| x.as_arr()).map(Vec::len).unwrap_or(0);
        eprintln!("routes: {n} persisted route(s) for {smiles} (remote)");
        println!("{r}");
        return Ok(());
    }
    let path = args
        .flags
        .get("cache-path")
        .context("--cache-path FILE (or --connect ADDR) required")?;
    let key = retroserve::chem::cache_key(smiles);
    let all = retroserve::store::read_routes(std::path::Path::new(path))?;
    let routes = all
        .iter()
        .find(|(t, _)| *t == key)
        .map(|(_, r)| r.as_slice())
        .unwrap_or(&[]);
    println!("{}", protocol::routes_response(0, &key, routes));
    if routes.is_empty() {
        eprintln!("routes: none persisted for {key} in {path}");
    } else {
        for (i, r) in routes.iter().enumerate() {
            eprintln!(
                "routes: #{} cost {:.3} depth {}:\n{}",
                i + 1,
                r.cost,
                r.route.depth(),
                r.route.render()
            );
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = args.flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let cfg = retroserve::runtime::RuntimeConfig::load(std::path::Path::new(artifacts))?;
    println!("artifacts: {artifacts}");
    println!(
        "model: vocab={} d_model={} medusa_heads={} max_src={} max_tgt={}",
        cfg.vocab, cfg.d_model, cfg.n_medusa, cfg.max_src, cfg.max_tgt
    );
    println!("encode buckets: {:?}", cfg.enc_buckets);
    println!(
        "decode buckets: rows {:?} x len {:?} x win {:?}",
        cfg.dec_row_buckets, cfg.dec_len_buckets, cfg.dec_win_buckets
    );
    println!("params: {} arrays", cfg.param_names.len());
    Ok(())
}
