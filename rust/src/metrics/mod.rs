//! Serving metrics: counters and log-bucketed latency histograms,
//! exported as JSON by the coordinator's `metrics` op.

use crate::jsonx::Json;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Log-scaled histogram from 1 µs to ~100 s (5 buckets per decade).
#[derive(Clone, Debug)]
pub struct Hist {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const DECADES: usize = 8; // 1e-6 .. 1e2 seconds
const PER_DECADE: usize = 5;

impl Default for Hist {
    fn default() -> Self {
        Self {
            buckets: vec![0; DECADES * PER_DECADE + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Hist {
    fn bucket_of(secs: f64) -> usize {
        if secs <= 1e-6 {
            return 0;
        }
        let idx = ((secs.log10() + 6.0) * PER_DECADE as f64).floor() as isize;
        idx.clamp(0, (DECADES * PER_DECADE) as isize) as usize
    }

    /// Lower bound of bucket `i` in seconds.
    fn bucket_lo(i: usize) -> f64 {
        10f64.powf(i as f64 / PER_DECADE as f64 - 6.0)
    }

    pub fn record(&mut self, secs: f64) {
        self.buckets[Self::bucket_of(secs)] += 1;
        self.count += 1;
        self.sum += secs;
        self.min = self.min.min(secs);
        self.max = self.max.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate percentile from the bucket boundaries.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_lo(i);
            }
        }
        self.max
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean_s", Json::num(self.mean())),
            ("min_s", Json::num(if self.count == 0 { 0.0 } else { self.min })),
            ("max_s", Json::num(if self.count == 0 { 0.0 } else { self.max })),
            ("p50_s", Json::num(self.percentile(50.0))),
            ("p90_s", Json::num(self.percentile(90.0))),
            ("p99_s", Json::num(self.percentile(99.0))),
        ])
    }
}

/// Global metrics registry (cheap enough at our request rates; a
/// sharded design would replace the mutexes under real load).
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    hists: Mutex<BTreeMap<String, Hist>>,
    gauges: Mutex<BTreeMap<String, u64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a gauge to the current value.
    pub fn gauge_set(&self, name: &str, v: u64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    /// Record a gauge as a running maximum (high-water mark) — e.g.
    /// the deepest in-flight task count a scheduler ever reached.
    pub fn gauge_max(&self, name: &str, v: u64) {
        let mut g = self.gauges.lock().unwrap();
        let e = g.entry(name.to_string()).or_insert(0);
        *e = (*e).max(v);
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn observe(&self, name: &str, secs: f64) {
        self.hists
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .record(secs);
    }

    /// Time a closure into histogram `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let out = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn snapshot(&self) -> Json {
        let counters = self.counters.lock().unwrap();
        let hists = self.hists.lock().unwrap();
        let gauges = self.gauges.lock().unwrap();
        Json::obj(vec![
            (
                "counters",
                Json::Obj(
                    counters
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    gauges
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                        .collect(),
                ),
            ),
            (
                "latency",
                Json::Obj(hists.iter().map(|(k, h)| (k.clone(), h.to_json())).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("requests", 1);
        m.inc("requests", 2);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Hist::default();
        for x in [0.001, 0.002, 0.004, 0.1] {
            h.record(x);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 0.02675).abs() < 1e-9);
        assert!(h.percentile(50.0) <= 0.004);
        assert!(h.percentile(100.0) >= 0.05);
    }

    #[test]
    fn bucket_monotone() {
        let mut last = 0;
        for secs in [1e-7, 1e-6, 1e-5, 1e-3, 0.1, 1.0, 10.0, 99.0] {
            let b = Hist::bucket_of(secs);
            assert!(b >= last, "{secs}");
            last = b;
        }
    }

    #[test]
    fn snapshot_shape() {
        let m = Metrics::new();
        m.inc("a", 1);
        m.observe("lat", 0.5);
        let s = m.snapshot();
        assert!(s.get("counters").unwrap().get("a").is_some());
        assert!(s.get("latency").unwrap().get("lat").unwrap().get("count").is_some());
    }

    #[test]
    fn gauges_track_high_water_and_snapshot() {
        let m = Metrics::new();
        m.gauge_max("depth", 3);
        m.gauge_max("depth", 7);
        m.gauge_max("depth", 5);
        assert_eq!(m.gauge("depth"), 7);
        m.gauge_set("depth", 2);
        assert_eq!(m.gauge("depth"), 2);
        assert_eq!(m.gauge("missing"), 0);
        let s = m.snapshot();
        assert_eq!(
            s.get("gauges").unwrap().get("depth").unwrap().as_usize(),
            Some(2)
        );
    }

    #[test]
    fn time_records() {
        let m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        let s = m.snapshot();
        assert_eq!(
            s.get("latency").unwrap().get("op").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
    }
}
