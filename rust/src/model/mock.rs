//! A deterministic in-process [`StepModel`] for tests and benches.
//!
//! Semantics: a "copy translation" task. For a source `[BOS, t1..tn, EOS]`
//! the correct target is `[t1..tn, EOS]`; the distribution at decoder
//! position `p` puts most mass on `src[p+1]` (the copy), a bit on a
//! deterministic "alternative" token, and a flat tail — enough structure
//! to exercise beam bookkeeping, speculative verification and nucleus
//! cuts without any artifacts. Medusa head `h` predicts `src[p+1+h]`,
//! with a per-head accuracy knob that deterministically (seeded hash)
//! corrupts some positions so acceptance rates are interesting.

use super::{DecodeOut, DecodeRow, MemHandle, StateId, StateStore, StepModel};
use crate::tokenizer::EOS;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Configuration for the mock distribution.
#[derive(Clone, Debug)]
pub struct MockConfig {
    pub vocab: usize,
    /// Extra Medusa heads (M).
    pub medusa_heads: usize,
    pub max_src: usize,
    pub max_tgt: usize,
    /// Percent of positions where Medusa head h (1-based) emits the
    /// correct token; decays with h: `acc = base_acc - decay * h`.
    pub head_base_acc: u32,
    pub head_acc_decay: u32,
    /// Seed for the deterministic corruption hash.
    pub seed: u64,
}

impl Default for MockConfig {
    fn default() -> Self {
        Self {
            vocab: 26,
            medusa_heads: 6,
            max_src: 64,
            max_tgt: 72,
            head_base_acc: 95,
            head_acc_decay: 4,
            seed: 0xC0FFEE,
        }
    }
}

/// Deterministic mock model. Thread-safe; counts calls. Supports the
/// incremental decode protocol (its "KV cache" is a [`StateStore`] of
/// committed prefixes; logits depend only on the source and position,
/// so delta rows are bit-identical to full rows by construction — but
/// the store still *validates* every incremental row, so a stale or
/// cross-row state reference fails the decode loudly).
pub struct MockModel {
    cfg: MockConfig,
    store: Mutex<HashMap<u64, Vec<Vec<i32>>>>,
    states: StateStore,
    next_id: AtomicU64,
    pub encode_calls: AtomicU64,
    pub decode_calls: AtomicU64,
}

impl MockModel {
    pub fn new(cfg: MockConfig) -> Self {
        Self {
            cfg,
            store: Mutex::new(HashMap::new()),
            states: StateStore::new(),
            next_id: AtomicU64::new(1),
            encode_calls: AtomicU64::new(0),
            decode_calls: AtomicU64::new(0),
        }
    }

    fn hash(&self, a: u64, b: u64, c: u64) -> u64 {
        let mut x = self
            .cfg
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(a)
            .wrapping_mul(0xBF58476D1CE4E5B9)
            .wrapping_add(b)
            .wrapping_mul(0x94D049BB133111EB)
            .wrapping_add(c);
        x ^= x >> 31;
        x = x.wrapping_mul(0xD6E8FEB86659FD93);
        x ^ (x >> 32)
    }

    /// The "true" next token for head `h` at decoder position `p`:
    /// `src[p + 1 + h]`, or EOS past the end.
    fn oracle(&self, src: &[i32], p: usize, h: usize) -> i32 {
        let idx = p + 1 + h;
        // src = [BOS, t1..tn, EOS]; target = [t1..tn, EOS]: the token at
        // target position q is src[q + 1]. Decoder position p predicts
        // target position p, i.e. src[p + 1]; head h shifts h more.
        if idx < src.len() {
            src[idx]
        } else {
            EOS
        }
    }

    /// Encoded batches currently held (leak diagnostics: every
    /// `encode` must be balanced by a `release`).
    pub fn live_handles(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    /// Cached decoder states currently held (leak diagnostics: every
    /// claim a task takes must be released by retirement/cancel).
    pub fn live_states(&self) -> usize {
        self.states.live()
    }

    /// A deterministic wrong-but-plausible alternative token.
    fn alt(&self, correct: i32, p: usize) -> i32 {
        let v = self.cfg.vocab as i32;
        let cand = 4 + ((correct + 7 + p as i32) % (v - 4).max(1));
        if cand == correct {
            4 + ((cand + 1 - 4) % (v - 4).max(1))
        } else {
            cand
        }
    }
}

impl StepModel for MockModel {
    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn medusa_heads(&self) -> usize {
        self.cfg.medusa_heads
    }

    fn max_src(&self) -> usize {
        self.cfg.max_src
    }

    fn max_tgt(&self) -> usize {
        self.cfg.max_tgt
    }

    fn encode(&self, src: &[Vec<i32>]) -> Result<MemHandle> {
        self.encode_calls.fetch_add(1, Ordering::Relaxed);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.store.lock().unwrap().insert(id, src.to_vec());
        Ok(MemHandle(id))
    }

    fn decode(&self, rows: &[DecodeRow], win: usize) -> Result<DecodeOut> {
        let mut out = DecodeOut::default();
        self.decode_into(rows, win, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, rows: &[DecodeRow], win: usize, out: &mut DecodeOut) -> Result<()> {
        self.decode_calls.fetch_add(1, Ordering::Relaxed);
        let store = self.store.lock().unwrap();
        let heads = self.cfg.medusa_heads + 1;
        let vocab = self.cfg.vocab;
        out.data.clear();
        out.data.resize(rows.len() * win * heads * vocab, 0f32);
        out.starts.clear();
        out.rows = rows.len();
        out.win = win;
        out.heads = heads;
        out.vocab = vocab;
        out.padded_rows = self.pad_rows(rows.len());
        let mut full = Vec::new();
        for (r, row) in rows.iter().enumerate() {
            let srcs = store
                .get(&row.mem.0)
                .ok_or_else(|| anyhow::anyhow!("unknown mem handle"))?;
            let src = &srcs[row.mem_row];
            // The mock's logits depend only on (src, position), so the
            // delta tokens are not needed to compute them — but resolve
            // incremental rows anyway so stale-state protocol bugs
            // surface here instead of silently decoding garbage.
            if !row.state.is_none() {
                self.states.resolve_into(row.state, row.mem, row.mem_row, &row.delta, &mut full)?;
                anyhow::ensure!(row.pos < full.len(), "window start past row end");
            }
            // emulate the dynamic_slice clamp against the padded length
            let padded = self.cfg.max_tgt;
            let start = row.pos.min(padded - win);
            out.starts.push(start);
            for j in 0..win {
                let p = start + j;
                for h in 0..heads {
                    let correct = self.oracle(src, p, h);
                    // per-head deterministic corruption
                    let emitted = if h == 0 {
                        correct
                    } else {
                        let acc = self
                            .cfg
                            .head_base_acc
                            .saturating_sub(self.cfg.head_acc_decay * h as u32);
                        if (self.hash(row.mem.0 * 131 + row.mem_row as u64, p as u64, h as u64)
                            % 100)
                            < acc as u64
                        {
                            correct
                        } else {
                            self.alt(correct, p)
                        }
                    };
                    let alt = self.alt(emitted, p);
                    let base = ((r * win + j) * heads + h) * vocab;
                    let slice = &mut out.data[base..base + vocab];
                    for s in slice.iter_mut() {
                        *s = -4.0;
                    }
                    slice[emitted as usize] = 8.0;
                    slice[alt as usize] = 4.0;
                }
            }
        }
        Ok(())
    }

    fn release(&self, mem: MemHandle) {
        self.store.lock().unwrap().remove(&mem.0);
    }

    fn supports_incremental(&self) -> bool {
        true
    }

    fn state_commit(
        &self,
        mem: MemHandle,
        mem_row: usize,
        parent: StateId,
        delta: &[i32],
    ) -> Result<StateId> {
        self.states.commit(mem, mem_row, parent, delta)
    }

    fn state_retain(&self, state: StateId) {
        self.states.retain(state)
    }

    fn state_release(&self, state: StateId) {
        self.states.release(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::argmax;
    use crate::tokenizer::BOS;

    fn src_seq() -> Vec<i32> {
        vec![BOS, 5, 6, 7, 8, 9, EOS]
    }

    #[test]
    fn greedy_main_head_copies_source() {
        let m = MockModel::new(MockConfig::default());
        let h = m.encode(&[src_seq()]).unwrap();
        let mut prefix = vec![BOS];
        for _ in 0..10 {
            let out = m
                .decode(
                    &[DecodeRow::full(h, 0, prefix.clone(), prefix.len() - 1)],
                    1,
                )
                .unwrap();
            let j = out.offset_of(0, prefix.len() - 1).unwrap();
            let next = argmax(out.logits(0, j, 0)) as i32;
            prefix.push(next);
            if next == EOS {
                break;
            }
        }
        assert_eq!(prefix, vec![BOS, 5, 6, 7, 8, 9, EOS]);
    }

    #[test]
    fn medusa_heads_predict_ahead() {
        let m = MockModel::new(MockConfig {
            head_base_acc: 100,
            head_acc_decay: 0,
            ..Default::default()
        });
        let h = m.encode(&[src_seq()]).unwrap();
        let out = m
            .decode(&[DecodeRow::full(h, 0, vec![BOS], 0)], 1)
            .unwrap();
        // head k at position 0 predicts src[1 + k]
        for k in 0..=6 {
            let expect = if 1 + k < 7 { src_seq()[1 + k] } else { EOS };
            assert_eq!(argmax(out.logits(0, 0, k)) as i32, expect, "head {k}");
        }
    }

    #[test]
    fn corruption_is_deterministic_and_present() {
        let cfg = MockConfig { head_base_acc: 50, head_acc_decay: 0, ..Default::default() };
        let m1 = MockModel::new(cfg.clone());
        let m2 = MockModel::new(cfg);
        let h1 = m1.encode(&[src_seq()]).unwrap();
        let h2 = m2.encode(&[src_seq()]).unwrap();
        let r1 = m1
            .decode(&[DecodeRow::full(h1, 0, vec![BOS], 0)], 1)
            .unwrap();
        let r2 = m2
            .decode(&[DecodeRow::full(h2, 0, vec![BOS], 0)], 1)
            .unwrap();
        assert_eq!(r1.data, r2.data);
        // at 50% accuracy some head must disagree with the oracle
        let mut wrong = 0;
        for k in 1..=6 {
            let expect = if 1 + k < 7 { src_seq()[1 + k] } else { EOS };
            if argmax(r1.logits(0, 0, k)) as i32 != expect {
                wrong += 1;
            }
        }
        assert!(wrong > 0);
    }

    #[test]
    fn window_clamp_mirrors_dynamic_slice() {
        let m = MockModel::new(MockConfig { max_tgt: 16, ..Default::default() });
        let h = m.encode(&[src_seq()]).unwrap();
        let out = m
            .decode(&[DecodeRow::full(h, 0, vec![BOS], 14)], 8)
            .unwrap();
        assert_eq!(out.starts[0], 8); // min(14, 16-8)
    }

    #[test]
    fn decode_into_recycles_buffers() {
        let m = MockModel::new(MockConfig::default());
        let h = m.encode(&[src_seq()]).unwrap();
        let row = DecodeRow::full(h, 0, vec![BOS], 0);
        let mut out = DecodeOut::default();
        m.decode_into(std::slice::from_ref(&row), 2, &mut out).unwrap();
        let want = m.decode(std::slice::from_ref(&row), 2).unwrap();
        assert_eq!(out.data, want.data);
        assert_eq!(out.starts, want.starts);
        assert_eq!(out.padded_rows, want.padded_rows);
        let ptr = out.data.as_ptr();
        // Refill with a smaller window: same backing buffer.
        m.decode_into(std::slice::from_ref(&row), 1, &mut out).unwrap();
        assert_eq!(ptr, out.data.as_ptr(), "data buffer must be recycled");
        assert_eq!(out.win, 1);
    }

    #[test]
    fn pad_rows_is_next_power_of_two() {
        let m = MockModel::new(MockConfig::default());
        let h = m.encode(&[src_seq(), src_seq(), src_seq()]).unwrap();
        let rows: Vec<DecodeRow> = (0..3)
            .map(|i| DecodeRow::full(h, i, vec![BOS], 0))
            .collect();
        let out = m.decode(&rows, 1).unwrap();
        assert_eq!(out.padded_rows, m.pad_rows(3));
        assert_eq!(out.padded_rows, 4);
    }

    #[test]
    fn release_frees_handle() {
        let m = MockModel::new(MockConfig::default());
        let h = m.encode(&[src_seq()]).unwrap();
        m.release(h);
        assert!(m
            .decode(&[DecodeRow::full(h, 0, vec![BOS], 0)], 1)
            .is_err());
    }
}
