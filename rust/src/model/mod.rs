//! The `StepModel` abstraction: what a single-step retrosynthesis model
//! looks like to the decoding engines and the planner.
//!
//! Three implementations exist:
//!
//! * [`crate::runtime::PjrtModel`] — the real thing: AOT-compiled HLO
//!   executed through the PJRT C API;
//! * [`mock::MockModel`] — a deterministic, pure-Rust fake with the same
//!   interface and Medusa-head semantics, used by unit/integration tests
//!   and benches that must not depend on artifacts;
//! * [`scripted::ScriptedModel`] — a trie-shaped distribution over
//!   caller-provided target strings per source, so planner tests and
//!   search benches get a neural path that actually *solves* molecules
//!   (e.g. [`scripted::oracle_script`] replays the SynthChem templates
//!   through real multi-cycle decoding).
//!
//! The interface mirrors the exported executables (see
//! `python/compile/aot.py`): `encode` turns token rows into an opaque
//! memory handle; `decode` runs the decoder on a set of rows, returning
//! main + Medusa-head logits for a *window* of positions per row.
//!
//! ## Incremental decode protocol
//!
//! A [`DecodeRow`] carries `(state, delta, pos)`: a [`StateId`] naming
//! cached decoder state the model owns (the processed prefix — a KV
//! cache in a real runtime) plus only the *new* tokens past it, so
//! decode cost is proportional to fresh positions per cycle instead of
//! resending the whole prefix every call. Models opt in via
//! [`StepModel::supports_incremental`]; engines fall back to
//! full-prefix rows (`state = NONE`, delta = the whole BOS-led input)
//! for models that cannot cache state — mirroring how
//! `Decoder::start_task` defaults over `start_task_on`.
//!
//! **State-ownership rule (fork / commit / release):** states are
//! ref-counted and content-addressed ([`state::StateStore`]). A decode
//! task commits a state only for positions the call it just absorbed
//! actually processed; every surviving beam holds exactly one claim on
//! its anchor state (beam reordering = explicit forking — siblings of
//! one parent share the committed state, each with its own claim);
//! rejected draft positions are simply never committed and unadopted
//! commits are released at the end of the cycle (rollback). A task's
//! whole chain is released when it retires **or is cancelled**, never
//! stranding a sibling fork — the same lifetime discipline as
//! [`MemView`] encoder memory.

pub mod mock;
pub mod replica;
pub mod scratch;
pub mod scripted;
pub mod state;

pub use replica::{is_replica_gone, PooledModel, ReplicaPool, ReplicaStats};
pub use state::{StateId, StateStore};

use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Opaque handle to encoder memory for a batch of sources.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MemHandle(pub u64);

/// Interior of a shared encoder batch: the device handle plus the count
/// of outstanding row views. Private — callers only ever hold
/// [`MemView`]s.
#[derive(Debug)]
struct SharedMemInner {
    mem: MemHandle,
    live: AtomicUsize,
}

/// A row-sliced view of a **ref-counted** batch encode: several decode
/// tasks share one [`StepModel::encode`] call (one row each), and the
/// device memory is released exactly when the *last* view drops its
/// claim via [`MemView::release`] — whether that task retired normally
/// or was cancelled mid-flight. Speculative cancellation of one member
/// therefore never strands its siblings' encoder memory, and no member
/// can free memory a sibling still decodes from.
///
/// Views are deliberately not `Clone`: each view is a unique claim, and
/// release consumes it, so the count cannot drift. The refcount lives
/// host-side in an `Arc`, which makes the same bookkeeping correct for
/// in-process models and for [`crate::runtime::server::SharedModel`]
/// (the final `release` crosses to the executor thread as an ordinary
/// release request).
#[derive(Debug)]
pub struct MemView {
    shared: Arc<SharedMemInner>,
    row: usize,
}

impl MemView {
    /// Split one encoded batch of `rows` rows into per-row views, each
    /// holding one claim on the handle. `rows` must be at least 1 —
    /// with zero views nobody could ever release the handle.
    pub fn split(mem: MemHandle, rows: usize) -> Vec<MemView> {
        debug_assert!(rows > 0, "a zero-view split would strand the handle");
        let shared = Arc::new(SharedMemInner { mem, live: AtomicUsize::new(rows) });
        (0..rows).map(|row| MemView { shared: shared.clone(), row }).collect()
    }

    /// The underlying batch handle (for [`DecodeRow::mem`]).
    pub fn mem(&self) -> MemHandle {
        self.shared.mem
    }

    /// This view's row within the encoded batch (for
    /// [`DecodeRow::mem_row`]).
    pub fn row(&self) -> usize {
        self.row
    }

    /// Outstanding views on this view's batch (diagnostics and the
    /// ref-count tests).
    pub fn live(&self) -> usize {
        self.shared.live.load(Ordering::Acquire)
    }

    /// Drop this view's claim; the device memory is released iff this
    /// was the last one.
    pub fn release(self, model: &dyn StepModel) {
        if self.shared.live.fetch_sub(1, Ordering::AcqRel) == 1 {
            model.release(self.shared.mem);
        }
    }
}

/// Encode a batch of sources in ONE [`StepModel::encode`] call and
/// return a per-row [`MemView`] for each source. This is the
/// fused-encode admission primitive: co-arriving cache-missing
/// molecules share a single encoder call, each decoding over its own
/// row view, and the batch memory is freed when the last of them
/// retires or is cancelled.
pub fn encode_shared(model: &dyn StepModel, srcs: &[Vec<i32>]) -> Result<Vec<MemView>> {
    if srcs.is_empty() {
        return Ok(Vec::new());
    }
    Ok(MemView::split(model.encode(srcs)?, srcs.len()))
}

/// Release every view in `views` (task teardown and error-path
/// cleanup).
pub fn release_views(model: &dyn StepModel, views: Vec<MemView>) {
    for v in views {
        v.release(model);
    }
}

/// One decoder row: cached state plus the delta tokens extending it,
/// over one encoded source.
///
/// The model's decoder input for the row is `state's prefix ++ delta`.
/// With `state == StateId::NONE` the delta is the full BOS-led input
/// (prefix ++ draft) — the classic full-prefix row every model
/// understands. With a real state the model processes only the delta
/// positions (plus any window positions the clamp pulls into the
/// cached region, which it may re-derive); `DecodeStats::decode_tokens`
/// charges exactly the delta lengths.
#[derive(Debug, Clone)]
pub struct DecodeRow {
    pub mem: MemHandle,
    /// Row within the encoded batch.
    pub mem_row: usize,
    /// Cached decoder state covering this row's tokens before `delta`
    /// (`StateId::NONE`: no cached state).
    pub state: StateId,
    /// Decoder-input tokens past the cached state, unpadded.
    pub delta: Vec<i32>,
    /// First position whose logits are needed (window start).
    pub pos: usize,
}

impl DecodeRow {
    /// A full-prefix row (no cached state): `tgt` is the whole BOS-led
    /// decoder input. The pre-incremental contract, still what engines
    /// send to models without [`StepModel::supports_incremental`].
    pub fn full(mem: MemHandle, mem_row: usize, tgt: Vec<i32>, pos: usize) -> DecodeRow {
        DecodeRow { mem, mem_row, state: StateId::NONE, delta: tgt, pos }
    }
}

/// Parent reference for one entry of a
/// [`StepModel::state_commit_batch`] call: either an already-committed
/// state (or [`StateId::NONE`] for a root commit) or the freshly
/// committed result of an *earlier entry in the same batch*. Slot
/// references are how an engine ships a chained backbone — each fork's
/// parent is the previous fork's result — in one executor round trip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StateParent {
    /// An existing state id (`StateId::NONE` = no cached parent).
    Id(StateId),
    /// The id committed by batch entry `i` (must be an earlier entry).
    Slot(usize),
}

/// One decoder-state fork queued for [`StepModel::state_commit_batch`]:
/// commit `parent's prefix ++ [tok]` on encoder row `(mem, mem_row)`.
#[derive(Debug, Clone)]
pub struct StateForkReq {
    pub mem: MemHandle,
    pub mem_row: usize,
    pub parent: StateParent,
    pub tok: i32,
}

/// Logits for a window of positions per row: `(rows, win, heads, vocab)`.
///
/// `Default` yields an empty buffer suitable for
/// [`StepModel::decode_into`]: callers keep one `DecodeOut` alive across
/// calls and the implementation refills `data`/`starts` in place, so
/// steady-state decode output costs no heap allocation.
#[derive(Debug, Clone, Default)]
pub struct DecodeOut {
    pub data: Vec<f32>,
    pub rows: usize,
    pub win: usize,
    pub heads: usize,
    pub vocab: usize,
    /// Actual window start per row after the dynamic-slice clamp
    /// (`min(pos, padded_len - win)`); callers index relative to this.
    pub starts: Vec<usize>,
    /// Padded row count actually submitted to the executable (the
    /// effective batch size for Table 1C accounting).
    pub padded_rows: usize,
}

impl DecodeOut {
    /// Logits slice for `(row, window offset, head)`.
    pub fn logits(&self, row: usize, j: usize, head: usize) -> &[f32] {
        debug_assert!(row < self.rows && j < self.win && head < self.heads);
        let base = ((row * self.win + j) * self.heads + head) * self.vocab;
        &self.data[base..base + self.vocab]
    }

    /// Window offset for absolute position `pos` in row `row`, if inside.
    pub fn offset_of(&self, row: usize, pos: usize) -> Option<usize> {
        let start = self.starts[row];
        if pos >= start && pos < start + self.win {
            Some(pos - start)
        } else {
            None
        }
    }
}

/// A single-step model: encoder memory + windowed Medusa decode.
///
/// Deliberately *not* `Send + Sync`: the PJRT wrapper types are
/// `Rc`-based. Multi-threaded users go through
/// [`crate::runtime::server::SharedModel`], which serializes calls onto
/// a dedicated model-executor thread (the natural shape for a
/// single-accelerator serving system).
pub trait StepModel {
    /// Vocabulary size (ids `0..vocab`, specials per [`crate::tokenizer`]).
    fn vocab(&self) -> usize;
    // (blanket impls for Box/&T are below the trait definition)
    /// Number of *extra* Medusa heads M (0 = plain transformer).
    fn medusa_heads(&self) -> usize;
    /// Maximum source length (tokens incl. BOS/EOS).
    fn max_src(&self) -> usize;
    /// Maximum target length.
    fn max_tgt(&self) -> usize;
    /// Model identity string for cache binding: the persistent
    /// expansion store refuses to serve records written under a
    /// different fingerprint. The default derives it from the four
    /// meta accessors, which every wrapper forwards, so instrumented /
    /// chaos / shared wrappers fingerprint identically to the model
    /// they wrap; real artifact-backed models should override with a
    /// build hash when one is available.
    fn fingerprint(&self) -> String {
        format!(
            "v{}-m{}-s{}-t{}",
            self.vocab(),
            self.medusa_heads(),
            self.max_src(),
            self.max_tgt()
        )
    }
    /// Encode a batch of sources (unpadded token rows). The handle stays
    /// valid until [`StepModel::release`].
    fn encode(&self, src: &[Vec<i32>]) -> Result<MemHandle>;
    /// Run the decoder on `rows`, returning a `win`-wide logits window
    /// per row. One invocation = one model call (Table 1B accounting).
    fn decode(&self, rows: &[DecodeRow], win: usize) -> Result<DecodeOut>;
    /// [`StepModel::decode`] into a caller-owned buffer. The default
    /// delegates to `decode` (allocating); implementations that can
    /// refill `out.data`/`out.starts` in place (mock, shared-model
    /// executor) override it so the decoding hot loop and the fused
    /// scheduler recycle one output buffer across calls.
    fn decode_into(&self, rows: &[DecodeRow], win: usize, out: &mut DecodeOut) -> Result<()> {
        *out = self.decode(rows, win)?;
        Ok(())
    }
    /// Padded (device-submitted) row count for a batch of `n` logical
    /// rows — the number `decode` reports in `DecodeOut::padded_rows`.
    /// Used for per-task Table 1C accounting when several tasks share
    /// one fused call: each task is charged what the device *would*
    /// have padded had it decoded alone, which is what solo `generate`
    /// reports. Default: next power of two (the mock's rule).
    fn pad_rows(&self, n: usize) -> usize {
        n.next_power_of_two()
    }
    /// Drop an encoded batch.
    fn release(&self, mem: MemHandle);
    /// Whether this model caches per-row decoder state ([`StateId`]),
    /// enabling delta rows. Models that return `false` keep working:
    /// engines send full-prefix rows instead (the reconstruction-free
    /// path), exactly as before the incremental protocol existed.
    fn supports_incremental(&self) -> bool {
        false
    }
    /// Commit the decoder state for `parent's prefix ++ delta` on
    /// encoder row `(mem, mem_row)` and return a ref-counted claim on
    /// it. Callers may only commit positions a decode call has already
    /// processed for that row (the model can then snapshot its cache
    /// rather than recompute). Content-addressed: an identical prefix
    /// returns the same id with its count bumped.
    fn state_commit(
        &self,
        mem: MemHandle,
        mem_row: usize,
        parent: StateId,
        delta: &[i32],
    ) -> Result<StateId> {
        let _ = (mem, mem_row, parent, delta);
        anyhow::bail!("model does not support incremental decode state")
    }
    /// Commit a batch of decoder-state forks in ONE call, in order.
    /// Entry `i` may name an earlier entry's freshly committed id via
    /// [`StateParent::Slot`], so chained forks (each link's parent is
    /// the previous link's result) cost one call, not one per link —
    /// on [`crate::runtime::server::SharedModel`] that is one executor
    /// round trip per decode cycle instead of one per committed row.
    ///
    /// Semantics mirror sequential committing exactly: entries commit
    /// in order and the batch STOPS at the first failure — every later
    /// entry returns `Err` *uncommitted*, and a slot reference to a
    /// failed or out-of-range entry fails its own entry the same way.
    /// A caller that degrades to full-prefix rows on the first `Err`
    /// therefore observes the identical committed-state set it would
    /// have under one-call-at-a-time committing. Like single commits,
    /// the batch is never retried (a replay could double-claim).
    fn state_commit_batch(&self, reqs: &[StateForkReq]) -> Vec<Result<StateId>> {
        let mut out: Vec<Result<StateId>> = Vec::with_capacity(reqs.len());
        let mut alive = true;
        for r in reqs {
            if !alive {
                out.push(Err(anyhow::anyhow!("state commit batch stopped at earlier failure")));
                continue;
            }
            let parent = match r.parent {
                StateParent::Id(id) => Ok(id),
                StateParent::Slot(i) => match out.get(i) {
                    Some(Ok(id)) => Ok(*id),
                    _ => Err(anyhow::anyhow!("batch slot {i} is not an earlier committed entry")),
                },
            };
            match parent {
                Ok(p) => {
                    let res = self.state_commit(r.mem, r.mem_row, p, std::slice::from_ref(&r.tok));
                    alive = res.is_ok();
                    out.push(res);
                }
                Err(e) => {
                    alive = false;
                    out.push(Err(e));
                }
            }
        }
        out
    }
    /// Add a claim on a cached state (a surviving fork adopting an
    /// anchor). No-op by default.
    fn state_retain(&self, state: StateId) {
        let _ = state;
    }
    /// Drop a claim on a cached state; the model frees it when the last
    /// claim goes. No-op by default.
    fn state_release(&self, state: StateId) {
        let _ = state;
    }
}

impl<T: StepModel + ?Sized> StepModel for Box<T> {
    fn vocab(&self) -> usize {
        (**self).vocab()
    }
    fn medusa_heads(&self) -> usize {
        (**self).medusa_heads()
    }
    fn max_src(&self) -> usize {
        (**self).max_src()
    }
    fn max_tgt(&self) -> usize {
        (**self).max_tgt()
    }
    fn fingerprint(&self) -> String {
        (**self).fingerprint()
    }
    fn encode(&self, src: &[Vec<i32>]) -> Result<MemHandle> {
        (**self).encode(src)
    }
    fn decode(&self, rows: &[DecodeRow], win: usize) -> Result<DecodeOut> {
        (**self).decode(rows, win)
    }
    fn decode_into(&self, rows: &[DecodeRow], win: usize, out: &mut DecodeOut) -> Result<()> {
        (**self).decode_into(rows, win, out)
    }
    fn pad_rows(&self, n: usize) -> usize {
        (**self).pad_rows(n)
    }
    fn release(&self, mem: MemHandle) {
        (**self).release(mem)
    }
    fn supports_incremental(&self) -> bool {
        (**self).supports_incremental()
    }
    fn state_commit(
        &self,
        mem: MemHandle,
        mem_row: usize,
        parent: StateId,
        delta: &[i32],
    ) -> Result<StateId> {
        (**self).state_commit(mem, mem_row, parent, delta)
    }
    fn state_commit_batch(&self, reqs: &[StateForkReq]) -> Vec<Result<StateId>> {
        (**self).state_commit_batch(reqs)
    }
    fn state_retain(&self, state: StateId) {
        (**self).state_retain(state)
    }
    fn state_release(&self, state: StateId) {
        (**self).state_release(state)
    }
}

/// Log-softmax over a logits slice (f64 accumulation for stability).
/// Allocates the result; the decoding hot loop uses
/// [`scratch::ScoringScratch`] to reuse buffers instead.
pub fn log_softmax(logits: &[f32]) -> Vec<f64> {
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut z = 0.0f64;
    for &x in logits {
        z += ((x as f64) - mx).exp();
    }
    let lz = z.ln();
    logits.iter().map(|&x| (x as f64) - mx - lz).collect()
}

/// Softmax probabilities.
pub fn softmax(logits: &[f32]) -> Vec<f64> {
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = logits.iter().map(|&x| ((x as f64) - mx).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

/// Argmax index of a slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the top-`k` entries, descending (ties broken by ascending
/// index, like a stable sort). Partial selection, O(n + k log k).
pub fn top_k(xs: &[f64], k: usize) -> Vec<usize> {
    scratch::top_k_indices(xs, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let ls = log_softmax(&[1.0, 2.0, 3.0]);
        let z: f64 = ls.iter().map(|l| l.exp()).sum();
        assert!((z - 1.0).abs() < 1e-9);
        assert!(ls[2] > ls[1] && ls[1] > ls[0]);
    }

    #[test]
    fn softmax_matches_log_softmax() {
        let logits = [0.5f32, -1.0, 2.0, 0.0];
        let p = softmax(&logits);
        let lp = log_softmax(&logits);
        for (a, b) in p.iter().zip(lp.iter()) {
            assert!((a.ln() - b).abs() < 1e-9);
        }
    }

    #[test]
    fn top_k_and_argmax() {
        let xs = [0.1f64, 0.7, 0.2];
        assert_eq!(top_k(&xs, 2), vec![1, 2]);
        assert_eq!(argmax(&[0.1f32, 0.7, 0.2]), 1);
    }

    #[test]
    fn mem_views_release_on_last_claim() {
        use crate::model::mock::{MockConfig, MockModel};
        let m = MockModel::new(MockConfig::default());
        let srcs: Vec<Vec<i32>> = (0..3).map(|i| vec![1, 5 + i, 2]).collect();
        let views = encode_shared(&m, &srcs).unwrap();
        assert_eq!(views.len(), 3);
        assert_eq!(m.encode_calls.load(Ordering::Relaxed), 1, "one fused encode");
        assert_eq!(m.live_handles(), 1, "one shared batch handle");
        let mem = views[0].mem();
        for (i, v) in views.iter().enumerate() {
            assert_eq!(v.mem(), mem, "all views share the batch handle");
            assert_eq!(v.row(), i);
        }
        let mut it = views.into_iter();
        it.next().unwrap().release(&m);
        assert_eq!(m.live_handles(), 1, "siblings keep the memory alive");
        it.next().unwrap().release(&m);
        assert_eq!(m.live_handles(), 1);
        it.next().unwrap().release(&m);
        assert_eq!(m.live_handles(), 0, "last claim frees the device memory");
    }

    #[test]
    fn encode_shared_empty_batch_encodes_nothing() {
        use crate::model::mock::{MockConfig, MockModel};
        let m = MockModel::new(MockConfig::default());
        let views = encode_shared(&m, &[]).unwrap();
        assert!(views.is_empty());
        assert_eq!(m.encode_calls.load(Ordering::Relaxed), 0);
        assert_eq!(m.live_handles(), 0);
    }

    #[test]
    fn release_views_drains_every_claim() {
        use crate::model::mock::{MockConfig, MockModel};
        let m = MockModel::new(MockConfig::default());
        let views = encode_shared(&m, &[vec![1, 5, 2], vec![1, 6, 2]]).unwrap();
        assert_eq!(views[1].live(), 2);
        release_views(&m, views);
        assert_eq!(m.live_handles(), 0);
    }

    #[test]
    fn state_commit_batch_matches_sequential_and_stops_at_failure() {
        use crate::model::mock::{MockConfig, MockModel};
        let m = MockModel::new(MockConfig::default());
        let h = m.encode(&[vec![1, 5, 6, 7, 2]]).unwrap();
        // Chained batch: a root commit, then a link whose parent is the
        // root's slot — the msbs/hsbs backbone shape.
        let out = m.state_commit_batch(&[
            StateForkReq { mem: h, mem_row: 0, parent: StateParent::Id(StateId::NONE), tok: 1 },
            StateForkReq { mem: h, mem_row: 0, parent: StateParent::Slot(0), tok: 5 },
        ]);
        let s0 = *out[0].as_ref().unwrap();
        let s1 = *out[1].as_ref().unwrap();
        // Content-addressing makes equivalence observable: sequential
        // commits of the same prefixes return the very same ids.
        let t0 = m.state_commit(h, 0, StateId::NONE, &[1]).unwrap();
        let t1 = m.state_commit(h, 0, t0, &[5]).unwrap();
        assert_eq!(s0, t0);
        assert_eq!(s1, t1);
        // A slot reference that names no earlier committed entry fails
        // its own entry AND stops the batch (later entries uncommitted).
        let bad = m.state_commit_batch(&[
            StateForkReq { mem: h, mem_row: 0, parent: StateParent::Slot(7), tok: 1 },
            StateForkReq { mem: h, mem_row: 0, parent: StateParent::Id(StateId::NONE), tok: 1 },
        ]);
        assert!(bad[0].is_err());
        assert!(bad[1].is_err());
        m.release(h);
    }

    #[test]
    fn decode_out_indexing() {
        // rows=1, win=2, heads=2, vocab=3
        let data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let out = DecodeOut {
            data,
            rows: 1,
            win: 2,
            heads: 2,
            vocab: 3,
            starts: vec![4],
            padded_rows: 1,
        };
        assert_eq!(out.logits(0, 0, 0), &[0.0, 1.0, 2.0]);
        assert_eq!(out.logits(0, 1, 1), &[9.0, 10.0, 11.0]);
        assert_eq!(out.offset_of(0, 5), Some(1));
        assert_eq!(out.offset_of(0, 3), None);
    }
}
