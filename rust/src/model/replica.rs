//! Model replica pool: N executor-backed [`StepModel`]s behind one
//! load-aware dispatcher.
//!
//! One `SharedModel` executor serializes every fused call onto a single
//! device — the throughput ceiling once the sharded hub fans out
//! (ROADMAP: "multi-device serving"). The pool wraps N independent
//! executors (typically N [`crate::runtime::SharedModel`]s, each owning
//! its own supervised device thread) and hands shard rounds the
//! *least-loaded live* replica. Replicas may be heterogeneous — the
//! trait object erases the model type, so the pool doubles as the
//! ensemble substrate later.
//!
//! The pool is pure bookkeeping: it never calls the models itself.
//! Shard loops `pick()` a replica, run encode/tick on
//! [`ReplicaPool::model`], and report load via `charge`/`discharge`
//! (outstanding logical rows — the same signal the fused-call budget
//! is denominated in). All counters are atomics; the pool is shared
//! across shard threads as a plain `Arc` with no lock.
//!
//! **Failure domain**: a replica whose executor died past
//! `max_restarts` answers every call with a "model thread gone" error
//! ([`is_replica_gone`] recognizes it). The shard that observes this
//! calls [`ReplicaPool::mark_dead`] and re-submits the dead replica's
//! work to a survivor — waiters are failed only when the *last*
//! replica dies ([`ReplicaPool::alive_count`] == 0).

use super::StepModel;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;

/// A pool-managed model: shareable across shard threads. Concrete
/// models that are not `Sync` (the PJRT wrappers) enter the pool via
/// their `SharedModel` executor handle, which is.
pub type PooledModel = Arc<dyn StepModel + Send + Sync>;

struct ReplicaSlot {
    model: PooledModel,
    alive: AtomicBool,
    /// Logical rows currently in flight on this replica (charged at
    /// task start, discharged at retire/cancel/failure).
    outstanding_rows: AtomicI64,
    fused_calls: AtomicU64,
    rows_dispatched: AtomicU64,
}

/// Point-in-time view of one replica's counters (benches, metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplicaStats {
    pub alive: bool,
    pub outstanding_rows: i64,
    pub fused_calls: u64,
    pub rows_dispatched: u64,
}

/// N `StepModel` executors behind least-outstanding-rows dispatch.
pub struct ReplicaPool {
    slots: Vec<ReplicaSlot>,
}

impl ReplicaPool {
    /// Pool over pre-built models (one executor each). Panics on an
    /// empty list — a hub without a model cannot serve.
    pub fn from_models(models: Vec<PooledModel>) -> Self {
        assert!(!models.is_empty(), "replica pool needs at least one model");
        let slots = models
            .into_iter()
            .map(|model| ReplicaSlot {
                model,
                alive: AtomicBool::new(true),
                outstanding_rows: AtomicI64::new(0),
                fused_calls: AtomicU64::new(0),
                rows_dispatched: AtomicU64::new(0),
            })
            .collect();
        Self { slots }
    }

    /// Single-replica pool — the parity configuration: `pick` always
    /// answers 0, so dispatch adds no behavior over the bare model.
    pub fn single<M: StepModel + Send + Sync + 'static>(model: M) -> Self {
        Self::from_models(vec![Arc::new(model)])
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn alive_count(&self) -> usize {
        self.slots.iter().filter(|s| s.alive.load(Ordering::Relaxed)).count()
    }

    pub fn is_alive(&self, i: usize) -> bool {
        self.slots[i].alive.load(Ordering::Relaxed)
    }

    /// The replica's model, for encode/tick calls. Valid for dead
    /// replicas too (fire-and-forget releases drain harmlessly into a
    /// gone executor).
    pub fn model(&self, i: usize) -> &dyn StepModel {
        self.slots[i].model.as_ref()
    }

    /// Clone the shareable handle (per-task decode references).
    pub fn model_arc(&self, i: usize) -> PooledModel {
        Arc::clone(&self.slots[i].model)
    }

    /// Least-outstanding-rows dispatch over live replicas, lowest index
    /// on ties (deterministic; a 1-replica pool always answers 0).
    /// `None` means every replica is dead.
    pub fn pick(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.alive.load(Ordering::Relaxed))
            .min_by_key(|(i, s)| (s.outstanding_rows.load(Ordering::Relaxed), *i))
            .map(|(i, _)| i)
    }

    /// Rows entering flight on replica `i`.
    pub fn charge(&self, i: usize, rows: usize) {
        self.slots[i].outstanding_rows.fetch_add(rows as i64, Ordering::Relaxed);
    }

    /// Rows leaving flight (retired, cancelled, or failed).
    pub fn discharge(&self, i: usize, rows: usize) {
        self.slots[i].outstanding_rows.fetch_sub(rows as i64, Ordering::Relaxed);
    }

    /// Account one fused device call of `rows` logical rows.
    pub fn note_fused_call(&self, i: usize, rows: usize) {
        self.slots[i].fused_calls.fetch_add(1, Ordering::Relaxed);
        self.slots[i].rows_dispatched.fetch_add(rows as u64, Ordering::Relaxed);
    }

    /// Take replica `i` out of dispatch (executor past `max_restarts`).
    /// Its outstanding charge is zeroed — the caller re-submits that
    /// work elsewhere. Returns `true` only for the FIRST caller to kill
    /// this replica (several shards may observe the same death; death
    /// metrics should count replicas, not observations).
    pub fn mark_dead(&self, i: usize) -> bool {
        let was_alive = self.slots[i].alive.swap(false, Ordering::Relaxed);
        self.slots[i].outstanding_rows.store(0, Ordering::Relaxed);
        was_alive
    }

    pub fn stats(&self) -> Vec<ReplicaStats> {
        self.slots
            .iter()
            .map(|s| ReplicaStats {
                alive: s.alive.load(Ordering::Relaxed),
                outstanding_rows: s.outstanding_rows.load(Ordering::Relaxed),
                fused_calls: s.fused_calls.load(Ordering::Relaxed),
                rows_dispatched: s.rows_dispatched.load(Ordering::Relaxed),
            })
            .collect()
    }
}

/// Does this error mean the replica's executor thread is gone (its
/// supervisor gave up past `max_restarts`)? Such errors are a property
/// of the *replica*, not the request — the caller should fail over,
/// not fail the waiter.
pub fn is_replica_gone(e: &anyhow::Error) -> bool {
    format!("{e:#}").contains("model thread gone")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mock::{MockConfig, MockModel};

    fn pool(n: usize) -> ReplicaPool {
        ReplicaPool::from_models(
            (0..n)
                .map(|_| Arc::new(MockModel::new(MockConfig::default())) as PooledModel)
                .collect(),
        )
    }

    #[test]
    fn pick_prefers_least_outstanding_with_index_tiebreak() {
        let p = pool(3);
        assert_eq!(p.pick(), Some(0), "all-zero load ties break to index 0");
        p.charge(0, 10);
        p.charge(1, 4);
        assert_eq!(p.pick(), Some(2));
        p.charge(2, 4);
        assert_eq!(p.pick(), Some(1), "4-row tie breaks to the lower index");
        p.discharge(0, 10);
        assert_eq!(p.pick(), Some(0));
    }

    #[test]
    fn dead_replicas_leave_dispatch() {
        let p = pool(2);
        p.charge(1, 100);
        p.mark_dead(0);
        assert_eq!(p.alive_count(), 1);
        assert_eq!(p.pick(), Some(1), "loaded survivor beats dead idle replica");
        p.mark_dead(1);
        assert_eq!(p.pick(), None);
        assert_eq!(p.alive_count(), 0);
    }

    #[test]
    fn mark_dead_zeroes_outstanding_charge() {
        let p = pool(1);
        p.charge(0, 42);
        assert!(p.mark_dead(0), "first observer kills the replica");
        assert!(!p.mark_dead(0), "repeat observers see it already dead");
        assert_eq!(p.stats()[0].outstanding_rows, 0);
        assert!(!p.stats()[0].alive);
    }

    #[test]
    fn single_is_a_one_replica_pool() {
        let p = ReplicaPool::single(MockModel::new(MockConfig::default()));
        assert_eq!(p.len(), 1);
        assert_eq!(p.pick(), Some(0));
        assert_eq!(p.model(0).vocab(), p.model_arc(0).vocab());
    }

    #[test]
    fn fused_call_accounting_feeds_stats() {
        let p = pool(2);
        p.note_fused_call(1, 8);
        p.note_fused_call(1, 4);
        let st = p.stats();
        assert_eq!(st[0].fused_calls, 0);
        assert_eq!(st[1].fused_calls, 2);
        assert_eq!(st[1].rows_dispatched, 12);
    }

    #[test]
    fn gone_error_detection_matches_executor_message() {
        assert!(is_replica_gone(&anyhow::anyhow!("model thread gone")));
        assert!(is_replica_gone(
            &anyhow::anyhow!("model thread gone").context("encode failed")
        ));
        assert!(!is_replica_gone(&anyhow::anyhow!("device OOM")));
    }
}
