//! Reusable scoring buffers for the decoding hot loop.
//!
//! The seed implementation allocated a fresh full-vocab `Vec<f64>` for
//! every `softmax`/`log_softmax` call and sorted the entire vocabulary
//! in `top_k` — per position, per cycle, per beam. [`ScoringScratch`]
//! owns those buffers once per `generate` call and refills them in
//! place, and top-k selection uses `select_nth_unstable_by` (O(V + k
//! log k)) instead of a full O(V log V) sort.
//!
//! Numeric parity with the seed is deliberate and exact: max/sum/ln are
//! evaluated in the same order with the same f64 intermediates, so
//! `lsm` values are bit-identical to the seed's `log_softmax`, and the
//! top-k comparator totalizes the seed's stable sort (value descending,
//! then index ascending), so tie-breaks match the seed's output
//! token-for-token.

/// Reusable buffers: log-softmax values + top-k index selection.
pub struct ScoringScratch {
    /// Log-softmax of the last scored logits row (valid after
    /// [`ScoringScratch::log_softmax`] / [`ScoringScratch::top_k_log_softmax`]).
    pub lsm: Vec<f64>,
    /// Top-k indices into `lsm`, descending score (valid after
    /// [`ScoringScratch::top_k_log_softmax`]).
    pub topk: Vec<usize>,
    idx: Vec<u32>,
}

impl ScoringScratch {
    pub fn new() -> Self {
        Self { lsm: Vec::new(), topk: Vec::new(), idx: Vec::new() }
    }

    /// Fill `self.lsm` with the log-softmax of `logits` (f64
    /// accumulation, bit-identical to [`crate::model::log_softmax`]).
    pub fn log_softmax(&mut self, logits: &[f32]) {
        let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut z = 0.0f64;
        for &x in logits {
            z += ((x as f64) - mx).exp();
        }
        let lz = z.ln();
        self.lsm.clear();
        self.lsm.extend(logits.iter().map(|&x| (x as f64) - mx - lz));
    }

    /// Log-softmax `logits` into `self.lsm`, then select the top-`k`
    /// indices into `self.topk` (descending; ties by ascending index,
    /// matching the seed's stable full sort).
    pub fn top_k_log_softmax(&mut self, logits: &[f32], k: usize) {
        self.log_softmax(logits);
        let lsm = &self.lsm;
        self.idx.clear();
        self.idx.extend(0..lsm.len() as u32);
        let cmp = |a: &u32, b: &u32| {
            lsm[*b as usize]
                .partial_cmp(&lsm[*a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.cmp(b))
        };
        let n = self.idx.len();
        if k < n {
            self.idx.select_nth_unstable_by(k, cmp);
            self.idx.truncate(k);
        }
        self.idx.sort_unstable_by(cmp);
        self.topk.clear();
        self.topk.extend(self.idx.iter().map(|&i| i as usize));
    }
}

impl Default for ScoringScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Probability mass of tokens strictly more probable than `tok`, fused
/// over raw logits: one max pass + one sum pass, no `Vec` materialized.
/// This is the MSBS nucleus acceptance test.
///
/// Equivalence to the seed's materializing form (`softmax(logits)` then
/// summing entries greater than `probs[tok]`): the filter is exact —
/// distinct f32 logits stay distinct through `exp` in f64 (an f32 ulp
/// is ~1e9 f64 ulps), so `p_i > p_tok` iff `logits[i] > logits[tok]` —
/// but the mass itself is computed as `(Σ e_i)/z` instead of
/// `Σ (e_i/z)`, which can differ in the last ulp (~1e-16 relative).
/// The accept decision `mass < nucleus` therefore agrees with the seed
/// unless the true mass lies within ~1e-16 of the nucleus parameter —
/// unobservable in practice and impossible for the mock's logit grid,
/// which is what the parity suite pins.
pub fn nucleus_mass_before(logits: &[f32], tok: usize) -> f64 {
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lt = logits[tok];
    let mut z = 0.0f64;
    let mut above = 0.0f64;
    for &x in logits {
        let e = ((x as f64) - mx).exp();
        z += e;
        if x > lt {
            above += e;
        }
    }
    above / z
}

/// Indices of the top-`k` entries of `xs`, descending (ties by ascending
/// index). Partial selection: O(n + k log k). The convenience form of
/// [`ScoringScratch::top_k_log_softmax`] for callers outside the hot loop.
pub fn top_k_indices(xs: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    let cmp = |a: &usize, b: &usize| {
        xs[*b]
            .partial_cmp(&xs[*a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.cmp(b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{log_softmax, softmax};

    #[test]
    fn log_softmax_matches_free_function() {
        let logits: Vec<f32> = vec![0.5, -1.0, 2.0, 0.0, 8.0, -4.0];
        let mut s = ScoringScratch::new();
        s.log_softmax(&logits);
        let want = log_softmax(&logits);
        assert_eq!(s.lsm, want, "scratch log-softmax must be bit-identical");
        // buffer reuse across different widths
        s.log_softmax(&logits[..3]);
        assert_eq!(s.lsm, log_softmax(&logits[..3]));
    }

    #[test]
    fn top_k_matches_stable_full_sort() {
        // include exact ties to exercise the index tie-break
        let xs = vec![0.1, 0.9, 0.5, 0.9, 0.5, 0.5, -1.0];
        for k in 0..=xs.len() + 1 {
            let got = top_k_indices(&xs, k);
            // reference: the seed's stable sort
            let mut idx: Vec<usize> = (0..xs.len()).collect();
            idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal));
            idx.truncate(k);
            assert_eq!(got, idx, "k={k}");
        }
    }

    #[test]
    fn top_k_log_softmax_selects_same_indices() {
        let logits: Vec<f32> = (0..26).map(|i| ((i * 7) % 13) as f32 - 4.0).collect();
        let mut s = ScoringScratch::new();
        for k in [1usize, 3, 10, 26] {
            s.top_k_log_softmax(&logits, k);
            let want = top_k_indices(&log_softmax(&logits), k);
            assert_eq!(s.topk, want, "k={k}");
            assert_eq!(s.lsm, log_softmax(&logits));
        }
    }

    #[test]
    fn nucleus_mass_matches_softmax_filter() {
        let logits: Vec<f32> = vec![8.0, 4.0, -4.0, -4.0, 2.0, -1.0];
        let probs = softmax(&logits);
        for tok in 0..logits.len() {
            let p_tok = probs[tok];
            let want: f64 = probs.iter().filter(|&&p| p > p_tok).sum();
            let got = nucleus_mass_before(&logits, tok);
            assert!((got - want).abs() < 1e-12, "tok={tok}: {got} vs {want}");
        }
        // argmax always has zero mass before it
        assert_eq!(nucleus_mass_before(&logits, 0), 0.0);
    }
}
