//! A scripted [`StepModel`]: behaves like a perfectly-trained
//! transformer whose conditional distribution is a weighted trie over
//! caller-provided target strings per source.
//!
//! [`MockModel`](super::mock::MockModel) exercises decoder *mechanics*
//! (its copy task never yields chemically meaningful precursors), so
//! multi-step planning over it can never solve anything. `ScriptedModel`
//! closes that gap: `encode` decodes each source back to its SMILES via
//! the vocabulary and asks a script closure for the target strings that
//! "model" should generate — e.g. [`oracle_script`] replays the
//! SynthChem retro templates. `decode` then emits logits shaped as a
//! trie over those targets, so beam search / HSBS / MSBS recover them
//! through real multi-cycle decoding with realistic model-call counts.
//! End-to-end planner tests and the search benches get a neural path
//! that actually solves molecules, without any artifacts.
//!
//! Distribution shape: at each position every scripted continuation
//! token gets logit `CAND_BASE + w` (`w` is the target's caller-given
//! log-weight; branches sharing a token take the max), everything else
//! sits at `FLOOR`, and a position past a target's end (or an
//! off-script prefix) emits EOS. Relative candidate probabilities after
//! softmax are `exp(w_i - w_j)` — the weights act as unnormalized
//! per-sequence log-probs, approximated at shared-prefix branch points
//! by the best branch. Medusa head `h` predicts position `p + h` along
//! the same trie (no corruption, so speculative acceptance is high).

use super::{DecodeOut, DecodeRow, MemHandle, StateId, StateStore, StepModel};
use crate::tokenizer::{Vocab, EOS};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Produces the weighted target strings for one source string.
pub type Script = Box<dyn Fn(&str) -> Vec<(String, f64)> + Send + Sync>;

const FLOOR: f32 = -30.0;
const CAND_BASE: f32 = 10.0;

/// One encoded source: its scripted targets as token rows (EOS-ended)
/// with log-weights.
struct Scripted {
    seqs: Vec<(Vec<i32>, f64)>,
}

/// Deterministic scripted model. Thread-safe.
pub struct ScriptedModel {
    vocab: Vocab,
    medusa_heads: usize,
    max_src: usize,
    max_tgt: usize,
    script: Script,
    store: Mutex<HashMap<u64, Vec<Scripted>>>,
    states: StateStore,
    next_id: AtomicU64,
}

impl ScriptedModel {
    pub fn new(vocab: Vocab, script: Script) -> Self {
        Self {
            vocab,
            medusa_heads: 6,
            max_src: 192,
            max_tgt: 224,
            script,
            store: Mutex::new(HashMap::new()),
            states: StateStore::new(),
            next_id: AtomicU64::new(1),
        }
    }

    pub fn with_heads(mut self, medusa_heads: usize) -> Self {
        self.medusa_heads = medusa_heads;
        self
    }

    /// Encoded batches currently held (leak diagnostics).
    pub fn live_handles(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    /// Cached decoder states currently held (leak diagnostics).
    pub fn live_states(&self) -> usize {
        self.states.live()
    }
}

impl StepModel for ScriptedModel {
    fn vocab(&self) -> usize {
        self.vocab.len()
    }

    fn medusa_heads(&self) -> usize {
        self.medusa_heads
    }

    fn max_src(&self) -> usize {
        self.max_src
    }

    fn max_tgt(&self) -> usize {
        self.max_tgt
    }

    fn encode(&self, src: &[Vec<i32>]) -> Result<MemHandle> {
        let rows = src
            .iter()
            .map(|tokens| {
                let product = self.vocab.decode(tokens);
                let seqs = (self.script)(&product)
                    .into_iter()
                    .map(|(tgt, w)| {
                        let mut ids = self.vocab.encode(&tgt, false);
                        ids.push(EOS);
                        (ids, w)
                    })
                    .collect();
                Scripted { seqs }
            })
            .collect();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.store.lock().unwrap().insert(id, rows);
        Ok(MemHandle(id))
    }

    fn decode(&self, rows: &[DecodeRow], win: usize) -> Result<DecodeOut> {
        let mut out = DecodeOut::default();
        self.decode_into(rows, win, &mut out)?;
        Ok(out)
    }

    fn decode_into(&self, rows: &[DecodeRow], win: usize, out: &mut DecodeOut) -> Result<()> {
        let store = self.store.lock().unwrap();
        let heads = self.medusa_heads + 1;
        let vocab = self.vocab.len();
        out.data.clear();
        out.data.resize(rows.len() * win * heads * vocab, FLOOR);
        out.starts.clear();
        out.rows = rows.len();
        out.win = win;
        out.heads = heads;
        out.vocab = vocab;
        out.padded_rows = self.pad_rows(rows.len());
        let mut full = Vec::new();
        for (r, row) in rows.iter().enumerate() {
            let srcs = store
                .get(&row.mem.0)
                .ok_or_else(|| anyhow::anyhow!("unknown mem handle"))?;
            let entry = &srcs[row.mem_row];
            // Incremental rows: reconstruct the full decoder input from
            // the cached state (the full-prefix shim) — the trie
            // conditioning below genuinely reads the target tokens, so
            // this is where delta-row/full-row bit-identity is earned.
            let tgt: &[i32] = if row.state.is_none() {
                &row.delta
            } else {
                self.states.resolve_into(row.state, row.mem, row.mem_row, &row.delta, &mut full)?;
                &full
            };
            // emulate the dynamic_slice clamp against the padded length
            let start = row.pos.min(self.max_tgt - win);
            out.starts.push(start);
            for j in 0..win {
                let p = start + j;
                // Conditioning: the first p target tokens the row
                // carries (tgt[0] is BOS). Positions past the provided
                // tokens condition on everything available — the trie
                // continuation fills in the rest, which is what Medusa
                // look-ahead needs.
                let ctx_len = p.min(tgt.len() - 1);
                let ctx = &tgt[1..1 + ctx_len];
                for h in 0..heads {
                    let q = p + h;
                    let base = ((r * win + j) * heads + h) * vocab;
                    let slice = &mut out.data[base..base + vocab];
                    let mut any = false;
                    for (seq, w) in &entry.seqs {
                        if seq.len() < ctx.len() || &seq[..ctx.len()] != ctx {
                            continue;
                        }
                        any = true;
                        let tok = seq.get(q).copied().unwrap_or(EOS);
                        let logit = CAND_BASE + *w as f32;
                        if logit > slice[tok as usize] {
                            slice[tok as usize] = logit;
                        }
                    }
                    if !any {
                        // off-script or no targets at all: finish fast
                        slice[EOS as usize] = CAND_BASE;
                    }
                }
            }
        }
        Ok(())
    }

    fn release(&self, mem: MemHandle) {
        self.store.lock().unwrap().remove(&mem.0);
    }

    fn supports_incremental(&self) -> bool {
        true
    }

    fn state_commit(
        &self,
        mem: MemHandle,
        mem_row: usize,
        parent: StateId,
        delta: &[i32],
    ) -> Result<StateId> {
        self.states.commit(mem, mem_row, parent, delta)
    }

    fn state_retain(&self, state: StateId) {
        self.states.retain(state)
    }

    fn state_release(&self, state: StateId) {
        self.states.release(state)
    }
}

/// The SynthChem retro templates as a script: expanding a product
/// yields its oracle disconnections as canonical reactant-set strings,
/// best-first — [`crate::search::policy::OraclePolicy`] spoken through
/// a neural decode path.
pub fn oracle_script() -> Script {
    Box::new(|product: &str| {
        let Ok(mol) = crate::chem::parse_validated(product) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for (i, d) in crate::synthchem::find_disconnections(&mol).into_iter().enumerate() {
            let r = crate::synthchem::apply_retro(&mol, &d);
            let mut reactants: Vec<String> =
                r.reactants.iter().map(crate::chem::canonical_smiles).collect();
            reactants.sort();
            let joined = reactants.join(".");
            if seen.insert(joined.clone()) {
                out.push((joined, -0.7 - 0.05 * i as f64));
            }
        }
        out
    })
}

/// A vocabulary wide enough for any SMILES the SynthChem generator and
/// its retro expansions emit (plus the given corpus strings).
pub fn smiles_vocab<'a>(corpus: impl IntoIterator<Item = &'a str>) -> Vocab {
    // Note "B " (bare boron, boronic acids) next to "Br": the
    // tokenizer greedily fuses B+r, so both spellings must appear.
    const KITCHEN_SINK: &str =
        "CNOPSFI B Br Cl cnops ()[]=#-+.@/\\0123456789%10%11%12[nH][NH2][OH][O-][N+][C@H][C@@H]";
    let mut strings: Vec<&str> = corpus.into_iter().collect();
    strings.push(KITCHEN_SINK);
    Vocab::build(strings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoding::{beam::BeamSearch, msbs::Msbs, DecodeStats, Decoder};

    fn model_for(product: &str, targets: &[(&str, f64)]) -> (ScriptedModel, Vocab) {
        let vocab = smiles_vocab([product].into_iter());
        let targets: Vec<(String, f64)> =
            targets.iter().map(|(s, w)| (s.to_string(), *w)).collect();
        let script: Script = Box::new(move |_p: &str| targets.clone());
        (ScriptedModel::new(vocab.clone(), script), vocab)
    }

    #[test]
    fn beam_search_recovers_scripted_targets_in_weight_order() {
        let (model, vocab) = model_for(
            "CC(=O)NC",
            &[("CC(=O)O.CN", -0.5), ("CC(=O)Cl.CN", -1.0)],
        );
        let dec = BeamSearch::optimized();
        let mut st = DecodeStats::default();
        let out =
            dec.generate(&model, &[vocab.encode("CC(=O)NC", true)], 4, &mut st).unwrap();
        let texts: Vec<String> = out[0]
            .hyps
            .iter()
            .filter(|h| h.finished())
            .map(|h| vocab.decode(h.body()))
            .collect();
        assert!(texts.len() >= 2, "{texts:?}");
        assert_eq!(texts[0], "CC(=O)O.CN");
        assert_eq!(texts[1], "CC(=O)Cl.CN");
        assert!(out[0].hyps[0].logp > out[0].hyps[1].logp);
    }

    #[test]
    fn msbs_accepts_drafts_on_scripted_trie() {
        let (model, vocab) = model_for("CCOC(C)=O", &[("CC(=O)O.CCO", -0.3)]);
        let dec = Msbs::default();
        let mut st = DecodeStats::default();
        let out =
            dec.generate(&model, &[vocab.encode("CCOC(C)=O", true)], 2, &mut st).unwrap();
        let best = vocab.decode(out[0].hyps[0].body());
        assert_eq!(best, "CC(=O)O.CCO");
        assert!(st.drafts_accepted > 0, "medusa heads must accept on-script drafts");
    }

    #[test]
    fn empty_script_finishes_immediately() {
        let (model, vocab) = model_for("CCO", &[]);
        let dec = BeamSearch::optimized();
        let mut st = DecodeStats::default();
        let out = dec.generate(&model, &[vocab.encode("CCO", true)], 3, &mut st).unwrap();
        for h in &out[0].hyps {
            assert!(h.body().is_empty(), "off-script decode must emit bare EOS");
        }
        assert!(st.model_calls <= 4);
    }

    #[test]
    fn oracle_script_round_trips_through_policy_layer() {
        use crate::search::policy::{ExpansionPolicy, ModelPolicy};
        let product = crate::chem::canonicalize("CC(=O)NC").unwrap();
        let vocab = smiles_vocab([product.as_str()].into_iter());
        let model = ScriptedModel::new(vocab.clone(), oracle_script());
        let policy = ModelPolicy::new(model, Box::new(Msbs::default()), vocab);
        let out = policy.expand_batch(&[product.as_str()], 5).unwrap();
        let mut expect = vec![
            crate::chem::canonicalize("CC(=O)O").unwrap(),
            crate::chem::canonicalize("CN").unwrap(),
        ];
        expect.sort();
        assert!(
            out[0].iter().any(|p| p.reactants == expect),
            "scripted oracle must reproduce the amide disconnection: {:?}",
            out[0]
        );
    }

    #[test]
    fn delta_rows_match_full_prefix_rows() {
        use crate::model::{DecodeRow, StateId};
        let (model, vocab) = model_for("CCOC(C)=O", &[("CC(=O)O.CCO", -0.3)]);
        let src = vocab.encode("CCOC(C)=O", true);
        let mem = model.encode(&[src]).unwrap();
        // Target prefix [BOS, t0, t1]: full row vs state(BOS,t0) + delta [t1].
        let t = vocab.encode("CC", false);
        let full_tgt = {
            let mut v = vec![crate::tokenizer::BOS];
            v.extend_from_slice(&t[..2.min(t.len())]);
            v
        };
        let full = model
            .decode(&[DecodeRow::full(mem, 0, full_tgt.clone(), full_tgt.len() - 1)], 2)
            .unwrap();
        let state = model
            .state_commit(mem, 0, StateId::NONE, &full_tgt[..full_tgt.len() - 1])
            .unwrap();
        let inc = model
            .decode(
                &[DecodeRow {
                    mem,
                    mem_row: 0,
                    state,
                    delta: vec![full_tgt[full_tgt.len() - 1]],
                    pos: full_tgt.len() - 1,
                }],
                2,
            )
            .unwrap();
        assert_eq!(inc.data, full.data, "delta row must be bit-identical to full row");
        assert_eq!(inc.starts, full.starts);
        model.state_release(state);
        assert_eq!(model.live_states(), 0);
        model.release(mem);
    }

    #[test]
    fn engines_leave_no_states_behind() {
        let (model, vocab) = model_for("CC(=O)NC", &[("CC(=O)O.CN", -0.5)]);
        assert!(model.supports_incremental());
        let dec = Msbs::default();
        let mut st = DecodeStats::default();
        let out =
            dec.generate(&model, &[vocab.encode("CC(=O)NC", true)], 3, &mut st).unwrap();
        assert!(out[0].hyps[0].finished());
        assert_eq!(model.live_states(), 0, "a retired task must release its state chain");
        assert_eq!(model.live_handles(), 0);
        // MSBS incremental identity: draft rows carry 1 fresh position,
        // verify rows carry exactly their draft (prefix-shared
        // verification) — never the whole prefix again.
        assert_eq!(
            st.decode_tokens,
            st.rows_logical / 2 + st.drafts_offered,
            "incremental decode must process O(delta) tokens per row"
        );
    }

    #[test]
    fn release_frees_scripted_entries() {
        let (model, vocab) = model_for("CCO", &[("CC.O", -0.1)]);
        let h = model.encode(&[vocab.encode("CCO", true)]).unwrap();
        assert_eq!(model.live_handles(), 1);
        model.release(h);
        assert_eq!(model.live_handles(), 0);
    }
}
