//! Content-addressed decoder-state store: the host-side bookkeeping
//! behind the incremental decode protocol.
//!
//! A [`StateId`] names **cached decoder state** owned by a model: the
//! result of having processed one decoded prefix of one encoded source
//! row (in a real transformer runtime this is the per-row KV cache; the
//! in-process models simulate it by storing the prefix tokens and
//! reconstructing the full decoder input on demand). Rows carry a state
//! plus only their *delta* tokens, so decode cost is proportional to
//! new positions per cycle instead of O(prefix²) per sequence — the
//! dominant inference cost identified for industrial SMILES-to-SMILES
//! deployment (Andronov et al., arXiv:2407.09685).
//!
//! ## Lifecycle (fork / commit / release)
//!
//! * **Commit** ([`StateStore::commit`]) registers `parent ++ delta` as
//!   a cached prefix and returns a ref-counted id. The store is
//!   *content-addressed* — committing the same `(mem, row, prefix)`
//!   twice returns the same id with its count bumped — so beam
//!   reordering is explicit state **forking**: every surviving beam
//!   that extends the same parent shares one committed state, each
//!   holding its own claim.
//! * **Retain** ([`StateStore::retain`]) adds a claim (a survivor beam
//!   adopting an anchor another beam also uses).
//! * **Release** ([`StateStore::release`]) drops a claim; the state is
//!   freed when the last claim goes, which is the **rollback** path for
//!   rejected speculation: draft positions past the accepted prefix are
//!   simply never committed, and committed backbones nobody adopted are
//!   released at the end of the cycle.
//!
//! Claims are owned by decode tasks (each beam holds exactly one claim
//! on its anchor), so a task retiring or being cancelled releases its
//! whole chain without stranding a sibling fork — the same ownership
//! discipline as [`super::MemView`] encoder memory.

use super::MemHandle;
use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// Names cached decoder state owned by a model. `StateId::NONE` means
/// "no cached state" (the row's delta is the full BOS-led input).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StateId(pub u64);

impl StateId {
    /// The empty state: no cached positions.
    pub const NONE: StateId = StateId(0);

    /// Whether this is the empty state.
    pub fn is_none(self) -> bool {
        self == StateId::NONE
    }
}

struct Entry {
    mem: u64,
    row: usize,
    tokens: Vec<i32>,
    refs: usize,
}

#[derive(Default)]
struct Inner {
    /// `(mem, row, prefix tokens)` -> id: the content address.
    by_content: HashMap<(u64, usize, Vec<i32>), u64>,
    entries: HashMap<u64, Entry>,
    next: u64,
}

/// Thread-safe ref-counted store of cached decoder prefixes, embedded
/// by models that support the incremental protocol (`MockModel`,
/// `ScriptedModel`; a real KV-cache runtime would keep device-side
/// state under the same ids).
pub struct StateStore {
    inner: Mutex<Inner>,
}

impl Default for StateStore {
    fn default() -> Self {
        Self::new()
    }
}

impl StateStore {
    pub fn new() -> Self {
        Self { inner: Mutex::new(Inner { next: 1, ..Default::default() }) }
    }

    /// Commit the prefix `parent ++ delta` of encoder row
    /// `(mem, mem_row)` and return a claim on its state. Content-
    /// addressed: an identical prefix returns the existing id with its
    /// refcount bumped. Errors if `parent` is unknown (released or
    /// never committed) or bound to a different encoder row.
    pub fn commit(
        &self,
        mem: MemHandle,
        mem_row: usize,
        parent: StateId,
        delta: &[i32],
    ) -> Result<StateId> {
        let mut g = self.inner.lock().unwrap();
        let tokens = if parent.is_none() {
            delta.to_vec()
        } else {
            let p = g
                .entries
                .get(&parent.0)
                .ok_or_else(|| anyhow!("unknown parent state {parent:?}"))?;
            ensure!(
                p.mem == mem.0 && p.row == mem_row,
                "parent state {parent:?} bound to a different encoder row"
            );
            let mut t = Vec::with_capacity(p.tokens.len() + delta.len());
            t.extend_from_slice(&p.tokens);
            t.extend_from_slice(delta);
            t
        };
        let key = (mem.0, mem_row, tokens);
        if let Some(&id) = g.by_content.get(&key) {
            g.entries.get_mut(&id).expect("content-indexed entry").refs += 1;
            return Ok(StateId(id));
        }
        let id = g.next;
        g.next += 1;
        g.entries.insert(id, Entry { mem: mem.0, row: mem_row, tokens: key.2.clone(), refs: 1 });
        g.by_content.insert(key, id);
        Ok(StateId(id))
    }

    /// Add a claim on `state` (no-op for `NONE`).
    pub fn retain(&self, state: StateId) {
        if state.is_none() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.entries.get_mut(&state.0) {
            e.refs += 1;
        } else {
            debug_assert!(false, "retain of unknown state {state:?}");
        }
    }

    /// Drop a claim on `state`; the cached prefix is freed when the
    /// last claim goes (no-op for `NONE`).
    pub fn release(&self, state: StateId) {
        if state.is_none() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.entries.get_mut(&state.0) else {
            debug_assert!(false, "release of unknown state {state:?}");
            return;
        };
        e.refs -= 1;
        if e.refs == 0 {
            let e = g.entries.remove(&state.0).expect("present above");
            g.by_content.remove(&(e.mem, e.row, e.tokens));
        }
    }

    /// Reconstruct a row's full decoder input (`state tokens ++ delta`)
    /// into `out` — the full-prefix shim the in-process models use.
    /// Verifies the state is live and bound to `(mem, mem_row)`, so a
    /// use-after-release or a cross-row state reference fails loudly.
    pub fn resolve_into(
        &self,
        state: StateId,
        mem: MemHandle,
        mem_row: usize,
        delta: &[i32],
        out: &mut Vec<i32>,
    ) -> Result<()> {
        out.clear();
        if !state.is_none() {
            let g = self.inner.lock().unwrap();
            let e = g
                .entries
                .get(&state.0)
                .ok_or_else(|| anyhow!("unknown decode state {state:?}"))?;
            ensure!(
                e.mem == mem.0 && e.row == mem_row,
                "decode state {state:?} bound to a different encoder row"
            );
            out.extend_from_slice(&e.tokens);
        }
        out.extend_from_slice(delta);
        Ok(())
    }

    /// Cached states currently live (leak diagnostics: every claim a
    /// task takes must be balanced by a release by the time it
    /// retires or is cancelled).
    pub fn live(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MEM: MemHandle = MemHandle(7);

    #[test]
    fn commit_is_content_addressed_and_refcounted() {
        let s = StateStore::new();
        let a = s.commit(MEM, 0, StateId::NONE, &[1, 5]).unwrap();
        let b = s.commit(MEM, 0, StateId::NONE, &[1, 5]).unwrap();
        assert_eq!(a, b, "same content, same id");
        assert_eq!(s.live(), 1);
        // A chain commit reaching the same content also dedups.
        let root = s.commit(MEM, 0, StateId::NONE, &[1]).unwrap();
        let c = s.commit(MEM, 0, root, &[5]).unwrap();
        assert_eq!(c, a);
        assert_eq!(s.live(), 2, "root + shared [1,5]");
        // Three claims on `a`: release them all, then the root.
        s.release(a);
        s.release(b);
        assert_eq!(s.live(), 2, "one claim left on [1,5]");
        s.release(c);
        assert_eq!(s.live(), 1);
        s.release(root);
        assert_eq!(s.live(), 0);
    }

    #[test]
    fn retain_adds_a_claim() {
        let s = StateStore::new();
        let a = s.commit(MEM, 0, StateId::NONE, &[1]).unwrap();
        s.retain(a);
        s.release(a);
        assert_eq!(s.live(), 1, "retained claim keeps the state alive");
        s.release(a);
        assert_eq!(s.live(), 0);
    }

    #[test]
    fn resolve_reconstructs_and_validates() {
        let s = StateStore::new();
        let a = s.commit(MEM, 2, StateId::NONE, &[1, 5, 6]).unwrap();
        let mut out = Vec::new();
        s.resolve_into(a, MEM, 2, &[7, 8], &mut out).unwrap();
        assert_eq!(out, vec![1, 5, 6, 7, 8]);
        // NONE state: delta is the full input.
        s.resolve_into(StateId::NONE, MEM, 2, &[1, 9], &mut out).unwrap();
        assert_eq!(out, vec![1, 9]);
        // Wrong row / released state fail loudly.
        assert!(s.resolve_into(a, MEM, 0, &[], &mut out).is_err());
        s.release(a);
        assert!(s.resolve_into(a, MEM, 2, &[], &mut out).is_err());
    }

    #[test]
    fn commit_rejects_foreign_or_dead_parents() {
        let s = StateStore::new();
        let a = s.commit(MEM, 0, StateId::NONE, &[1]).unwrap();
        assert!(s.commit(MEM, 1, a, &[5]).is_err(), "parent bound to row 0");
        s.release(a);
        assert!(s.commit(MEM, 0, a, &[5]).is_err(), "parent released");
    }

    #[test]
    fn states_key_on_encoder_row() {
        let s = StateStore::new();
        let a = s.commit(MEM, 0, StateId::NONE, &[1, 5]).unwrap();
        let b = s.commit(MEM, 1, StateId::NONE, &[1, 5]).unwrap();
        let c = s.commit(MemHandle(8), 0, StateId::NONE, &[1, 5]).unwrap();
        assert_ne!(a, b, "same tokens, different row: distinct state");
        assert_ne!(a, c, "same tokens, different batch: distinct state");
    }
}
